"""Make `compile.*` importable whether pytest runs from python/ or the
repository root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
