"""L1 correctness: the Pallas CIM kernel against the pure-jnp oracle.

This is the core correctness signal of the compile path: the kernel must
reproduce the oracle's ADC codes bit-exactly across the macro's full
configuration space (precisions, gain, array split, batch)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import params as P
from compile.kernels import cim_macro, ref


def random_case(seed, r_in, r_w, units, n_out, batch):
    rng = np.random.default_rng(seed)
    cfg = P.OpConfig(r_in=r_in, r_w=r_w, r_out=8, gamma=1.0, connected_units=units)
    rows = cfg.active_rows
    x = rng.integers(0, 1 << r_in, (batch, rows)).astype(np.int32)
    mx = (1 << r_w) - 1
    w = (2 * rng.integers(0, 1 << r_w, (rows, n_out)) - mx).astype(np.int32)
    return cfg, x, w


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    r_in=st.integers(1, 8),
    r_w=st.integers(1, 4),
    units=st.sampled_from([1, 2, 3, 8, 32]),
    n_out=st.sampled_from([1, 5, 16, 130]),
    batch=st.integers(1, 4),
    gamma=st.sampled_from([1.0, 2.0, 8.0, 32.0]),
    r_out=st.integers(1, 8),
)
def test_pallas_matches_ref(seed, r_in, r_w, units, n_out, batch, gamma, r_out):
    cfg, x, w = random_case(seed, r_in, r_w, units, n_out, batch)
    cfg = P.OpConfig(r_in=r_in, r_w=r_w, r_out=r_out, gamma=gamma, connected_units=units)
    got = np.asarray(cim_macro.cim_matvec_pallas(x, w, cfg))
    want = np.asarray(ref.cim_matvec_ref(x, w, cfg)).astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), beta_seed=st.integers(0, 2**31))
def test_pallas_matches_ref_with_beta(seed, beta_seed):
    cfg, x, w = random_case(seed, 4, 2, 2, 12, 2)
    rng = np.random.default_rng(beta_seed)
    beta = rng.integers(-16, 16, 12).astype(np.int32)
    got = np.asarray(cim_macro.cim_matvec_pallas(x, w, cfg, beta))
    want = np.asarray(ref.cim_matvec_ref(x, w, cfg, beta)).astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_1d_input_squeezes():
    cfg, x, w = random_case(0, 4, 1, 1, 8, 1)
    got = cim_macro.cim_matvec_pallas(x[0], w, cfg)
    assert got.shape == (8,)
    want = ref.cim_matvec_ref(x[0], w, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want).astype(np.int32))


def test_codes_clip_to_rout_range():
    # All-max inputs against all-positive weights saturate the ADC.
    cfg = P.OpConfig(r_in=8, r_w=1, r_out=6, gamma=32.0, connected_units=32)
    rows = cfg.active_rows
    x = np.full((1, rows), 255, np.int32)
    w = np.ones((rows, 4), np.int32)
    got = np.asarray(cim_macro.cim_matvec_pallas(x, w, cfg))
    assert got.max() == (1 << 6) - 1
    w_neg = -w
    got2 = np.asarray(cim_macro.cim_matvec_pallas(x, w_neg, cfg))
    assert got2.min() == 0


def test_binary_input_bypass_doubles_swing():
    # r_in=1 bypasses the accumulator: same ±1 pattern produces 2x the
    # code deviation of an r_in=2 input with the same sign content.
    units, n_out = 2, 4
    rows = P.rows_for_units(units)
    rng = np.random.default_rng(3)
    w = (2 * rng.integers(0, 2, (rows, n_out)) - 1).astype(np.int32)
    cfg1 = P.OpConfig(r_in=1, r_w=1, r_out=8, gamma=4.0, connected_units=units)
    x1 = np.ones((1, rows), np.int32)  # all bit-1 → (2x-1) = +1 each row
    c1 = np.asarray(ref.cim_matvec_ref(x1, w, cfg1)).astype(np.int64) - 128
    cfg2 = P.OpConfig(r_in=2, r_w=1, r_out=8, gamma=4.0, connected_units=units)
    x2 = np.full((1, rows), 3, np.int32)  # both bits 1 → (2X-M) = +3 of 4
    c2 = np.asarray(ref.cim_matvec_ref(x2, w, cfg2)).astype(np.int64) - 128
    # bypass: dot/1 ; serial: dot·(3/4)/1 … ratio = 1 / (3/4) = 4/3 < 2,
    # but against midscale r_in=2 (X=2 ⇒ 2X-M=+1 of 4): ratio = 4.
    x2m = np.full((1, rows), 2, np.int32)
    c2m = np.asarray(ref.cim_matvec_ref(x2m, w, cfg2)).astype(np.int64) - 128
    np.testing.assert_allclose(c1, 4 * c2m, atol=4)
    assert np.all(np.abs(c1) >= np.abs(c2) - 1)


def test_column_tiling_edge_cases():
    # n_out smaller than, equal to, and not divisible by the tile.
    for n_out in [1, 127, 128, 129, 200]:
        cfg, x, w = random_case(7, 2, 1, 1, n_out, 2)
        got = np.asarray(cim_macro.cim_matvec_pallas(x, w, cfg))
        want = np.asarray(ref.cim_matvec_ref(x, w, cfg)).astype(np.int64)
        np.testing.assert_array_equal(got.astype(np.int64), want, err_msg=f"n_out={n_out}")


def test_vmem_footprint_under_budget():
    # DESIGN.md §8: full-macro tile must fit VMEM comfortably (< 4 MiB).
    bytes_ = cim_macro.vmem_footprint_bytes(rows=1152, n_out=256, batch=8)
    assert bytes_ < 4 * 1024 * 1024
    assert cim_macro.mxu_tiles_per_bitplane(1152) == 9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_monotone_in_single_input(seed):
    # Increasing one input against a +1 weight never decreases the code.
    cfg, x, w = random_case(seed, 4, 1, 1, 4, 1)
    w[:, 0] = 1
    codes = []
    for v in range(16):
        x[0, 0] = v
        codes.append(int(np.asarray(ref.cim_matvec_ref(x, w, cfg))[0, 0]))
    assert all(b >= a for a, b in zip(codes, codes[1:]))
