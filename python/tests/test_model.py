"""L2 model tests: row mapping, quantizers, mode agreement, shapes."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import params as P
from compile.kernels import ref


# ---------------------------------------------------------------------------
# im2col + physical row order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c_in", [4, 8, 16, 32, 5, 13])
def test_row_order_is_bijective_over_real_features(c_in):
    order = M.im2col_row_order(c_in)
    units = -(-c_in // 4)
    assert len(order) == units * 36
    real = order[order >= 0]
    assert sorted(real.tolist()) == list(range(9 * c_in))


def test_im2col_matches_manual_patch():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 3, 5, 5)).astype(np.float32))
    pat = M.im2col(x)  # [1, 5, 5, 27] tap-major
    # Patch at (2,2), tap (dy=0,dx=0) = x[:, :, 1, 1] (zero-pad 1).
    np.testing.assert_allclose(np.asarray(pat[0, 2, 2, 0:3]), np.asarray(x[0, :, 1, 1]))
    # Center tap (dy=1,dx=1) index 4 → x[:, :, 2, 2].
    np.testing.assert_allclose(np.asarray(pat[0, 2, 2, 12:15]), np.asarray(x[0, :, 2, 2]))
    # Border pixel picks up zero padding.
    np.testing.assert_allclose(np.asarray(pat[0, 0, 0, 0:3]), 0.0)


def test_conv_row_padding_uses_constant_plus_one():
    # A conv layer with c_in=5 pads to 2 units (72 rows); pad rows carry
    # the constant pad value in activations and +1 in weights.
    spec = M.CimLayerSpec(
        "c", "conv3", 5, 4, P.OpConfig(r_in=2, r_w=1, r_out=8, connected_units=2)
    )
    x2d = jnp.arange(45, dtype=jnp.float32)[None, :]  # 9*5 features
    got = M.pad_rows(x2d, spec, pad_value=99.0)
    assert got.shape == (1, 72)
    order = M.im2col_row_order(5)
    assert float(got[0, np.where(order < 0)[0][0]]) == 99.0
    w2d = jnp.ones((45, 4)) * 2.0
    wp = M.pad_weight_rows(w2d, spec)
    assert wp.shape == (72, 4)
    assert float(wp[np.where(order < 0)[0][0], 0]) == 1.0


# ---------------------------------------------------------------------------
# Quantizers
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    r_w=st.integers(1, 4),
    vals=st.lists(st.floats(-10, 10, allow_nan=False), min_size=1, max_size=32),
)
def test_weight_quantizer_hits_representable_levels(r_w, vals):
    w = jnp.asarray(vals, jnp.float32)
    q = np.asarray(M.quantize_weight_st(w, 1.0, r_w))
    mx = (1 << r_w) - 1
    assert np.all(np.abs(q) <= mx)
    # Levels are 2B - mx: same parity as mx (odd steps of 2).
    assert np.all((q + mx) % 2 == 0)


@settings(max_examples=50, deadline=None)
@given(r_in=st.integers(1, 8), v=st.floats(-4, 4, allow_nan=False))
def test_act_quantizer_range(r_in, v):
    q = float(M.quantize_act(jnp.asarray(v), 0.01, r_in))
    assert 0.0 <= q <= float((1 << r_in) - 1)
    assert q == round(q)


def test_quantizers_pass_gradients():
    g = jax.grad(lambda w: float(jnp.sum(M.quantize_weight_st(w, 1.0, 4))) if False
                 else jnp.sum(M.quantize_weight_st(w, 1.0, 4)))(jnp.zeros(4))
    assert np.all(np.asarray(g) != 0.0)  # STE passes unit-ish gradient


# ---------------------------------------------------------------------------
# Mode agreement + shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,xshape", [
    ("mlp784", (2, 784)),
    ("lenet_cim", (2, 4, 28, 28)),
    ("vgg_small", (2, 4, 32, 32)),
])
def test_model_shapes_and_eval_pallas_agree(name, xshape):
    spec = M.model_by_name(name)
    key = jax.random.PRNGKey(1)
    params = M.init_params(spec, key)
    x = jnp.asarray(np.random.default_rng(0).random(xshape, np.float32))
    y_eval = M.forward(params, spec, x, mode="eval")
    y_pallas = M.forward(params, spec, x, mode="pallas")
    assert y_eval.shape == (xshape[0], 10)
    np.testing.assert_allclose(np.asarray(y_eval), np.asarray(y_pallas), atol=1e-5)


def test_train_mode_without_noise_matches_eval_codes():
    # The float surrogate + STE floor equals the integer oracle exactly
    # when no noise is injected (same affine map, same floor).
    spec = M.model_by_name("mlp784")
    params = M.init_params(spec, jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.default_rng(1).random((4, 784), np.float32))
    yt = M.forward(params, spec, x, mode="train", key=None)
    ye = M.forward(params, spec, x, mode="eval")
    np.testing.assert_allclose(np.asarray(yt), np.asarray(ye), atol=1e-4)


def test_pad_input_channels():
    x = jnp.zeros((2, 28, 28))
    out = M.pad_input_channels(x)
    assert out.shape == (2, 4, 28, 28)
    x3 = jnp.ones((2, 3, 32, 32))
    out3 = M.pad_input_channels(x3)
    assert out3.shape == (2, 4, 32, 32)
    assert float(out3[0, 3].sum()) == 0.0


def test_layer_specs_fit_macro():
    for name in ["mlp784", "lenet_cim", "vgg_small"]:
        spec = M.model_by_name(name)
        for layer in spec.layers:
            layer.validated()
            assert layer.rows <= P.N_ROWS
            assert layer.out_features <= 512


def test_beta_codes_clip_to_5b():
    cfg = P.OpConfig()
    codes = np.asarray(M._beta_codes(jnp.asarray([-1e3, 0.0, 1e3]), cfg))
    assert codes[0] == -16 and codes[2] == 15 and codes[1] == 0
