"""Synthetic dataset tests: determinism, ranges, learnability proxy."""

import numpy as np

from compile import datasets


def test_digits_deterministic_and_in_range():
    x1, y1 = datasets.make_digits(64, seed=5)
    x2, y2 = datasets.make_digits(64, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 28, 28)
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    assert set(np.unique(y1)).issubset(set(range(10)))


def test_digits_different_seeds_differ():
    x1, _ = datasets.make_digits(16, seed=1)
    x2, _ = datasets.make_digits(16, seed=2)
    assert not np.allclose(x1, x2)


def test_digits_classes_are_distinguishable():
    # Nearest-class-mean classifier on raw pixels must beat chance by a
    # wide margin — the glyphs are distinct templates.
    x, y = datasets.make_digits(800, seed=3)
    xf = x.reshape(len(x), -1)
    means = np.stack([xf[y == c].mean(0) for c in range(10)])
    pred = np.argmin(
        ((xf[:, None, :] - means[None, :, :]) ** 2).sum(-1), axis=1
    )
    acc = (pred == y).mean()
    assert acc > 0.45, f"template acc={acc}"


def test_textures_deterministic_and_shaped():
    x1, y1 = datasets.make_textures(32, seed=7)
    x2, y2 = datasets.make_textures(32, seed=7)
    np.testing.assert_array_equal(x1, x2)
    assert x1.shape == (32, 3, 32, 32)
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    np.testing.assert_array_equal(y1, y2)


def test_split_is_disjoint_and_complete():
    x, y = datasets.make_digits(100, seed=0)
    (xtr, ytr), (xte, yte) = datasets.train_test_split(x, y, 0.2, seed=0)
    assert len(ytr) == 80 and len(yte) == 20
    assert len(ytr) + len(yte) == len(y)
