"""Training, export round-trip and AOT lowering smoke tests."""

import json
import os
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, export
from compile import model as M
from compile.train import train_model


def _tiny_train(tmp):
    params, spec, metrics = train_model(
        "mlp784", epochs=2, n_train=1500, n_test=300, batch=64, verbose=False
    )
    export.save_model(tmp, spec, params, metrics)
    return params, spec, metrics


def test_train_beats_chance_and_exports(tmp_path):
    tmp = str(tmp_path)
    params, spec, metrics = _tiny_train(tmp)
    assert metrics["test_acc"] > 0.2, metrics  # well above 10% chance
    assert os.path.exists(os.path.join(tmp, "mlp784.imgt"))
    assert os.path.exists(os.path.join(tmp, "mlp784.manifest.json"))

    # Round-trip: physical forward reproduces the eval-mode logits' argmax.
    spec2, phys, manifest = export.load_model(tmp, "mlp784")
    x = jnp.asarray(np.random.default_rng(0).random((8, 784), np.float32))
    y_master = M.forward(params, spec, x, mode="eval")
    y_phys = aot.infer_forward(spec2, phys, x)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(y_master), 1), np.argmax(np.asarray(y_phys), 1)
    )


def test_imgt_roundtrip(tmp_path):
    path = str(tmp_path / "t.imgt")
    tensors = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.array([-128, 0, 127], np.int8),
        "c": np.array([[1152, 256]], np.int32),
    }
    export.write_imgt(path, tensors)
    back = export.read_imgt(path)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_aot_smoke_artifact(tmp_path):
    tmp = str(tmp_path)
    hlo = aot.lower_smoke(tmp)
    text = open(hlo).read()
    assert "HloModule" in text
    meta = json.load(open(os.path.join(tmp, "smoke_cim.meta.json")))
    golden = np.loadtxt(os.path.join(tmp, "smoke_cim.golden.txt"))
    assert golden.shape == (meta["batch"], meta["n_out"])
    # Codes in the r_out=8 range.
    assert golden.min() >= 0 and golden.max() <= 255


def test_aot_model_lowering(tmp_path):
    tmp = str(tmp_path)
    _tiny_train(tmp)
    path = aot.lower_model(tmp, "mlp784", batch=2)
    text = open(path).read()
    assert "HloModule" in text
    meta = json.load(open(os.path.join(tmp, "mlp784.hlo.json")))
    assert meta["input_shape"] == [2, 784]
    assert meta["output_shape"] == [2, 10]
