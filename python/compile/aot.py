"""AOT lowering: trained CIM model -> HLO *text* artifacts for the rust
runtime.

The interchange format is HLO text, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The exported computation is the *inference* graph in ``pallas`` mode —
the L1 kernel lowered with interpret=True so the CPU PJRT client can run
it — taking a float image batch and returning logits. Python never runs
at request time; the rust coordinator loads these artifacts once.

Run:  python -m compile.aot --model lenet_cim --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import export
from . import model as M
from .kernels import cim_macro


def infer_forward(spec: M.ModelSpec, params, x):
    """Inference forward using the exported *physical* parameters
    (quantized weights in macro row order, 5b beta codes).

    x: [B, ...input_shape] float. Returns logits [B, 10].
    """
    y = x
    conv_i = 0
    for layer in spec.layers:
        n = layer.name
        cfg = layer.cfg
        w_phys = params[f"{n}/w_phys"]
        beta = params[f"{n}/beta_codes"]
        a_scale = params[f"{n}/a_scale"]
        out_gain = params[f"{n}/out_gain"]
        m = float((1 << cfg.r_in) - 1)

        if layer.kind == "dense" and y.ndim > 2:
            y = y.reshape(y.shape[0], -1)
        if layer.kind == "conv3":
            b, c, h, wd = y.shape
            pat = M.im2col(y, 3, layer.stride)
            hh, ww = pat.shape[1], pat.shape[2]
            x2d = pat.reshape(-1, 9 * c)
        else:
            x2d = y
            b = x2d.shape[0]

        xq = jnp.clip(jnp.round(x2d / a_scale), 0.0, m)
        xq = M.pad_rows(xq, layer, (m + 1.0) / 2.0).astype(jnp.int32)

        code = cim_macro.cim_matvec_pallas(xq, w_phys, cfg, beta).astype(jnp.float32)
        half = float(1 << (cfg.r_out - 1))
        out = (code - half) * out_gain
        if layer.relu:
            out = jax.nn.relu(out)
        if layer.kind == "conv3":
            out = out.reshape(b, hh, ww, layer.out_features).transpose(0, 3, 1, 2)
            pool = spec.pools[conv_i] if conv_i < len(spec.pools) else None
            out = M.pool_apply(out, pool)
            conv_i += 1
        y = out
    return y


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe bridge).

    print_large_constants is essential: the default printer elides big
    weight tensors as ``constant({...})``, which the 0.5.1 text parser
    silently mis-fills — the compiled module then computes garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(out_dir: str, name: str, batch: int = 1) -> str:
    """Load a trained model from out_dir and write <name>.hlo.txt."""
    spec, params, manifest = export.load_model(out_dir, name)
    fn = functools.partial(infer_forward, spec, params)

    in_shape = (batch, *spec.input_shape)
    x_spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    lowered = jax.jit(lambda x: (fn(x),)).lower(x_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    meta = {
        "model": name,
        "batch": batch,
        "input_shape": list(in_shape),
        "output_shape": [batch, spec.layers[-1].out_features],
        "hlo_chars": len(text),
    }
    with open(os.path.join(out_dir, f"{name}.hlo.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {len(text)} chars to {path}")
    return path


def lower_smoke(out_dir: str) -> str:
    """A tiny single-layer CIM matvec HLO used by the quickstart example
    and the runtime integration test (fixed weights, deterministic)."""
    import numpy as np

    from . import params as P
    from .kernels import ref

    cfg = P.OpConfig(r_in=4, r_w=1, r_out=8, gamma=4.0, connected_units=1)
    rows = cfg.active_rows
    rng = np.random.default_rng(1234)
    w = (2 * rng.integers(0, 2, (rows, 8)) - 1).astype(np.int32)

    def fn(x):
        codes = cim_macro.cim_matvec_pallas(x, jnp.asarray(w), cfg)
        return (codes.astype(jnp.int32),)

    x_spec = jax.ShapeDtypeStruct((4, rows), jnp.int32)
    lowered = jax.jit(fn).lower(x_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "smoke_cim.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    # Golden vectors for the rust integration test.
    x = rng.integers(0, 16, (4, rows)).astype(np.int32)
    codes = np.asarray(ref.cim_matvec_ref(jnp.asarray(x), jnp.asarray(w), cfg))
    np.savetxt(os.path.join(out_dir, "smoke_cim.inputs.txt"), x, fmt="%d")
    np.savetxt(os.path.join(out_dir, "smoke_cim.golden.txt"), codes, fmt="%d")
    with open(os.path.join(out_dir, "smoke_cim.meta.json"), "w") as f:
        json.dump(
            {
                "rows": rows,
                "n_out": 8,
                "batch": 4,
                "cfg": {
                    "r_in": cfg.r_in,
                    "r_w": cfg.r_w,
                    "r_out": cfg.r_out,
                    "gamma": cfg.gamma,
                    "connected_units": cfg.connected_units,
                },
                "weights_seed": 1234,
            },
            f,
            indent=2,
        )
    print(f"wrote {len(text)} chars to {path}")
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default=None, help="trained model name to lower")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", help="emit the smoke HLO")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    if args.smoke or args.model is None:
        lower_smoke(args.out)
    if args.model:
        lower_model(args.out, args.model, args.batch)


if __name__ == "__main__":
    main()
