"""One-shot artifact builder: trains all models, exports weights, lowers
HLO. Invoked by `make artifacts`; everything downstream (rust runtime,
examples, benches) consumes only the files this produces.

Outputs in artifacts/:
  smoke_cim.hlo.txt / .inputs.txt / .golden.txt / .meta.json
  mlp784.imgt / .manifest.json / .hlo.txt / .hlo.json
  lenet_cim.imgt / .manifest.json / .hlo.txt / .hlo.json
  vgg_small.imgt / .manifest.json / .hlo.txt / .hlo.json
  training_summary.json
"""

import argparse
import json
import os
import time

from . import aot, export
from .train import train_model

MODELS = {
    # name: (epochs, n_train, n_test, batch, lr)
    "mlp784": (8, 6000, 1500, 64, 2e-3),
    "lenet_cim": (6, 6000, 1500, 64, 2e-3),
    "vgg_small": (5, 4000, 1000, 64, 2e-3),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="1-epoch tiny runs (CI smoke)")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of models")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    aot.lower_smoke(args.out)
    from . import export_datasets
    import sys
    argv_save = sys.argv
    sys.argv = ["export_datasets", "--out", args.out]
    export_datasets.main()
    sys.argv = argv_save

    names = list(MODELS) if not args.models else args.models.split(",")
    summary = {}
    for name in names:
        epochs, n_train, n_test, batch, lr = MODELS[name]
        if args.fast:
            epochs, n_train, n_test = 1, 800, 200
        t0 = time.time()
        print(f"=== training {name} ({epochs} epochs, {n_train} samples) ===",
              flush=True)
        params, spec, metrics = train_model(
            name, epochs=epochs, n_train=n_train, n_test=n_test,
            batch=batch, lr=lr, verbose=True,
        )
        export.save_model(args.out, spec, params, metrics)
        aot.lower_model(args.out, name, batch=1)
        metrics["wall_seconds"] = time.time() - t0
        summary[name] = {k: v for k, v in metrics.items() if k != "history"}
        print(f"=== {name}: acc={metrics['test_acc']*100:.2f}% "
              f"({metrics['wall_seconds']:.0f}s) ===", flush=True)

    with open(os.path.join(args.out, "training_summary.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
