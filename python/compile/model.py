"""Layer-2 JAX model: CIM-mapped CNNs with hardware-aware quantization.

Every compute layer runs through the macro's functional contract (the L1
kernel / its jnp oracle): unsigned r_in-bit activations against antipodal
r_w-bit weights, DSCI-ADC output codes with per-layer ABN gain gamma and
per-channel 5b ABN offset beta — exactly the knobs the silicon exposes.

Three execution modes share one parameter set:

* ``train``  — differentiable surrogate + straight-through floor +
  equivalent-noise injection (the paper's CIM-aware training, §I/§III.B);
* ``eval``   — bit-exact integer forward through the jnp oracle;
* ``pallas`` — bit-exact forward through the L1 Pallas kernel (what
  ``aot.py`` lowers to HLO for the rust runtime).

Row mapping: convolutions are expressed as im2col with the macro's
physical row order — DP units of 36 rows = 9 kernel taps x 4 channels,
channels grouped per unit (§III.B). Feature counts are padded to unit
multiples with a constant input of (M+1)/2 (so 2x-M = +1) against +1
weights; the resulting constant column offset is absorbed by beta/bias
during training.
"""

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import params as P
from .kernels import cim_macro, ref


# ---------------------------------------------------------------------------
# Quantization helpers (straight-through estimators)
# ---------------------------------------------------------------------------


def ste_round(x):
    """Round with identity gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def ste_floor(x):
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


def quantize_act(x_real, scale, r_in):
    """Real activations -> unsigned r_in-bit grid (differentiable)."""
    q = ste_round(x_real / scale)
    return jnp.clip(q, 0.0, float((1 << r_in) - 1))


def quantize_weight_st(w_real, w_scale, r_w):
    """Real weights -> antipodal integer levels with STE.

    Levels are odd integers in [-(2^r_w - 1), 2^r_w - 1]; w_scale maps the
    float range onto that grid.
    """
    mx = float((1 << r_w) - 1)
    g = w_real / w_scale
    b = jnp.clip(ste_round((g + mx) / 2.0), 0.0, mx)
    return 2.0 * b - mx


# ---------------------------------------------------------------------------
# Layer specifications
# ---------------------------------------------------------------------------


@dataclass
class CimLayerSpec:
    """One macro-mapped layer (dense or 3x3 conv)."""

    name: str
    kind: str  # "dense" | "conv3"
    in_features: int  # dense: features; conv: input channels
    out_features: int  # dense: outputs;  conv: output channels
    cfg: P.OpConfig = field(default_factory=P.OpConfig)
    relu: bool = True
    # Spatial dims for conv layers (set by the model builder).
    stride: int = 1

    @property
    def rows_unpadded(self) -> int:
        return self.in_features if self.kind == "dense" else 9 * self.in_features

    @property
    def rows(self) -> int:
        """Physical rows after padding to DP-unit multiples."""
        return P.rows_for_units(self.units)

    @property
    def units(self) -> int:
        if self.kind == "dense":
            return max(1, math.ceil(self.in_features / P.ROWS_PER_UNIT))
        return P.units_for_cin(self.in_features)

    def validated(self):
        assert self.rows <= P.N_ROWS, f"{self.name}: {self.rows} rows > macro"
        assert self.cfg.connected_units == self.units, (
            f"{self.name}: cfg units {self.cfg.connected_units} != {self.units}"
        )
        return self


@dataclass
class ModelSpec:
    name: str
    input_shape: tuple  # (C, H, W) or (features,)
    layers: list = field(default_factory=list)
    # Pooling after each conv layer: "max2", "avg2", "gap" or None.
    pools: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# im2col with the macro's physical row order
# ---------------------------------------------------------------------------


def im2col_row_order(c_in: int, k: int = 3):
    """Permutation mapping (tap-major, channel-minor) patch features to
    macro rows: unit u holds channels [4u, 4u+4) x all 9 taps, rows within
    a unit ordered tap-major. Returns an index array `rows -> (tap, ch)`
    flat index tap * c_in + ch into the natural patch layout."""
    order = []
    n_units = math.ceil(c_in / 4)
    for u in range(n_units):
        for tap in range(k * k):
            for cc in range(4):
                ch = 4 * u + cc
                if ch < c_in:
                    order.append(tap * c_in + ch)
                else:
                    order.append(-1)  # padding row
    return np.array(order, np.int64)


def im2col(x, k=3, stride=1):
    """Extract 3x3 patches with zero padding 1.

    x: [B, C, H, W] -> patches [B, H', W', k*k*C] (tap-major, channel-minor).
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(xp[:, :, dy : dy + h : stride, dx : dx + w : stride])
    # [k*k, B, C, H', W'] -> [B, H', W', k*k, C]
    pat = jnp.stack(cols, 0).transpose(1, 3, 4, 0, 2)
    hh, ww = pat.shape[1], pat.shape[2]
    return pat.reshape(b, hh, ww, k * k * c)


def pad_rows(x2d, spec: CimLayerSpec, pad_value: float):
    """Map patch features to macro rows (physical order + unit padding).

    x2d: [N, rows_unpadded] -> [N, spec.rows]. Padding rows get
    `pad_value` ((M+1)/2 so that 2x - M = +1).
    """
    if spec.kind == "dense":
        rows = spec.rows
        n = x2d.shape[1]
        if rows == n:
            return x2d
        pad = jnp.full((x2d.shape[0], rows - n), pad_value, x2d.dtype)
        return jnp.concatenate([x2d, pad], axis=1)
    order = im2col_row_order(spec.in_features)
    cols = jnp.where(
        jnp.asarray(order) >= 0,
        x2d[:, jnp.asarray(np.maximum(order, 0))],
        pad_value,
    )
    return cols


def pad_weight_rows(w2d, spec: CimLayerSpec):
    """Same row mapping for the weight matrix [rows_unpadded, out] ->
    [rows, out]; padding rows get +1 (absorbed by beta/bias)."""
    if spec.kind == "dense":
        rows = spec.rows
        n = w2d.shape[0]
        if rows == n:
            return w2d
        pad = jnp.ones((rows - n, w2d.shape[1]), w2d.dtype)
        return jnp.concatenate([w2d, pad], axis=0)
    order = im2col_row_order(spec.in_features)
    w_rows = jnp.where(
        (jnp.asarray(order) >= 0)[:, None],
        w2d[jnp.asarray(np.maximum(order, 0)), :],
        1.0,
    )
    return w_rows


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(spec: ModelSpec, key):
    """He-init float master weights + per-layer quant scales + ABN params."""
    params = {}
    for layer in spec.layers:
        layer.validated()
        rows = layer.rows_unpadded
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (rows, layer.out_features), jnp.float32)
        w = w * jnp.sqrt(2.0 / rows)
        params[f"{layer.name}/w"] = w
        # Per-layer weight scale: map ~3 sigma onto the antipodal grid.
        mx = float((1 << layer.cfg.r_w) - 1)
        params[f"{layer.name}/w_scale"] = jnp.asarray(
            3.0 * jnp.sqrt(2.0 / rows) / mx, jnp.float32
        )
        # Activation scale (input side), refined by calibration.
        params[f"{layer.name}/a_scale"] = jnp.asarray(
            1.0 / float((1 << layer.cfg.r_in) - 1), jnp.float32
        )
        # ABN: per-channel beta (real, quantized to 5b codes on export) and
        # a per-layer post-ADC gain stored in LOG space (Adam's fixed-size
        # steps would otherwise wreck a raw sub-1e-2 scale parameter).
        params[f"{layer.name}/beta"] = jnp.zeros((layer.out_features,), jnp.float32)
        params[f"{layer.name}/out_log_gain"] = jnp.zeros((), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _beta_codes(beta, cfg):
    """Real beta [out] -> 5b ABN offset codes (STE in train mode)."""
    lsb = P.adc_lsb(cfg.r_out, cfg.gamma)
    step = 0.030 / 16.0  # volts per code
    codes = ste_round(beta * lsb / step)
    return jnp.clip(codes, -16.0, 15.0)


def cim_layer_apply(params, layer: CimLayerSpec, x_real, mode, noise_key=None,
                    noise_lsb=0.5):
    """Apply one CIM layer.

    x_real: dense -> [N, features]; conv -> [B, C, H, W] real activations
    (non-negative, roughly in [0, 1] x scale).
    Returns real-valued activations for the next layer.
    """
    cfg = layer.cfg
    m = float((1 << cfg.r_in) - 1)
    # Quantization scales are calibration-owned, not optimizer-owned: a
    # gradient step on a ~4e-3 scale would saturate the whole grid.
    a_scale = jax.lax.stop_gradient(params[f"{layer.name}/a_scale"])
    w = params[f"{layer.name}/w"]
    w_scale = jax.lax.stop_gradient(params[f"{layer.name}/w_scale"])
    beta = params[f"{layer.name}/beta"]
    out_gain = jnp.exp(params[f"{layer.name}/out_log_gain"])

    # ---- arrange activations as macro rows ----
    if layer.kind == "conv3":
        b, c, h, wd = x_real.shape
        pat = im2col(x_real, 3, layer.stride)  # [B,H',W',9C]
        hh, ww = pat.shape[1], pat.shape[2]
        x2d = pat.reshape(-1, 9 * c)
    else:
        x2d = x_real
        b = x2d.shape[0]

    xq = quantize_act(x2d, a_scale, cfg.r_in)  # [N, rows_unpadded]
    pad_val = (m + 1.0) / 2.0
    xq = pad_rows(xq, layer, pad_val)

    wq = quantize_weight_st(w, w_scale, cfg.r_w)  # [rows_unpadded, out]
    wq = pad_weight_rows(wq, layer)

    beta_q = _beta_codes(beta, cfg)

    if mode == "train":
        code = ref.cim_matvec_float(xq, wq, cfg, beta_q)
        if noise_key is not None:
            # Post-silicon equivalent noise: RMS grows with gamma as the
            # LSB shrinks toward the macro's analog noise floor (§V.A).
            sigma = noise_lsb * (1.0 + cfg.gamma / 16.0)
            code = code + sigma * jax.random.normal(noise_key, code.shape)
        code = ste_floor(code)
        code = jnp.clip(code, 0.0, float((1 << cfg.r_out) - 1))
    elif mode == "eval":
        code = ref.cim_matvec_ref(
            xq.astype(jnp.int32), wq.astype(jnp.int32), cfg, beta_q.astype(jnp.int32)
        ).astype(jnp.float32)
    elif mode == "pallas":
        code = cim_macro.cim_matvec_pallas(
            xq.astype(jnp.int32), wq.astype(jnp.int32), cfg, beta_q.astype(jnp.int32)
        ).astype(jnp.float32)
    else:
        raise ValueError(mode)

    # ---- post-ADC digital path: recenter, scale, ReLU ----
    half = float(1 << (cfg.r_out - 1))
    y = (code - half) * out_gain
    if layer.relu:
        y = jax.nn.relu(y)

    if layer.kind == "conv3":
        y = y.reshape(b, hh, ww, layer.out_features).transpose(0, 3, 1, 2)
    return y


def pool_apply(y, pool):
    if pool is None:
        return y
    if pool == "max2":
        b, c, h, w = y.shape
        h2, w2 = (h // 2) * 2, (w // 2) * 2  # floor crop for odd dims
        y = y[:, :, :h2, :w2]
        return y.reshape(b, c, h2 // 2, 2, w2 // 2, 2).max(axis=(3, 5))
    if pool == "avg2":
        b, c, h, w = y.shape
        h2, w2 = (h // 2) * 2, (w // 2) * 2
        y = y[:, :, :h2, :w2]
        return y.reshape(b, c, h2 // 2, 2, w2 // 2, 2).mean(axis=(3, 5))
    if pool == "gap":
        return y.mean(axis=(2, 3))
    raise ValueError(pool)


def forward(params, spec: ModelSpec, x, mode="eval", key=None, noise_lsb=0.5):
    """Full network forward. x: [B, ...input_shape]. Returns logits or,
    for the last (non-relu) layer, its real-valued outputs."""
    y = x
    conv_i = 0
    for i, layer in enumerate(spec.layers):
        nk = None
        if key is not None:
            key, nk = jax.random.split(key)
        if layer.kind == "dense" and y.ndim > 2:
            y = y.reshape(y.shape[0], -1)
        y = cim_layer_apply(params, layer, y, mode, nk, noise_lsb)
        if layer.kind == "conv3":
            pool = spec.pools[conv_i] if conv_i < len(spec.pools) else None
            y = pool_apply(y, pool)
            conv_i += 1
    return y


# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------


def _cfg(r_in, r_w, r_out, units, gamma=8.0):
    return P.OpConfig(r_in=r_in, r_w=r_w, r_out=r_out, gamma=gamma,
                      connected_units=units)


def mlp_784(r_in=8, r_w=1, r_out=8, gamma=8.0):
    """The Fig. 3(b) MLP: 784-512-128-10."""
    layers = [
        CimLayerSpec("fc1", "dense", 784, 512,
                     _cfg(r_in, r_w, r_out, math.ceil(784 / 36), gamma)),
        CimLayerSpec("fc2", "dense", 512, 128,
                     _cfg(r_in, r_w, r_out, math.ceil(512 / 36), gamma)),
        CimLayerSpec("fc3", "dense", 128, 10,
                     _cfg(r_in, r_w, r_out, math.ceil(128 / 36), gamma), relu=False),
    ]
    return ModelSpec("mlp784", (784,), layers, [])


def lenet_cim(r_in=4, r_w=4, r_out=8, gamma=8.0):
    """LeNet-5-class CNN for 28x28 digits (the paper's modified 4b LeNet-5,
    Table I note 4). Channels padded to the macro's min C_in = 4."""
    layers = [
        CimLayerSpec("conv1", "conv3", 4, 16, _cfg(r_in, r_w, r_out, 1, gamma)),
        CimLayerSpec("conv2", "conv3", 16, 32, _cfg(r_in, r_w, r_out, 4, gamma)),
        CimLayerSpec("conv3", "conv3", 32, 32, _cfg(r_in, r_w, r_out, 8, gamma)),
        CimLayerSpec("fc1", "dense", 288, 128,
                     _cfg(r_in, r_w, r_out, math.ceil(288 / 36), gamma)),
        CimLayerSpec("fc2", "dense", 128, 10,
                     _cfg(r_in, r_w, r_out, math.ceil(128 / 36), gamma), relu=False),
    ]
    # 28 -> pool 14 -> pool 7 -> pool 3 (floor); fc1 sees 32*3*3 = 288.
    return ModelSpec("lenet_cim", (4, 28, 28), layers, ["max2", "max2", "max2"])


def vgg_small(r_in=8, r_w=4, r_out=8, gamma=8.0):
    """Compact VGG-style CNN for 3x32x32 textures (stands in for the
    paper's VGG-16/CIFAR-10 evaluation; DESIGN.md §2)."""
    layers = [
        CimLayerSpec("conv1", "conv3", 4, 32, _cfg(r_in, r_w, r_out, 1, gamma)),
        CimLayerSpec("conv2", "conv3", 32, 32, _cfg(r_in, r_w, r_out, 8, gamma)),
        CimLayerSpec("conv3", "conv3", 32, 64, _cfg(r_in, r_w, r_out, 8, gamma)),
        CimLayerSpec("conv4", "conv3", 64, 64, _cfg(r_in, r_w, r_out, 16, gamma)),
        CimLayerSpec("conv5", "conv3", 64, 128, _cfg(r_in, r_w, r_out, 16, gamma)),
        CimLayerSpec("fc1", "dense", 128, 10,
                     _cfg(r_in, r_w, r_out, math.ceil(128 / 36), gamma), relu=False),
    ]
    # 32 -> p 16 -> p 8 -> (none) 8 -> p 4 -> gap; fc1 sees 128.
    return ModelSpec(
        "vgg_small", (4, 32, 32), layers, ["max2", "max2", None, "max2", "gap"]
    )


def model_by_name(name: str, **kw) -> ModelSpec:
    zoo = {"mlp784": mlp_784, "lenet_cim": lenet_cim, "vgg_small": vgg_small}
    return zoo[name](**kw)


def pad_input_channels(x, c_target=4):
    """Grayscale/3-channel images -> the macro's minimum 4-channel input
    (extra channels zero)."""
    if x.ndim == 3:
        x = x[:, None, :, :]
    b, c, h, w = x.shape
    if c >= c_target:
        return x
    pad = jnp.zeros((b, c_target - c, h, w), x.dtype)
    return jnp.concatenate([x, pad], axis=1)
