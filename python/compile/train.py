"""CIM-aware CNN training (build-time only).

Implements the paper's hardware-aware training framework (§I, §III):
quantization-aware training through the macro's functional contract with
straight-through estimators, plus injection of the post-silicon
equivalent noise (output RMS that grows with the ABN gain gamma, §V.A) so
the network learns resilience to the macro's residual nonlinearity and
variability.

Also performs the two distribution-aware calibration steps of §II:
(i) channel-adaptive swing — each layer connects only the DP units its
input depth needs; (ii) ABN rescaling — per-layer gamma picked so the DP
distribution fills the ADC range, per-channel beta learned.

Run:  python -m compile.train --model lenet_cim --epochs 4
Artifacts land in ../artifacts/ (weights .imgt + manifest .json).
"""

import argparse
import json
import math
import time
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, export
from . import model as M
from . import params as P


# ---------------------------------------------------------------------------
# Hand-rolled Adam (the vendored environment has no optax)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


# ---------------------------------------------------------------------------
# Calibration (distribution-aware data reshaping, §II)
# ---------------------------------------------------------------------------

HW_GAMMAS = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0]


def calibrate(params, spec: M.ModelSpec, x_cal, verbose=False):
    """Set per-layer activation scales and hardware gamma from data.

    Walks the network layer by layer (in eval-surrogate mode), measuring
    (a) the input activation range -> a_scale, and (b) the DP voltage
    distribution -> the largest hardware gamma whose zoomed ADC range
    still covers ~3.5 sigma of the distribution (Fig. 3a's recipe).
    """
    y = x_cal
    conv_i = 0
    for layer in spec.layers:
        if layer.kind == "dense" and y.ndim > 2:
            y = y.reshape(y.shape[0], -1)
        # (a) input scale: 99.9th percentile fills the input grid.
        hi = float(jnp.percentile(jnp.abs(y), 99.9))
        hi = max(hi, 1e-6)
        params[f"{layer.name}/a_scale"] = jnp.asarray(
            hi / float((1 << layer.cfg.r_in) - 1), jnp.float32
        )
        # (b) measure dv distribution at gamma=1 and zoom.
        cfg1 = layer.cfg.with_gamma(1.0)
        saved_cfg = layer.cfg
        layer.cfg = cfg1
        code = M.cim_layer_apply(params, layer, y, "train")
        layer.cfg = saved_cfg
        half = float(1 << (layer.cfg.r_out - 1))
        lsb1 = P.adc_lsb(layer.cfg.r_out, 1.0)
        dv_sigma = float(jnp.std(code)) * lsb1  # volts on the DPL
        target = P.ALPHA_ADC * P.VDDH / max(3.5 * dv_sigma, 1e-9)
        gamma = max(g for g in HW_GAMMAS if g <= max(target, 1.0))
        layer.cfg = layer.cfg.with_gamma(gamma)
        # Keep the post-ADC path roughly unit-variance for training health
        # (stored in log space — see model.init_params).
        params[f"{layer.name}/out_log_gain"] = jnp.asarray(
            -math.log(max(float(jnp.std(code)) * gamma, 1e-3)), jnp.float32
        )
        if verbose:
            print(
                f"  calib {layer.name}: a_scale={float(params[f'{layer.name}/a_scale']):.4g}"
                f" dv_sigma={dv_sigma*1e3:.2f}mV gamma={gamma}"
            )
        # Advance activations with the calibrated layer.
        y = M.cim_layer_apply(params, layer, y, "train")
        _ = half
        if layer.kind == "conv3":
            pool = spec.pools[conv_i] if conv_i < len(spec.pools) else None
            y = M.pool_apply(y, pool)
            conv_i += 1
    return params, spec


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------


def make_step(spec, noise_lsb, lr):
    @jax.jit
    def step(params, opt, x, yl, key):
        def loss_fn(p):
            logits = M.forward(p, spec, x, mode="train", key=key, noise_lsb=noise_lsb)
            return cross_entropy(logits, yl)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt2 = adam_update(params, grads, opt, lr=lr)
        return params2, opt2, loss

    return step


def evaluate(params, spec, x, y, mode="eval", batch=256):
    """Bit-exact accuracy through the integer oracle."""
    correct = 0
    for i in range(0, len(y), batch):
        logits = M.forward(params, spec, x[i : i + batch], mode=mode)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == y[i : i + batch]))
    return correct / len(y)


def prepare_data(model_name, n_train, n_test, seed):
    if model_name in ("mlp784", "lenet_cim"):
        x, y = datasets.make_digits(n_train + n_test, seed=seed)
        if model_name == "mlp784":
            x = x.reshape(len(x), -1)
        else:
            x = np.asarray(M.pad_input_channels(jnp.asarray(x)))
    else:
        x, y = datasets.make_textures(n_train + n_test, seed=seed)
        x = np.asarray(M.pad_input_channels(jnp.asarray(x)))
    (xtr, ytr), (xte, yte) = datasets.train_test_split(x, y, n_test / (n_train + n_test), seed)
    return (
        jnp.asarray(xtr),
        jnp.asarray(ytr.astype(np.int32)),
        jnp.asarray(xte),
        jnp.asarray(yte.astype(np.int32)),
    )


def train_model(
    model_name="lenet_cim",
    epochs=4,
    n_train=6000,
    n_test=1500,
    batch=64,
    lr=2e-3,
    noise_lsb=0.5,
    seed=0,
    r_in=None,
    r_w=None,
    r_out=None,
    verbose=True,
):
    kw = {}
    if r_in:
        kw["r_in"] = r_in
    if r_w:
        kw["r_w"] = r_w
    if r_out:
        kw["r_out"] = r_out
    spec = M.model_by_name(model_name, **kw)
    xtr, ytr, xte, yte = prepare_data(model_name, n_train, n_test, seed)

    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    params = M.init_params(spec, kinit)
    params, spec = calibrate(params, spec, xtr[:256], verbose=verbose)

    step = make_step(spec, noise_lsb, lr)
    opt = adam_init(params)
    n = len(ytr)
    steps_per_epoch = n // batch
    t0 = time.time()
    history = []
    for ep in range(epochs):
        key, kperm = jax.random.split(key)
        perm = jax.random.permutation(kperm, n)
        ep_loss = 0.0
        for s in range(steps_per_epoch):
            idx = perm[s * batch : (s + 1) * batch]
            key, kn = jax.random.split(key)
            params, opt, loss = step(params, opt, xtr[idx], ytr[idx], kn)
            ep_loss += float(loss)
        acc = evaluate(params, spec, xte, yte)
        history.append({"epoch": ep, "loss": ep_loss / steps_per_epoch, "test_acc": acc})
        if verbose:
            print(
                f"[{model_name}] epoch {ep}: loss={ep_loss/steps_per_epoch:.4f} "
                f"test_acc={acc*100:.2f}%  ({time.time()-t0:.1f}s)"
            )
    # Recalibrate a_scale drift once more, then final exact eval.
    final_acc = evaluate(params, spec, xte, yte)
    float_acc = None
    return params, spec, {
        "model": model_name,
        "epochs": epochs,
        "n_train": n_train,
        "n_test": n_test,
        "seed": seed,
        "noise_lsb": noise_lsb,
        "test_acc": final_acc,
        "float_ref_acc": float_acc,
        "history": history,
        "train_seconds": time.time() - t0,
        "layer_gammas": {l.name: l.cfg.gamma for l in spec.layers},
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="lenet_cim",
                    choices=["mlp784", "lenet_cim", "vgg_small"])
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--n-train", type=int, default=6000)
    ap.add_argument("--n-test", type=int, default=1500)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--noise-lsb", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    params, spec, metrics = train_model(
        args.model, args.epochs, args.n_train, args.n_test,
        args.batch, args.lr, args.noise_lsb, args.seed,
    )
    export.save_model(args.out, spec, params, metrics)
    print(json.dumps({k: v for k, v in metrics.items() if k != "history"}, indent=2))


if __name__ == "__main__":
    main()
