"""Pure-jnp oracle of the CIM macro's functional contract.

This is the golden reference every other implementation is tested
against: the Pallas kernel (``cim_macro.py``), the rust behavioral
simulator (``CimMacro::ideal_code``) and the AOT-exported HLO all have to
reproduce these codes bit-exactly on the nominal path.

Contract (see rust ``macro_model.rs`` module docs):

    dot_j = sum_i (2 X_i - M) * W_ij          M = 2^r_in - 1
    dv_j  = alpha_eff(rows) * V_DDL * dot_j / 2^(r_in' + r_w')
    D_j   = clip( floor(2^(r_out-1) + gamma * dv_j / (alpha_adc * V_DDH
                  / 2^(r_out-1))), 0, 2^r_out - 1 )          (Eq. 7)

with the bypass rule r' = r if r > 1 else 0 (binary inputs skip the MBIW
input accumulator, binary weights skip the column share — each preserves
a 2x voltage swing, §III.C).
"""

import jax.numpy as jnp

from .. import params as P


def cim_matvec_ref(x, w, cfg: P.OpConfig, beta_codes=None):
    """Ideal macro codes for unsigned inputs ``x`` against signed weights
    ``w``.

    Args:
      x: uint/int array [rows] or [batch, rows], values in [0, 2^r_in).
      w: int array [rows, n_out]; values must be odd-step antipodal levels
         in [-(2^r_w - 1), 2^r_w - 1] (enforced by the caller/quantizer).
      cfg: operation configuration (precision, gamma, connected units).
      beta_codes: optional int array [n_out], the per-column 5b ABN offset
         codes in [-16, 15] (each worth 30 mV / 16 on the DPL).

    Returns:
      uint32 array [n_out] or [batch, n_out] of ADC codes.
    """
    x = jnp.asarray(x)
    w = jnp.asarray(w)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    assert x.shape[1] == w.shape[0], f"{x.shape} vs {w.shape}"
    assert x.shape[1] == cfg.active_rows, (
        f"rows {x.shape[1]} != active rows {cfg.active_rows}"
    )

    m = (1 << cfg.r_in) - 1
    xb = 2 * x.astype(jnp.int32) - m
    dot = xb @ w.astype(jnp.int32)  # [batch, n_out]

    dv = cfg.dv_scale() * dot.astype(jnp.float32)
    if beta_codes is not None:
        dv = dv + jnp.asarray(beta_codes, jnp.float32) * (0.030 / 16.0)

    lsb = P.adc_lsb(cfg.r_out, cfg.gamma)
    half = 1 << (cfg.r_out - 1)
    code = jnp.floor(half + dv / lsb)
    code = jnp.clip(code, 0, (1 << cfg.r_out) - 1).astype(jnp.uint32)
    return code[0] if squeeze else code


def cim_matvec_float(x, w, cfg: P.OpConfig, beta_codes=None):
    """Differentiable surrogate: same affine map but without the floor —
    used inside the CIM-aware training loss (the floor is applied with a
    straight-through estimator by the caller)."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    m = float((1 << cfg.r_in) - 1)
    dot = (2.0 * x - m) @ w
    dv = cfg.dv_scale() * dot
    if beta_codes is not None:
        dv = dv + jnp.asarray(beta_codes, jnp.float32) * (0.030 / 16.0)
    lsb = P.adc_lsb(cfg.r_out, cfg.gamma)
    half = float(1 << (cfg.r_out - 1))
    return half + dv / lsb


def quantize_weights_antipodal(w_real, r_w: int):
    """Map real-valued weights (already scaled to the integer grid) to the
    macro's representable antipodal levels: odd integers in
    [-(2^r_w - 1), 2^r_w - 1] (i.e. 2B - (2^r_w - 1), B in [0, 2^r_w))."""
    mx = (1 << r_w) - 1
    b = jnp.clip(jnp.round((w_real + mx) / 2.0), 0, (1 << r_w) - 1)
    return (2 * b - mx).astype(jnp.int32)


def quantize_inputs_unsigned(x_real, r_in: int):
    """Clip+round real activations to the unsigned r_in-bit input grid."""
    return jnp.clip(jnp.round(x_real), 0, (1 << r_in) - 1).astype(jnp.int32)
