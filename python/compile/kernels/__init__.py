"""L1 kernels: the Pallas CIM macro kernel and its pure-jnp oracle."""

from .cim_macro import cim_matvec_pallas  # noqa: F401
from .ref import (  # noqa: F401
    cim_matvec_float,
    cim_matvec_ref,
    quantize_inputs_unsigned,
    quantize_weights_antipodal,
)
