"""Layer-1 Pallas kernel: the CIM macro's bit-serial, weight-parallel
dot-product + DSCI-ADC quantization.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's compute
substrate is an analog crossbar, so the Pallas mapping reproduces its
*dataflow* on a TPU-style memory hierarchy:

* the weight matrix tile stays **stationary in VMEM** for the whole layer
  (the in-memory-computing analogy) while input bitplanes stream through;
* the input-serial accumulation of Eq. 5 is an in-kernel loop over r_in
  bitplanes with the exact alpha_mb = 1/2 charge-sharing recurrence
  ``acc <- acc/2 + dp/2`` (not an integer shift-add — the kernel is
  bit-true to the charge model);
* the inter-column weight share (Eq. 6) is linear, so multi-bit weights
  enter as their combined signed value W = sum_k 2^k s_k with the final
  1/2^r_w scale folded into the epilogue;
* the DSCI ADC + ABN (Eq. 7) is the fused affine-quantize epilogue
  (gamma zoom, 5b offset, floor, clip).

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode emits plain HLO that both
pytest and the rust runtime can run. Real-TPU performance is *estimated*
from the BlockSpec (DESIGN.md §8), never measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params as P

# Column tile per grid step. 128 matches both the TPU lane width and the
# macro's natural "two 64-block halves" split.
COL_TILE = 128


def _cim_kernel(x_ref, w_ref, beta_ref, o_ref, *, r_in, r_w, r_out, gamma, dv_scale):
    """One column tile: bit-serial DP + MBIW recurrence + ADC epilogue."""
    x = x_ref[...]  # [B, R] int32 unsigned values < 2^r_in
    w = w_ref[...].astype(jnp.float32)  # [R, C] combined signed weights

    batch = x.shape[0]
    cols = w.shape[1]

    if r_in == 1:
        # Binary inputs bypass the MBIW accumulator (full swing, §III.C).
        s = (2 * x - 1).astype(jnp.float32)
        acc = s @ w
    else:
        # Charge-sharing recurrence: acc_k = (acc_{k-1} + dp_k) / 2,
        # LSB first, starting from the V_DDL precharge (acc = 0 in
        # DPL-deviation units). After r_in steps bitplane b carries the
        # weight (1/2)^(r_in - b) — Eq. 5 with alpha_mb = 1/2.
        acc = jnp.zeros((batch, cols), jnp.float32)
        for b in range(r_in):
            bit = (x >> b) & 1
            s = (2 * bit - 1).astype(jnp.float32)
            dp = s @ w
            acc = 0.5 * acc + 0.5 * dp

    # acc is Σ_k (1/2)^(r_in-k) S_k (or S_0 for binary inputs); the column
    # share contributes 1/2^r_w (folded, Eq. 6); dv_scale carries
    # alpha_eff·V_DDL and both bypass exponents.
    dv = dv_scale * acc
    beta_v = beta_ref[...].astype(jnp.float32) * (0.030 / 16.0)
    dv = dv + beta_v[None, :]

    lsb = P.adc_lsb(r_out, gamma)
    half = float(1 << (r_out - 1))
    code = jnp.floor(half + dv / lsb)
    code = jnp.clip(code, 0.0, float((1 << r_out) - 1))
    o_ref[...] = code.astype(jnp.int32)


def cim_matvec_pallas(x, w, cfg: P.OpConfig, beta_codes=None, col_tile: int = COL_TILE):
    """Run the macro contract through the Pallas kernel.

    Args:
      x: int array [batch, rows] (or [rows]) of unsigned r_in-bit inputs.
      w: int array [rows, n_out] of combined signed antipodal weights.
      cfg: operation configuration.
      beta_codes: optional int array [n_out] of 5b ABN offset codes.
      col_tile: column tile width (grid granularity).

    Returns:
      int32 codes [batch, n_out] (or [n_out]).
    """
    x = jnp.asarray(x, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    rows, n_out = w.shape
    assert x.shape[1] == rows
    assert rows == cfg.active_rows, f"rows {rows} != active {cfg.active_rows}"

    if beta_codes is None:
        beta = jnp.zeros((n_out,), jnp.int32)
    else:
        beta = jnp.asarray(beta_codes, jnp.int32)

    # Pad the column dimension to a tile multiple.
    tile = min(col_tile, n_out) if n_out < col_tile else col_tile
    pad = (-n_out) % tile
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)), constant_values=1)
        beta = jnp.pad(beta, (0, pad))
    n_pad = n_out + pad
    grid = (n_pad // tile,)

    # dv per unit of the bit-serial accumulator output (see kernel docs):
    # alpha_eff·V_DDL / 2^r_w_eff. The 1/2^r_in_eff lives in the
    # recurrence itself.
    rw_div = float(1 << cfg.rw_eff)
    dv_scale = P.alpha_eff(rows) * P.VDDL / rw_div

    kernel = functools.partial(
        _cim_kernel,
        r_in=cfg.r_in,
        r_w=cfg.r_w,
        r_out=cfg.r_out,
        gamma=cfg.gamma,
        dv_scale=dv_scale,
    )
    batch = x.shape[0]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, rows), lambda i: (0, 0)),
            pl.BlockSpec((rows, tile), lambda i: (0, i)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((batch, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, n_pad), jnp.int32),
        interpret=True,
    )(x, w, beta)
    out = out[:, :n_out]
    return out[0] if squeeze else out


def vmem_footprint_bytes(rows: int, n_out: int, batch: int, col_tile: int = COL_TILE) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §8): the
    resident weight tile + input block + accumulator/output tile."""
    tile = min(col_tile, n_out)
    w_tile = rows * tile * 4
    x_block = batch * rows * 4
    acc = batch * tile * 4 * 2  # accumulator + bitplane dp
    return w_tile + x_block + acc


def mxu_tiles_per_bitplane(rows: int, col_tile: int = COL_TILE) -> int:
    """How many 128x128 MXU passes one bitplane's dp matmul needs —
    the utilization estimate for DESIGN.md §8."""
    return -(-rows // 128) * -(-col_tile // 128)
