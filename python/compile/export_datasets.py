"""Export the synthetic test datasets as IMGT tensors so the rust
coordinator evaluates on exactly the same data as the python trainer
(numpy's PCG64 streams are not reimplemented in rust — we ship the data).

Run: python -m compile.export_datasets --out ../artifacts
"""

import argparse
import os

import numpy as np

from . import datasets, export


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=7500,
                    help="total samples; the trainer's split uses the same seed")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # Mirror train.prepare_data: generate n_train+n_test then split.
    x, y = datasets.make_digits(args.n, seed=args.seed)
    (xtr, ytr), (xte, yte) = datasets.train_test_split(x, y, 1500 / args.n, args.seed)
    export.write_imgt(
        os.path.join(args.out, "digits_test.imgt"),
        {"x": xte.astype(np.float32), "y": yte.astype(np.int32)},
    )
    print(f"digits_test: {xte.shape}")

    xt, yt = datasets.make_textures(5000, seed=args.seed)
    (xttr, yttr), (xtte, ytte) = datasets.train_test_split(xt, yt, 1000 / 5000, args.seed)
    export.write_imgt(
        os.path.join(args.out, "textures_test.imgt"),
        {"x": xtte.astype(np.float32), "y": ytte.astype(np.int32)},
    )
    print(f"textures_test: {xtte.shape}")


if __name__ == "__main__":
    main()
