"""Synthetic datasets for the offline reproduction.

The paper evaluates on MNIST (LeNet-5-class models, Fig. 3b / Table I)
and CIFAR-10 (VGG-class). This environment has no network access, so we
substitute procedurally generated datasets of matching shape and task
structure (documented in DESIGN.md §2):

* ``digits``  — 28x28 grayscale, 10 classes: seven-segment-style glyph
  skeletons rendered with random affine jitter (shift/scale/shear),
  stroke-width variation and pixel noise. MNIST-like dimensionality and
  class count; linearly non-separable but learnable.
* ``textures`` — 3x32x32 color, 10 classes: parametric texture/shape
  families (oriented gratings, checkers, blobs, rings, corners...) with
  random phase, frequency, color and noise. CIFAR-like shape; harder than
  digits, exercising the deeper VGG-style model and the linear-ABN claim.

Everything is deterministic in (seed, n) and pure numpy, so the rust side
can regenerate identical data from the recorded seed.
"""

import numpy as np

# ---------------------------------------------------------------------------
# digits
# ---------------------------------------------------------------------------

# Seven-segment truth table: segments (a, b, c, d, e, f, g).
_SEGMENTS = {
    0: "abcdef",
    1: "bc",
    2: "abged",
    3: "abgcd",
    4: "fgbc",
    5: "afgcd",
    6: "afgedc",
    7: "abc",
    8: "abcdefg",
    9: "abcfgd",
}

# Segment endpoints on a unit glyph box (x0, y0, x1, y1) in [0,1]^2.
_SEG_LINES = {
    "a": (0.15, 0.05, 0.85, 0.05),
    "b": (0.85, 0.05, 0.85, 0.50),
    "c": (0.85, 0.50, 0.85, 0.95),
    "d": (0.15, 0.95, 0.85, 0.95),
    "e": (0.15, 0.50, 0.15, 0.95),
    "f": (0.15, 0.05, 0.15, 0.50),
    "g": (0.15, 0.50, 0.85, 0.50),
}


def _draw_line(img, x0, y0, x1, y1, width):
    """Rasterize an anti-aliased thick line onto img (H, W) in-place."""
    h, w = img.shape
    ys, xs = np.mgrid[0:h, 0:w]
    xs = (xs + 0.5) / w
    ys = (ys + 0.5) / h
    dx, dy = x1 - x0, y1 - y0
    seg_len2 = dx * dx + dy * dy + 1e-12
    t = ((xs - x0) * dx + (ys - y0) * dy) / seg_len2
    t = np.clip(t, 0.0, 1.0)
    px = x0 + t * dx
    py = y0 + t * dy
    dist = np.sqrt((xs - px) ** 2 + (ys - py) ** 2)
    img += np.clip(1.0 - dist / width, 0.0, 1.0)


def make_digits(n, seed=0, image_size=28):
    """Generate the synthetic digit dataset.

    Returns (x, y): x float32 [n, image_size, image_size] in [0, 1],
    y int32 [n] in [0, 10).
    """
    rng = np.random.default_rng(seed)
    x = np.zeros((n, image_size, image_size), np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    for i in range(n):
        img = np.zeros((image_size, image_size), np.float64)
        # Random affine jitter of the glyph box.
        cx = rng.uniform(0.22, 0.38)  # glyph half-width
        cy = rng.uniform(0.28, 0.42)  # glyph half-height
        ox = rng.uniform(0.06 + cx, 0.94 - cx)
        oy = rng.uniform(0.04 + cy, 0.96 - cy)
        shear = rng.uniform(-0.18, 0.18)
        width = rng.uniform(0.045, 0.085)
        for seg in _SEGMENTS[int(y[i])]:
            x0, y0, x1, y1 = _SEG_LINES[seg]
            # Map unit box -> jittered box with shear.
            def m(px, py):
                gx = (px - 0.5) * 2 * cx + ox + shear * (py - 0.5)
                gy = (py - 0.5) * 2 * cy + oy
                return gx, gy

            a0, b0 = m(x0, y0)
            a1, b1 = m(x1, y1)
            _draw_line(img, a0, b0, a1, b1, width)
        img = np.clip(img, 0.0, 1.0)
        img += rng.normal(0.0, 0.08, img.shape)
        # Occasional blur-ish smoothing via a cheap box pass.
        if rng.random() < 0.5:
            img = 0.25 * (
                img
                + np.roll(img, 1, 0)
                + np.roll(img, 1, 1)
                + np.roll(np.roll(img, 1, 0), 1, 1)
            )
        x[i] = np.clip(img, 0.0, 1.0).astype(np.float32)
    return x, y


# ---------------------------------------------------------------------------
# textures (CIFAR-like)
# ---------------------------------------------------------------------------


def _grating(h, w, freq, angle, phase):
    ys, xs = np.mgrid[0:h, 0:w] / h
    u = xs * np.cos(angle) + ys * np.sin(angle)
    return 0.5 + 0.5 * np.sin(2 * np.pi * freq * u + phase)


def _checker(h, w, freq, phase):
    ys, xs = np.mgrid[0:h, 0:w] / h
    return 0.5 + 0.5 * np.sign(
        np.sin(2 * np.pi * freq * xs + phase) * np.sin(2 * np.pi * freq * ys + phase)
    )


def _blob(h, w, cx, cy, r):
    ys, xs = np.mgrid[0:h, 0:w] / h
    d = np.sqrt((xs - cx) ** 2 + (ys - cy) ** 2)
    return np.clip(1.0 - d / r, 0.0, 1.0)


def _ring(h, w, cx, cy, r, thick):
    ys, xs = np.mgrid[0:h, 0:w] / h
    d = np.sqrt((xs - cx) ** 2 + (ys - cy) ** 2)
    return np.clip(1.0 - np.abs(d - r) / thick, 0.0, 1.0)


def make_textures(n, seed=0, image_size=32):
    """Generate the synthetic 10-class texture/shape dataset.

    Returns (x, y): x float32 [n, 3, image_size, image_size] in [0, 1],
    y int32 [n].

    Classes: 0-3 gratings at four orientations (freq varies), 4 checker,
    5 blob, 6 ring, 7 two blobs, 8 grating+blob composite, 9 corner wedge.
    """
    rng = np.random.default_rng(seed + 1)
    h = w = image_size
    x = np.zeros((n, 3, h, w), np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    for i in range(n):
        c = int(y[i])
        f = rng.uniform(2.5, 6.0)
        ph = rng.uniform(0, 2 * np.pi)
        if c in (0, 1, 2, 3):
            base_angle = c * np.pi / 4
            img = _grating(h, w, f, base_angle + rng.uniform(-0.15, 0.15), ph)
        elif c == 4:
            img = _checker(h, w, f * 0.7, ph)
        elif c == 5:
            img = _blob(h, w, rng.uniform(0.3, 0.7), rng.uniform(0.3, 0.7), rng.uniform(0.2, 0.4))
        elif c == 6:
            img = _ring(h, w, rng.uniform(0.35, 0.65), rng.uniform(0.35, 0.65), rng.uniform(0.2, 0.35), rng.uniform(0.05, 0.1))
        elif c == 7:
            img = _blob(h, w, rng.uniform(0.15, 0.4), rng.uniform(0.15, 0.4), rng.uniform(0.12, 0.25)) + _blob(
                h, w, rng.uniform(0.6, 0.85), rng.uniform(0.6, 0.85), rng.uniform(0.12, 0.25)
            )
        elif c == 8:
            img = 0.6 * _grating(h, w, f, rng.uniform(0, np.pi), ph) + 0.6 * _blob(
                h, w, rng.uniform(0.3, 0.7), rng.uniform(0.3, 0.7), rng.uniform(0.2, 0.35)
            )
        else:  # 9: corner wedge
            ys_, xs_ = np.mgrid[0:h, 0:w] / h
            k = rng.integers(0, 4)
            a = xs_ if k % 2 == 0 else 1 - xs_
            b = ys_ if k < 2 else 1 - ys_
            img = np.clip(1.5 - 2.0 * (a + b), 0.0, 1.0)
        img = np.clip(img, 0.0, 1.0)
        # Random colorization: per-channel affine of the base pattern.
        for ch in range(3):
            gain = rng.uniform(0.4, 1.0)
            off = rng.uniform(0.0, 0.3)
            noise = rng.normal(0.0, 0.06, img.shape)
            x[i, ch] = np.clip(off + gain * img + noise, 0.0, 1.0).astype(np.float32)
    return x, y


def train_test_split(x, y, test_frac=0.2, seed=0):
    rng = np.random.default_rng(seed + 2)
    idx = rng.permutation(len(y))
    n_test = int(len(y) * test_frac)
    te, tr = idx[:n_test], idx[n_test:]
    return (x[tr], y[tr]), (x[te], y[te])
