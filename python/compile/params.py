"""Functional macro parameters — the python mirror of
``rust/src/config/params.rs`` (``MacroParams::paper()``).

Only the constants that enter the *functional* (ideal) contract live here;
the rust side owns the full circuit-level parameter set. The integration
test ``rust/tests/hlo_equivalence.rs`` checks that both sides produce the
same ADC codes, so keep these numbers in lockstep with the rust file.
"""

from dataclasses import dataclass, replace

# ---- capacitances [F] (MacroParams::paper) ----
C_C = 0.7e-15            # bitcell MoM coupling cap
C_P_PER_ROW = 0.105e-15  # DPL routing parasitic per row
C_LOAD = 40e-15          # MBIW + ADC load on the DPL
C_SAR = 33.0 * C_C       # SAR array capacitance
C_P_SAR = 6.0 * C_C      # SAR-side parasitics

# ---- supplies [V] ----
VDDL = 0.4
VDDH = 0.8

# ---- array geometry ----
N_ROWS = 1152
ROWS_PER_UNIT = 36
N_COLS = 256
COLS_PER_BLOCK = 4
N_UNITS = N_ROWS // ROWS_PER_UNIT   # 32
N_BLOCKS = N_COLS // COLS_PER_BLOCK  # 64

ALPHA_ADC = C_SAR / (C_SAR + C_P_SAR)


def units_for_cin(c_in: int) -> int:
    """DP units needed for ``c_in`` channels with a 3x3 kernel."""
    return max(1, min(N_UNITS, -(-c_in // 4)))


def rows_for_units(units: int) -> int:
    return min(units, N_UNITS) * ROWS_PER_UNIT


def alpha_eff(connected_rows: int) -> float:
    """Charge-injection attenuation, serial-split DPL (Eq. 4)."""
    c_p = C_P_PER_ROW * connected_rows
    return C_C / (connected_rows * C_C + c_p + C_LOAD)


def adc_lsb(r_out: int, gamma: float) -> float:
    """DPL-referred ADC LSB at gain gamma (Eq. 7)."""
    return ALPHA_ADC * VDDH / (gamma * float(1 << (r_out - 1)))


@dataclass(frozen=True)
class OpConfig:
    """Mirror of rust ``OpConfig``: one macro operation's precision/gain."""

    r_in: int = 8
    r_w: int = 1
    r_out: int = 8
    gamma: float = 1.0
    connected_units: int = 32

    def __post_init__(self):
        assert 1 <= self.r_in <= 8
        assert 1 <= self.r_w <= COLS_PER_BLOCK
        assert 1 <= self.r_out <= 8
        assert 1.0 <= self.gamma <= 32.0
        assert 1 <= self.connected_units <= N_UNITS

    @property
    def active_rows(self) -> int:
        return rows_for_units(self.connected_units)

    @property
    def rin_eff(self) -> int:
        """Bit-serial scaling exponent; r_in = 1 bypasses the accumulator."""
        return self.r_in if self.r_in > 1 else 0

    @property
    def rw_eff(self) -> int:
        """Column-share scaling exponent; r_w = 1 bypasses the share."""
        return self.r_w if self.r_w > 1 else 0

    def with_units(self, units: int) -> "OpConfig":
        return replace(self, connected_units=units)

    def with_gamma(self, gamma: float) -> "OpConfig":
        return replace(self, gamma=gamma)

    def dv_scale(self) -> float:
        """Volts of DPL deviation per unit of the integer dot product
        dot = sum_i (2 X_i - M) W_i."""
        return (
            alpha_eff(self.active_rows)
            * VDDL
            / float(1 << (self.rin_eff + self.rw_eff))
        )

    def code_scale(self) -> float:
        """ADC codes per unit of integer dot product (the end-to-end gain
        the CNN training must learn around). The gamma zoom is already
        folded into the LSB."""
        return self.dv_scale() / adc_lsb(self.r_out, self.gamma)
