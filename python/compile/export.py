"""Artifact export: trained CIM model -> IMGT tensor file + JSON manifest.

The IMGT binary format is defined in ``rust/src/util/tensorfile.rs``
(keep the two writers in lockstep):

    magic  b"IMGT" | version u32 | count u32
    per tensor: name_len u32, name, dtype u8 (0=f32, 1=i8, 2=i32),
                ndim u32, dims u32*, data (LE)

Weights are exported in *physical* macro layout: rows already padded to
DP-unit multiples and permuted to the unit-grouped row order
(``model.im2col_row_order``), so the rust executor reproduces codes
without re-deriving the mapping. Beta codes are the 5b ABN offsets.
"""

import json
import os
import struct

import jax.numpy as jnp
import numpy as np

from . import model as M
from . import params as P


def _write_tensor(f, name: str, arr: np.ndarray):
    dtype_tag = {"float32": 0, "int8": 1, "int32": 2}[str(arr.dtype)]
    nb = name.encode()
    f.write(struct.pack("<I", len(nb)))
    f.write(nb)
    f.write(struct.pack("<B", dtype_tag))
    f.write(struct.pack("<I", arr.ndim))
    for d in arr.shape:
        f.write(struct.pack("<I", d))
    f.write(arr.astype(arr.dtype).tobytes(order="C"))


def write_imgt(path: str, tensors: dict):
    """tensors: ordered dict name -> np.ndarray (f32/i8/i32)."""
    with open(path, "wb") as f:
        f.write(b"IMGT")
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            _write_tensor(f, name, np.ascontiguousarray(arr))


def physical_weights(params, layer: M.CimLayerSpec) -> np.ndarray:
    """Quantized weights in physical row order, int8 [rows, out]."""
    w = params[f"{layer.name}/w"]
    w_scale = params[f"{layer.name}/w_scale"]
    wq = M.quantize_weight_st(w, w_scale, layer.cfg.r_w)
    w_phys = M.pad_weight_rows(wq, layer)
    arr = np.asarray(w_phys, np.float32)
    assert np.all(np.abs(arr) <= (1 << layer.cfg.r_w) - 1)
    return arr.astype(np.int8)


def beta_codes(params, layer: M.CimLayerSpec) -> np.ndarray:
    beta = params[f"{layer.name}/beta"]
    codes = M._beta_codes(beta, layer.cfg)
    return np.asarray(codes, np.float32).astype(np.int8)


def save_model(out_dir: str, spec: M.ModelSpec, params, metrics: dict):
    """Write <name>.imgt + <name>.manifest.json into out_dir."""
    os.makedirs(out_dir, exist_ok=True)
    tensors = {}
    layer_meta = []
    conv_i = 0
    for layer in spec.layers:
        tensors[f"{layer.name}/w_phys"] = physical_weights(params, layer)
        tensors[f"{layer.name}/beta"] = beta_codes(params, layer)
        tensors[f"{layer.name}/a_scale"] = np.asarray(
            [float(params[f"{layer.name}/a_scale"])], np.float32
        )
        tensors[f"{layer.name}/out_gain"] = np.asarray(
            [float(np.exp(params[f"{layer.name}/out_log_gain"]))], np.float32
        )
        pool = None
        if layer.kind == "conv3":
            pool = spec.pools[conv_i] if conv_i < len(spec.pools) else None
            conv_i += 1
        layer_meta.append(
            {
                "name": layer.name,
                "kind": layer.kind,
                "in_features": layer.in_features,
                "out_features": layer.out_features,
                "relu": layer.relu,
                "stride": layer.stride,
                "pool": pool,
                "rows": layer.rows,
                "cfg": {
                    "r_in": layer.cfg.r_in,
                    "r_w": layer.cfg.r_w,
                    "r_out": layer.cfg.r_out,
                    "gamma": layer.cfg.gamma,
                    "connected_units": layer.cfg.connected_units,
                },
            }
        )

    imgt_path = os.path.join(out_dir, f"{spec.name}.imgt")
    write_imgt(imgt_path, tensors)
    manifest = {
        "format": "imagine-model-v1",
        "name": spec.name,
        "input_shape": list(spec.input_shape),
        "layers": layer_meta,
        "metrics": {k: v for k, v in metrics.items() if k != "history"},
        "weights_file": os.path.basename(imgt_path),
    }
    with open(os.path.join(out_dir, f"{spec.name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return imgt_path


def load_model(out_dir: str, name: str):
    """Reload a saved model into (spec, params) for aot.py / tests."""
    with open(os.path.join(out_dir, f"{name}.manifest.json")) as f:
        manifest = json.load(f)
    tensors = read_imgt(os.path.join(out_dir, manifest["weights_file"]))
    layers = []
    pools = []
    for lm in manifest["layers"]:
        cfg = P.OpConfig(**lm["cfg"])
        layers.append(
            M.CimLayerSpec(
                lm["name"], lm["kind"], lm["in_features"], lm["out_features"],
                cfg, lm["relu"], lm["stride"],
            )
        )
        if lm["kind"] == "conv3":
            pools.append(lm["pool"])
    spec = M.ModelSpec(manifest["name"], tuple(manifest["input_shape"]), layers, pools)
    params = {}
    for lm in manifest["layers"]:
        n = lm["name"]
        params[f"{n}/w_phys"] = jnp.asarray(tensors[f"{n}/w_phys"], jnp.int32)
        params[f"{n}/beta_codes"] = jnp.asarray(tensors[f"{n}/beta"], jnp.int32)
        params[f"{n}/a_scale"] = jnp.asarray(tensors[f"{n}/a_scale"][0])
        params[f"{n}/out_gain"] = jnp.asarray(tensors[f"{n}/out_gain"][0])
    return spec, params, manifest


def read_imgt(path: str) -> dict:
    """Python-side IMGT reader (round-trip tests + aot.py)."""
    out = {}
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"IMGT", magic
        (version,) = struct.unpack("<I", f.read(4))
        assert version == 1
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            (tag,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if dims else 1
            dt = {0: np.float32, 1: np.int8, 2: np.int32}[tag]
            data = np.frombuffer(f.read(n * np.dtype(dt).itemsize), dt)
            out[name] = data.reshape(dims)
    return out
