#!/usr/bin/env python3
"""Merge bench metric JSONs into one BENCH report and gate on regressions.

Usage:
  python3 scripts/bench_guard.py \
      --merge bench_out/perf.json bench_out/train_smoke.json \
      --out BENCH_pr5.json --baseline BENCH_baseline.json [--tolerance 0.25]

Reads flat {metric: value} objects produced by the benches' MetricSink,
merges them (later files win on key collisions), writes the merged report
to --out, and compares against the committed baseline:

  * keys matching *_per_s           are higher-is-better
  * keys matching *_ns_per_* / *_us_per_*  are lower-is-better
  * keys present in only one side are reported but never fail the gate
  * a value regressing more than --tolerance (default 25%) past the
    baseline fails with exit code 1

Baselines committed from a developer machine are conservative floors; CI
uploads the fresh report as an artifact so the baseline can be tightened
from real runner numbers (copy the artifact over BENCH_baseline.json).

Stdlib only — runs on a bare CI runner.
"""

import argparse
import json
import sys


def lower_is_better(key: str) -> bool:
    return "_ns_per_" in key or "_us_per_" in key or key.endswith("_ns") or key.endswith("_us")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--merge", nargs="+", required=True, help="metric JSONs to merge")
    ap.add_argument("--out", required=True, help="merged report path")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25, help="allowed regression fraction")
    args = ap.parse_args()

    merged = {}
    for path in args.merge:
        try:
            with open(path) as fh:
                part = json.load(fh)
        except FileNotFoundError:
            print(f"bench_guard: missing {path} (bench did not run?)", file=sys.stderr)
            return 1
        if not isinstance(part, dict):
            print(f"bench_guard: {path} is not a flat JSON object", file=sys.stderr)
            return 1
        merged.update({k: float(v) for k, v in part.items()})

    with open(args.out, "w") as fh:
        json.dump(merged, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"bench_guard: wrote {args.out} with {len(merged)} metrics")

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    baseline = {k: v for k, v in baseline.items() if not k.startswith("_")}

    failures = []
    for key in sorted(set(merged) | set(baseline)):
        if key not in merged:
            print(f"  {key:<40} baseline {baseline[key]:>12.1f}  (not measured this run)")
            continue
        if key not in baseline:
            print(f"  {key:<40} current  {merged[key]:>12.1f}  (no baseline yet)")
            continue
        cur, base = merged[key], float(baseline[key])
        if lower_is_better(key):
            limit = base * (1.0 + args.tolerance)
            ok = cur <= limit
            direction = "<="
        else:
            limit = base * (1.0 - args.tolerance)
            ok = cur >= limit
            direction = ">="
        status = "ok " if ok else "REGRESSION"
        print(
            f"  {key:<40} current {cur:>12.1f}  baseline {base:>12.1f}  "
            f"(need {direction} {limit:.1f})  {status}"
        )
        if not ok:
            failures.append(key)

    if failures:
        print(
            f"bench_guard: {len(failures)} metric(s) regressed more than "
            f"{args.tolerance:.0%} vs {args.baseline}: {', '.join(failures)}",
            file=sys.stderr,
        )
        return 1
    print("bench_guard: no regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
