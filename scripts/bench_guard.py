#!/usr/bin/env python3
"""Merge bench metric JSONs into one BENCH report and gate on regressions.

Usage:
  python3 scripts/bench_guard.py \
      --merge bench_out/perf.json bench_out/train_smoke.json \
      --out BENCH_report.json --baseline BENCH_baseline.json \
      [--tolerance 0.25] [--suggest BENCH_suggested.json] \
      [--json bench_diag.json]

Reads flat {metric: value} objects produced by the benches' MetricSink,
merges them (later files win on key collisions), writes the merged report
to --out, and compares against the committed baseline:

  * keys matching *_per_s           are higher-is-better
  * keys matching *_ns_per_* / *_us_per_*  are lower-is-better
  * keys present in only one side are reported but never fail the gate
  * a value regressing more than --tolerance (default 25%) past the
    baseline fails with exit code 1
  * a value *improving* more than --tolerance past the baseline is
    flagged IMPROVED and summarized at the end — the baseline is stale
  * --suggest <path> writes a tightened candidate baseline (current
    values, keeping baseline-only keys) for the CI artifact workflow
  * --json <path> writes the findings in the shared diagnostic shape
    emitted by `imagine lint --json` — {"tool", "count", "diagnostics":
    [{"file", "line", "rule", "message"}]} — so CI consumers parse lint
    findings and bench regressions with one reader (rules:
    bench-regression, bench-improvement)

Baselines committed from a developer machine are conservative floors; CI
uploads the fresh report and the --suggest candidate as artifacts so the
baseline can be tightened from real runner numbers (review the suggested
file and copy it over BENCH_baseline.json).

Stdlib only — runs on a bare CI runner.
"""

import argparse
import json
import sys


def lower_is_better(key: str) -> bool:
    return "_ns_per_" in key or "_us_per_" in key or key.endswith("_ns") or key.endswith("_us")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--merge", nargs="+", required=True, help="metric JSONs to merge")
    ap.add_argument("--out", required=True, help="merged report path")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.25, help="allowed regression fraction")
    ap.add_argument(
        "--suggest",
        default=None,
        help="write a tightened candidate baseline (current values) to this path",
    )
    ap.add_argument(
        "--json",
        default=None,
        help="write findings in the imagine-lint diagnostic shape to this path",
    )
    args = ap.parse_args()

    merged = {}
    for path in args.merge:
        try:
            with open(path) as fh:
                part = json.load(fh)
        except FileNotFoundError:
            print(f"bench_guard: missing {path} (bench did not run?)", file=sys.stderr)
            return 1
        if not isinstance(part, dict):
            print(f"bench_guard: {path} is not a flat JSON object", file=sys.stderr)
            return 1
        merged.update({k: float(v) for k, v in part.items()})

    with open(args.out, "w") as fh:
        json.dump(merged, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"bench_guard: wrote {args.out} with {len(merged)} metrics")

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    baseline = {k: v for k, v in baseline.items() if not k.startswith("_")}

    failures = []
    improvements = []
    for key in sorted(set(merged) | set(baseline)):
        if key not in merged:
            print(f"  {key:<40} baseline {baseline[key]:>12.1f}  (not measured this run)")
            continue
        if key not in baseline:
            print(f"  {key:<40} current  {merged[key]:>12.1f}  (no baseline yet)")
            continue
        cur, base = merged[key], float(baseline[key])
        if lower_is_better(key):
            limit = base * (1.0 + args.tolerance)
            ok = cur <= limit
            improved = cur < base * (1.0 - args.tolerance)
            direction = "<="
        else:
            limit = base * (1.0 - args.tolerance)
            ok = cur >= limit
            improved = cur > base * (1.0 + args.tolerance)
            direction = ">="
        status = "REGRESSION" if not ok else ("IMPROVED" if improved else "ok ")
        print(
            f"  {key:<40} current {cur:>12.1f}  baseline {base:>12.1f}  "
            f"(need {direction} {limit:.1f})  {status}"
        )
        if not ok:
            failures.append(key)
        elif improved:
            improvements.append((key, base, cur))

    if args.json:
        # Same shape as `imagine lint --json`: metrics have no source
        # span, so `file` is the baseline the finding is relative to.
        diagnostics = [
            {
                "file": args.baseline,
                "line": 0,
                "rule": "bench-regression",
                "message": (
                    f"{key}: {merged[key]:.1f} regressed more than "
                    f"{args.tolerance:.0%} vs baseline {float(baseline[key]):.1f}"
                ),
            }
            for key in failures
        ] + [
            {
                "file": args.baseline,
                "line": 0,
                "rule": "bench-improvement",
                "message": (
                    f"{key}: {cur:.1f} improved more than {args.tolerance:.0%} "
                    f"vs baseline {base:.1f} (baseline is stale)"
                ),
            }
            for key, base, cur in improvements
        ]
        with open(args.json, "w") as fh:
            json.dump(
                {
                    "tool": "bench-guard",
                    "count": len(diagnostics),
                    "diagnostics": diagnostics,
                },
                fh,
                indent=2,
                sort_keys=True,
            )
            fh.write("\n")
        print(f"bench_guard: wrote {len(diagnostics)} diagnostic(s) to {args.json}")

    if improvements:
        print(
            f"bench_guard: {len(improvements)} metric(s) improved more than "
            f"{args.tolerance:.0%} — baseline is stale, suggested updates:"
        )
        for key, base, cur in improvements:
            print(f"  {key:<40} {base:>12.1f} -> {cur:>12.1f}")

    if args.suggest:
        # Candidate baseline: current values where measured, old floors for
        # baseline-only keys, `_`-prefixed annotations preserved.
        with open(args.baseline) as fh:
            suggested = json.load(fh)
        suggested.update(merged)
        suggested["_note"] = (
            "candidate baseline generated by bench_guard --suggest; review "
            "and copy over BENCH_baseline.json to tighten the gate"
        )
        with open(args.suggest, "w") as fh:
            json.dump(suggested, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"bench_guard: wrote suggested baseline to {args.suggest}")

    if failures:
        print(
            f"bench_guard: {len(failures)} metric(s) regressed more than "
            f"{args.tolerance:.0%} vs {args.baseline}: {', '.join(failures)}",
            file=sys.stderr,
        )
        return 1
    print("bench_guard: no regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
