#!/usr/bin/env python3
"""Check relative markdown links and anchors in the repo's docs.

Usage:
  python3 scripts/check_links.py [FILE.md ...]

With no arguments, checks the default set: every `docs/*.md`, the root
markdown files and `rust/README.md`. For each `[text](target)` link it
verifies:

  * http(s)/mailto targets are skipped (no network on CI);
  * a relative path target resolves to an existing file or directory,
    relative to the file containing the link;
  * a `#fragment` (same-file or `path#fragment`) matches a heading in
    the target file under GitHub's anchor rules (lowercase, spaces to
    dashes, punctuation dropped).

Exits non-zero listing every broken link. Stdlib only — runs on a bare
CI runner.
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
# GitHub's anchor algorithm: keep word chars and dashes, spaces → dashes.
ANCHOR_STRIP_RE = re.compile(r"[^\w\- ]", re.UNICODE)
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def default_files():
    files = sorted((REPO / "docs").glob("*.md"))
    files += sorted(REPO.glob("*.md"))
    rust_readme = REPO / "rust" / "README.md"
    if rust_readme.exists():
        files.append(rust_readme)
    return files


def anchor_of(heading):
    text = ANCHOR_STRIP_RE.sub("", heading.strip().lower())
    return text.replace(" ", "-")


def markdown_lines(path):
    """Lines outside fenced code blocks, with their 1-based numbers."""
    in_fence = False
    for lineno, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield lineno, line


def anchors_of(path, cache):
    if path not in cache:
        found = set()
        for _, line in markdown_lines(path):
            m = HEADING_RE.match(line)
            if m:
                found.add(anchor_of(m.group(1)))
        cache[path] = found
    return cache[path]


def check_file(path, anchor_cache):
    errors = []
    for lineno, line in markdown_lines(path):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel, _, fragment = target.partition("#")
            dest = (path.parent / rel).resolve() if rel else path
            if not dest.exists():
                errors.append(f"{path.relative_to(REPO)}:{lineno}: missing target {target}")
                continue
            if fragment and dest.suffix == ".md":
                if fragment not in anchors_of(dest, anchor_cache):
                    errors.append(
                        f"{path.relative_to(REPO)}:{lineno}: "
                        f"no heading for anchor #{fragment} in {rel or path.name}"
                    )
    return errors


def main():
    files = [Path(a).resolve() for a in sys.argv[1:]] or default_files()
    anchor_cache = {}
    errors = []
    for path in files:
        errors.extend(check_file(path, anchor_cache))
    for err in errors:
        print(err, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
