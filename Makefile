# Repository entry points. `cargo build/test` need no artifacts; the
# artifact-dependent integration tests skip with a message until
# `make artifacts` has been run (requires python3 with jax + numpy).

.PHONY: build test artifacts bench bench-check cluster-test docs fmt lint pytest ci

build:
	cargo build --release

test: build
	cargo test -q

# Train + export all models, golden vectors and HLO artifacts into
# ./artifacts (the prerequisite for tests/e2e_network.rs,
# tests/runtime_integration.rs and `imagine run/serve` on real models).
artifacts:
	cd python && python3 -m compile.make_artifacts --out ../artifacts

bench:
	cargo bench --bench perf_hotpath --features simd
	cargo bench --bench train_smoke

# What the CI bench job runs: benches + the 25%-regression gate against
# the committed baseline, writing the merged BENCH_report.json report and
# a tightened BENCH_suggested.json candidate baseline. (cargo runs bench
# binaries with CWD = the package root, so the metric JSONs land under
# rust/bench_out/.)
bench-check: bench
	python3 scripts/bench_guard.py \
	  --merge rust/bench_out/perf.json rust/bench_out/train_smoke.json \
	  --out BENCH_report.json --baseline BENCH_baseline.json \
	  --suggest BENCH_suggested.json --json BENCH_diag.json

# What the CI cluster job runs: the router/fleet end-to-end suite. It
# spawns real worker processes and binds ephemeral ports, so it runs
# release, single-threaded, under a hard timeout (a wedged fleet fails
# in minutes, not hours).
cluster-test:
	timeout 900 cargo test --release --test cluster_integration -- --test-threads 1

# What the CI docs job runs: rustdoc with warnings denied (the crate's
# `#![warn(missing_docs)]` makes undocumented public items in the
# non-opted-out modules hard errors here) + the dependency-free
# relative-link checker over docs/*.md and the READMEs.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	python3 scripts/check_links.py

fmt:
	cargo fmt --all --check

# Repo-invariant static analysis (see rust/src/analysis/ and the
# "Static analysis" section of rust/README.md). Exits non-zero on any
# diagnostic; `imagine lint --json` emits the machine-readable report.
lint: build
	cargo run --release -p imagine -- lint

pytest:
	cd python && python3 -m pytest tests -q

# Mirror the CI workflow locally (rust job matrix + lint + docs jobs)
# so a push that passes `make ci` passes the workflow: all feature-
# matrix arms (build, test, bench compilation), blocking clippy/fmt,
# the blocking `imagine lint` repo-invariant pass, rustdoc with
# warnings denied, and the docs link check.
ci:
	cargo build --release --no-default-features
	cargo test -q --no-default-features
	cargo bench --no-run --no-default-features
	cargo build --release --features pjrt
	cargo test -q --features pjrt
	cargo bench --no-run --features pjrt
	cargo build --release --features simd
	cargo test -q --features simd
	cargo bench --no-run --features simd
	cargo clippy --all-targets -- -D warnings
	cargo fmt --all --check
	cargo run --release -p imagine -- lint
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	python3 scripts/check_links.py
