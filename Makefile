# Repository entry points. `cargo build/test` need no artifacts; the
# artifact-dependent integration tests skip with a message until
# `make artifacts` has been run (requires python3 with jax + numpy).

.PHONY: build test artifacts bench fmt pytest

build:
	cargo build --release

test: build
	cargo test -q

# Train + export all models, golden vectors and HLO artifacts into
# ./artifacts (the prerequisite for tests/e2e_network.rs,
# tests/runtime_integration.rs and `imagine run/serve` on real models).
artifacts:
	cd python && python3 -m compile.make_artifacts --out ../artifacts

bench:
	cargo bench --bench perf_hotpath

fmt:
	cargo fmt --all --check

pytest:
	cd python && python3 -m pytest tests -q
