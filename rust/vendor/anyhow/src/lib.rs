//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline (no crates.io
//! registry), so the one external crate the code base relies on is vendored
//! here as a small API-compatible subset:
//!
//! * [`Error`] — an opaque error carrying a context chain of messages;
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default type
//!   parameter;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — the usual macros.
//!
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! context, `{:#}` prints the whole chain joined with `": "`, and `{:?}`
//! prints the outermost message followed by a `Caused by:` list.

use std::fmt::{self, Debug, Display};

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (at least one entry).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: a blanket conversion from any std error. `Error` itself
// deliberately does NOT implement `std::error::Error`, which keeps this
// impl coherent (no downstream crate can add that impl either).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod ext {
    use super::Error;

    /// Anything that can become an [`Error`]: std errors and `Error` itself.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "file missing");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context_and_with_context() {
        let n: Option<u32> = None;
        let e = n.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }

    #[test]
    fn result_of_error_takes_more_context() {
        fn inner() -> Result<()> {
            bail!("inner failure");
        }
        let e = inner().context("outer step").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer step: inner failure");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let v = 3;
        let e = anyhow!("value {v} bad: {}", "why");
        assert_eq!(format!("{e}"), "value 3 bad: why");
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert!(f(false).is_err());
    }
}
