//! Quickstart: the whole stack through the `ModelHub` in one page.
//!
//! Builds two small CIM-mapped MLPs in memory (no artifacts needed) and
//! serves them from **one hub** — one shared engine worker pool, many
//! named deployments, any 1..=8b precision per request:
//!
//! 1. `"mnist"` on the **ideal** backend — batched closed-form macro
//!    contract (bit-exact with the python oracle), and
//! 2. `"mnist-analog"` on the **analog** backend — a pool of
//!    circuit-behavioral simulated dies (mismatch + noise + SA-offset
//!    calibration).
//!
//! Along the way it shows the call styles every frontend uses: cheap
//! session handles with per-request precision
//! (`hub.session(..)?.with_precision(2, 4)?`), sync `infer_one`,
//! whole-batch `infer_batch`, the async `submit` handle, and hot
//! deploy/undeploy while the engine keeps running.
//!
//! Run: `cargo run --release --example quickstart`

use imagine::api::{BackendKind, Deployment, ModelHub};
use imagine::config::params::MacroParams;
use imagine::coordinator::manifest::NetworkModel;

fn main() -> anyhow::Result<()> {
    let p = MacroParams::paper();
    let model = NetworkModel::synthetic_mlp(&[144, 32, 10], 8, 4, 8, 7, &p);

    // ---- one hub, two tenants over one shared engine ----
    let hub = ModelHub::builder().batch(32).workers(2).seed(2024).build()?;
    hub.deploy("mnist", Deployment::new(model.clone()))?;
    hub.deploy(
        "mnist-analog",
        Deployment::new(model).backend(BackendKind::Analog),
    )?;
    let ideal = hub.session("mnist")?;
    let analog = hub.session("mnist-analog")?;
    println!("deployments: {:?} (default {:?})", hub.models(), hub.default_model());
    println!("ideal  session: {}", ideal.describe());
    println!("analog session: {}", analog.describe());

    // ---- sync single-image inference ----
    let image: Vec<f32> = (0..144).map(|i| (i % 16) as f32 / 16.0).collect();
    let exact = ideal.infer_one(image.clone())?;
    let noisy = analog.infer_one(image.clone())?;
    let delta = exact
        .iter()
        .zip(&noisy)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("ideal  logits[..4]: {:?}", &exact[..4]);
    println!("analog logits[..4]: {:?}", &noisy[..4]);
    println!("max |analog - ideal| = {delta:.4} (mismatch + noise, post-calibration)");

    // ---- per-request precision: a cheap re-targeted handle ----
    // No backend is rebuilt; the deployed one re-shapes per route key,
    // bit-identical to a session built at that precision.
    for r in [8u32, 4, 2, 1] {
        let logits = ideal.with_precision(r, r)?.infer_one(image.clone())?;
        println!("precision {r}b logits[..3]: {:?}", &logits[..3]);
    }

    // ---- whole-batch inference is bit-identical to one-by-one ----
    let images: Vec<Vec<f32>> = (0..6)
        .map(|k| (0..144).map(|i| ((i + 13 * k) % 32) as f32 / 32.0).collect())
        .collect();
    let batched = ideal.infer_batch(&images)?;
    for (k, im) in images.iter().enumerate() {
        assert_eq!(batched[k], ideal.infer_one(im.clone())?, "image {k}");
    }
    println!("batched == per-image on the ideal contract ({} images)", images.len());

    // ---- async submission through the work queue ----
    let pending: Vec<_> = images
        .iter()
        .map(|im| ideal.submit(im.clone()))
        .collect::<Result<_, _>>()?;
    for (k, handle) in pending.into_iter().enumerate() {
        assert_eq!(handle.wait()?, batched[k], "async image {k}");
    }
    println!("async submit/wait agrees with the sync paths");

    // ---- hot deploy/undeploy while the engine keeps running ----
    hub.deploy("tiny", Deployment::new(NetworkModel::synthetic_mlp(&[36, 4], 8, 4, 8, 3, &p)))?;
    let tiny_logits = hub.session("tiny")?.infer_one(vec![0.5; 36])?;
    hub.undeploy("tiny")?;
    println!(
        "hot-deployed 'tiny' ({} logits), undeployed, {} models remain",
        tiny_logits.len(),
        hub.models().len()
    );

    // ---- modeled accelerator cost, straight from the session ----
    let snap = ideal.snapshot()?;
    if let Some(cost) = snap.cost {
        println!(
            "modeled cost over {} images: {:.3} uJ, {:.1} TOPS/W (8b-norm)",
            snap.images,
            cost.e_total() * 1e6,
            cost.ee_8b() / 1e12
        );
    }

    println!("quickstart OK");
    Ok(())
}
