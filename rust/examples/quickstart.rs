//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT-compiled smoke artifact (a single CIM macro matvec,
//!    JAX/Pallas-lowered at build time) into the PJRT runtime.
//! 2. Run it on the python-generated golden inputs and check the codes.
//! 3. Run the same class of operation through the rust circuit-behavioral
//!    macro simulator and show that silicon-fidelity effects (noise,
//!    mismatch) stay within a few ADC LSBs of the ideal contract after
//!    calibration.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use imagine::analog::macro_model::{CimMacro, OpConfig};
use imagine::config::params::MacroParams;
use imagine::runtime::Runtime;
use imagine::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = "artifacts";

    // ---- 1. AOT artifact through PJRT (the request path) ----
    let meta = Json::parse(&std::fs::read_to_string(format!(
        "{dir}/smoke_cim.meta.json"
    ))?)
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let rows = meta.req_usize("rows")?;
    let batch = meta.req_usize("batch")?;
    let cfg_j = meta.get("cfg").unwrap();

    let mut rt = Runtime::new()?;
    rt.load_hlo_text("smoke", format!("{dir}/smoke_cim.hlo.txt"))?;
    println!("PJRT platform: {}", rt.platform());

    let inputs: Vec<i32> = std::fs::read_to_string(format!("{dir}/smoke_cim.inputs.txt"))?
        .split_whitespace()
        .map(|t| t.parse().unwrap())
        .collect();
    let golden: Vec<i32> = std::fs::read_to_string(format!("{dir}/smoke_cim.golden.txt"))?
        .split_whitespace()
        .map(|t| t.parse::<f64>().unwrap() as i32)
        .collect();

    let codes = rt.run_i32("smoke", &inputs, &[batch, rows])?;
    assert_eq!(codes, golden, "HLO output must match the python oracle");
    println!(
        "AOT/PJRT codes (batch 0): {:?}  -- matches python golden",
        &codes[..8]
    );

    // ---- 2. Same class of op on the circuit-behavioral simulator ----
    let cfg = OpConfig::new(
        cfg_j.req_usize("r_in")? as u32,
        cfg_j.req_usize("r_w")? as u32,
        cfg_j.req_usize("r_out")? as u32,
    )
    .with_gamma(cfg_j.req_f64("gamma")?)
    .with_units(cfg_j.req_usize("connected_units")?);

    let mut die = CimMacro::new(MacroParams::paper(), 2024);
    let mut w = Vec::with_capacity(rows);
    let mut s = 0x1234_5678_u64;
    for _ in 0..rows {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        w.push(if s >> 63 == 1 { 1 } else { -1 });
    }
    die.load_weights(&w, 1, 1);
    die.calibrate_all();

    let x: Vec<u8> = inputs[..rows].iter().map(|&v| v as u8).collect();
    let ideal = CimMacro::ideal_code(&die.p, &x, &w, &cfg);
    let measured = die.block_op(0, &x, &cfg);
    println!(
        "circuit sim: ideal code {ideal}, simulated die {measured} \
         (delta = {} LSB; mismatch+noise, post-calibration)",
        measured as i64 - ideal as i64
    );
    assert!((measured as i64 - ideal as i64).abs() <= 4);

    println!("quickstart OK");
    Ok(())
}
