//! Quickstart: the whole stack through the `Session` facade in one page.
//!
//! Builds a small CIM-mapped MLP in memory (no artifacts needed), then
//! drives it through two sessions sharing the same builder API:
//!
//! 1. the **ideal** backend — batched closed-form macro contract
//!    (bit-exact with the python oracle), and
//! 2. the **analog** backend — a pool of circuit-behavioral simulated
//!    dies (mismatch + noise + SA-offset calibration).
//!
//! Along the way it shows the three call styles every frontend uses:
//! sync `infer_one`, whole-batch `infer_batch`, and the async `submit`
//! handle into the engine's work-queue scheduler.
//!
//! Run: `cargo run --release --example quickstart`

use imagine::api::{BackendKind, Session};
use imagine::config::params::MacroParams;
use imagine::coordinator::manifest::NetworkModel;

fn main() -> anyhow::Result<()> {
    let p = MacroParams::paper();
    let model = NetworkModel::synthetic_mlp(&[144, 32, 10], 8, 4, 8, 7, &p);

    // ---- one builder API over every backend ----
    let ideal = Session::builder(model.clone())
        .backend(BackendKind::Ideal)
        .workers(2)
        .build()?;
    let analog = Session::builder(model)
        .backend(BackendKind::Analog)
        .seed(2024)
        .workers(2)
        .build()?;
    println!("ideal  session: {}", ideal.describe());
    println!("analog session: {}", analog.describe());

    // ---- sync single-image inference ----
    let image: Vec<f32> = (0..144).map(|i| (i % 16) as f32 / 16.0).collect();
    let exact = ideal.infer_one(image.clone())?;
    let noisy = analog.infer_one(image.clone())?;
    let delta = exact
        .iter()
        .zip(&noisy)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("ideal  logits[..4]: {:?}", &exact[..4]);
    println!("analog logits[..4]: {:?}", &noisy[..4]);
    println!("max |analog - ideal| = {delta:.4} (mismatch + noise, post-calibration)");

    // ---- whole-batch inference is bit-identical to one-by-one ----
    let images: Vec<Vec<f32>> = (0..6)
        .map(|k| (0..144).map(|i| ((i + 13 * k) % 32) as f32 / 32.0).collect())
        .collect();
    let batched = ideal.infer_batch(&images)?;
    for (k, im) in images.iter().enumerate() {
        assert_eq!(batched[k], ideal.infer_one(im.clone())?, "image {k}");
    }
    println!("batched == per-image on the ideal contract ({} images)", images.len());

    // ---- async submission through the work queue ----
    let pending: Vec<_> = images
        .iter()
        .map(|im| ideal.submit(im.clone()))
        .collect::<Result<_, _>>()?;
    for (k, handle) in pending.into_iter().enumerate() {
        assert_eq!(handle.wait()?, batched[k], "async image {k}");
    }
    println!("async submit/wait agrees with the sync paths");

    // ---- modeled accelerator cost, straight from the session ----
    let snap = ideal.snapshot()?;
    if let Some(cost) = snap.cost {
        println!(
            "modeled cost over {} images: {:.3} uJ, {:.1} TOPS/W (8b-norm)",
            snap.images,
            cost.e_total() * 1e6,
            cost.ee_8b() / 1e12
        );
    }

    println!("quickstart OK");
    Ok(())
}
