//! Standalone macro characterization — the software twin of §V.A's
//! measurement setup (Fig. 16b): sweep the simulated die in FC test mode
//! and print transfer function, INL, RMS and calibration statistics;
//! then re-run a network-level sweep across process corners and supply
//! points through the `Session` facade (the corner/supply knobs every
//! frontend shares).
//!
//! Run: `cargo run --release --example characterize -- [seed]`

use imagine::analog::macro_model::{CimMacro, OpConfig};
use imagine::api::{BackendKind, Session};
use imagine::config::params::{Corner, MacroParams, Supply};
use imagine::coordinator::manifest::NetworkModel;
use imagine::util::stats;

fn main() -> anyhow::Result<()> {
    let seed: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);

    // The measured CERBERUS sample sits in the slow corner.
    let p = MacroParams::measured_chip();
    let mut die = CimMacro::new(p.clone(), seed);

    // ---- calibration (Fig. 19-style) ----
    println!("== SA-offset calibration across 256 columns ==");
    let lsb = p.adc_lsb(8, 1.0);
    let pre: Vec<f64> = die.adcs.iter().map(|a| a.sa.offset / lsb).collect();
    let resid = die.calibrate_all();
    let post: Vec<f64> = resid.iter().map(|r| r / lsb).collect();
    println!(
        "offset spread pre-cal : {:>6.2} LSB rms, max |{:.1}| LSB",
        stats::std(&pre),
        stats::max_abs(&pre)
    );
    println!(
        "offset spread post-cal: {:>6.2} LSB rms, max |{:.1}| LSB",
        stats::std(&post),
        stats::max_abs(&post)
    );
    let within = post.iter().filter(|e| e.abs() <= 1.0).count();
    println!("columns within 1 LSB  : {within}/256 ({:.1}%)\n", within as f64 / 2.56);

    // ---- FC-mode transfer function at 16 channels (Fig. 17-style) ----
    println!("== 8b transfer function, 16 channels (128 rows), gamma=1 ==");
    let cfg = OpConfig::new(8, 1, 8).with_units(4).with_gamma(1.0);
    let rows = cfg.active_rows(&p);
    let x = vec![0u8; rows]; // inputs at zero; sweep stored weights
    println!("w(+1 count)  code(mean over 16 blocks)");
    let mut codes_sweep = Vec::new();
    for n_ones in (0..=rows).step_by(16) {
        let w: Vec<i32> = (0..rows).map(|r| if r < n_ones { 1 } else { -1 }).collect();
        die.load_weights_broadcast(&w, 16, 1);
        let mut samples = Vec::new();
        for blk in 0..16 {
            samples.push(die.block_op(blk, &x, &cfg) as f64);
        }
        let mean = stats::mean(&samples);
        codes_sweep.push(mean);
        if n_ones % 32 == 0 {
            println!("{n_ones:>10}  {mean:>8.2}");
        }
    }
    let xs: Vec<f64> = (0..codes_sweep.len()).map(|i| i as f64).collect();
    let inl = stats::inl_best_fit(&xs, &codes_sweep);
    println!("max |INL| over the sweep: {:.2} LSB\n", stats::max_abs(&inl));

    // ---- temporal-noise RMS (Fig. 18a-style) ----
    println!("== output RMS vs gamma (100 repeats, fixed input) ==");
    // Near-zero DP (balanced weights, midscale inputs) so that the γ zoom
    // amplifies the noise floor instead of clipping (the Fig. 18a setup).
    let w: Vec<i32> = (0..rows).map(|r| if r % 2 == 0 { 1 } else { -1 }).collect();
    die.load_weights_broadcast(&w, 16, 1);
    let x: Vec<u8> = vec![128u8; rows];
    for gamma in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0] {
        let cfg = OpConfig::new(8, 1, 8).with_units(4).with_gamma(gamma);
        let samples: Vec<f64> = (0..100).map(|_| die.block_op(0, &x, &cfg) as f64).collect();
        let mean = stats::mean(&samples);
        let rms: f64 = stats::std(&samples);
        println!("gamma {gamma:>4}: mean code {mean:>7.2}, RMS {rms:.2} LSB");
    }

    // ---- network-level corner/supply sweep through the facade ----
    // One synthetic MLP, one batch of images; per corner, fabricate an
    // analog die pool next to an ideal reference at the *same* operating
    // point and report the mean |analog − ideal| logit deviation.
    println!("\n== Session facade: corner/supply sensitivity (analog vs ideal) ==");
    let p0 = MacroParams::paper();
    let model = NetworkModel::synthetic_mlp(&[72, 24, 10], 4, 2, 6, seed, &p0);
    let images: Vec<Vec<f32>> = (0..8)
        .map(|k| (0..72).map(|i| ((i * 5 + k * 11) % 16) as f32 / 16.0).collect())
        .collect();
    for supply in [Supply::NOMINAL, Supply::LOW_POWER] {
        for corner in Corner::ALL {
            let ideal = Session::builder(model.clone())
                .backend(BackendKind::Ideal)
                .supply(supply)
                .corner(corner)
                .workers(2)
                .build()?;
            let analog = Session::builder(model.clone())
                .backend(BackendKind::Analog)
                .supply(supply)
                .corner(corner)
                .seed(seed)
                .workers(2)
                .build()?;
            let reference = ideal.infer_batch(&images)?;
            let measured = analog.infer_batch(&images)?;
            let mut dev = 0.0f64;
            let mut count = 0usize;
            for (r, m) in reference.iter().zip(&measured) {
                for (a, b) in r.iter().zip(m) {
                    dev += (a - b).abs() as f64;
                    count += 1;
                }
            }
            println!(
                "supply {:.1}/{:.1} V corner {}: mean |analog - ideal| = {:.4}",
                supply.vddl,
                supply.vddh,
                corner.name(),
                dev / count as f64
            );
        }
    }

    println!("\ncharacterization done (seed {seed}, measured-chip corner SS for the die sweeps)");
    Ok(())
}
