//! End-to-end CIM-aware training walkthrough — the paper's accuracy
//! pillar in one run, no artifacts or python required:
//!
//! 1. **characterize** — probe the analog backend's equivalent output
//!    noise at the configured supply/corner;
//! 2. **train** — two identical MLPs on a synthetic digit task, one with
//!    the measured σ injected into every forward (STE through the 4b
//!    antipodal weight quantizer and the r_in/r_out activation grids),
//!    one noise-free;
//! 3. **evaluate** — both through the in-process CIM mapping and the
//!    circuit-behavioral analog die pool: the noise-trained network
//!    holds its accuracy where the noise-free one degrades;
//! 4. **deploy** — lower the noise-trained graph, save artifacts, and
//!    serve them back through a `ModelHub` session.
//!
//! Run: `cargo run --release --example train_deploy`

use imagine::api::{
    BackendKind, Deployment, ModelHub, NoiseInjection, TrainConfig, Trainer,
};
use imagine::config::params::MacroParams;
use imagine::engine::noise::probe_equivalent_noise;
use imagine::nn::dataset::Dataset;
use imagine::nn::graph::Graph;
use imagine::nn::layers::{DenseNode, Node};
use imagine::nn::mlp::Dense;
use imagine::util::rng::Rng;
use imagine::util::stats::argmax_f32 as argmax;

fn digit_graph(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    Graph::new("cim_digits", vec![64])
        .with(Node::Dense(DenseNode::new(Dense::new(64, 32, &mut rng))))
        .with(Node::Relu)
        .with(Node::Dense(DenseNode::new(Dense::new(32, 10, &mut rng))))
}

fn analog_accuracy(
    model: &imagine::coordinator::manifest::NetworkModel,
    test: &Dataset,
    seed: u64,
) -> anyhow::Result<f64> {
    let session = imagine::api::Session::builder(model.clone())
        .backend(BackendKind::Analog)
        .seed(seed)
        .workers(2)
        .build()?;
    let images: Vec<Vec<f32>> = (0..test.n).map(|i| test.image(i).to_vec()).collect();
    let outs = session.infer_batch_owned(images)?;
    let correct = outs
        .iter()
        .zip(&test.y)
        .filter(|(logits, &y)| argmax(logits) == y as usize)
        .count();
    Ok(correct as f64 / test.n as f64)
}

fn main() -> anyhow::Result<()> {
    let p = MacroParams::paper();
    let train = Dataset::synthetic(480, vec![8, 8], 10, 5, 11, 0.22);
    let test = Dataset::synthetic(240, vec![8, 8], 10, 5, 12, 0.22);

    // ---- 1. characterize the die ----
    let stats = probe_equivalent_noise(&p, 8, 4, 7)?;
    println!(
        "probed equivalent noise @ r_in=8 r_out=4, {:.2}/{:.2} V {}: \
         temporal {:.3} LSB + fixed-pattern {:.3} LSB = {:.3} LSB",
        p.supply.vddl,
        p.supply.vddh,
        p.corner.name(),
        stats.sigma_temporal_lsb,
        stats.sigma_mismatch_lsb,
        stats.total_lsb()
    );

    // ---- 2. train twice: measured noise in the loop vs none ----
    let base = TrainConfig { epochs: 6, r_in: 8, r_out: 4, seed: 7, ..TrainConfig::default() };
    let noisy_cfg = TrainConfig { noise: NoiseInjection::Probe, ..base };
    let clean_cfg = TrainConfig { noise: NoiseInjection::Off, ..base };
    println!("\ntraining with injected σ (probe) ...");
    let noisy = Trainer::new(digit_graph(3)).config(noisy_cfg).fit(&train)?;
    println!(
        "  {} steps, {:.0} steps/s, final loss {:.3} (σ = {:.3} LSB in the loop)",
        noisy.report.steps,
        noisy.report.steps_per_s(),
        noisy.report.final_loss(),
        noisy.report.noise_lsb
    );
    println!("training noise-free ...");
    let clean = Trainer::new(digit_graph(3)).config(clean_cfg).fit(&train)?;
    println!(
        "  {} steps, {:.0} steps/s, final loss {:.3}",
        clean.report.steps,
        clean.report.steps_per_s(),
        clean.report.final_loss()
    );

    // ---- 3. evaluate: in-process mapping and the analog die pool ----
    let sigma = noisy.report.noise_lsb;
    println!("\nheld-out accuracy (240 images):");
    println!(
        "  in-process CIM, noiseless : noise-trained {:.1}%  noise-free {:.1}%",
        100.0 * noisy.accuracy_cim(&test, 0.0)?,
        100.0 * clean.accuracy_cim(&test, 0.0)?
    );
    println!(
        "  in-process CIM, σ={sigma:.2}   : noise-trained {:.1}%  noise-free {:.1}%",
        100.0 * noisy.accuracy_cim(&test, sigma)?,
        100.0 * clean.accuracy_cim(&test, sigma)?
    );
    let noisy_model = noisy.lower(&train)?;
    let clean_model = clean.lower(&train)?;
    let analog_n = analog_accuracy(&noisy_model, &test, 2024)?;
    let analog_c = analog_accuracy(&clean_model, &test, 2024)?;
    println!(
        "  analog die pool           : noise-trained {:.1}%  noise-free {:.1}%",
        100.0 * analog_n,
        100.0 * analog_c
    );

    // ---- 4. deploy the noise-trained model and serve it back ----
    let dir = std::env::temp_dir().join(format!("imagine_train_deploy_{}", std::process::id()));
    let dir = dir.to_str().unwrap().to_string();
    noisy.save(&dir, "cim_digits", &train)?;
    println!("\nexported {dir}/cim_digits.manifest.json (+ .imgt)");

    let hub = ModelHub::builder().batch(32).build()?;
    hub.deploy("digits", Deployment::from_artifacts(&dir, "cim_digits")?)?;
    let session = hub.session("digits")?;
    println!("serving: {}", session.config().render());
    let mut agree = 0usize;
    let mapped_acc = noisy.accuracy_cim(&test, 0.0)?;
    let mut correct = 0usize;
    for i in 0..test.n {
        let logits = session.infer_one(test.image(i).to_vec())?;
        let pred = argmax(&logits);
        if pred == test.y[i] as usize {
            correct += 1;
        }
        let inproc = noisy.graph.forward_float(test.image(i))?;
        if pred == argmax(&inproc) {
            agree += 1;
        }
    }
    println!(
        "served accuracy {:.1}% (in-process mapping {:.1}%), served-vs-float agreement {}/{}",
        100.0 * correct as f64 / test.n as f64,
        100.0 * mapped_acc,
        agree,
        test.n
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
