//! End-to-end validation (DESIGN.md / EXPERIMENTS.md §E2E): run the
//! CIM-aware-trained LeNet-class CNN over the synthetic-digit test set
//! through the WHOLE system — every backend constructed through the one
//! `Session` registry — and report accuracy plus the modeled accelerator
//! throughput/energy:
//!
//! * `pjrt`   — the AOT HLO artifact on the PJRT runtime (skipped with a
//!              message when this build cannot run it);
//! * `ideal`  — the batched ideal-contract engine (must agree with pjrt);
//! * `analog` — the circuit-behavioral die pool with mismatch + noise +
//!              calibration (silicon fidelity).
//!
//! Run: `make artifacts && cargo run --release --example mnist_e2e -- [n_images]`

use imagine::api::{BackendKind, ImagineError, Session};
use imagine::config::params::MacroParams;
use imagine::coordinator::manifest::NetworkModel;
use imagine::coordinator::scheduler;
use imagine::energy::system::LayerCost;
use imagine::nn::dataset::Dataset;
use imagine::util::stats::argmax_f32 as argmax;

fn main() -> anyhow::Result<()> {
    let dir = "artifacts";
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let model = NetworkModel::load(dir, "lenet_cim")?;
    let ds = Dataset::load_imgt(format!("{dir}/digits_test.imgt"))?;
    let n = n.min(ds.n);
    println!(
        "lenet_cim: trained acc (python QAT eval) = {:.2}%",
        100.0 * model.trained_accuracy().unwrap_or(f64::NAN)
    );
    println!("evaluating {n} synthetic-digit test images\n");

    let images: Vec<Vec<f32>> = (0..n)
        .map(|i| ds.image_padded(i, model.input_shape[0]))
        .collect();

    let mut preds_by_backend: Vec<(BackendKind, Vec<usize>)> = Vec::new();
    let mut ideal_cost: Option<(LayerCost, u64)> = None;

    for kind in [BackendKind::Pjrt, BackendKind::Ideal, BackendKind::Analog] {
        // The analog sim is ~20 ms/image: cap its share of the run.
        let n_eval = if kind == BackendKind::Analog { n.min(100) } else { n };
        let session = match Session::builder(model.clone())
            .artifacts(dir, "lenet_cim")
            .backend(kind)
            .seed(7)
            .batch(64)
            .build()
        {
            Ok(session) => session,
            Err(ImagineError::BackendUnavailable { reason, .. }) => {
                println!("{:>6} : skipped ({reason})", kind.name());
                continue;
            }
            Err(e) => return Err(e.into()),
        };

        let t0 = std::time::Instant::now();
        let mut preds = Vec::with_capacity(n_eval);
        for chunk in images[..n_eval].chunks(64) {
            for logits in session.infer_batch(chunk)? {
                preds.push(argmax(&logits));
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let correct = preds
            .iter()
            .enumerate()
            .filter(|&(i, &p)| p == ds.y[i] as usize)
            .count();
        println!(
            "{:>6} : {:.2}% over {n_eval} images ({:.1} ms/image host wall)",
            kind.name(),
            100.0 * correct as f64 / n_eval as f64,
            1e3 * wall / n_eval as f64
        );
        if kind == BackendKind::Ideal {
            let snap = session.snapshot()?;
            ideal_cost = snap.cost.map(|c| (c, snap.images));
        }
        preds_by_backend.push((kind, preds));
    }

    // Argmax agreement between the functional paths, when both ran.
    let find = |kind: BackendKind| {
        preds_by_backend
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, p)| p)
    };
    if let (Some(pjrt), Some(ideal)) = (find(BackendKind::Pjrt), find(BackendKind::Ideal)) {
        let agree = pjrt.iter().zip(ideal).filter(|(a, b)| a == b).count();
        println!("argmax agreement pjrt vs ideal: {agree}/{}", pjrt.len().min(ideal.len()));
    }

    // ---- modeled accelerator cost ----
    let plan = scheduler::plan(&model, &MacroParams::paper());
    println!("\naccelerator plan (0.4/0.8 V):\n{}", plan.render());
    if let Some((c, images_run)) = ideal_cost {
        println!(
            "ideal-run modeled totals: {:.3} uJ over {images_run} images -> {:.3} uJ/image, \
             EE {:.1} TOPS/W (8b-norm)",
            c.e_total() * 1e6,
            c.e_total() * 1e6 / images_run as f64,
            c.ee_8b() / 1e12
        );
    }
    Ok(())
}
