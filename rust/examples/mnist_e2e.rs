//! End-to-end validation (DESIGN.md / EXPERIMENTS.md §E2E): run the
//! CIM-aware-trained LeNet-class CNN over the synthetic-digit test set
//! through the WHOLE system, three ways, and report accuracy plus the
//! modeled accelerator throughput/energy:
//!
//! * `pjrt`   — the AOT HLO artifact on the PJRT runtime (request path);
//! * `ideal`  — the rust ideal-contract executor (must match pjrt);
//! * `analog` — the circuit-behavioral die with mismatch + noise +
//!              calibration (silicon fidelity).
//!
//! Run: `cargo run --release --example mnist_e2e -- [n_images]`

use imagine::config::params::MacroParams;
use imagine::coordinator::executor::{Backend, Executor};
use imagine::coordinator::manifest::NetworkModel;
use imagine::coordinator::scheduler;
use imagine::nn::dataset::Dataset;
use imagine::runtime::Runtime;

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn main() -> anyhow::Result<()> {
    let dir = "artifacts";
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let model = NetworkModel::load(dir, "lenet_cim")?;
    let ds = Dataset::load_imgt(format!("{dir}/digits_test.imgt"))?;
    let n = n.min(ds.n);
    println!(
        "lenet_cim: trained acc (python QAT eval) = {:.2}%",
        100.0 * model.trained_accuracy().unwrap_or(f64::NAN)
    );
    println!("evaluating {n} synthetic-digit test images\n");

    // ---- PJRT functional path ----
    let mut rt = Runtime::new()?;
    rt.load_hlo_text("lenet", format!("{dir}/lenet_cim.hlo.txt"))?;
    let t0 = std::time::Instant::now();
    let mut correct_pjrt = 0;
    let mut pjrt_preds = Vec::with_capacity(n);
    for i in 0..n {
        let img = ds.image_padded(i, model.input_shape[0]);
        let logits = rt.run_f32("lenet", &img, &[1, 4, 28, 28])?;
        let p = argmax(&logits);
        pjrt_preds.push(p);
        if p == ds.y[i] as usize {
            correct_pjrt += 1;
        }
    }
    let t_pjrt = t0.elapsed().as_secs_f64();
    println!(
        "pjrt   : {:.2}%  ({:.1} ms/image host wall)",
        100.0 * correct_pjrt as f64 / n as f64,
        1e3 * t_pjrt / n as f64
    );

    // ---- rust ideal executor (must agree with pjrt) ----
    let mut exec = Executor::new(model.clone(), MacroParams::paper(), Backend::Ideal)?;
    let mut correct_ideal = 0;
    let mut agree = 0;
    for i in 0..n {
        let img = ds.image_padded(i, model.input_shape[0]);
        let p = argmax(&exec.forward(&img)?);
        if p == ds.y[i] as usize {
            correct_ideal += 1;
        }
        if p == pjrt_preds[i] {
            agree += 1;
        }
    }
    println!(
        "ideal  : {:.2}%  (argmax agreement with pjrt: {agree}/{n})",
        100.0 * correct_ideal as f64 / n as f64
    );

    // ---- circuit-behavioral die ----
    let n_analog = n.min(100); // the analog sim is ~20 ms/image
    let mut exec_a = Executor::new(
        model.clone(),
        MacroParams::paper(),
        Backend::Analog { seed: 7, noise: true, calibrate: true },
    )?;
    let t0 = std::time::Instant::now();
    let mut correct_analog = 0;
    for i in 0..n_analog {
        let img = ds.image_padded(i, model.input_shape[0]);
        if argmax(&exec_a.forward(&img)?) == ds.y[i] as usize {
            correct_analog += 1;
        }
    }
    let t_analog = t0.elapsed().as_secs_f64();
    println!(
        "analog : {:.2}% over {n_analog} images ({:.1} ms/image sim wall)",
        100.0 * correct_analog as f64 / n_analog as f64,
        1e3 * t_analog / n_analog as f64
    );

    // ---- modeled accelerator cost ----
    let plan = scheduler::plan(&model, &MacroParams::paper());
    println!("\naccelerator plan (0.4/0.8 V):\n{}", plan.render());
    let c = &exec.cost;
    println!(
        "ideal-run modeled totals: {:.3} uJ over {} images -> {:.3} uJ/image, \
         EE {:.1} TOPS/W (8b-norm)",
        c.e_total() * 1e6,
        exec.images,
        c.e_total() * 1e6 / exec.images as f64,
        c.ee_8b() / 1e12
    );
    Ok(())
}
