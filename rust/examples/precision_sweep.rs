//! Precision/efficiency trade-off sweep — the macro's headline feature:
//! 1-to-8b scalable computing with quasi-linear efficiency scaling
//! (abstract: 0.15–8 POPS/W, 2.6–154 TOPS/mm²).
//!
//! Prints the (r_in, r_out) grid of Fig. 22a plus the Table I extremes,
//! at both supply points.
//!
//! Run: `cargo run --release --example precision_sweep`

use imagine::analog::macro_model::OpConfig;
use imagine::config::params::{MacroParams, Supply};
use imagine::energy::{analog as ea, area, timing};

fn main() {
    for (label, supply) in [("0.4/0.8 V", Supply::NOMINAL), ("0.3/0.6 V", Supply::LOW_POWER)] {
        let p = MacroParams::paper().with_supply(supply);
        println!("== {label} ==");
        println!("r_in r_out |  raw EE       8b-norm EE   throughput(8b)  AE(raw)");
        for r_in in [1u32, 2, 4, 8] {
            for r_out in [r_in] {
                let cfg = OpConfig::new(r_in, 1, r_out).with_units(32);
                let ee_raw = ea::ee_raw(&p, &cfg);
                let ee_8b = ea::ee_8b(&p, &cfg);
                let tput = timing::peak_throughput_8b(&p, &cfg);
                let ae = area::area_efficiency_raw(&p, &cfg);
                println!(
                    "{r_in:>4} {r_out:>5} | {:>7.2} POPS/W {:>7.1} TOPS/W {:>9.3} TOPS  {:>7.1} TOPS/mm2",
                    ee_raw / 1e15,
                    ee_8b / 1e12,
                    tput / 1e12,
                    ae / 1e12,
                );
            }
        }
        // Mixed-precision corners of the paper's grid.
        for (r_in, r_out) in [(4u32, 8u32), (8, 4), (1, 8)] {
            let cfg = OpConfig::new(r_in, 1, r_out).with_units(32);
            println!(
                "{r_in:>4} {r_out:>5} | {:>7.2} POPS/W {:>7.1} TOPS/W {:>9.3} TOPS  (mixed)",
                ea::ee_raw(&p, &cfg) / 1e15,
                ea::ee_8b(&p, &cfg) / 1e12,
                timing::peak_throughput_8b(&p, &cfg) / 1e12,
            );
        }
        println!();
    }
    let p = MacroParams::paper();
    println!(
        "density {:.0} kB/mm2 | paper: 187 kB/mm2, 0.15-8 POPS/W, 2.6-154 TOPS/mm2",
        p.density_kb_mm2()
    );
}
