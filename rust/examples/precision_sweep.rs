//! Precision/efficiency trade-off sweep — the macro's headline feature:
//! 1-to-8b scalable computing with quasi-linear efficiency scaling
//! (abstract: 0.15–8 POPS/W, 2.6–154 TOPS/mm²).
//!
//! Two views of the same knob:
//!
//! 1. the closed-form (r_in, r_out) grid of Fig. 22a plus the Table I
//!    extremes, at both supply points;
//! 2. the `Session` facade: the same synthetic workload rebuilt at each
//!    precision via `SessionBuilder::precision`, with the modeled
//!    energy-per-image read back from the running engine — energy drops
//!    monotonically as bits are removed.
//!
//! Run: `cargo run --release --example precision_sweep`

use imagine::analog::macro_model::OpConfig;
use imagine::api::Session;
use imagine::config::params::{MacroParams, Supply};
use imagine::coordinator::manifest::NetworkModel;
use imagine::energy::{analog as ea, area, timing};

fn main() -> anyhow::Result<()> {
    for (label, supply) in [("0.4/0.8 V", Supply::NOMINAL), ("0.3/0.6 V", Supply::LOW_POWER)] {
        let p = MacroParams::paper().with_supply(supply);
        println!("== {label} ==");
        println!("r_in r_out |  raw EE       8b-norm EE   throughput(8b)  AE(raw)");
        for r_in in [1u32, 2, 4, 8] {
            let r_out = r_in;
            let cfg = OpConfig::new(r_in, 1, r_out).with_units(32);
            let ee_raw = ea::ee_raw(&p, &cfg);
            let ee_8b = ea::ee_8b(&p, &cfg);
            let tput = timing::peak_throughput_8b(&p, &cfg);
            let ae = area::area_efficiency_raw(&p, &cfg);
            println!(
                "{r_in:>4} {r_out:>5} | {:>7.2} POPS/W {:>7.1} TOPS/W {:>9.3} TOPS  {:>7.1} TOPS/mm2",
                ee_raw / 1e15,
                ee_8b / 1e12,
                tput / 1e12,
                ae / 1e12,
            );
        }
        // Mixed-precision corners of the paper's grid.
        for (r_in, r_out) in [(4u32, 8u32), (8, 4), (1, 8)] {
            let cfg = OpConfig::new(r_in, 1, r_out).with_units(32);
            println!(
                "{r_in:>4} {r_out:>5} | {:>7.2} POPS/W {:>7.1} TOPS/W {:>9.3} TOPS  (mixed)",
                ea::ee_raw(&p, &cfg) / 1e15,
                ea::ee_8b(&p, &cfg) / 1e12,
                timing::peak_throughput_8b(&p, &cfg) / 1e12,
            );
        }
        println!();
    }

    // ---- the same sweep through the Session facade ----
    let p = MacroParams::paper();
    let model = NetworkModel::synthetic_mlp(&[288, 64, 10], 8, 1, 8, 11, &p);
    let images: Vec<Vec<f32>> = (0..32)
        .map(|i| (0..288).map(|k| ((i * 7 + k) % 32) as f32 / 32.0).collect())
        .collect();
    println!("Session-measured (synthetic 288-64-10 MLP, 32-image batch, ideal backend):");
    println!("r_in/r_out | energy/image | modeled system EE");
    let mut last = f64::INFINITY;
    for r in [8u32, 4, 2, 1] {
        let session = Session::builder(model.clone())
            .precision(r, r)
            .workers(2)
            .batch(32)
            .build()?;
        session.infer_batch(&images)?;
        let snap = session.snapshot()?;
        let cost = snap.cost.expect("ideal backend models cost");
        let per_image = cost.e_total() * 1e6 / snap.images as f64;
        println!(
            "{r:>5}/{r:<4} | {per_image:>9.4} uJ | {:>7.1} TOPS/W (8b-norm)",
            cost.ee_8b() / 1e12
        );
        assert!(per_image <= last, "energy must not increase with fewer bits");
        last = per_image;
    }

    let p = MacroParams::paper();
    println!(
        "\ndensity {:.0} kB/mm2 | paper: 187 kB/mm2, 0.15-8 POPS/W, 2.6-154 TOPS/mm2",
        p.density_kb_mm2()
    );
    Ok(())
}
