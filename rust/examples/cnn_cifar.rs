//! CNN inference through the layer-graph IR — the paper's workload
//! class, end to end and artifact-free:
//!
//! 1. build a conv-conv-pool-dense graph (`nn::graph`) over procedurally
//!    generated CIFAR-like color textures (oriented gratings, 4 classes,
//!    3×16×16 — sized so the flattened feature map fits one macro);
//! 2. train only the dense head on the frozen random conv features
//!    (random convolutional features + linear readout — enough to
//!    separate oriented textures, and trainable in seconds with the
//!    existing MLP machinery);
//! 3. evaluate through the CIM mapping at several precision points with
//!    the batched graph executor (streaming-im2col lowering, Eq. 7
//!    contract, per-layer γ/α calibration);
//! 4. lower the same graph to a physical `NetworkModel` and serve it
//!    through the `Session` facade on the batched ideal engine and the
//!    circuit-behavioral analog die pool, reporting the per-layer
//!    modeled accelerator cost (what `{"cmd":"graph_info"}` returns).
//!
//! Run: `cargo run --release --example cnn_cifar`

use imagine::api::{BackendKind, Session};
use imagine::config::params::MacroParams;
use imagine::nn::cim_eval::EvalCfg;
use imagine::nn::dataset::Dataset;
use imagine::nn::graph::{eval_graph, Graph};
use imagine::nn::layers::{AbnSpec, Conv3x3, DenseNode, Node, PoolKind};
use imagine::nn::mlp::Mlp;
use imagine::util::rng::Rng;
use imagine::util::stats::argmax_f32 as argmax;

const SIDE: usize = 16;
const CLASSES: usize = 4;

/// Procedural color textures: oriented gratings plus a checker class,
/// randomly colorized and noised (a 16×16 miniature of the compile
/// path's synthetic texture set).
fn make_textures(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 3 * SIDE * SIDE);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let k = rng.below(CLASSES as u64) as usize;
        let freq = rng.uniform_range(1.5, 3.5);
        let phase = rng.uniform_range(0.0, std::f64::consts::TAU);
        let mut base = vec![0f32; SIDE * SIDE];
        for (i, b) in base.iter_mut().enumerate() {
            let (px, py) = ((i % SIDE) as f64 / SIDE as f64, (i / SIDE) as f64 / SIDE as f64);
            let t = match k {
                0 => px,                  // vertical stripes
                1 => py,                  // horizontal stripes
                2 => (px + py) / 2.0,     // diagonal stripes
                _ => px - py,             // anti-diagonal (checker-like mix below)
            };
            let mut v = 0.5 + 0.5 * (std::f64::consts::TAU * freq * t + phase).sin();
            if k == 3 {
                v *= 0.5
                    + 0.5 * (std::f64::consts::TAU * freq * (px * py + 0.3) + phase).cos();
            }
            *b = v as f32;
        }
        for _ch in 0..3 {
            let gain = rng.uniform_range(0.4, 1.0) as f32;
            let off = rng.uniform_range(0.0, 0.3) as f32;
            for &b in &base {
                let noisy = off + gain * b + rng.normal(0.0, 0.05) as f32;
                x.push(noisy.clamp(0.0, 1.0));
            }
        }
        y.push(k as i32);
    }
    Dataset { x, y, n, shape: vec![3, SIDE, SIDE] }
}

fn main() -> anyhow::Result<()> {
    let p = MacroParams::paper();
    let train = make_textures(512, 1);
    let test = make_textures(256, 2);

    // ---- the graph: conv-conv-pool-dense ----
    let (c_in, h, w) = train.chw()?; // the dataset's validated CHW view
    let mut rng = Rng::new(7);
    let conv1 = Conv3x3::new(c_in, 8, &mut rng);
    let conv2 = Conv3x3::new(8, 16, &mut rng);
    let feat_len = 16 * (h / 2) * (w / 2); // 16×8×8 = 1024 macro rows
    let mut graph = Graph::new("cnn_textures", vec![c_in, h, w])
        .with(Node::Conv3x3(conv1))
        .with(Node::Relu)
        .with(Node::Conv3x3(conv2))
        .with(Node::Relu)
        .with(Node::Pool2x2(PoolKind::Max))
        .with(Node::Flatten);
    let n_trunk = graph.nodes.len();

    // ---- train the dense head on the frozen conv features ----
    let features = |ds: &Dataset| -> anyhow::Result<Dataset> {
        let mut x = Vec::with_capacity(ds.n * feat_len);
        for i in 0..ds.n {
            x.extend(graph.forward_float_prefix(ds.image(i), n_trunk)?);
        }
        Ok(Dataset { x, y: ds.y.clone(), n: ds.n, shape: vec![feat_len] })
    };
    let feats_train = features(&train)?;
    let feats_test = features(&test)?;
    let mut head = Mlp::new(&[feat_len, CLASSES], 9);
    let loss = head.train(&feats_train, 8, 32, 1e-2, 3);
    let float_acc = head.accuracy(&feats_test);
    println!("float: head train loss {loss:.3}, test accuracy {:.1}%", 100.0 * float_acc);

    // Stitch the trained head into the graph; pin its ADC output to 8b
    // regardless of the graph-level sweep point (a per-layer AbnSpec
    // override — classifier logits keep full output precision).
    let mut head_node = DenseNode::new(head.layers[0].clone());
    head_node.abn = AbnSpec { r_out: Some(8), ..AbnSpec::INHERIT };
    graph = graph.with(Node::Dense(head_node));

    // ---- CIM-mapped evaluation at several precision points ----
    println!("\nCIM-mapped accuracy (batched graph executor, noise 0.5 LSB):");
    for (label, cfg) in [
        ("8b in / 8b out, 5 gamma bits", EvalCfg::new(8, 5, true)),
        ("4b in / 6b out, 5 gamma bits", EvalCfg { r_in: 4, ..EvalCfg::new(6, 5, true) }),
        ("4b in / 4b out, gamma = 1   ", EvalCfg { r_in: 4, ..EvalCfg::new(4, 0, false) }),
    ] {
        let acc = eval_graph(&graph, &test, &p, &cfg)?;
        println!("  {label} : {:.1}%", 100.0 * acc);
    }

    // ---- lower to a physical model and serve through Session ----
    let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(8, 5, true) };
    let model = graph.lower(&train.take(96), &p, &cfg)?;
    println!("\nlowered model '{}' ({} layers):", model.name, model.layers.len());

    let session = Session::builder(model.clone()).backend(BackendKind::Ideal).batch(64).build()?;
    let mut correct = 0usize;
    let indices: Vec<usize> = (0..test.n).collect();
    for chunk in indices.chunks(64) {
        let imgs: Vec<Vec<f32>> = chunk.iter().map(|&i| test.image(i).to_vec()).collect();
        for (logits, &i) in session.infer_batch_owned(imgs)?.iter().zip(chunk) {
            if argmax(logits) == test.y[i] as usize {
                correct += 1;
            }
        }
    }
    println!(
        " ideal engine : {:.1}% over {} images via `{}`",
        100.0 * correct as f64 / test.n as f64,
        test.n,
        session.describe()
    );

    // Per-layer modeled accelerator cost — the graph_info view.
    let snap = session.snapshot()?;
    if let Some(costs) = snap.layer_costs {
        println!(" per-layer modeled cost over the run (graph_info):");
        for (summary, cost) in session.layers().iter().zip(&costs) {
            println!(
                "   {:<6} {:>5} -> {:<4} rows {:>4}  r {}:{}  gamma {:>4.0}  pool {:<4}  \
                 {:>9.3} uJ  {:>7.1} TOPS/W",
                summary.name,
                summary.in_features,
                summary.out_features,
                summary.rows,
                summary.r_in,
                summary.r_out,
                summary.gamma,
                summary.pool,
                cost.e_total() * 1e6,
                if cost.e_total() > 0.0 { cost.ee_8b() / 1e12 } else { 0.0 },
            );
        }
    }

    // ---- the analog die pool on a subset (mismatch + noise + cal) ----
    let n_analog = 16usize;
    let analog = Session::builder(model)
        .backend(BackendKind::Analog)
        .seed(2024)
        .workers(2)
        .build()?;
    let imgs: Vec<Vec<f32>> = (0..n_analog).map(|i| test.image(i).to_vec()).collect();
    let outs = analog.infer_batch_owned(imgs)?;
    let correct = outs
        .iter()
        .enumerate()
        .filter(|(i, logits)| argmax(logits) == test.y[*i] as usize)
        .count();
    println!(
        " analog pool  : {correct}/{n_analog} correct via `{}`",
        analog.describe()
    );
    Ok(())
}
