//! Shared utilities: deterministic PRNG, statistics, JSON, tensor I/O.

pub mod json;
pub mod rng;
pub mod stats;
pub mod tensorfile;
