//! Deterministic pseudo-random number generation for Monte-Carlo circuit
//! simulation and property-based tests.
//!
//! The vendored dependency set does not include the `rand` crate, so we
//! implement a small, well-tested generator stack ourselves:
//!
//! * [`SplitMix64`] — seed expander (Steele et al., used to initialise
//!   xoshiro state from a single `u64`).
//! * [`Xoshiro256`] — xoshiro256++ main generator (Blackman & Vigna),
//!   64-bit output, period 2^256 − 1.
//! * Gaussian variates via the Marsaglia polar method (exact, no table).
//!
//! All circuit-level Monte-Carlo draws (mismatch, noise, corners) flow
//! through [`Rng`] so that every experiment in the repository is
//! reproducible from a single seed recorded in EXPERIMENTS.md.

/// SplitMix64 seed expander. Passes BigCrush when used alone; we use it
/// only to derive xoshiro state words from a user seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ generator. Main PRNG used across the simulator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian variate from the polar method.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream for a named subsystem. Streams derived
    /// with different tags are statistically independent; this is how we
    /// give every column / bitcell / trial its own reproducible noise.
    pub fn fork(&self, tag: u64) -> Rng {
        // Mix the current state with the tag through SplitMix64.
        let mixed = self.s[0] ^ self.s[2].rotate_left(17) ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(mixed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal variate (Marsaglia polar method).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal variate with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gaussian()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            acc += u;
        }
        let mean = acc / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(1234);
        let n = 100_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(99);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count={c}");
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(3);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }
}
