//! Binary tensor container ("IMGT" format) used to ship trained weights
//! from the python compile path to the rust coordinator.
//!
//! Layout (little-endian):
//! ```text
//! magic   : 4 bytes  b"IMGT"
//! version : u32      (currently 1)
//! count   : u32      number of tensors
//! repeat count times:
//!   name_len : u32, name : utf-8 bytes
//!   dtype    : u8   (0 = f32, 1 = i8, 2 = i32)
//!   ndim     : u32, dims : u32 × ndim
//!   data     : dtype-sized elements, row-major
//! ```
//! The python writer lives in `python/compile/export.py`; keep in sync.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"IMGT";
pub const VERSION: u32 = 1;

/// Element type of a stored tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I8 = 1,
    I32 = 2,
}

impl DType {
    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(DType::F32),
            1 => Ok(DType::I8),
            2 => Ok(DType::I32),
            _ => bail!("unknown dtype tag {v}"),
        }
    }
}

/// A named, shaped tensor. Data is stored as f64-agnostic raw variants to
/// avoid pulling in a generic tensor library.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I8(_) => DType::I8,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32, converting integers. Cheap clone for i8/i32.
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.data {
            TensorData::F32(v) => v.clone(),
            TensorData::I8(v) => v.iter().map(|&x| x as f32).collect(),
            TensorData::I32(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor '{}' is not f32", self.name)),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            _ => Err(anyhow!("tensor '{}' is not i8", self.name)),
        }
    }
}

/// An ordered collection of tensors with name lookup.
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: Tensor) {
        self.index.insert(t.name.clone(), self.tensors.len());
        self.tensors.push(t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn req(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not found in file"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    // ---------------- serialization ----------------

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            let expected: usize = t.dims.iter().product();
            let actual = match &t.data {
                TensorData::F32(v) => v.len(),
                TensorData::I8(v) => v.len(),
                TensorData::I32(v) => v.len(),
            };
            if expected != actual {
                bail!(
                    "tensor '{}' dims {:?} imply {} elements but data has {}",
                    t.name,
                    t.dims,
                    expected,
                    actual
                );
            }
            w.write_all(&(t.name.len() as u32).to_le_bytes())?;
            w.write_all(t.name.as_bytes())?;
            w.write_all(&[t.dtype() as u8])?;
            w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for &d in &t.dims {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            match &t.data {
                TensorData::F32(v) => {
                    for x in v {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                TensorData::I8(v) => {
                    let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                    w.write_all(&bytes)?;
                }
                TensorData::I32(v) => {
                    for x in v {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        self.write_to(&mut f)
    }

    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("truncated IMGT header")?;
        if &magic != MAGIC {
            bail!("bad magic: {:?} (not an IMGT tensor file)", magic);
        }
        let version = read_u32(r)?;
        if version != VERSION {
            bail!("unsupported tensor file version {version}");
        }
        let count = read_u32(r)? as usize;
        if count > 1_000_000 {
            bail!("implausible tensor count {count}");
        }
        let mut tf = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 4096 {
                bail!("implausible tensor name length {name_len}");
            }
            let mut name_bytes = vec![0u8; name_len];
            r.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes).context("tensor name not utf-8")?;
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let dtype = DType::from_u8(tag[0])?;
            let ndim = read_u32(r)? as usize;
            if ndim > 16 {
                bail!("implausible ndim {ndim}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(r)? as usize);
            }
            // A corrupt header must not panic (checked multiply — u32 dims
            // can overflow usize arithmetic when multiplied) and must not
            // allocate the claimed size up front: the data is read through
            // a bounded `take`, so a tensor whose header claims gigabytes
            // but whose file is truncated fails with a typed error after
            // reading only what is actually there.
            let n = dims
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| anyhow!("tensor '{name}' dims {dims:?} overflow"))?;
            if n > 512 * 1024 * 1024 {
                bail!("implausible tensor size {n}");
            }
            let elem_bytes = match dtype {
                DType::F32 | DType::I32 => 4usize,
                DType::I8 => 1,
            };
            let want = n
                .checked_mul(elem_bytes)
                .ok_or_else(|| anyhow!("tensor '{name}' byte size overflows"))?;
            let mut buf = Vec::new();
            r.by_ref()
                .take(want as u64)
                .read_to_end(&mut buf)
                .with_context(|| format!("reading data of tensor '{name}'"))?;
            if buf.len() != want {
                bail!(
                    "tensor '{name}' truncated: got {} of {want} data bytes",
                    buf.len()
                );
            }
            let data = match dtype {
                DType::F32 => TensorData::F32(
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                DType::I8 => TensorData::I8(buf.into_iter().map(|b| b as i8).collect()),
                DType::I32 => TensorData::I32(
                    buf.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
            };
            tf.push(Tensor { name, dims, data });
        }
        Ok(tf)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        Self::read_from(&mut f)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TensorFile {
        let mut tf = TensorFile::new();
        tf.push(Tensor {
            name: "w1".into(),
            dims: vec![2, 3],
            data: TensorData::F32(vec![1.0, -2.0, 3.5, 0.0, 1e-3, -7.25]),
        });
        tf.push(Tensor {
            name: "q".into(),
            dims: vec![4],
            data: TensorData::I8(vec![-128, -1, 0, 127]),
        });
        tf.push(Tensor {
            name: "meta".into(),
            dims: vec![2],
            data: TensorData::I32(vec![1152, 256]),
        });
        tf
    }

    #[test]
    fn roundtrip_in_memory() {
        let tf = sample();
        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        let tf2 = TensorFile::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(tf2.tensors.len(), 3);
        assert_eq!(tf2.req("w1").unwrap().as_f32().unwrap()[2], 3.5);
        assert_eq!(tf2.req("q").unwrap().as_i8().unwrap(), &[-128, -1, 0, 127]);
        assert_eq!(tf2.req("meta").unwrap().dims, vec![2]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = b"NOPE".to_vec();
        buf.extend_from_slice(&[0u8; 16]);
        assert!(TensorFile::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn dims_data_mismatch_rejected_on_write() {
        let mut tf = TensorFile::new();
        tf.push(Tensor {
            name: "bad".into(),
            dims: vec![10],
            data: TensorData::F32(vec![1.0]),
        });
        let mut buf = Vec::new();
        assert!(tf.write_to(&mut buf).is_err());
    }

    #[test]
    fn to_f32_converts_integers() {
        let tf = sample();
        assert_eq!(tf.req("q").unwrap().to_f32(), vec![-128.0, -1.0, 0.0, 127.0]);
    }

    #[test]
    fn empty_input_is_typed_error() {
        let err = TensorFile::read_from(&mut [].as_slice()).unwrap_err();
        assert!(err.to_string().contains("truncated IMGT header"), "{err}");
    }

    #[test]
    fn every_truncation_point_errors_without_panic() {
        // The router's failover path re-reads tensorfiles at the worst
        // possible time; a half-written or half-copied file must surface
        // as Err at EVERY prefix length — header, name, dims, or data.
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        for cut in 0..buf.len() {
            let res = TensorFile::read_from(&mut &buf[..cut]);
            assert!(res.is_err(), "prefix of {cut}/{} bytes parsed", buf.len());
        }
        // Sanity: the full buffer still parses.
        assert!(TensorFile::read_from(&mut buf.as_slice()).is_ok());
    }

    #[test]
    fn corrupt_huge_dims_error_without_allocating() {
        // Header claims a tensor of u32::MAX^4 elements: the checked
        // product must reject it (on 64-bit this overflows usize; the
        // plausibility bound catches what doesn't).
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // count
        buf.extend_from_slice(&1u32.to_le_bytes()); // name_len
        buf.push(b'x');
        buf.push(0); // dtype f32
        buf.extend_from_slice(&4u32.to_le_bytes()); // ndim
        for _ in 0..4 {
            buf.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let err = TensorFile::read_from(&mut buf.as_slice()).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("overflow") || msg.contains("implausible"),
            "{msg}"
        );
    }

    #[test]
    fn plausible_header_with_missing_data_is_truncation_error() {
        // Header claims 1M f32 elements but carries no data: must fail
        // with a truncation error after reading 0 bytes, not allocate
        // 4 MB and fail mid-read_exact with a generic EOF.
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // count
        buf.extend_from_slice(&1u32.to_le_bytes()); // name_len
        buf.push(b'w');
        buf.push(0); // dtype f32
        buf.extend_from_slice(&1u32.to_le_bytes()); // ndim
        buf.extend_from_slice(&1_000_000u32.to_le_bytes());
        let err = TensorFile::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("imgt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.imgt");
        sample().save(&path).unwrap();
        let tf = TensorFile::load(&path).unwrap();
        assert_eq!(tf.names(), vec!["w1", "q", "meta"]);
    }
}
