//! Minimal JSON parser + writer.
//!
//! The vendored dependency set has no `serde`/`serde_json`, and the
//! compile-path (python) exchanges manifests, layer graphs and experiment
//! records with the rust coordinator as JSON. This module implements the
//! subset we need: objects, arrays, strings (with escapes), numbers,
//! booleans, null. It is strict about structure and lenient about
//! whitespace; errors carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// serialization, which keeps artifact diffs stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors (used by config/manifest loaders) ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `j.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers that produce readable errors.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub fn arr_usize(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {lit})")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.req_arr("a").unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"w": [1.5, -2, 3], "name": "lenet", "ok": true, "n": null}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string_compact();
        let j2 = Json::parse(&compact).unwrap();
        assert_eq!(j, j2);
        let pretty = j.to_string_pretty();
        let j3 = Json::parse(&pretty).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escape_and_multibyte() {
        let j = Json::parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("é café"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        let j = obj(vec![("n", Json::Num(42.0))]);
        assert_eq!(j.to_string_compact(), "{\"n\":42}");
    }
}
