//! Small statistics helpers used by the characterization benches and the
//! measurement-style experiments (INL/DNL extraction, RMS, histograms).

/// Mean of a slice. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Root-mean-square of a slice (e.g. error vectors in LSB).
pub fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Maximum absolute value.
pub fn max_abs(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
}

/// Minimum and maximum. Returns (0, 0) for empty input.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Percentile via linear interpolation on the sorted copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Linear regression y = a + b*x over paired slices; returns (a, b, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..xs.len() {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 {
        1.0
    } else {
        (sxy * sxy) / (sxx * syy)
    };
    let _ = n;
    (a, b, r2)
}

/// Integral nonlinearity of a transfer curve `codes[i]` measured against the
/// best-fit line through (inputs, codes). Returned per point, in LSB.
pub fn inl_best_fit(inputs: &[f64], codes: &[f64]) -> Vec<f64> {
    let (a, b, _) = linreg(inputs, codes);
    inputs
        .iter()
        .zip(codes)
        .map(|(&x, &c)| c - (a + b * x))
        .collect()
}

/// Differential nonlinearity: DNL[k] = (codes[k] - codes[k-1]) - ideal_step.
pub fn dnl(codes: &[f64], ideal_step: f64) -> Vec<f64> {
    codes
        .windows(2)
        .map(|w| (w[1] - w[0]) - ideal_step)
        .collect()
}

/// Histogram with `bins` equal-width bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || x >= hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        h[b] += 1;
    }
    h
}

/// Shannon entropy (bits) of a discrete distribution given as counts.
pub fn entropy_bits(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.log2();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_rms_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0, 20.0, 30.0];
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 30.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn inl_of_perfect_line_is_zero() {
        let xs: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 4.0 * x - 1.0).collect();
        let inl = inl_best_fit(&xs, &ys);
        assert!(max_abs(&inl) < 1e-9);
    }

    #[test]
    fn dnl_of_uniform_steps_is_zero() {
        let codes: Vec<f64> = (0..16).map(|i| i as f64).collect();
        assert!(max_abs(&dnl(&codes, 1.0)) < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.55, 0.9, 0.95];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn entropy_uniform_is_log2_n() {
        let counts = [10usize; 8];
        assert!((entropy_bits(&counts) - 3.0).abs() < 1e-12);
    }
}
