//! Small statistics helpers used by the characterization benches and the
//! measurement-style experiments (INL/DNL extraction, RMS, histograms).

/// Mean of a slice. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Root-mean-square of a slice (e.g. error vectors in LSB).
pub fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Maximum absolute value.
pub fn max_abs(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
}

/// NaN-safe argmax over logits, shared by the server, the CLI and the
/// examples: `f32::total_cmp` gives a total order, so a noisy analog
/// backend emitting NaN cannot panic a request handler (+NaN compares
/// greater than every finite value and wins the argmax; ties keep the
/// last index). Returns 0 for empty input.
pub fn argmax_f32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Minimum and maximum. Returns (0, 0) for empty input.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Percentile via linear interpolation on the sorted copy (p in [0,100]).
/// Returns 0.0 for empty input (matching `mean`/`std`); NaN values sort
/// to the top under the total order instead of panicking.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Linear regression y = a + b*x over paired slices; returns (a, b, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..xs.len() {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 {
        1.0
    } else {
        (sxy * sxy) / (sxx * syy)
    };
    let _ = n;
    (a, b, r2)
}

/// Integral nonlinearity of a transfer curve `codes[i]` measured against the
/// best-fit line through (inputs, codes). Returned per point, in LSB.
pub fn inl_best_fit(inputs: &[f64], codes: &[f64]) -> Vec<f64> {
    let (a, b, _) = linreg(inputs, codes);
    inputs
        .iter()
        .zip(codes)
        .map(|(&x, &c)| c - (a + b * x))
        .collect()
}

/// Differential nonlinearity: DNL[k] = (codes[k] - codes[k-1]) - ideal_step.
pub fn dnl(codes: &[f64], ideal_step: f64) -> Vec<f64> {
    codes
        .windows(2)
        .map(|w| (w[1] - w[0]) - ideal_step)
        .collect()
}

/// Histogram with `bins` equal-width bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || x >= hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        h[b] += 1;
    }
    h
}

/// Shannon entropy (bits) of a discrete distribution given as counts.
pub fn entropy_bits(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.log2();
        }
    }
    h
}

/// Lock-free bucketed histogram for concurrent recording (server latency
/// and batch-occupancy stats). Buckets are `counts[i]` for values
/// `<= bounds[i]`, plus one overflow bucket. Recording is a single
/// relaxed atomic increment; percentiles are approximate (bucket upper
/// edge), which is what p50/p99 serving dashboards need.
#[derive(Debug)]
pub struct AtomicHistogram {
    bounds: Vec<u64>,
    counts: Vec<std::sync::atomic::AtomicU64>,
    total: std::sync::atomic::AtomicU64,
    n: std::sync::atomic::AtomicU64,
}

/// Power-of-two bucket bounds `1, 2, 4, …, 2^max_exp`.
pub fn pow2_bounds(max_exp: u32) -> Vec<u64> {
    (0..=max_exp).map(|e| 1u64 << e).collect()
}

impl AtomicHistogram {
    pub fn new(bounds: Vec<u64>) -> Self {
        assert!(!bounds.is_empty());
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must increase");
        let counts = (0..bounds.len() + 1)
            .map(|_| std::sync::atomic::AtomicU64::new(0))
            .collect();
        Self {
            bounds,
            counts,
            total: std::sync::atomic::AtomicU64::new(0),
            n: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn record(&self, value: u64) {
        use std::sync::atomic::Ordering::Relaxed;
        let idx = self
            .bounds
            .partition_point(|&b| b < value)
            .min(self.counts.len() - 1);
        self.counts[idx].fetch_add(1, Relaxed);
        self.total.fetch_add(value, Relaxed);
        self.n.fetch_add(1, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.total.load(std::sync::atomic::Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile (`p` in [0, 100]): the upper edge of the
    /// bucket containing the p-th sample. Overflow reports the last bound.
    pub fn percentile(&self, p: f64) -> u64 {
        use std::sync::atomic::Ordering::Relaxed;
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Relaxed);
            if seen >= rank {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap()
                };
            }
        }
        *self.bounds.last().unwrap()
    }

    /// (bound, count) pairs for non-empty buckets; the overflow bucket is
    /// reported with bound `u64::MAX`.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        use std::sync::atomic::Ordering::Relaxed;
        self.counts
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Relaxed);
                if n == 0 {
                    return None;
                }
                Some((self.bounds.get(i).copied().unwrap_or(u64::MAX), n))
            })
            .collect()
    }
}

/// Weighted merge of per-worker `(bound, count)` bucket lists (the shape
/// produced by [`AtomicHistogram::nonzero_buckets`]) into one fleet-wide
/// list. Counts for the same bound accumulate; the overflow bucket keeps
/// its `u64::MAX` bound and sorts last. Workers with different bucket
/// layouts merge correctly because buckets are keyed by bound, not index.
pub fn merge_histogram_buckets(sources: &[Vec<(u64, u64)>]) -> Vec<(u64, u64)> {
    let mut merged: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
    for src in sources {
        for &(bound, count) in src {
            *merged.entry(bound).or_insert(0) += count;
        }
    }
    merged.into_iter().filter(|&(_, c)| c != 0).collect()
}

/// Approximate percentile over a merged `(bound, count)` bucket list,
/// using the same rank convention as [`AtomicHistogram::percentile`]:
/// the upper edge of the bucket containing the p-th sample. The overflow
/// bucket (`u64::MAX` bound) reports the largest finite bound, matching
/// the single-histogram clamp. Returns 0 for an empty fleet.
pub fn bucket_percentile(buckets: &[(u64, u64)], p: f64) -> u64 {
    let n: u64 = buckets.iter().map(|&(_, c)| c).sum();
    if n == 0 {
        return 0;
    }
    let last_finite = buckets
        .iter()
        .rev()
        .map(|&(b, _)| b)
        .find(|&b| b != u64::MAX)
        .unwrap_or(0);
    let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for &(bound, count) in buckets {
        seen += count;
        if seen >= rank {
            return if bound == u64::MAX { last_finite } else { bound };
        }
    }
    last_finite
}

/// Serialize a `(bound, count)` bucket list as a JSON array of
/// `[bound, count]` pairs for the server `stats` response. The overflow
/// bound `u64::MAX` is not representable as a JSON number and is
/// serialized as `null`.
pub fn buckets_to_json(buckets: &[(u64, u64)]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        buckets
            .iter()
            .map(|&(bound, count)| {
                let b = if bound == u64::MAX {
                    Json::Null
                } else {
                    Json::Num(bound as f64)
                };
                Json::Arr(vec![b, Json::Num(count as f64)])
            })
            .collect(),
    )
}

/// Parse a bucket list serialized by [`buckets_to_json`] back into
/// `(bound, count)` pairs (`null` bound → `u64::MAX`). Tolerant of a
/// missing or malformed field — the router treats that as an empty
/// histogram rather than failing the whole stats aggregation.
pub fn buckets_from_json(j: Option<&crate::util::json::Json>) -> Vec<(u64, u64)> {
    use crate::util::json::Json;
    let Some(Json::Arr(items)) = j else {
        return Vec::new();
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        let Json::Arr(pair) = item else { continue };
        if pair.len() != 2 {
            continue;
        }
        let bound = match &pair[0] {
            Json::Null => u64::MAX,
            Json::Num(b) if *b >= 0.0 => *b as u64,
            _ => continue,
        };
        let Json::Num(count) = pair[1] else { continue };
        if count >= 0.0 {
            out.push((bound, count as u64));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_rms_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0, 20.0, 30.0];
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 30.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty input: defined as 0.0, like mean/std — not a panic.
        assert_eq!(percentile(&[], 50.0), 0.0);
        // Single sample: every percentile is that sample.
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], p), 7.5);
        }
        // All-equal distribution: interpolation between equal ranks.
        let flat = [3.0; 5];
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&flat, p), 3.0);
        }
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn inl_of_perfect_line_is_zero() {
        let xs: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 4.0 * x - 1.0).collect();
        let inl = inl_best_fit(&xs, &ys);
        assert!(max_abs(&inl) < 1e-9);
    }

    #[test]
    fn dnl_of_uniform_steps_is_zero() {
        let codes: Vec<f64> = (0..16).map(|i| i as f64).collect();
        assert!(max_abs(&dnl(&codes, 1.0)) < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.55, 0.9, 0.95];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn entropy_uniform_is_log2_n() {
        let counts = [10usize; 8];
        assert!((entropy_bits(&counts) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn atomic_histogram_percentiles() {
        let h = AtomicHistogram::new(pow2_bounds(10)); // 1..1024
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        // p50 of 1..=100 lands in the (32, 64] bucket → upper edge 64.
        assert_eq!(h.percentile(50.0), 64);
        assert_eq!(h.percentile(99.0), 128);
        assert_eq!(h.percentile(0.0), 1);
        // Overflow values clamp to the top bound.
        h.record(1u64 << 40);
        assert_eq!(h.percentile(100.0), 1024);
    }

    #[test]
    fn atomic_histogram_bucket_edges() {
        let h = AtomicHistogram::new(vec![1, 2, 4]);
        h.record(1); // bucket 0 (<=1)
        h.record(2); // bucket 1
        h.record(3); // bucket 2 (<=4)
        h.record(4); // bucket 2
        h.record(9); // overflow
        let buckets = h.nonzero_buckets();
        assert_eq!(buckets, vec![(1, 1), (2, 1), (4, 2), (u64::MAX, 1)]);
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn atomic_histogram_empty() {
        let h = AtomicHistogram::new(pow2_bounds(4));
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(100.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn atomic_histogram_single_sample() {
        let h = AtomicHistogram::new(pow2_bounds(6)); // 1..64
        h.record(5); // lands in the (4, 8] bucket
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean(), 5.0);
        // Every percentile reports the one occupied bucket's upper edge.
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 8, "p={p}");
        }
        assert_eq!(h.nonzero_buckets(), vec![(8, 1)]);
    }

    #[test]
    fn bucket_merge_empty_fleet() {
        // No shards, or shards that have served nothing: empty merge,
        // every percentile 0 — not a panic.
        assert!(merge_histogram_buckets(&[]).is_empty());
        let merged = merge_histogram_buckets(&[Vec::new(), Vec::new()]);
        assert!(merged.is_empty());
        assert_eq!(bucket_percentile(&merged, 50.0), 0);
        assert_eq!(bucket_percentile(&merged, 99.0), 0);
    }

    #[test]
    fn bucket_merge_single_sample() {
        // One shard, one sample: every percentile is that bucket's edge.
        let h = AtomicHistogram::new(pow2_bounds(6));
        h.record(5);
        let merged = merge_histogram_buckets(&[h.nonzero_buckets(), Vec::new()]);
        assert_eq!(merged, vec![(8, 1)]);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(bucket_percentile(&merged, p), 8, "p={p}");
        }
    }

    #[test]
    fn bucket_merge_is_weighted() {
        // A shard with 90 fast samples and a shard with 10 slow samples:
        // fleet p50 must sit in the fast bucket, fleet p99 in the slow
        // one — a weighted merge, not an average of per-shard p50s.
        let fast = AtomicHistogram::new(pow2_bounds(10));
        let slow = AtomicHistogram::new(pow2_bounds(10));
        for _ in 0..90 {
            fast.record(3); // (2, 4] bucket
        }
        for _ in 0..10 {
            slow.record(700); // (512, 1024] bucket
        }
        let merged = merge_histogram_buckets(&[fast.nonzero_buckets(), slow.nonzero_buckets()]);
        assert_eq!(merged, vec![(4, 90), (1024, 10)]);
        assert_eq!(bucket_percentile(&merged, 50.0), 4);
        assert_eq!(bucket_percentile(&merged, 90.0), 4);
        assert_eq!(bucket_percentile(&merged, 99.0), 1024);
    }

    #[test]
    fn bucket_merge_matches_single_histogram() {
        // Splitting one sample stream across two shards and merging must
        // reproduce the percentiles of recording everything in one
        // histogram (same bounds, same rank convention).
        let whole = AtomicHistogram::new(pow2_bounds(10));
        let a = AtomicHistogram::new(pow2_bounds(10));
        let b = AtomicHistogram::new(pow2_bounds(10));
        for v in 1..=100u64 {
            whole.record(v);
            if v % 2 == 0 { &a } else { &b }.record(v);
        }
        let merged = merge_histogram_buckets(&[a.nonzero_buckets(), b.nonzero_buckets()]);
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(bucket_percentile(&merged, p), whole.percentile(p), "p={p}");
        }
    }

    #[test]
    fn bucket_merge_overflow_reports_last_finite_bound() {
        let h = AtomicHistogram::new(vec![1, 2, 4]);
        h.record(9); // overflow bucket
        let merged = merge_histogram_buckets(&[h.nonzero_buckets()]);
        assert_eq!(merged, vec![(u64::MAX, 1)]);
        // Same clamp as AtomicHistogram::percentile: report the largest
        // finite bound the histogram knows about — here there is none in
        // the merged list besides the overflow marker, so 0.
        assert_eq!(bucket_percentile(&merged, 99.0), 0);
        h.record(3);
        let merged = merge_histogram_buckets(&[h.nonzero_buckets()]);
        assert_eq!(bucket_percentile(&merged, 100.0), 4);
        assert_eq!(h.percentile(100.0), 4);
    }

    #[test]
    fn buckets_json_roundtrip() {
        let buckets = vec![(1u64, 3u64), (64, 9), (u64::MAX, 2)];
        let j = buckets_to_json(&buckets);
        let text = j.to_string_compact();
        // The overflow bound must serialize as null, not a huge float.
        assert!(text.contains("null"), "{text}");
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(buckets_from_json(Some(&parsed)), buckets);
        // Missing / malformed fields degrade to an empty histogram.
        assert!(buckets_from_json(None).is_empty());
        let junk = crate::util::json::Json::parse("{\"x\":1}").unwrap();
        assert!(buckets_from_json(Some(&junk)).is_empty());
    }

    #[test]
    fn atomic_histogram_all_equal_samples() {
        let h = AtomicHistogram::new(pow2_bounds(6));
        for _ in 0..9 {
            h.record(16); // exactly on a bucket bound
        }
        assert_eq!(h.count(), 9);
        assert_eq!(h.mean(), 16.0);
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(h.percentile(p), 16, "p={p}");
        }
        assert_eq!(h.nonzero_buckets(), vec![(16, 9)]);
    }
}
