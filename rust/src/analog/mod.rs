//! Circuit-behavioral models of the IMAGINE analog core (§III).
//!
//! Module map (one file per physical block):
//! * [`bitcell`] — 10T1C array, weight storage, per-die C_c mismatch;
//! * [`dpl`] — dot-product-line charge sharing, split topologies, settling;
//! * [`mbiw`] — multi-bit input-and-weight accumulator (Eq. 5–6);
//! * [`sense_amp`] — StrongArm comparator with offset/noise;
//! * [`ladder`] — gain-adaptive resistive reference (ABN zoom);
//! * [`adc`] — DSCI SAR ADC with ABN offset + calibration (Eq. 7);
//! * [`macro_model`] — the full 1152×256 macro composing all of the above.

pub mod adc;
pub mod bitcell;
pub mod dpl;
pub mod ladder;
pub mod macro_model;
pub mod mbiw;
pub mod sense_amp;
