//! StrongArm sense amplifier model (§III.E, Fig. 14).
//!
//! The SAR's comparator is a low-kickback StrongArm latch with a
//! minimum-length input pair. Minimum-length devices keep the kickback on
//! the floating DPL below 0.03 mV but worsen mismatch: the pre-layout
//! offset is σ = 20 mV (3σ = 60 mV), degraded a further 75% post-layout
//! by resizing constraints and proximity effects (σ ≈ 35 mV). On top of
//! the static offset each decision carries temporal noise.

use crate::config::params::MacroParams;
use crate::util::rng::Rng;

/// One instantiated comparator: static offset drawn at "fabrication",
/// temporal noise drawn per decision.
#[derive(Clone, Debug)]
pub struct SenseAmp {
    /// Static input-referred offset [V] (per-die, per-column).
    pub offset: f64,
    /// Temporal decision-noise sigma [V].
    pub noise_sigma: f64,
    /// Kickback injected on the DPL per decision [V] (bounded < 0.03 mV).
    pub kickback: f64,
}

impl SenseAmp {
    /// Draw a post-layout instance.
    pub fn sample(p: &MacroParams, rng: &mut Rng) -> Self {
        Self {
            offset: rng.normal(0.0, p.sa_sigma()),
            noise_sigma: p.sa_noise,
            kickback: 0.025e-3,
        }
    }

    /// Draw a pre-layout instance (Fig. 14b comparison).
    pub fn sample_prelayout(p: &MacroParams, rng: &mut Rng) -> Self {
        Self {
            offset: rng.normal(0.0, p.sa_sigma_prelayout),
            noise_sigma: p.sa_noise,
            kickback: 0.025e-3,
        }
    }

    /// Ideal comparator (tests, golden model).
    pub fn ideal() -> Self {
        Self { offset: 0.0, noise_sigma: 0.0, kickback: 0.0 }
    }

    /// Compare `v_plus` against `v_minus`. `rng = None` disables temporal
    /// noise (deterministic mode used by the golden-model tests).
    #[inline]
    pub fn decide(&self, v_plus: f64, v_minus: f64, rng: Option<&mut Rng>) -> bool {
        let noise = match rng {
            Some(r) if self.noise_sigma > 0.0 => r.normal(0.0, self.noise_sigma),
            _ => 0.0,
        };
        v_plus - v_minus + self.offset + noise > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::MacroParams;
    use crate::util::stats;

    #[test]
    fn ideal_comparator_is_exact() {
        let sa = SenseAmp::ideal();
        assert!(sa.decide(0.5, 0.4, None));
        assert!(!sa.decide(0.4, 0.5, None));
    }

    #[test]
    fn offset_shifts_threshold() {
        let sa = SenseAmp { offset: 0.02, noise_sigma: 0.0, kickback: 0.0 };
        // With +20 mV offset, an input 10 mV below threshold still trips.
        assert!(sa.decide(0.39, 0.40, None));
        assert!(!sa.decide(0.37, 0.40, None));
    }

    #[test]
    fn postlayout_sigma_75pct_worse() {
        let p = MacroParams::paper();
        let mut rng = Rng::new(42);
        let pre: Vec<f64> = (0..4000)
            .map(|_| SenseAmp::sample_prelayout(&p, &mut rng).offset)
            .collect();
        let post: Vec<f64> = (0..4000)
            .map(|_| SenseAmp::sample(&p, &mut rng).offset)
            .collect();
        let s_pre = stats::std(&pre);
        let s_post = stats::std(&post);
        assert!((s_pre - 0.020).abs() < 0.002, "pre σ={s_pre}");
        assert!((s_post / s_pre - 1.75).abs() < 0.1, "ratio={}", s_post / s_pre);
    }

    #[test]
    fn temporal_noise_randomizes_marginal_decisions() {
        let p = MacroParams::paper();
        let sa = SenseAmp { offset: 0.0, noise_sigma: p.sa_noise, kickback: 0.0 };
        let mut rng = Rng::new(7);
        let highs = (0..2000)
            .filter(|_| sa.decide(0.4000, 0.4000, Some(&mut rng)))
            .count();
        // Exactly-at-threshold input should flip ~50/50.
        assert!((900..1100).contains(&highs), "highs={highs}");
    }

    #[test]
    fn kickback_below_paper_bound() {
        let p = MacroParams::paper();
        let mut rng = Rng::new(1);
        let sa = SenseAmp::sample(&p, &mut rng);
        assert!(sa.kickback < 0.03e-3);
    }
}
