//! Distribution-shaping charge-injection (DSCI) SAR ADC with in-ADC
//! analog batch-normalization (§III.D–E, Figs. 11–14).
//!
//! The converter works directly on the column's floating DPL:
//!
//! 1. **Offset phase** — the 5b ABN offset unit and the 7b calibration
//!    unit inject their pre-stored charge onto the DPL (±30 mV range,
//!    0.47 mV calibration resolution).
//! 2. **SAR phase** — `r_out` decision/update cycles. Each decision is a
//!    StrongArm comparison of the DPL against mid-rail; each update
//!    injects ±S-IN(b) through the 10T1C split-DAC. The ABN gain γ scales
//!    all S-IN levels by 1/γ (the *zoom*), which is mathematically
//!    equivalent to amplifying the DP distribution before an ordinary
//!    conversion — Eq. 7:
//!    `D = ⌊2^(r_out−1) + γ·(ΔV_MBIW+ΔV_β+ΔV_cal)/(α_adc·V_DDH/2^(r_out−1))⌋`.
//!
//! Calibration mode (§III.E) runs the same loop against the calibration
//! DAC with the DPL precharged to V_DDL, converging on a code that nulls
//! the comparator offset (to within ladder/thermal noise, and only if the
//! offset lies within the ±30 mV compensable range).

use crate::analog::ladder::Ladder;
use crate::analog::sense_amp::SenseAmp;
use crate::config::params::MacroParams;
use crate::util::rng::Rng;

/// One column's DSCI ADC instance.
#[derive(Clone, Debug)]
pub struct DsciAdc {
    pub sa: SenseAmp,
    /// 5b signed ABN offset code ∈ [−16, 15].
    pub abn_offset_code: i32,
    /// Signed calibration code ∈ [−128, 127] (7b array + 4×C_c MSB device,
    /// 0.47 mV/step ⇒ ±60 mV range covering the 3σ pre-layout offset).
    pub cal_code: i32,
    /// Per-bit SAR capacitor mismatch (static, relative).
    pub sar_cap_eps: Vec<f64>,
}

impl DsciAdc {
    pub fn sample(p: &MacroParams, rng: &mut Rng) -> Self {
        Self {
            sa: SenseAmp::sample(p, rng),
            abn_offset_code: 0,
            cal_code: 0,
            sar_cap_eps: (0..8).map(|_| rng.normal(0.0, p.cap_mismatch)).collect(),
        }
    }

    pub fn ideal() -> Self {
        Self {
            sa: SenseAmp::ideal(),
            abn_offset_code: 0,
            cal_code: 0,
            sar_cap_eps: vec![0.0; 8],
        }
    }

    /// ABN offset voltage ΔV_β for the stored 5b code.
    pub fn abn_offset_v(&self, p: &MacroParams) -> f64 {
        // 5b signed, full range ±abn_offset_range on the DPL.
        self.abn_offset_code as f64 * p.abn_offset_range / 16.0
    }

    /// Calibration voltage ΔV_cal for the stored 7b code.
    pub fn cal_v(&self, p: &MacroParams) -> f64 {
        self.cal_code as f64 * p.cal_step
    }

    /// Set the ABN offset from a *target voltage*, quantized to the 5b DAC.
    pub fn set_abn_offset_target(&mut self, p: &MacroParams, v_target: f64) {
        let step = p.abn_offset_range / 16.0;
        self.abn_offset_code = ((v_target / step).round() as i32).clamp(-16, 15);
    }

    /// Convert the MBIW voltage on the DPL to a digital code.
    ///
    /// `ladder` supplies the (possibly γ-zoomed, mismatched) S-IN steps;
    /// `rng = Some(_)` enables temporal noise (SA noise + kT/C sampling
    /// noise on the SAR array).
    pub fn convert(
        &self,
        p: &MacroParams,
        ladder: &Ladder,
        v_dpl: f64,
        gamma: f64,
        r_out: u32,
        mut rng: Option<&mut Rng>,
    ) -> u32 {
        assert!((1..=8).contains(&r_out));
        let v_mid = p.supply.vddl; // DPL mid-rail reference = V_DDH/2 = V_DDL
        let mut v = v_dpl + self.abn_offset_v(p) + self.cal_v(p);
        // kT/C sampling noise of the SAR array, once per conversion.
        if let Some(r) = rng.as_deref_mut() {
            let sigma = MacroParams::ktc_sigma(p.c_sar + p.c_p_sar);
            v += r.normal(0.0, sigma);
        }
        let mut code = 0u32;
        for b in (0..r_out).rev() {
            let d = self.sa.decide(v, v_mid, rng.as_deref_mut());
            code = (code << 1) | d as u32;
            let step =
                ladder.sar_step(p, r_out, gamma, b) * (1.0 + self.sar_cap_eps[b as usize]);
            v += if d { -step } else { step };
        }
        code
    }

    /// Eq. 7 evaluated directly (the golden transfer function).
    pub fn ideal_code(p: &MacroParams, dv: f64, gamma: f64, r_out: u32) -> u32 {
        let half = (1u64 << (r_out - 1)) as f64;
        let lsb = p.alpha_adc() * p.supply.vddh / (gamma * half);
        let code = (half + dv / lsb).floor();
        code.clamp(0.0, (1u64 << r_out) as f64 - 1.0) as u32
    }

    /// Run the calibration sequence (§III.E): with the DPL precharged to
    /// V_DDL, SAR-search the 7b calibration code that nulls the comparator
    /// offset. Temporal noise during calibration (if `rng` given) limits
    /// the achievable residual, as on silicon. Returns the residual offset
    /// [V] after calibration.
    pub fn calibrate(&mut self, p: &MacroParams, mut rng: Option<&mut Rng>) -> f64 {
        let v_mid = p.supply.vddl;
        // Successive approximation over the signed code range (the MSB
        // trial exercises the 4×C_c device that covers the 3σ pre-layout
        // offset, §III.E). The comparator's decision at trial code t is
        // `t·step + offset > 0`, monotone in t; bisect to the flip point.
        let mut lo: i32 = -128;
        let mut hi: i32 = 127;
        for _ in 0..8 {
            if lo >= hi {
                break;
            }
            let mid = (lo + hi).div_euclid(2);
            let v_trial = v_mid + mid as f64 * p.cal_step;
            if self.sa.decide(v_trial, v_mid, rng.as_deref_mut()) {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        self.cal_code = hi.clamp(-128, 127);
        self.sa.offset + self.cal_v(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::MacroParams;
    use crate::util::stats;

    fn setup() -> (MacroParams, Ladder, DsciAdc) {
        let p = MacroParams::paper();
        let l = Ladder::ideal(&p);
        (p, l, DsciAdc::ideal())
    }

    #[test]
    fn nominal_transfer_matches_eq7_within_one_code() {
        let (p, l, adc) = setup();
        for r_out in [4u32, 6, 8] {
            for gamma in [1.0, 2.0, 4.0] {
                for i in 0..200 {
                    let dv = -0.35 + 0.7 * i as f64 / 199.0;
                    let got = adc.convert(&p, &l, p.supply.vddl + dv, gamma, r_out, None);
                    let want = DsciAdc::ideal_code(&p, dv, gamma, r_out);
                    let diff = got as i64 - want as i64;
                    assert!(
                        diff.abs() <= 1,
                        "r_out={r_out} γ={gamma} dv={dv}: got={got} want={want}"
                    );
                }
            }
        }
    }

    #[test]
    fn codes_clip_at_range_ends() {
        let (p, l, adc) = setup();
        let hi = adc.convert(&p, &l, p.supply.vddl + 2.0, 1.0, 8, None);
        let lo = adc.convert(&p, &l, p.supply.vddl - 2.0, 1.0, 8, None);
        assert_eq!(hi, 255);
        assert_eq!(lo, 0);
    }

    #[test]
    fn monotone_in_input_nominal() {
        let (p, l, adc) = setup();
        let mut last = 0;
        for i in 0..500 {
            let dv = -0.3 + 0.6 * i as f64 / 499.0;
            let c = adc.convert(&p, &l, p.supply.vddl + dv, 2.0, 8, None);
            assert!(c >= last, "non-monotone at i={i}");
            last = c;
        }
    }

    #[test]
    fn gamma_zoom_amplifies_small_signals() {
        let (p, l, adc) = setup();
        let dv = 0.01;
        let c1 = adc.convert(&p, &l, p.supply.vddl + dv, 1.0, 8, None) as i64 - 128;
        let c8 = adc.convert(&p, &l, p.supply.vddl + dv, 8.0, 8, None) as i64 - 128;
        // The zoomed code resolves the same ΔV with 8× finer LSBs; both
        // quantize with ±1-code floor uncertainty.
        assert!((c8 - 8 * c1).abs() <= 8, "c1={c1} c8={c8}");
        assert!(c8 > c1, "zoom should enlarge the code magnitude");
    }

    #[test]
    fn abn_offset_shifts_code() {
        let (p, l, mut adc) = setup();
        let c0 = adc.convert(&p, &l, p.supply.vddl, 1.0, 8, None);
        adc.set_abn_offset_target(&p, 0.020); // +20 mV
        let c1 = adc.convert(&p, &l, p.supply.vddl, 1.0, 8, None);
        let lsb = p.adc_lsb(8, 1.0);
        let expect = (0.020 / lsb).round() as i64;
        assert!(
            ((c1 as i64 - c0 as i64) - expect).abs() <= 1,
            "shift={} expect={expect}",
            c1 as i64 - c0 as i64
        );
    }

    #[test]
    fn offset_dac_quantizes_and_clamps() {
        let (p, _, mut adc) = setup();
        adc.set_abn_offset_target(&p, 1.0);
        assert_eq!(adc.abn_offset_code, 15);
        adc.set_abn_offset_target(&p, -1.0);
        assert_eq!(adc.abn_offset_code, -16);
        adc.set_abn_offset_target(&p, 0.0);
        assert_eq!(adc.abn_offset_code, 0);
    }

    #[test]
    fn calibration_nulls_in_range_offsets() {
        let p = MacroParams::paper();
        for off in [-0.055, -0.025, -0.01, 0.004, 0.017, 0.029, 0.052] {
            let mut adc = DsciAdc::ideal();
            adc.sa.offset = off;
            let resid = adc.calibrate(&p, None);
            assert!(
                resid.abs() <= p.cal_step,
                "offset={off}: residual={resid}"
            );
        }
    }

    #[test]
    fn calibration_cannot_fix_out_of_range_offsets() {
        let p = MacroParams::paper();
        let mut adc = DsciAdc::ideal();
        adc.sa.offset = 0.085; // beyond the ±60 mV DAC range
        let resid = adc.calibrate(&p, None);
        assert!(resid.abs() > 0.02, "resid={resid}");
    }

    #[test]
    fn calibration_improves_population_spread() {
        // Fig. 14c / Fig. 19: post-calibration, ~95% of columns fall within
        // one 8b LSB.
        let p = MacroParams::paper();
        let mut rng = Rng::new(11);
        let lsb = p.adc_lsb(8, 1.0);
        let mut pre = Vec::new();
        let mut post = Vec::new();
        for i in 0..256 {
            let mut adc = DsciAdc::sample(&p, &mut rng.fork(i));
            pre.push(adc.sa.offset / lsb);
            let mut noise = rng.fork(1000 + i);
            let resid = adc.calibrate(&p, Some(&mut noise));
            post.push(resid / lsb);
        }
        let spread_pre = stats::std(&pre);
        let spread_post = stats::std(&post);
        assert!(spread_pre > 4.0, "pre spread={spread_pre} LSB");
        assert!(spread_post < spread_pre / 4.0, "post spread={spread_post}");
        let within = post.iter().filter(|e| e.abs() <= 1.0).count();
        assert!(within as f64 / 256.0 > 0.90, "within 1 LSB: {within}/256");
    }

    #[test]
    fn noisy_conversion_rms_under_unity_gain_below_one_lsb() {
        // §V.A: maximum RMS error 0.52 LSB at 8b, γ=1 after calibration.
        let p = MacroParams::paper();
        let l = Ladder::ideal(&p);
        let mut adc = DsciAdc::ideal();
        adc.sa.noise_sigma = p.sa_noise;
        let mut rng = Rng::new(5);
        let dv = 0.085;
        let want = DsciAdc::ideal_code(&p, dv, 1.0, 8) as f64;
        let errs: Vec<f64> = (0..300)
            .map(|_| {
                adc.convert(&p, &l, p.supply.vddl + dv, 1.0, 8, Some(&mut rng)) as f64 - want
            })
            .collect();
        let rms = stats::rms(&errs);
        assert!(rms < 1.0, "rms={rms} LSB");
        assert!(rms > 0.05, "suspiciously quiet: rms={rms}");
    }
}
