//! Dot-product-line (DPL) charge-sharing model with split topologies and
//! settling dynamics (§II Eq. 1–4, §III.B, Figs. 6 & 8).
//!
//! The DPL of one column collects the charge injected by all connected
//! 10T1C bitcells. Three topologies are modelled (Fig. 6a):
//!
//! * **Baseline** — one monolithic DPL over all 1152 rows; the attenuation
//!   α is fixed at its worst value regardless of how many rows are used.
//! * **Parallel-split** — 32 local DPLs joined to a global DPL through
//!   switches; connected units scale α but the global line adds C_p,glob.
//! * **Serial-split** — units daisy-chained with transmission gates on the
//!   main DPL (the fabricated choice). α scales with connected units, but
//!   charge from distant units must settle through a chain of series
//!   gates, which is what produces the paper's slow-corner measurement
//!   artefacts (Fig. 8b/c, Fig. 17b, Fig. 20).
//!
//! The settling model is first-order per unit: the charge contributed by
//! unit `u` (distance `u` gates from the MBIW end) reaches the output with
//! a residual deficit `exp(−T_DP / τ_u)`, where
//! `τ_u = τ_tg · (u + 1) · m(V_target) / drive(corner)` and `m(·)` is the
//! mid-rail drive-weakening factor of a transmission gate (worst when the
//! target voltage sits near V_DDH/2, §III.B).

use crate::config::params::{DplTopology, MacroParams};

/// Result of one single-bit DP phase on one column.
#[derive(Clone, Copy, Debug)]
pub struct DpResult {
    /// Settled (or partially settled) DPL voltage [V].
    pub v_dpl: f64,
    /// The ideal target voltage had settling been complete [V].
    pub v_ideal: f64,
}

/// Compute the ideal (fully settled) DPL voltage for a signed sum `s_total`
/// over `connected_rows` rows: V = V_DDL + α_eff · V_DDL · Σs  (Eq. 1).
pub fn ideal_dp_voltage(p: &MacroParams, connected_rows: usize, s_total: f64) -> f64 {
    let alpha = p.alpha_eff(connected_rows);
    p.supply.vddl + alpha * p.supply.vddl * s_total
}

/// Mid-rail drive weakening of a serial-split transmission gate: gates
/// passing a voltage near V_DDH/2 have the least overdrive. Factor ≥ 1.
pub fn midrail_weakening(p: &MacroParams, v_target: f64) -> f64 {
    let v_mid = p.supply.vddh / 2.0;
    let width = 0.06; // V, fitted to give Fig. 8b's T_DP requirement
    let amp = 1.2 / p.corner.drive();
    1.0 + amp * (-((v_target - v_mid) / width).powi(2)).exp()
}

/// One single-bit DP phase over a column, given the per-unit signed sums
/// `unit_sums[u]` (unit 0 is adjacent to the MBIW/ADC end).
///
/// `connected_units` ≤ 32 units participate (serial/parallel split); for
/// the baseline topology all 1152 rows load the line regardless.
pub fn dp_phase(
    p: &MacroParams,
    unit_sums: &[f64],
    connected_units: usize,
    t_dp: f64,
) -> DpResult {
    assert!(connected_units >= 1 && connected_units <= p.n_units());
    assert!(unit_sums.len() >= connected_units);
    let connected_rows = p.rows_for_units(connected_units);
    let alpha = p.alpha_eff(connected_rows);
    let vddl = p.supply.vddl;

    let s_total: f64 = unit_sums[..connected_units].iter().sum();
    let v_ideal = vddl + alpha * vddl * s_total;

    let v_dpl = match p.topology {
        DplTopology::Baseline => v_ideal,
        DplTopology::ParallelSplit => {
            // Local lines settle through ONE switch each onto the global
            // line: single-gate τ, no distance dependence (1.5 ns is
            // enough per §III.B). Residual error is tiny but modelled.
            let m = midrail_weakening(p, v_ideal);
            let tau = p.tau_tg * m / p.corner.drive() / 3.0;
            let deficit = (-t_dp / tau).exp();
            let err: f64 = unit_sums[..connected_units]
                .iter()
                .map(|&s| alpha * vddl * s * deficit)
                .sum();
            v_ideal - err
        }
        DplTopology::SerialSplit => {
            // Charge from unit u crosses u series gates; with Elmore
            // RC-diffusion the residual deficit grows quadratically with
            // distance. Opposing-sign unit sums do not cancel in the
            // residual — the paper's half-1/half-0 worst case (Fig. 8b/c)
            // and clustered-weight distortion (Fig. 20b).
            let m = midrail_weakening(p, v_ideal);
            let mut err = 0.0;
            for (u, &s) in unit_sums[..connected_units].iter().enumerate() {
                let d = u as f64 + 1.0;
                let tau = p.tau_tg * d * d * m / p.corner.drive();
                let xponent = t_dp / tau;
                if xponent > 30.0 {
                    continue; // residual < 1e-13 of the contribution
                }
                err += alpha * vddl * s * (-xponent).exp();
            }
            v_ideal - err
        }
    };
    DpResult { v_dpl, v_ideal }
}

/// Maximum DPL voltage swing (one side) achievable with `connected_units`
/// active and all cells injecting the same polarity — Fig. 6(b)'s y-axis.
pub fn max_swing(p: &MacroParams, connected_units: usize) -> f64 {
    let rows = p.rows_for_units(connected_units);
    let alpha = p.alpha_eff(rows);
    alpha * p.supply.vddl * rows as f64
}

/// Effective number of ADC bits usable for a DP with standard deviation
/// `sigma_dp` (in units of rows) and `connected_units` active, for an
/// `r_out`-bit full-scale ADC at gain γ — the quantity Fig. 3(a) tracks.
///
/// The ADC covers ±α_adc·V_DDH/(2γ)... whereas the DP distribution spans
/// roughly ±3σ·α_eff·V_DDL. Bits that resolve voltages outside the DP
/// span are wasted.
pub fn effective_adc_bits(
    p: &MacroParams,
    connected_units: usize,
    sigma_dp_rows: f64,
    r_out: u32,
    gamma: f64,
) -> f64 {
    let rows = p.rows_for_units(connected_units);
    let alpha = p.alpha_eff(rows);
    let span_dp = 2.0 * 3.0 * sigma_dp_rows * alpha * p.supply.vddl; // ±3σ
    let lsb = p.adc_lsb(r_out, gamma);
    let full_scale = lsb * (1u64 << r_out) as f64;
    let used = (span_dp / full_scale).min(1.0);
    (r_out as f64 + used.log2()).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{Corner, MacroParams};

    fn p() -> MacroParams {
        MacroParams::paper()
    }

    #[test]
    fn ideal_voltage_is_linear_in_sum() {
        let p = p();
        let v0 = ideal_dp_voltage(&p, 1152, 0.0);
        assert!((v0 - p.supply.vddl).abs() < 1e-15);
        let v1 = ideal_dp_voltage(&p, 1152, 100.0);
        let v2 = ideal_dp_voltage(&p, 1152, 200.0);
        assert!(((v2 - v0) - 2.0 * (v1 - v0)).abs() < 1e-12);
    }

    #[test]
    fn swing_stays_within_rails() {
        let p = p();
        for units in [1, 8, 16, 32] {
            let s = max_swing(&p, units);
            assert!(s > 0.0 && p.supply.vddl + s < p.supply.vddh, "units={units} swing={s}");
        }
    }

    #[test]
    fn serial_split_beats_baseline_at_low_cin() {
        let p = p();
        let base = p.clone().with_topology(DplTopology::Baseline);
        let split = p.clone().with_topology(DplTopology::SerialSplit);
        // One unit active: split swing should be far larger (paper: up to ~20×).
        let gain = max_swing(&split, 1) / max_swing(&base, 1);
        assert!(gain > 5.0, "gain={gain}");
        // At full utilization they converge (same connected capacitance).
        let gain_full = max_swing(&split, 32) / max_swing(&base, 32);
        assert!((gain_full - 1.0).abs() < 1e-9);
    }

    #[test]
    fn settling_error_vanishes_with_long_t_dp() {
        let p = p();
        let unit_sums = vec![36.0; 32];
        let short = dp_phase(&p, &unit_sums, 32, 2e-9);
        let long = dp_phase(&p, &unit_sums, 32, 100e-9);
        let err_short = (short.v_dpl - short.v_ideal).abs();
        let err_long = (long.v_dpl - long.v_ideal).abs();
        assert!(err_long < err_short * 1e-3, "short={err_short} long={err_long}");
        assert!(err_long < 1e-9);
    }

    #[test]
    fn opposing_halves_worst_case() {
        // Half-1/half-0 pattern: near-zero ideal target but large residual
        // (Fig. 8b). Compare against a uniform pattern with the same |sum|.
        let p = p();
        let mut opposing = vec![36.0; 32];
        for s in opposing.iter_mut().skip(16) {
            *s = -36.0;
        }
        let uniform = vec![0.0; 32];
        let r_op = dp_phase(&p, &opposing, 32, p.t_dp);
        let r_un = dp_phase(&p, &uniform, 32, p.t_dp);
        let err_op = (r_op.v_dpl - r_op.v_ideal).abs();
        let err_un = (r_un.v_dpl - r_un.v_ideal).abs();
        assert!(err_op > err_un + 1e-9, "opposing={err_op} uniform={err_un}");
    }

    #[test]
    fn slow_corner_settles_worse() {
        let pt = p().with_corner(Corner::Tt);
        let ps = p().with_corner(Corner::Ss);
        let mut sums = vec![36.0; 32];
        for s in sums.iter_mut().skip(16) {
            *s = -36.0;
        }
        let et = (dp_phase(&pt, &sums, 32, pt.t_dp).v_dpl
            - dp_phase(&pt, &sums, 32, pt.t_dp).v_ideal)
            .abs();
        let es = (dp_phase(&ps, &sums, 32, ps.t_dp).v_dpl
            - dp_phase(&ps, &sums, 32, ps.t_dp).v_ideal)
            .abs();
        assert!(es > et, "SS={es} TT={et}");
    }

    #[test]
    fn effective_bits_recover_with_gamma() {
        let p = p();
        // Narrow distribution, quarter utilization: many wasted bits.
        let lo = effective_adc_bits(&p, 8, 30.0, 8, 1.0);
        let hi = effective_adc_bits(&p, 8, 30.0, 8, 8.0);
        assert!(hi > lo + 2.5, "lo={lo} hi={hi}");
        assert!(hi <= 8.0 + 1e-9);
    }

    #[test]
    fn parallel_split_settles_faster_than_serial() {
        let p = p();
        let ser = p.clone().with_topology(DplTopology::SerialSplit);
        let par = p.clone().with_topology(DplTopology::ParallelSplit);
        let mut sums = vec![36.0; 32];
        for s in sums.iter_mut().skip(16) {
            *s = -36.0;
        }
        // At the parallel topology's short 1.5 ns timing, serial has much
        // larger residual error (§III.B: parallel needs only 1.5 ns).
        let es = (dp_phase(&ser, &sums, 32, 1.5e-9).v_dpl
            - dp_phase(&ser, &sums, 32, 1.5e-9).v_ideal)
            .abs();
        let ep = (dp_phase(&par, &sums, 32, 1.5e-9).v_dpl
            - dp_phase(&par, &sums, 32, 1.5e-9).v_ideal)
            .abs();
        assert!(es > ep * 3.0, "serial={es} parallel={ep}");
    }
}
