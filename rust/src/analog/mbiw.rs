//! Multi-bit input-and-weight (MBIW) accumulation unit (§III.C, Fig. 9).
//!
//! The MBIW realizes the paper's input-serial, weight-parallel scheme with
//! nothing but capacitive charge sharing:
//!
//! * **Input accumulation** (phases 1–2): the DP result of each input
//!   bitplane is merged into the accumulation capacitance C_acc with
//!   attenuation α_mb ≈ ½ per cycle, so after r_in LSB-first cycles the
//!   bitplanes carry binary weights (Eq. 5):
//!   `V_acc = V_DDL + α_eff·V_DDL · Σ_k (½)^(r_in−k) · S_k`.
//! * **Weight accumulation** (phases 3–4): the LSB column self-weights by
//!   sharing with a V_DDL-precharged node, then adjacent columns share
//!   pairwise LSB→MSB, producing Eq. 6's
//!   `V_MBIW = Σ_k (½)^(r_w−k) · V_DPL,k` on the MSB column.
//!
//! Non-idealities modelled (Fig. 10): leakage droop of V_acc over the
//! accumulation window, and signal-dependent charge injection from the
//! MOS transmission gates, whose error depends on both the incoming DP
//! voltage and the previously stored accumulation voltage (the 2-D map of
//! Fig. 10c with its zero-error curve).

use crate::config::params::MacroParams;

/// Leakage-induced voltage error on the accumulation node after holding
/// `v_acc` for `t_hold` seconds (Fig. 10a). The droop pulls the node back
/// toward V_DDL; it is negligible near mid-rail and grows exponentially
/// toward the rails (subthreshold conduction of the access devices).
pub fn leakage_error(p: &MacroParams, v_acc: f64, t_hold: f64) -> f64 {
    let dv = v_acc - p.supply.vddl;
    let v_t = 0.05; // subthreshold slope-ish fitting constant [V]
    let i = p.i_leak0 * p.corner.leakage() * ((dv.abs() / v_t).exp() - 1.0);
    -dv.signum() * i * t_hold / p.c_acc()
}

/// Charge-injection error added to V_acc when the ACC_in transmission gate
/// opens after a share (Fig. 10b/c). The gate's channel charge and its
/// gate-drain overlap capacitance split as a function of both terminal
/// voltages, giving an error surface over (V_in, V_acc_prev) whose
/// zero-error locus is the curve highlighted in Fig. 10c.
pub fn injection_error(p: &MacroParams, v_in: f64, v_acc_prev: f64) -> f64 {
    let v_mid = p.supply.vddh / 2.0;
    let di = v_in - v_mid;
    let da = v_acc_prev - v_mid;
    // Corner dependence: Vt shift changes the channel charge at switch-off.
    let vt_gain = 1.0 + p.corner.vt_shift() / 0.12;
    // Linear terms of opposite sign + a bilinear term produce the curved
    // zero-error locus; coefficients fitted so the worst case stays within
    // ±1 LSB of an 8b ADC (paper: "reaches up to +/-1 LSB").
    p.inj_k * vt_gain * (di - 0.75 * da + 2.2 * di * da / 0.4)
}

/// One input-accumulation share: merge the DP-phase voltage `v_dp` into the
/// stored `v_acc_prev` with ratio α_mb, including charge injection (and
/// leaving leakage to be applied once over the full window by the caller).
pub fn accumulate_input(p: &MacroParams, v_acc_prev: f64, v_dp: f64) -> f64 {
    let a = p.alpha_mb();
    let shared = a * v_acc_prev + (1.0 - a) * v_dp;
    shared + injection_error(p, v_dp, v_acc_prev)
}

/// Full input-serial accumulation over `r_in` bitplane DP voltages
/// (`v_dp[k]`, k = 0 is the LSB), starting from the V_DDL precharge.
/// Binary inputs (r_in = 1) bypass the accumulator entirely (§III.C).
pub fn input_accumulation(p: &MacroParams, v_dp: &[f64]) -> f64 {
    assert!(!v_dp.is_empty() && v_dp.len() <= 8);
    if v_dp.len() == 1 {
        return v_dp[0];
    }
    let mut v_acc = p.supply.vddl;
    for &v in v_dp {
        v_acc = accumulate_input(p, v_acc, v);
    }
    // Leakage integrates over the whole multi-bit window.
    v_acc + leakage_error(p, v_acc, p.t_leak)
}

/// Ideal input accumulation (α_mb exactly ½, no injection, no leakage) —
/// the golden reference for Eq. 5.
pub fn input_accumulation_ideal(vddl: f64, v_dp: &[f64]) -> f64 {
    if v_dp.len() == 1 {
        return v_dp[0];
    }
    let mut v_acc = vddl;
    for &v in v_dp {
        v_acc = 0.5 * v_acc + 0.5 * v;
    }
    v_acc
}

/// Weight accumulation across a block of `r_w` adjacent columns
/// (phases 3–4). `v_cols[k]` is the accumulated voltage of the column
/// holding weight bit k (k = 0 is the LSB). Returns the MSB-column DPL
/// voltage implementing Eq. 6. Each share injects a (small) gate error.
pub fn weight_accumulation(p: &MacroParams, v_cols: &[f64]) -> f64 {
    assert!(!v_cols.is_empty() && v_cols.len() <= 4);
    if v_cols.len() == 1 {
        return v_cols[0];
    }
    // Phase 3: LSB self-weighting against a V_DDL-precharged node.
    let mut v = 0.5 * (v_cols[0] + p.supply.vddl);
    v += injection_error(p, v_cols[0], p.supply.vddl) * 0.5;
    // Phase 4: pairwise sharing LSB → MSB.
    for &v_next in &v_cols[1..] {
        let prev = v;
        v = 0.5 * (v + v_next);
        v += injection_error(p, v_next, prev) * 0.5;
    }
    v
}

/// Ideal Eq. 6: V = Σ_k (½)^(r_w−k) V_k, plus the V_DDL DC term that keeps
/// the mid-rail reference in place.
pub fn weight_accumulation_ideal(vddl: f64, v_cols: &[f64]) -> f64 {
    let r_w = v_cols.len() as u32;
    if r_w == 1 {
        return v_cols[0];
    }
    let mut v = vddl;
    for (k, &vk) in v_cols.iter().enumerate() {
        let w = 0.5f64.powi((r_w - k as u32) as i32);
        v += w * (vk - vddl);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{Corner, MacroParams};

    fn quiet(p: &MacroParams) -> MacroParams {
        // Disable non-idealities to isolate the ideal recurrences.
        let mut q = p.clone();
        q.inj_k = 0.0;
        q.i_leak0 = 0.0;
        q
    }

    #[test]
    fn ideal_input_accumulation_matches_closed_form() {
        // After r_in shares, bitplane k carries weight (½)^(r_in−k) and the
        // DC stays at V_DDL: V = V_DDL + Σ (½)^(r_in−k) (v_k − V_DDL).
        let vddl = 0.4;
        let v_dp = [0.45, 0.38, 0.52, 0.41];
        let got = input_accumulation_ideal(vddl, &v_dp);
        let r_in = v_dp.len() as u32;
        let want: f64 = vddl
            + v_dp
                .iter()
                .enumerate()
                .map(|(k, &v)| 0.5f64.powi((r_in - k as u32) as i32) * (v - vddl))
                .sum::<f64>();
        assert!((got - want).abs() < 1e-12, "got={got} want={want}");
    }

    #[test]
    fn quiet_model_equals_ideal_up_to_alpha_imbalance() {
        let p = quiet(&MacroParams::paper());
        let v_dp = [0.42, 0.39, 0.47, 0.36, 0.44, 0.40, 0.41, 0.43];
        let got = input_accumulation(&p, &v_dp);
        let ideal = input_accumulation_ideal(p.supply.vddl, &v_dp);
        // α_mb deviates from ½ by <1% (§III.C) → small but nonzero gap.
        assert!((got - ideal).abs() < 2e-3, "got={got} ideal={ideal}");
    }

    #[test]
    fn binary_input_bypasses_accumulator() {
        let p = MacroParams::paper();
        assert_eq!(input_accumulation(&p, &[0.47]), 0.47);
    }

    #[test]
    fn leakage_negligible_midrail_grows_at_extremes() {
        let p = MacroParams::paper().with_corner(Corner::Ff);
        let near = leakage_error(&p, p.supply.vddl + 0.01, p.t_leak).abs();
        let far = leakage_error(&p, p.supply.vddl + 0.20, p.t_leak).abs();
        assert!(near < 10e-6, "near={near}");
        assert!(far > 20.0 * near, "far={far} near={near}");
        // Droop pulls back toward V_DDL.
        assert!(leakage_error(&p, p.supply.vddl + 0.2, p.t_leak) < 0.0);
        assert!(leakage_error(&p, p.supply.vddl - 0.2, p.t_leak) > 0.0);
    }

    #[test]
    fn injection_error_bounded_by_one_lsb() {
        // Paper: accumulation error reaches up to ±1 LSB of an 8b ADC.
        let lsb = MacroParams::paper().adc_lsb(8, 1.0);
        for corner in Corner::ALL {
            let p = MacroParams::paper().with_corner(corner);
            let mut worst = 0.0f64;
            for i in 0..20 {
                for a in 0..20 {
                    let v_in = 0.2 + 0.4 * i as f64 / 19.0;
                    let v_acc = 0.2 + 0.4 * a as f64 / 19.0;
                    worst = worst.max(injection_error(&p, v_in, v_acc).abs());
                }
            }
            assert!(worst < 1.2 * lsb, "{corner:?}: worst={worst} lsb={lsb}");
            assert!(worst > 0.05 * lsb, "{corner:?}: error unrealistically small");
        }
    }

    #[test]
    fn injection_zero_error_curve_exists() {
        // Fig. 10c: a locus of (v_in, v_acc) pairs with zero error crosses
        // the map — check a sign change along a diagonal sweep.
        let p = MacroParams::paper();
        let mut signs = Vec::new();
        for t in 0..40 {
            let v_in = 0.25 + 0.3 * t as f64 / 39.0;
            let v_acc = 0.55 - 0.3 * t as f64 / 39.0;
            signs.push(injection_error(&p, v_in, v_acc) > 0.0);
        }
        assert!(signs.iter().any(|&s| s) && signs.iter().any(|&s| !s));
    }

    #[test]
    fn weight_accumulation_matches_eq6() {
        let p = quiet(&MacroParams::paper());
        let vddl = p.supply.vddl;
        let v_cols = [0.43, 0.37, 0.45, 0.50];
        let got = weight_accumulation(&p, &v_cols);
        let want = weight_accumulation_ideal(vddl, &v_cols);
        assert!((got - want).abs() < 1e-12, "got={got} want={want}");
        // MSB dominates: perturbing the MSB moves the output 4× more than
        // perturbing weight bit 1 (2^2 ratio at r_w = 4... check ratios).
        let mut v2 = v_cols;
        v2[3] += 0.01;
        let d_msb = weight_accumulation(&p, &v2) - got;
        let mut v3 = v_cols;
        v3[1] += 0.01;
        let d_b1 = weight_accumulation(&p, &v3) - got;
        assert!((d_msb / d_b1 - 4.0).abs() < 1e-9, "ratio={}", d_msb / d_b1);
    }

    #[test]
    fn single_column_weight_is_identity() {
        let p = MacroParams::paper();
        assert_eq!(weight_accumulation(&p, &[0.44]), 0.44);
    }

    #[test]
    fn range_compression_is_halved_per_pairwise_share() {
        // Pairwise sharing (vs all-at-once) preserves the MSB at weight ½;
        // verify the MSB weight equals 0.5 regardless of r_w.
        let p = quiet(&MacroParams::paper());
        for r_w in 2..=4 {
            let base = vec![p.supply.vddl; r_w];
            let mut bumped = base.clone();
            bumped[r_w - 1] += 0.1;
            let d = weight_accumulation(&p, &bumped) - weight_accumulation(&p, &base);
            assert!((d - 0.05).abs() < 1e-12, "r_w={r_w} d={d}");
        }
    }
}
