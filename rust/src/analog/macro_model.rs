//! End-to-end behavioral model of the 1152×256 CIM-SRAM macro (§III,
//! Fig. 5): the four-phase operation flow — per-bitplane charge-domain DP,
//! MBIW input accumulation, inter-column weight accumulation, and DSCI-ADC
//! conversion with ABN — on one continuous capacitor network.
//!
//! The model has two fidelity settings:
//! * **ideal** (no mismatch, no noise, settled timing) — must agree with
//!   the closed-form contract used by the python oracle (`ideal_code`);
//! * **sampled** (per-die mismatch + temporal noise + corner + finite
//!   T_DP) — reproduces the paper's measured artefacts.
//!
//! ### Functional contract (ideal path)
//!
//! With unsigned r_in-bit inputs X_i, antipodal weight bits s_{i,k} and
//! M = 2^r_in − 1, the MBIW voltage is
//!
//! ```text
//! ΔV = α_eff · V_DDL · Σ_i (2·X_i − M) · W_i / 2^(r_in' + r_w')
//!      W_i = Σ_k 2^k s_{i,k},    r' = r if r > 1 else 0 (bypass)
//! ```
//!
//! and the output code follows Eq. 7. The bypasses express §III.C: binary
//! inputs skip the input accumulator, binary weights skip the column
//! share, each preserving a 2× voltage swing.

use crate::analog::adc::DsciAdc;
use crate::analog::bitcell::BitcellArray;
use crate::analog::dpl;
use crate::analog::ladder::Ladder;
use crate::analog::mbiw;
use crate::config::params::MacroParams;
use crate::util::rng::Rng;

/// Per-operation configuration of the macro (precision, gain, array split).
#[derive(Clone, Copy, Debug)]
pub struct OpConfig {
    /// Input precision r_in ∈ 1..=8 (bit-serial).
    pub r_in: u32,
    /// Weight precision r_w ∈ 1..=4 (columns per block used).
    pub r_w: u32,
    /// Output (ADC) precision r_out ∈ 1..=8.
    pub r_out: u32,
    /// ABN gain γ (ladder zoom), 1..=32.
    pub gamma: f64,
    /// Connected serial-split DP units (1..=32); `units_for_cin` helps.
    pub connected_units: usize,
    /// Single-bit DP duration [s].
    pub t_dp: f64,
}

impl OpConfig {
    pub fn new(r_in: u32, r_w: u32, r_out: u32) -> Self {
        Self {
            r_in,
            r_w,
            r_out,
            gamma: 1.0,
            connected_units: 32,
            t_dp: 5e-9,
        }
    }

    pub fn with_gamma(mut self, g: f64) -> Self {
        self.gamma = g;
        self
    }

    pub fn with_units(mut self, u: usize) -> Self {
        self.connected_units = u;
        self
    }

    pub fn with_t_dp(mut self, t: f64) -> Self {
        self.t_dp = t;
        self
    }

    pub fn validate(&self, p: &MacroParams) {
        assert!((1..=8).contains(&self.r_in), "r_in out of range");
        assert!(
            (1..=p.cols_per_block as u32).contains(&self.r_w),
            "r_w out of range"
        );
        assert!((1..=8).contains(&self.r_out), "r_out out of range");
        assert!(self.gamma >= 1.0 && self.gamma <= 32.0, "gamma out of range");
        assert!(
            (1..=p.n_units()).contains(&self.connected_units),
            "connected_units out of range"
        );
    }

    /// Rows active under this configuration.
    pub fn active_rows(&self, p: &MacroParams) -> usize {
        p.rows_for_units(self.connected_units)
    }
}

/// The simulated macro instance (one die).
#[derive(Clone, Debug)]
pub struct CimMacro {
    pub p: MacroParams,
    pub cells: BitcellArray,
    pub adcs: Vec<DsciAdc>,
    pub ladder: Ladder,
    /// Enable temporal noise (kT/C + SA decision noise).
    pub noise: bool,
    rng: Rng,
}

impl CimMacro {
    /// Fabricate a die: draw all static mismatch from `seed`.
    pub fn new(p: MacroParams, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let cells = BitcellArray::new(&p, &mut rng);
        let adcs = (0..p.n_cols)
            .map(|c| DsciAdc::sample(&p, &mut rng.fork(0x5A00 + c as u64)))
            .collect();
        let ladder = Ladder::sample(&p, &mut rng.fork(0x1ADD));
        Self {
            p,
            cells,
            adcs,
            ladder,
            noise: true,
            rng: rng.fork(0x7E3),
        }
    }

    /// Ideal die: no mismatch, no noise. Used as the golden model and by
    /// the HLO-equivalence integration test.
    pub fn ideal(p: MacroParams) -> Self {
        let cells = BitcellArray::ideal(p.n_rows, p.n_cols);
        let adcs = (0..p.n_cols).map(|_| DsciAdc::ideal()).collect();
        let ladder = Ladder::ideal(&p);
        Self {
            p,
            cells,
            adcs,
            ladder,
            noise: false,
            rng: Rng::new(0),
        }
    }

    /// Also zero out the deterministic non-idealities (injection, leakage,
    /// settling) — the macro then matches `ideal_code` exactly.
    pub fn idealize_physics(&mut self) {
        self.p.inj_k = 0.0;
        self.p.i_leak0 = 0.0;
        self.p.alpha_mb_imbalance = 0.0; // α_mb exactly ½
        self.p.tau_tg = 1e-15; // instant settling
    }

    /// Calibrate every column ADC (§III.E). Returns per-column residual
    /// offsets [V].
    pub fn calibrate_all(&mut self) -> Vec<f64> {
        let p = self.p.clone();
        let noise = self.noise;
        let rng = self.rng.fork(0xCA1);
        self.adcs
            .iter_mut()
            .enumerate()
            .map(|(c, adc)| {
                let mut r = rng.fork(c as u64);
                adc.calibrate(&p, if noise { Some(&mut r) } else { None })
            })
            .collect()
    }

    /// Load signed integer weights for `r_w`-bit blocks. `w[row][outcol]`
    /// with `outcol < n_blocks`, values must be representable as
    /// Σ ±2^k over r_w antipodal bits, i.e. `2B − (2^r_w − 1)` for
    /// B ∈ [0, 2^r_w): odd integers in [−(2^r_w −1), 2^r_w −1].
    pub fn load_weights(&mut self, w: &[i32], n_out: usize, r_w: u32) {
        assert!(n_out <= self.p.n_blocks());
        assert_eq!(w.len() % n_out, 0);
        let rows = w.len() / n_out;
        assert!(rows <= self.p.n_rows);
        let max = (1i32 << r_w) - 1;
        for row in 0..rows {
            for oc in 0..n_out {
                let v = w[row * n_out + oc];
                assert!(
                    v.abs() <= max && (v + max) % 2 == 0,
                    "weight {v} not representable with r_w={r_w} antipodal bits"
                );
                let b = ((v + max) / 2) as u32; // offset-binary magnitude
                for k in 0..r_w {
                    let bit = ((b >> k) & 1) as u8;
                    self.cells
                        .set_weight(row, oc * self.p.cols_per_block + k as usize, bit);
                }
            }
        }
    }

    /// Load the same signed weight column into the first `n_out` blocks
    /// (characterization sweeps drive many blocks with one pattern).
    pub fn load_weights_broadcast(&mut self, col: &[i32], n_out: usize, r_w: u32) {
        let rows = col.len();
        let mut w = vec![0i32; rows * n_out];
        for (r, &v) in col.iter().enumerate() {
            for oc in 0..n_out {
                w[r * n_out + oc] = v;
            }
        }
        self.load_weights(&w, n_out, r_w);
    }

    /// Per-unit signed sums for one column and one (bipolar f32) bitplane.
    /// Single fused pass over the column's signed-factor slice with
    /// fixed-width chunks — the hottest loop of every characterization
    /// sweep (see EXPERIMENTS.md §Perf).
    fn unit_sums(&self, col: usize, sx: &[f32], cfg: &OpConfig) -> Vec<f64> {
        let upr = self.p.rows_per_unit;
        let sc = self.cells.column_signed(col, cfg.connected_units * upr);
        let mut sums = Vec::with_capacity(cfg.connected_units);
        for (cx, cc) in sx.chunks_exact(upr).zip(sc.chunks_exact(upr)) {
            let mut s = 0.0f32;
            for i in 0..upr {
                s += cx[i] * cc[i];
            }
            sums.push(s as f64);
        }
        sums
    }

    /// One single-bit DP phase voltage on `col` for bipolar bitplane `sx`.
    fn dp_voltage(&mut self, col: usize, sx: &[f32], cfg: &OpConfig) -> f64 {
        let sums = self.unit_sums(col, sx, cfg);
        let r = dpl::dp_phase(&self.p, &sums, cfg.connected_units, cfg.t_dp);
        let mut v = r.v_dpl;
        if self.noise {
            let rows = cfg.active_rows(&self.p);
            let alpha = self.p.alpha_eff(rows);
            // Aggregated bitcell kT/C (attenuated) + DPL sampling noise.
            let sigma_cells = self.p.v_noise_cell * alpha * (rows as f64).sqrt();
            let c_tot = rows as f64 * self.p.c_c
                + self.p.c_p_per_row * rows as f64
                + self.p.c_load;
            let sigma_dpl = MacroParams::ktc_sigma(c_tot);
            v += self.rng.normal(0.0, (sigma_cells.powi(2) + sigma_dpl.powi(2)).sqrt());
        }
        v
    }

    /// Pre-expand the bit-serial input into bipolar f32 bitplanes (shared
    /// by every column and block of one macro operation).
    pub fn expand_bitplanes(x: &[u8], r_in: u32) -> Vec<Vec<f32>> {
        (0..r_in)
            .map(|b| {
                x.iter()
                    .map(|&xv| (2 * ((xv >> b) & 1) as i32 - 1) as f32)
                    .collect()
            })
            .collect()
    }

    /// Full four-phase operation of one MBIW block. `x[r]` is the unsigned
    /// r_in-bit input of active row r (length = cfg.active_rows()).
    /// Returns the ADC code from the block's MSB column.
    pub fn block_op(&mut self, block: usize, x: &[u8], cfg: &OpConfig) -> u32 {
        let planes = Self::expand_bitplanes(x, cfg.r_in);
        self.block_op_planes(block, &planes, x.len(), cfg)
    }

    /// `block_op` with pre-expanded bitplanes (the matvec fast path).
    pub fn block_op_planes(
        &mut self,
        block: usize,
        bitplanes: &[Vec<f32>],
        x_len: usize,
        cfg: &OpConfig,
    ) -> u32 {
        cfg.validate(&self.p);
        let rows = cfg.active_rows(&self.p);
        assert_eq!(x_len, rows, "input length != active rows");
        let mut v_cols = Vec::with_capacity(cfg.r_w as usize);
        for k in 0..cfg.r_w as usize {
            let col = block * self.p.cols_per_block + k;
            // Phases 1–2: bit-serial DP + input accumulation (LSB first).
            let mut v_dp = Vec::with_capacity(cfg.r_in as usize);
            for bits in bitplanes {
                v_dp.push(self.dp_voltage(col, bits, cfg));
            }
            v_cols.push(mbiw::input_accumulation(&self.p, &v_dp));
        }
        // Phases 3–4: inter-column weight accumulation onto the MSB DPL.
        let v_mbiw = mbiw::weight_accumulation(&self.p, &v_cols);

        // ADC conversion with ABN gain/offset on the MSB column's DSCI.
        let adc_col = block * self.p.cols_per_block + (cfg.r_w as usize - 1);
        let adc = self.adcs[adc_col].clone();
        let salt = self.rng.next_u64();
        let mut rng = self.rng.fork(0xADC0 + adc_col as u64 ^ salt);
        let noise_rng = if self.noise { Some(&mut rng) } else { None };
        adc.convert(&self.p, &self.ladder, v_mbiw, cfg.gamma, cfg.r_out, noise_rng)
    }

    /// Matrix-vector product over the first `n_out` blocks. Bitplanes are
    /// expanded once and shared across all blocks.
    pub fn matvec(&mut self, x: &[u8], n_out: usize, cfg: &OpConfig) -> Vec<u32> {
        assert!(n_out <= self.p.n_blocks());
        debug_assert!(x.iter().all(|&v| (v as u32) < (1u32 << cfg.r_in)));
        let planes = Self::expand_bitplanes(x, cfg.r_in);
        (0..n_out)
            .map(|blk| self.block_op_planes(blk, &planes, x.len(), cfg))
            .collect()
    }

    /// Closed-form ideal output code for signed weights `w[row]` of one
    /// output (see module docs) — the golden contract shared with
    /// `python/compile/kernels/ref.py`.
    pub fn ideal_code(
        p: &MacroParams,
        x: &[u8],
        w: &[i32],
        cfg: &OpConfig,
    ) -> u32 {
        assert_eq!(x.len(), w.len());
        let rows = cfg.active_rows(p);
        assert_eq!(x.len(), rows);
        let m = (1i64 << cfg.r_in) - 1;
        let dot: i64 = x
            .iter()
            .zip(w)
            .map(|(&xv, &wv)| (2 * xv as i64 - m) * wv as i64)
            .sum();
        let rin_eff = if cfg.r_in > 1 { cfg.r_in } else { 0 };
        let rw_eff = if cfg.r_w > 1 { cfg.r_w } else { 0 };
        let alpha = p.alpha_eff(rows);
        let dv = alpha * p.supply.vddl * dot as f64 / (1u64 << (rin_eff + rw_eff)) as f64;
        DsciAdc::ideal_code(p, dv, cfg.gamma, cfg.r_out)
    }

    /// The ΔV seen by the ADC for a given dot product (used by the energy
    /// model and by distribution analyses).
    pub fn ideal_dv(p: &MacroParams, dot: i64, cfg: &OpConfig) -> f64 {
        let rows = cfg.active_rows(p);
        let rin_eff = if cfg.r_in > 1 { cfg.r_in } else { 0 };
        let rw_eff = if cfg.r_w > 1 { cfg.r_w } else { 0 };
        p.alpha_eff(rows) * p.supply.vddl * dot as f64
            / (1u64 << (rin_eff + rw_eff)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::MacroParams;

    /// A fully-idealized macro for golden-contract tests.
    fn golden_macro(p: &MacroParams) -> CimMacro {
        let mut m = CimMacro::ideal(p.clone());
        m.idealize_physics();
        m
    }

    fn fill_inputs(rng: &mut Rng, rows: usize, r_in: u32) -> Vec<u8> {
        (0..rows).map(|_| rng.below(1 << r_in) as u8).collect()
    }

    fn fill_weights(rng: &mut Rng, rows: usize, r_w: u32) -> Vec<i32> {
        let max = (1i32 << r_w) - 1;
        (0..rows)
            .map(|_| 2 * rng.below(1 << r_w) as i32 - max)
            .collect()
    }

    #[test]
    fn golden_macro_matches_ideal_code_all_precisions() {
        let p = MacroParams::paper();
        let mut rng = Rng::new(77);
        for (r_in, r_w, r_out) in [(1, 1, 4), (2, 1, 6), (4, 2, 8), (8, 4, 8), (8, 1, 8)] {
            for units in [1usize, 4, 32] {
                let cfg = OpConfig::new(r_in, r_w, r_out)
                    .with_units(units)
                    .with_gamma(2.0);
                let mut m = golden_macro(&p);
                let rows = cfg.active_rows(&p);
                let x = fill_inputs(&mut rng, rows, r_in);
                let w = fill_weights(&mut rng, rows, r_w);
                // Load into block 0 with column padding beyond `rows` zeroed
                // weights... zero *bits* mean weight −1, so restrict the
                // comparison to exactly `rows` active rows (matching the
                // connected-units config — disconnected units don't inject).
                let mut m2 = m.clone();
                m2.load_weights(&w, 1, r_w);
                m = m2;
                let got = m.block_op(0, &x, &cfg);
                let want = CimMacro::ideal_code(&p, &x, &w, &cfg);
                assert!(
                    (got as i64 - want as i64).abs() <= 1,
                    "r_in={r_in} r_w={r_w} r_out={r_out} units={units}: got={got} want={want}"
                );
            }
        }
    }

    #[test]
    fn zero_input_zero_weight_centers_midcode() {
        // X at midscale against balanced ±1 weights → code near 2^(r_out−1).
        let p = MacroParams::paper();
        let cfg = OpConfig::new(8, 1, 8).with_units(4);
        let mut m = golden_macro(&p);
        let rows = cfg.active_rows(&p);
        let w: Vec<i32> = (0..rows).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        m.load_weights(&w, 1, 1);
        let x = vec![127u8; rows]; // ≈ M/2 each
        let code = m.block_op(0, &x, &cfg);
        assert!((code as i64 - 128).abs() <= 2, "code={code}");
    }

    #[test]
    fn matvec_runs_all_blocks() {
        let p = MacroParams::paper();
        let cfg = OpConfig::new(2, 1, 4).with_units(1);
        let mut m = CimMacro::new(p.clone(), 9);
        m.noise = false;
        let rows = cfg.active_rows(&p);
        let x = vec![1u8; rows];
        let out = m.matvec(&x, 16, &cfg);
        assert_eq!(out.len(), 16);
        assert!(out.iter().all(|&c| c < 16));
    }

    #[test]
    fn load_weights_rejects_unrepresentable() {
        let p = MacroParams::paper();
        let mut m = CimMacro::ideal(p);
        // 0 is even → not representable with r_w=1 (±1 only).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.load_weights(&[0], 1, 1);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn weight_encoding_roundtrip() {
        let p = MacroParams::paper();
        let mut m = CimMacro::ideal(p.clone());
        let w = [-15, -3, 1, 15, 7, -7, 5, -1];
        m.load_weights(&w, 2, 4); // 8/2 = 4 rows × 2 outputs
        // Decode back from bits and compare.
        for row in 0..4 {
            for oc in 0..2 {
                let mut b = 0u32;
                for k in 0..4 {
                    b |= (m.cells.weight(row, oc * 4 + k) as u32) << k;
                }
                let v = 2 * b as i32 - 15;
                assert_eq!(v, w[row * 2 + oc]);
            }
        }
    }

    #[test]
    fn noisy_die_stays_close_to_golden() {
        let p = MacroParams::paper();
        let cfg = OpConfig::new(4, 1, 8).with_units(4).with_gamma(1.0);
        let mut rng = Rng::new(123);
        let rows = cfg.active_rows(&p);
        let x = fill_inputs(&mut rng, rows, 4);
        let w = fill_weights(&mut rng, rows, 1);

        let mut die = CimMacro::new(p.clone(), 4242);
        die.load_weights(&w, 1, 1);
        die.calibrate_all();
        let want = CimMacro::ideal_code(&p, &x, &w, &cfg) as f64;
        let err: Vec<f64> = (0..30)
            .map(|_| die.block_op(0, &x, &cfg) as f64 - want)
            .collect();
        let rms = crate::util::stats::rms(&err);
        assert!(rms < 4.0, "rms={rms} LSB (post-cal should be few-LSB)");
    }

    #[test]
    fn gamma_expands_output_range_for_narrow_dp() {
        // The whole point of the DSCI ADC: a narrow DP distribution maps to
        // few codes at γ=1 and many at γ=8.
        let p = MacroParams::paper();
        let mut rng = Rng::new(5);
        let mut spread = |gamma: f64| {
            let cfg = OpConfig::new(4, 1, 8).with_units(2).with_gamma(gamma);
            let rows = cfg.active_rows(&p);
            let mut m = golden_macro(&p);
            let w = fill_weights(&mut rng, rows, 1);
            m.load_weights(&w, 1, 1);
            let mut codes = Vec::new();
            for _ in 0..40 {
                let x = fill_inputs(&mut rng, rows, 4);
                codes.push(m.block_op(0, &x, &cfg) as f64);
            }
            crate::util::stats::std(&codes)
        };
        let s1 = spread(1.0);
        let s8 = spread(8.0);
        assert!(s8 > 3.0 * s1, "σ(γ=1)={s1} σ(γ=8)={s8}");
    }
}
