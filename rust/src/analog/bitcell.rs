//! 10T1C bitcell array model (§III.B, Fig. 2b / Fig. 7).
//!
//! Each bitcell stores one binary weight bit `w ∈ {0,1}` acting as a ±1
//! factor, and couples to its column's dot-product line (DPL) through a
//! MoM capacitance C_c = 0.7 fF. The *analog XNOR* of the broadcast input
//! bit and the stored weight decides the polarity of the injected charge:
//!
//! ```text
//!   s = (2·x − 1) · (2·w − 1)   ∈ {−1, +1}
//! ```
//!
//! The array also owns the per-cell capacitor mismatch ε (device-to-device
//! variation of C_c, σ ≈ 0.2%), drawn once per simulated die.

use crate::config::params::MacroParams;
use crate::util::rng::Rng;

/// Weight storage + static per-die capacitor mismatch for the full
/// `n_rows × n_cols` array. Storage is row-major (`row * n_cols + col`).
#[derive(Clone, Debug)]
pub struct BitcellArray {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Weight bits, one byte per cell (0 or 1). Row-major.
    weights: Vec<u8>,
    /// Per-cell relative C_c mismatch (1 + eps). Row-major, f32 to halve
    /// the footprint (1152×256 cells).
    cap_eps: Vec<f32>,
    /// Hot-path cache: signed mismatch-weighted factor per cell,
    /// `(2w−1)·(1+ε)` — kept in sync by every weight write so the DP
    /// inner loop is one multiply-add per cell. Stored COLUMN-major
    /// (`col · n_rows + row`) so a per-unit sum reads contiguously.
    signed: Vec<f32>,
}

impl BitcellArray {
    /// Build an array with all-zero weights and per-die mismatch drawn
    /// from `rng` (σ = `params.cap_mismatch`).
    pub fn new(params: &MacroParams, rng: &mut Rng) -> Self {
        let n = params.n_rows * params.n_cols;
        let cap_eps: Vec<f32> = (0..n)
            .map(|_| (rng.gaussian() * params.cap_mismatch) as f32)
            .collect();
        // Column-major signed cache: cell (r, c) at signed[c·n_rows + r].
        let (nr, nc) = (params.n_rows, params.n_cols);
        let mut signed = vec![0f32; n];
        for c in 0..nc {
            for r in 0..nr {
                signed[c * nr + r] = -(1.0 + cap_eps[r * nc + c]);
            }
        }
        Self {
            n_rows: nr,
            n_cols: nc,
            weights: vec![0u8; n],
            cap_eps,
            signed,
        }
    }

    /// Ideal array (no mismatch) — used by golden-model tests.
    pub fn ideal(n_rows: usize, n_cols: usize) -> Self {
        Self {
            n_rows,
            n_cols,
            weights: vec![0u8; n_rows * n_cols],
            cap_eps: vec![0.0; n_rows * n_cols],
            signed: vec![-1.0; n_rows * n_cols],
        }
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.n_rows && col < self.n_cols);
        row * self.n_cols + col
    }

    #[inline]
    pub fn weight(&self, row: usize, col: usize) -> u8 {
        self.weights[self.idx(row, col)]
    }

    #[inline]
    pub fn set_weight(&mut self, row: usize, col: usize, w: u8) {
        debug_assert!(w <= 1);
        let i = self.idx(row, col);
        self.weights[i] = w;
        self.signed[col * self.n_rows + row] =
            (2.0 * w as f32 - 1.0) * (1.0 + self.cap_eps[i]);
    }

    /// Write a whole column from a bit slice (SRAM R/W interface).
    pub fn write_column(&mut self, col: usize, bits: &[u8]) {
        assert!(bits.len() <= self.n_rows, "column write overflows array");
        for (row, &b) in bits.iter().enumerate() {
            self.set_weight(row, col, b);
        }
    }

    /// Write the full array from a row-major bit matrix.
    pub fn write_all(&mut self, bits: &[u8]) {
        assert_eq!(bits.len(), self.weights.len());
        for (i, &b) in bits.iter().enumerate() {
            debug_assert!(b <= 1);
            self.weights[i] = b;
            let (r, c) = (i / self.n_cols, i % self.n_cols);
            self.signed[c * self.n_rows + r] =
                (2.0 * b as f32 - 1.0) * (1.0 + self.cap_eps[i]);
        }
    }

    #[inline]
    pub fn cap_eps(&self, row: usize, col: usize) -> f64 {
        self.cap_eps[self.idx(row, col)] as f64
    }

    /// Signed XNOR contribution of one cell for input bit `x`:
    /// s·(1+ε) with s = (2x−1)(2w−1).
    #[inline]
    pub fn contribution(&self, row: usize, col: usize, x: u8) -> f64 {
        let i = self.idx(row, col);
        let s = ((2 * x as i32 - 1) * (2 * self.weights[i] as i32 - 1)) as f64;
        s * (1.0 + self.cap_eps[i] as f64)
    }

    /// Partial signed sum over a contiguous row range of one column for a
    /// given input bitplane. `bits[r]` is the broadcast input bit of row
    /// `rows.start + r`. This is the per-DP-unit quantity the settling
    /// model needs (charge injected by one 36-row unit).
    ///
    /// Hot path of every characterization sweep: uses the cached signed
    /// factors — `(2x−1)·(2w−1)(1+ε)` is `±signed[i]` — in a branchless
    /// strided loop the compiler vectorizes.
    pub fn unit_sum(&self, col: usize, row_start: usize, bits: &[u8]) -> f64 {
        let base = col * self.n_rows + row_start;
        let sc = &self.signed[base..base + bits.len()];
        let mut s = 0.0f32;
        for (&x, &f) in bits.iter().zip(sc) {
            // x ∈ {0,1}: (2x−1) flips the sign.
            s += (2 * x as i32 - 1) as f32 * f;
        }
        s as f64
    }

    /// Contiguous signed-factor slice of one column's first `rows` cells
    /// (column-major cache) — lets callers fuse multi-unit reductions.
    pub fn column_signed(&self, col: usize, rows: usize) -> &[f32] {
        let base = col * self.n_rows;
        &self.signed[base..base + rows]
    }

    /// Vectorizable variant: `sx[r] ∈ {−1.0, +1.0}` is the pre-expanded
    /// bipolar input bit; the loop is a plain f32 dot product.
    pub fn unit_sum_f32(&self, col: usize, row_start: usize, sx: &[f32]) -> f64 {
        let base = col * self.n_rows + row_start;
        let sc = &self.signed[base..base + sx.len()];
        let mut acc = [0.0f32; 8];
        let chunks = sx.len() / 8;
        for i in 0..chunks {
            for lane in 0..8 {
                let j = i * 8 + lane;
                acc[lane] += sx[j] * sc[j];
            }
        }
        let mut s: f32 = acc.iter().sum();
        for j in chunks * 8..sx.len() {
            s += sx[j] * sc[j];
        }
        s as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::MacroParams;

    #[test]
    fn xnor_polarity() {
        let mut a = BitcellArray::ideal(4, 2);
        a.set_weight(0, 0, 1);
        // x=1, w=1 → +1 ; x=0, w=1 → −1 ; x=1, w=0 → −1 ; x=0, w=0 → +1
        assert_eq!(a.contribution(0, 0, 1), 1.0);
        assert_eq!(a.contribution(0, 0, 0), -1.0);
        assert_eq!(a.contribution(1, 0, 1), -1.0);
        assert_eq!(a.contribution(1, 0, 0), 1.0);
    }

    #[test]
    fn unit_sum_matches_manual() {
        let mut a = BitcellArray::ideal(8, 1);
        for r in 0..4 {
            a.set_weight(r, 0, 1);
        }
        // rows 0..4 have w=1, rows 4..8 w=0; input all-ones bitplane.
        let bits = vec![1u8; 8];
        let s = a.unit_sum(0, 0, &bits);
        assert_eq!(s, 4.0 - 4.0);
        let s_lo = a.unit_sum(0, 0, &bits[..4]);
        assert_eq!(s_lo, 4.0);
    }

    #[test]
    fn mismatch_is_small_and_per_die() {
        let p = MacroParams::paper();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let a = BitcellArray::new(&p, &mut r1);
        let b = BitcellArray::new(&p, &mut r2);
        assert!(a.cap_eps(0, 0).abs() < 0.02);
        assert_ne!(a.cap_eps(0, 0), b.cap_eps(0, 0));
    }

    #[test]
    fn write_column_and_all() {
        let mut a = BitcellArray::ideal(4, 4);
        a.write_column(2, &[1, 0, 1, 1]);
        assert_eq!(a.weight(0, 2), 1);
        assert_eq!(a.weight(1, 2), 0);
        assert_eq!(a.weight(3, 2), 1);
        let bits = vec![1u8; 16];
        a.write_all(&bits);
        assert_eq!(a.weight(3, 3), 1);
    }
}
