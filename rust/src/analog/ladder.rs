//! Gain-adaptive resistive reference ladder (§III.D, Fig. 11b).
//!
//! The DSCI ADC's S-IN(b) levels are tapped from a double-sided resistive
//! ladder activated during conversion (≈1 mA for 5 ns settling). The ABN
//! gain γ is realized by *downscaling* all S-IN levels by 1/γ — the ADC
//! "zoom" — so no explicit amplifier touches the floating DPL.
//!
//! Imperfections modelled:
//! * per-tap mismatch of the ladder resistors (static per die), whose
//!   *absolute* voltage error is roughly constant — so its impact in LSB
//!   grows ∝ γ (the Fig. 13 INL/DNL-vs-γ trend);
//! * a deterministic bow from the ladder's series parasitic resistance;
//! * a finite minimum step of V_DDH/32: MSB-array gains above 16 cannot
//!   be generated exactly and truncate (lost-LSB regime, §III.D).

use crate::config::params::MacroParams;
use crate::util::rng::Rng;

/// A fabricated ladder instance shared by all 256 column ADCs.
#[derive(Clone, Debug)]
pub struct Ladder {
    /// Per-bit relative tap error (static mismatch), MSB-first, 8 entries.
    pub tap_eps: Vec<f64>,
    /// Deterministic bow amplitude (fraction of tap voltage).
    pub bow: f64,
    /// Minimum realizable tap step [V].
    pub min_step: f64,
    /// Maximum MSB-array gain (16).
    pub max_msb_gain: f64,
}

impl Ladder {
    pub fn sample(p: &MacroParams, rng: &mut Rng) -> Self {
        let tap_eps = (0..8).map(|_| rng.normal(0.0, p.ladder_mismatch)).collect();
        Self {
            tap_eps,
            bow: 0.0025,
            min_step: p.supply.vddh / p.ladder_min_step_div,
            max_msb_gain: p.max_msb_gain,
        }
    }

    pub fn ideal(p: &MacroParams) -> Self {
        Self {
            tap_eps: vec![0.0; 8],
            bow: 0.0,
            min_step: p.supply.vddh / p.ladder_min_step_div,
            max_msb_gain: p.max_msb_gain,
        }
    }

    /// Reference injection voltage for SAR bit `b` (b = r_out−1 is the
    /// MSB) at gain `gamma`, for an `r_out`-bit conversion.
    ///
    /// Ideal value: α_adc · V_DDH / γ · 2^b / 2^(r_out−1) / 2
    /// (half-step of the remaining search interval, referred through the
    /// SAR attenuation). Above the MSB-array gain limit the extra zoom is
    /// produced by the LSB split-array's downscaled swing; past the
    /// ladder's min-step resolution the level quantizes.
    pub fn sar_step(&self, p: &MacroParams, r_out: u32, gamma: f64, b: u32) -> f64 {
        assert!(b < r_out && r_out <= 8);
        let ideal = p.alpha_adc() * p.supply.vddh / gamma * (1u64 << b) as f64
            / (1u64 << (r_out - 1)) as f64
            / 2.0;
        // γ = 1 MSB taps connect straight to the rails (§V.A: unity gain
        // bypasses the ladder for the MSBs) → no mismatch there.
        let rail_direct = gamma <= 1.0 && b >= r_out.saturating_sub(2);
        let eps = if rail_direct { 0.0 } else { self.tap_eps[(7 - b.min(7)) as usize] };
        // Parasitic-R bow: worst mid-ladder, scaled by how deep into the
        // ladder this tap sits (finer taps sit further from the supplies).
        let depth = 1.0 - (1u64 << b) as f64 / (1u64 << (r_out - 1)) as f64 / 2.0;
        let bow_err = self.bow * depth * depth;
        // Min-step truncation: levels below the ladder's resolution (after
        // the LSB split-array's fixed ÷4 swing reduction) collapse.
        let lsb_split_div = 4.0;
        let resolvable = self.min_step / lsb_split_div / 8.0;
        let mut v = ideal * (1.0 + eps + bow_err);
        if v < resolvable {
            // Quantize harshly — the "lost LSB information above γ=8..16".
            v = (v / (resolvable / 2.0)).round() * (resolvable / 2.0);
        }
        v
    }

    /// DC current drawn while active [A] (§III.D: 1 mA to settle in 5 ns).
    pub fn active_current(&self) -> f64 {
        1.0e-3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::MacroParams;

    #[test]
    fn steps_are_binary_weighted_at_unity_gain() {
        let p = MacroParams::paper();
        let l = Ladder::ideal(&p);
        let s7 = l.sar_step(&p, 8, 1.0, 7);
        let s6 = l.sar_step(&p, 8, 1.0, 6);
        let s0 = l.sar_step(&p, 8, 1.0, 0);
        assert!((s7 / s6 - 2.0).abs() < 1e-9);
        assert!((s7 / s0 - 128.0).abs() < 1e-6);
        // MSB step is half the (attenuated) half-range ±α_adc·V_DDH.
        assert!((s7 - p.alpha_adc() * p.supply.vddh / 2.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_compresses_steps() {
        let p = MacroParams::paper();
        let l = Ladder::ideal(&p);
        let s_g1 = l.sar_step(&p, 8, 1.0, 7);
        let s_g4 = l.sar_step(&p, 8, 4.0, 7);
        assert!((s_g1 / s_g4 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn high_gamma_fine_steps_quantize() {
        let p = MacroParams::paper();
        let l = Ladder::ideal(&p);
        // At γ=32, the LSB steps fall below the ladder resolution and
        // quantize — relative error of the bottom bit becomes large.
        let ideal = p.alpha_adc() * p.supply.vddh / 32.0 / 128.0 / 2.0;
        let got = l.sar_step(&p, 8, 32.0, 0);
        let rel = (got - ideal).abs() / ideal;
        let got_lo = l.sar_step(&p, 8, 1.0, 0);
        let ideal_lo = p.alpha_adc() * p.supply.vddh / 128.0 / 2.0;
        let rel_lo = (got_lo - ideal_lo).abs() / ideal_lo;
        assert!(rel > rel_lo, "γ32 rel={rel} γ1 rel={rel_lo}");
    }

    #[test]
    fn mismatch_absolute_error_constant_so_lsb_error_grows_with_gamma() {
        let p = MacroParams::paper();
        let mut rng = Rng::new(3);
        let l = Ladder::sample(&p, &mut rng);
        // Absolute error of bit-4 tap at γ=1 vs γ=8 scales down with the
        // level, but measured IN LSB(γ) it is constant-to-growing.
        let b = 4u32;
        let ideal =
            |g: f64| p.alpha_adc() * p.supply.vddh / g * (1u64 << b) as f64 / 128.0 / 2.0;
        let err_g1 = (l.sar_step(&p, 8, 1.0, b) - ideal(1.0)).abs() / p.adc_lsb(8, 1.0);
        let err_g8 = (l.sar_step(&p, 8, 8.0, b) - ideal(8.0)).abs() / p.adc_lsb(8, 8.0);
        assert!(err_g8 >= err_g1 * 0.9, "g1={err_g1} g8={err_g8}");
    }

    #[test]
    fn ladder_current_matches_paper() {
        let p = MacroParams::paper();
        let l = Ladder::ideal(&p);
        assert_eq!(l.active_current(), 1.0e-3);
    }
}
