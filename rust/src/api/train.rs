//! The [`Trainer`] facade: CIM-aware training behind the public API,
//! closing the loop **train → lower → serve** in one binary.
//!
//! [`Trainer::fit`] runs [`crate::nn::train::train_graph`] (STE gradients
//! through the macro's quantizers, equivalent noise injected per
//! forward) and returns a [`TrainedModel`] that knows how to evaluate
//! itself, lower to a physical [`NetworkModel`], save artifacts the
//! server's hot-deploy path loads, and wrap itself in a [`Deployment`]
//! for a [`ModelHub`](super::ModelHub):
//!
//! ```no_run
//! use imagine::api::{ModelHub, Trainer, TrainConfig, NoiseInjection};
//! use imagine::nn::dataset::Dataset;
//! use imagine::nn::graph::Graph;
//! # fn mlp_graph() -> Graph { unimplemented!() }
//!
//! let train = Dataset::synthetic(480, vec![8, 8], 10, 5, 11, 0.22);
//! let trained = Trainer::new(mlp_graph())
//!     .config(TrainConfig { noise: NoiseInjection::Probe, ..TrainConfig::default() })
//!     .fit(&train)?;
//! trained.save("exports", "cim_digits", &train)?;   // → imagine serve --model cim_digits=exports
//! let hub = ModelHub::builder().build()?;
//! hub.deploy("digits", trained.deployment(&train)?)?; // or straight into a hub
//! # Ok::<(), imagine::api::ImagineError>(())
//! ```

use super::error::ImagineError;
use super::hub::Deployment;
use crate::config::params::MacroParams;
use crate::coordinator::manifest::NetworkModel;
use crate::nn::autotune::{self, AutotuneConfig, AutotuneReport, MatrixEntry};
use crate::nn::dataset::Dataset;
use crate::nn::graph::{eval_graph_workers, Graph};
use crate::nn::layers::AbnSpec;
use crate::nn::train::{train_graph, TrainConfig, TrainReport};
use crate::util::json::{obj, Json};

/// Builder-style facade over the CIM-aware trainer.
pub struct Trainer {
    graph: Graph,
    config: TrainConfig,
    params: MacroParams,
}

impl Trainer {
    /// Train `graph` (its current weights are the initialization) with
    /// the default [`TrainConfig`] and paper parameters.
    pub fn new(graph: Graph) -> Trainer {
        Trainer { graph, config: TrainConfig::default(), params: MacroParams::paper() }
    }

    /// Replace the training configuration (epochs, lr, noise, seed, …).
    pub fn config(mut self, config: TrainConfig) -> Trainer {
        self.config = config;
        self
    }

    /// Macro parameters to train against (supply/corner set the probed
    /// noise operating point).
    pub fn params(mut self, params: MacroParams) -> Trainer {
        self.params = params;
        self
    }

    /// Run the training loop on `data`; deterministic per config seed.
    pub fn fit(mut self, data: &Dataset) -> Result<TrainedModel, ImagineError> {
        let report = train_graph(&mut self.graph, data, &self.params, &self.config)
            .map_err(ImagineError::train)?;
        Ok(TrainedModel {
            graph: self.graph,
            report,
            config: self.config,
            params: self.params,
        })
    }
}

/// A trained graph plus everything needed to evaluate and deploy it.
pub struct TrainedModel {
    /// The trained float graph (master weights).
    pub graph: Graph,
    /// Loss trajectory, throughput and the σ trained against.
    pub report: TrainReport,
    config: TrainConfig,
    params: MacroParams,
}

impl TrainedModel {
    /// The configuration the model was trained with.
    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    /// The macro parameters the model was trained against.
    pub fn params(&self) -> &MacroParams {
        &self.params
    }

    /// Float-forward accuracy (no quantization) on `data`.
    pub fn accuracy_float(&self, data: &Dataset) -> Result<f64, ImagineError> {
        let mut correct = 0usize;
        for i in 0..data.n {
            let logits = self.graph.forward_float(data.image(i)).map_err(ImagineError::train)?;
            if crate::util::stats::argmax_f32(&logits) == data.y[i] as usize {
                correct += 1;
            }
        }
        Ok(correct as f64 / data.n.max(1) as f64)
    }

    /// Accuracy through the CIM mapping at the training operating point,
    /// with `noise_lsb` equivalent output noise injected (0 ⇒ noiseless).
    pub fn accuracy_cim(&self, data: &Dataset, noise_lsb: f64) -> Result<f64, ImagineError> {
        eval_graph_workers(
            &self.graph,
            data,
            &self.params,
            &self.config.eval_cfg(noise_lsb),
            self.config.workers.max(1),
        )
        .map_err(ImagineError::train)
    }

    /// Search a per-layer `(r_in, r_out)` precision profile for this
    /// model (see [`crate::nn::autotune`]): modeled system energy is
    /// minimized subject to an accuracy floor, accuracy measured under
    /// each candidate point's probed equivalent noise at the training
    /// supply/corner. `calib` calibrates activation ranges; `eval`
    /// scores candidates.
    pub fn autotune(
        &self,
        calib: &Dataset,
        eval: &Dataset,
        at: &AutotuneConfig,
    ) -> Result<AutotuneReport, ImagineError> {
        let cfg = self.config.eval_cfg(self.report.noise_lsb);
        autotune::autotune(&self.graph, calib, eval, &self.params, &cfg, at)
            .map_err(ImagineError::train)
    }

    /// Sweep `{nominal, low-power} × {TT, FF, SS, FS, SF} ×` the uniform
    /// precision grid on this model: the Fig. 3(b)-style accuracy/energy
    /// atlas behind `imagine autotune --matrix` (see
    /// [`crate::nn::autotune::operating_point_matrix`]).
    pub fn operating_point_matrix(
        &self,
        calib: &Dataset,
        eval: &Dataset,
        at: &AutotuneConfig,
    ) -> Result<Vec<MatrixEntry>, ImagineError> {
        let cfg = self.config.eval_cfg(self.report.noise_lsb);
        autotune::operating_point_matrix(&self.graph, calib, eval, &self.params, &cfg, at)
            .map_err(ImagineError::train)
    }

    /// Lower to a physical [`NetworkModel`] (integer antipodal weights in
    /// macro row order, 5b ABN offsets, post-ADC gains), calibrated on
    /// `calib` at the training operating point, with the training
    /// metrics recorded in the manifest's `metrics` field.
    pub fn lower(&self, calib: &Dataset) -> Result<NetworkModel, ImagineError> {
        self.lower_impl(calib, &[])
    }

    /// [`TrainedModel::lower`] with an autotuned per-layer profile baked
    /// in: each manifest layer is emitted at its own `(r_in, r_out)`
    /// point and the manifest carries the versioned `precision_profile`
    /// section, so [`ModelHub`](super::ModelHub) and `imagine serve`
    /// pick the profile up with zero flags.
    pub fn lower_tuned(
        &self,
        calib: &Dataset,
        report: &AutotuneReport,
    ) -> Result<NetworkModel, ImagineError> {
        self.lower_impl(calib, &report.overrides())
    }

    fn lower_impl(
        &self,
        calib: &Dataset,
        overrides: &[AbnSpec],
    ) -> Result<NetworkModel, ImagineError> {
        let cfg = self.config.eval_cfg(self.report.noise_lsb);
        let mut model = self
            .graph
            .lower_with(calib, &self.params, &cfg, overrides)
            .map_err(ImagineError::train)?;
        model.metrics = obj(vec![
            ("trained_by", Json::Str("imagine-train".to_string())),
            ("epochs", Json::Num(self.report.epoch_losses.len() as f64)),
            ("final_loss", Json::Num(self.report.final_loss())),
            ("noise_lsb", Json::Num(self.report.noise_lsb)),
            ("r_in", Json::Num(f64::from(self.config.r_in))),
            ("r_out", Json::Num(f64::from(self.config.r_out))),
            ("seed", Json::Num(self.config.seed as f64)),
        ]);
        Ok(model)
    }

    /// Lower and export `<dir>/<name>.manifest.json` + `<dir>/<name>.imgt`
    /// — artifacts `imagine serve --model <name>=<dir>` (or the server's
    /// `{"cmd":"deploy"}`) loads directly. Returns the lowered model.
    pub fn save(
        &self,
        dir: &str,
        name: &str,
        calib: &Dataset,
    ) -> Result<NetworkModel, ImagineError> {
        let model = self.lower(calib)?;
        export_model(model, dir, name)
    }

    /// [`TrainedModel::save`] with an autotuned per-layer profile baked
    /// into the exported manifest (see [`TrainedModel::lower_tuned`]).
    pub fn save_tuned(
        &self,
        dir: &str,
        name: &str,
        calib: &Dataset,
        report: &AutotuneReport,
    ) -> Result<NetworkModel, ImagineError> {
        let model = self.lower_tuned(calib, report)?;
        export_model(model, dir, name)
    }

    /// Wrap the lowered model in a [`Deployment`] spec for
    /// [`ModelHub::deploy`](super::ModelHub::deploy) — in-memory, no
    /// artifact round-trip.
    pub fn deployment(&self, calib: &Dataset) -> Result<Deployment, ImagineError> {
        Ok(Deployment::new(self.lower(calib)?))
    }
}

/// Rename and write manifest + weight artifacts for `model`.
fn export_model(
    mut model: NetworkModel,
    dir: &str,
    name: &str,
) -> Result<NetworkModel, ImagineError> {
    model.name = name.to_string();
    model.save(dir, name).map_err(|e| ImagineError::ModelLoad {
        model: name.to_string(),
        message: format!("{e:#}"),
    })?;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BackendKind, ModelHub, NoiseInjection};
    use crate::nn::layers::{DenseNode, Node};
    use crate::nn::mlp::Dense;
    use crate::util::rng::Rng;

    fn task(n: usize, draw_seed: u64) -> Dataset {
        Dataset::synthetic(n, vec![6, 6], 4, 5, draw_seed, 0.2)
    }

    fn graph(seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        Graph::new("api_train", vec![36])
            .with(Node::Dense(DenseNode::new(Dense::new(36, 16, &mut rng))))
            .with(Node::Relu)
            .with(Node::Dense(DenseNode::new(Dense::new(16, 4, &mut rng))))
    }

    #[test]
    fn fit_lower_deploy_roundtrip() {
        let train = task(160, 11);
        let test = task(80, 12);
        let cfg = TrainConfig {
            epochs: 4,
            noise: NoiseInjection::Off,
            workers: 1,
            ..TrainConfig::default()
        };
        let trained = Trainer::new(graph(3)).config(cfg).fit(&train).unwrap();
        assert!(trained.accuracy_cim(&test, 0.0).unwrap() > 0.75);

        let model = trained.lower(&train).unwrap();
        assert_eq!(model.layers.len(), 2);
        assert!(model.metrics.get("final_loss").is_some());

        // The lowered model serves through the hub and mostly agrees
        // with the in-process mapping on held-out data.
        let hub = ModelHub::builder().workers(1).build().unwrap();
        hub.deploy("t", trained.deployment(&train).unwrap().backend(BackendKind::Ideal))
            .unwrap();
        let session = hub.session("t").unwrap();
        let mut correct = 0usize;
        for i in 0..test.n {
            let logits = session.infer_one(test.image(i).to_vec()).unwrap();
            if crate::util::stats::argmax_f32(&logits) == test.y[i] as usize {
                correct += 1;
            }
        }
        let served = correct as f64 / test.n as f64;
        let inproc = trained.accuracy_cim(&test, 0.0).unwrap();
        assert!(
            (served - inproc).abs() < 0.15,
            "served {served} vs in-process {inproc}"
        );
    }

    #[test]
    fn save_exports_servable_artifacts() {
        let train = task(120, 21);
        let cfg = TrainConfig {
            epochs: 2,
            noise: NoiseInjection::Off,
            workers: 1,
            ..TrainConfig::default()
        };
        let trained = Trainer::new(graph(9)).config(cfg).fit(&train).unwrap();
        let dir = std::env::temp_dir().join(format!("imagine_api_train_{}", std::process::id()));
        let dir = dir.to_str().unwrap().to_string();
        trained.save(&dir, "toy", &train).unwrap();
        let loaded = NetworkModel::load(&dir, "toy").unwrap();
        assert_eq!(loaded.name, "toy");
        assert_eq!(loaded.layers.len(), 2);
        assert!(loaded.metrics.get("noise_lsb").is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
