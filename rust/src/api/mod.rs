//! # The public serving API: a [`ModelHub`] of named deployments
//!
//! One shared engine worker pool serves many named models, each at any
//! 1-to-8b (r_in, r_out) operating point *per request* — the paper's
//! workload-adaptive precision as a runtime routing knob instead of a
//! build-time constant:
//!
//! * [`ModelHub`] / [`HubBuilder`] — the deployment registry over the
//!   shared engine: [`ModelHub::deploy`] / [`ModelHub::undeploy`] hot
//!   load and unload named [`Deployment`]s (model + backend + default
//!   precision) while traffic flows;
//! * [`Session`] — a cheap routed handle
//!   (`hub.session("mnist")?.with_precision(2, 4)?`): sync
//!   [`Session::infer_one`] / [`Session::infer_batch`] plus the async
//!   [`Session::submit`] handle, coalesced per (deployment, precision)
//!   key by the engine's work-queue scheduler. Precision re-targeting
//!   reuses [`apply_precision`] inside the deployed backend — bit
//!   identical to a dedicated session built at that precision, without
//!   rebuilding the backend (the analog die pool and its deterministic
//!   seeds are shared across all tenants);
//! * [`SessionBuilder`] — the single-model facade (a one-deployment hub
//!   under the hood): `backend / precision / supply / corner / batch /
//!   workers / seed` knobs, validated at [`SessionBuilder::build`];
//! * [`Trainer`] / [`TrainConfig`] — CIM-aware training (STE through
//!   the macro's quantizers, post-silicon equivalent noise injected per
//!   forward); a [`TrainedModel`] lowers, saves and deploys straight
//!   into the hub — train → lower → serve in one binary;
//! * [`AutotuneConfig`] / [`AutotuneReport`] — the per-layer precision
//!   search ([`TrainedModel::autotune`]): minimize modeled energy under
//!   an accuracy floor, bake the winning profile into the saved
//!   manifest ([`TrainedModel::save_tuned`]) so hubs serve it by
//!   default;
//! * [`ImagineError`] — the typed error enum on this boundary.
//!
//! The CLI (`imagine run`, `imagine train`, `imagine serve`), the TCP
//! server and all examples construct backends exclusively through this
//! module, so the internal backend registry is the crate's one backend
//! match.

mod error;
mod hub;
mod registry;
mod session;
mod train;

pub use crate::nn::autotune::{
    matrix_to_json, operating_point_matrix, AutotuneConfig, AutotuneReport, MatrixEntry,
    MoveRecord, UniformPoint,
};
pub use crate::nn::train::{LrSchedule, NoiseInjection, OptimizerKind, TrainConfig, TrainReport};
pub use error::ImagineError;
pub use hub::{Deployment, HubBuilder, ModelHub, PendingInference, Session};
pub use session::{
    apply_precision, parse_corner, parse_precision, parse_supply, BackendKind, LayerSummary,
    SessionBuilder, SessionConfig,
};
pub use train::{TrainedModel, Trainer};
