//! # The public inference API: [`Session`] over every backend
//!
//! One precision-aware builder constructs every way this crate can run a
//! network — the closed-form ideal contract, the circuit-behavioral
//! analog die pool, or the AOT/PJRT artifact path — with the paper's
//! operating knobs (1-to-8b precision, supply point, process corner)
//! resolved in one place:
//!
//! * [`Session::builder`] / [`SessionBuilder::from_artifacts`] — entry
//!   points over an in-memory model or compiled artifacts;
//! * [`SessionBuilder`] — `backend / precision / supply / corner /
//!   batch / workers / seed` knobs, validated at [`SessionBuilder::build`];
//! * [`Session`] — sync [`Session::infer_one`] / [`Session::infer_batch`]
//!   plus the async [`Session::submit`] handle, all backed by the
//!   engine's work-queue scheduler;
//! * [`ImagineError`] — the typed error enum on this boundary.
//!
//! The CLI (`imagine run`, `imagine serve`), the TCP server and all
//! examples construct backends exclusively through this module, so the
//! internal backend registry is the crate's one backend match.

mod error;
mod registry;
mod session;

pub use error::ImagineError;
pub use session::{
    apply_precision, parse_corner, parse_precision, parse_supply, BackendKind, LayerSummary,
    PendingInference, Session, SessionBuilder, SessionConfig,
};
