//! The [`ModelHub`]: multi-tenant serving over one shared engine.
//!
//! IMAGINE's headline feature is *workload-adaptive* 1-to-8b precision —
//! a runtime knob, not a build-time constant. The hub makes the public
//! API match the silicon: one engine worker pool serves a registry of
//! named [`Deployment`]s (model + backend + default precision), and a
//! [`Session`] is a cheap routed handle into it. Per-request precision
//! re-targeting reuses the distribution-aware reshaping
//! ([`apply_precision`](super::apply_precision)) inside the deployed
//! backend instead of rebuilding it, so the analog die pool — its
//! deterministic seeds, mismatch draws and calibration — is shared
//! across all tenants and operating points:
//!
//! ```no_run
//! use imagine::api::{BackendKind, Deployment, ModelHub};
//! use imagine::config::params::MacroParams;
//! use imagine::coordinator::manifest::NetworkModel;
//!
//! let p = MacroParams::paper();
//! let hub = ModelHub::builder().batch(32).build()?;
//! hub.deploy(
//!     "mnist",
//!     Deployment::new(NetworkModel::synthetic_mlp(&[144, 32, 10], 8, 4, 8, 7, &p))
//!         .backend(BackendKind::Analog)
//!         .precision(4, 4),
//! )?;
//! // A cheap handle; re-target precision per request without touching
//! // the deployed dies:
//! let logits = hub.session("mnist")?.with_precision(2, 4)?.infer_one(vec![0.5; 144])?;
//! # let _ = logits;
//! # Ok::<(), imagine::api::ImagineError>(())
//! ```
//!
//! Models deploy and undeploy while traffic is flowing (the server's
//! `{"cmd":"deploy"}`/`{"cmd":"undeploy"}`); requests route per
//! (deployment, precision) key through the engine dispatcher, which
//! coalesces each key's traffic into batches independently. Results at a
//! requested precision are bit-identical to a dedicated single-model
//! [`Session`] built at that precision (the engine backends always
//! re-shape from a pristine copy of the deployed model).

use super::error::ImagineError;
use super::registry;
use super::session::{
    retarget_summaries, validate_precision, BackendKind, LayerSummary, SessionBuilder,
    SessionConfig,
};
use crate::config::params::MacroParams;
use crate::coordinator::manifest::NetworkModel;
use crate::engine::{
    self, BatchBackend, DeploymentId, EngineConfig, EngineHandle, EngineSnapshot, Pending,
    RouteKey,
};
use crate::util::stats::AtomicHistogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Specification of one named model a [`ModelHub`] serves: the model
/// itself plus its backend and per-deployment operating defaults.
/// Engine-level knobs (batch, workers, flush window) live on the hub —
/// all deployments share one worker pool.
pub struct Deployment {
    pub(crate) model: NetworkModel,
    pub(crate) backend: BackendKind,
    pub(crate) backend_note: Option<String>,
    pub(crate) precision: Option<(u32, u32)>,
    pub(crate) params: Option<MacroParams>,
    pub(crate) supply: Option<crate::config::params::Supply>,
    pub(crate) corner: Option<crate::config::params::Corner>,
    pub(crate) seed: Option<u64>,
    pub(crate) noise: bool,
    pub(crate) calibrate: bool,
    pub(crate) artifacts: Option<(String, String)>,
}

impl Deployment {
    /// A deployment serving an in-memory model on the ideal backend.
    pub fn new(model: NetworkModel) -> Deployment {
        Deployment {
            model,
            backend: BackendKind::Ideal,
            backend_note: None,
            precision: None,
            params: None,
            supply: None,
            corner: None,
            seed: None,
            noise: true,
            calibrate: true,
            artifacts: None,
        }
    }

    /// Load `<dir>/<name>.manifest.json` and remember the artifact
    /// directory (so [`BackendKind::Pjrt`] can find the HLO file).
    pub fn from_artifacts(dir: &str, name: &str) -> Result<Deployment, ImagineError> {
        let model = NetworkModel::load(dir, name).map_err(|e| ImagineError::ModelLoad {
            model: name.to_string(),
            message: format!("{e:#}"),
        })?;
        Ok(Deployment::new(model).artifacts(dir, name))
    }

    /// The name of the wrapped model (what a single-model
    /// [`SessionBuilder`] deploys it under).
    pub fn model_name(&self) -> &str {
        &self.model.name
    }

    /// Select the backend this deployment is served on
    /// ([`BackendKind::Ideal`] by default).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = kind;
        self
    }

    /// Why this backend was chosen, when it was resolved rather than
    /// requested (see [`BackendKind::auto_resolve`]); reported by the
    /// server's `info` command.
    pub fn backend_note(mut self, note: impl Into<String>) -> Self {
        self.backend_note = Some(note.into());
        self
    }

    /// Default (r_in, r_out) operating point for requests that do not
    /// carry their own precision; `None` keeps the per-layer manifest
    /// precision.
    pub fn precision(mut self, r_in: u32, r_out: u32) -> Self {
        self.precision = Some((r_in, r_out));
        self
    }

    /// Supply point of the simulated silicon for this deployment
    /// (defaults to the base parameters' supply).
    pub fn supply(mut self, supply: crate::config::params::Supply) -> Self {
        self.supply = Some(supply);
        self
    }

    /// Process corner of the simulated silicon for this deployment
    /// (defaults to the base parameters' corner).
    pub fn corner(mut self, corner: crate::config::params::Corner) -> Self {
        self.corner = Some(corner);
        self
    }

    /// Base macro parameters (defaults to [`MacroParams::paper`]);
    /// `supply`/`corner` settings apply on top.
    pub fn params(mut self, params: MacroParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Base die seed for the analog backend (defaults to the hub seed;
    /// die `d` derives its own).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Temporal noise on/off (analog backend).
    pub fn noise(mut self, on: bool) -> Self {
        self.noise = on;
        self
    }

    /// Run SA-offset calibration before inference (analog backend).
    pub fn calibrate(mut self, on: bool) -> Self {
        self.calibrate = on;
        self
    }

    /// Point the PJRT backend at `<dir>/<name>.hlo.txt`.
    pub fn artifacts(mut self, dir: &str, name: &str) -> Self {
        self.artifacts = Some((dir.to_string(), name.to_string()));
        self
    }

    /// Wrap this spec in a single-model [`SessionBuilder`] (a private
    /// one-deployment hub at build time) — the bridge between code that
    /// assembles a [`Deployment`] and the single-model serving path.
    pub fn into_session_builder(self) -> SessionBuilder {
        SessionBuilder::new(self)
    }
}

/// A live deployment: its engine id plus the resolved configuration.
/// Ids are unique per hub and never reused, so a stale session handle to
/// a replaced model fails cleanly instead of reaching the wrong backend.
pub(crate) struct Deployed {
    pub(crate) id: DeploymentId,
    /// Deployment-order rank of the *name*: inherited across hot
    /// reloads (which allocate a fresh engine id), so replacing the
    /// default model in place does not silently re-route default
    /// traffic to another deployment.
    pub(crate) seq: u64,
    pub(crate) default_precision: Option<(u32, u32)>,
    pub(crate) config: Arc<SessionConfig>,
}

struct HubShared {
    engine: EngineHandle,
    deployments: RwLock<BTreeMap<String, Arc<Deployed>>>,
    next_id: AtomicU64,
    batch: usize,
    workers: usize,
    flush_micros: u64,
    seed: u64,
}

/// Builder for a [`ModelHub`]: the engine-level knobs every deployment
/// shares.
pub struct HubBuilder {
    batch: usize,
    workers: usize,
    flush_micros: u64,
    seed: u64,
    occupancy: Option<Arc<AtomicHistogram>>,
}

impl Default for HubBuilder {
    fn default() -> Self {
        HubBuilder {
            batch: 32,
            workers: engine::default_workers(),
            flush_micros: 500,
            seed: 42,
            occupancy: None,
        }
    }
}

impl HubBuilder {
    /// Maximum images per coalesced engine batch (≥ 1).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Worker threads (matmul splits / analog dies) (≥ 1).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Dispatcher flush window for partial batches [µs].
    pub fn flush_micros(mut self, micros: u64) -> Self {
        self.flush_micros = micros;
        self
    }

    /// Default base die seed for analog deployments that do not set
    /// their own.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Histogram receiving the size of every dispatched batch (the
    /// server wires its `Stats` in here).
    pub fn occupancy(mut self, histogram: Arc<AtomicHistogram>) -> Self {
        self.occupancy = Some(histogram);
        self
    }

    /// Validate the knobs and start the (initially empty) engine
    /// dispatcher.
    pub fn build(self) -> Result<ModelHub, ImagineError> {
        if self.batch == 0 {
            return Err(ImagineError::InvalidConfig {
                field: "batch",
                message: "batch must be >= 1".to_string(),
            });
        }
        if self.workers == 0 {
            return Err(ImagineError::InvalidConfig {
                field: "workers",
                message: "workers must be >= 1".to_string(),
            });
        }
        let cfg = EngineConfig {
            batch: self.batch,
            workers: self.workers,
            flush_micros: self.flush_micros,
        };
        let engine = engine::start(cfg, self.occupancy)
            .map_err(|e| ImagineError::Engine { message: format!("{e:#}") })?;
        Ok(ModelHub {
            inner: Arc::new(HubShared {
                engine,
                deployments: RwLock::new(BTreeMap::new()),
                next_id: AtomicU64::new(1),
                batch: self.batch,
                workers: self.workers,
                flush_micros: self.flush_micros,
                seed: self.seed,
            }),
        })
    }
}

/// A registry of named model deployments served by one shared engine
/// worker pool. Cheap to clone; the engine dispatcher shuts down when
/// the last clone (including the ones inside [`Session`] handles) is
/// dropped.
#[derive(Clone)]
pub struct ModelHub {
    inner: Arc<HubShared>,
}

impl ModelHub {
    /// Start configuring a hub (engine-level knobs: batch, workers,
    /// flush window, seed).
    pub fn builder() -> HubBuilder {
        HubBuilder::default()
    }

    /// Deploy `spec` under `name`, building its backend on the shared
    /// engine. Deploying over an existing name is a hot reload: the new
    /// backend is installed first, then the old one is removed —
    /// sessions already routed to the old deployment get clean in-band
    /// errors, new sessions see the new model, and no other tenant is
    /// disturbed.
    ///
    /// Backend construction is also where the per-deployment
    /// packed-weight caches (bit-plane planes, validity masks) are
    /// built; they are shared read-only by every batch and worker
    /// thread until the deployment is retargeted or replaced, so
    /// steady-state inference never re-derives weight-side packing.
    pub fn deploy(&self, name: &str, spec: Deployment) -> Result<(), ImagineError> {
        if name.is_empty() {
            return Err(ImagineError::InvalidConfig {
                field: "model",
                message: "deployment name must not be empty".to_string(),
            });
        }
        if let Some((r_in, r_out)) = spec.precision {
            validate_precision(r_in, r_out)?;
        }
        // The PJRT artifact's arithmetic is compiled in: a default
        // precision would pass deploy and then fail every request when
        // the route key asks the backend to re-target. Fail fast with
        // the real reason instead. (The pre-hub builder silently served
        // the artifact's baked precision while reporting the override.)
        if spec.backend == BackendKind::Pjrt && spec.precision.is_some() {
            return Err(ImagineError::BackendUnavailable {
                backend: BackendKind::Pjrt,
                reason: "the HLO artifact's (r_in, r_out) is fixed at compile time; \
                         deploy without a precision override (per-request overrides \
                         are declined in-band)"
                    .to_string(),
            });
        }
        let Deployment {
            model,
            backend,
            backend_note,
            precision,
            params,
            supply,
            corner,
            seed,
            noise,
            calibrate,
            artifacts,
        } = spec;
        let mut params = params.unwrap_or_else(MacroParams::paper);
        if let Some(s) = supply {
            params.supply = s;
        }
        if let Some(c) = corner {
            params.corner = c;
        }
        let (supply, corner) = (params.supply, params.corner);
        let seed = seed.unwrap_or(self.inner.seed);

        let input_shape = model.input_shape.clone();
        let input_len = input_shape.iter().product();
        // Summaries reflect the deployment's *default* operating point;
        // per-handle overrides re-patch them (see Session::with_precision).
        let mut layers: Vec<LayerSummary> =
            model.layers.iter().map(LayerSummary::from_layer).collect();
        retarget_summaries(&mut layers, precision);

        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let factory = registry::factory(registry::BackendSpec {
            kind: backend,
            model,
            params,
            seed,
            noise,
            calibrate,
            workers: self.inner.workers,
            artifacts,
        })?;
        let (_, describe) = self
            .inner
            .engine
            .deploy(id, precision, factory)
            .map_err(|e| registry::map_start_error(backend, e))?;

        let config = SessionConfig {
            model: name.to_string(),
            input_shape,
            input_len,
            backend,
            backend_note,
            precision,
            supply,
            corner,
            batch: self.inner.batch,
            workers: self.inner.workers,
            flush_micros: self.inner.flush_micros,
            seed,
            engine: describe,
            layers,
        };
        self.install(name, id, precision, config)
    }

    /// Deploy a caller-provided backend (tests and embedders plugging
    /// custom [`BatchBackend`]s). `config` describes the deployment for
    /// `info`-style reporting; its `input_len` and `engine` fields are
    /// overwritten with what the backend itself reports.
    pub fn deploy_custom<F>(
        &self,
        name: &str,
        mut config: SessionConfig,
        factory: F,
    ) -> Result<(), ImagineError>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn BatchBackend>> + Send + 'static,
    {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        // The default precision is probed at deploy (retargeted on the
        // dispatcher), so a custom backend that keeps the default
        // `retarget` cannot be deployed into a config it would then
        // fail every request for.
        let (input_len, describe) = self
            .inner
            .engine
            .deploy(id, config.precision, Box::new(factory))
            .map_err(|e| ImagineError::Engine { message: format!("{e:#}") })?;
        config.model = name.to_string();
        config.input_len = input_len;
        config.engine = describe;
        let precision = config.precision;
        self.install(name, id, precision, config)
    }

    fn install(
        &self,
        name: &str,
        id: DeploymentId,
        default_precision: Option<(u32, u32)>,
        config: SessionConfig,
    ) -> Result<(), ImagineError> {
        let old = {
            let mut map = self.inner.deployments.write().unwrap();
            // A hot reload keeps the name's deployment-order rank, so
            // the default model stays the default across reloads.
            let seq = map.get(name).map(|d| d.seq).unwrap_or(id);
            map.insert(
                name.to_string(),
                Arc::new(Deployed {
                    id,
                    seq,
                    default_precision,
                    config: Arc::new(config),
                }),
            )
        };
        if let Some(old) = old {
            // Hot reload: the replacement is live before the old backend
            // goes away.
            let _ = self.inner.engine.undeploy(old.id);
        }
        Ok(())
    }

    /// Remove a deployment. In-flight requests already dispatched finish;
    /// later requests through stale session handles fail with clean
    /// in-band errors.
    pub fn undeploy(&self, name: &str) -> Result<(), ImagineError> {
        let removed = self.inner.deployments.write().unwrap().remove(name);
        match removed {
            Some(dep) => {
                self.inner
                    .engine
                    .undeploy(dep.id)
                    .map_err(|e| ImagineError::Engine { message: format!("{e:#}") })?;
                Ok(())
            }
            None => Err(ImagineError::UnknownModel { model: name.to_string() }),
        }
    }

    /// Names of the live deployments, sorted.
    pub fn models(&self) -> Vec<String> {
        self.inner.deployments.read().unwrap().keys().cloned().collect()
    }

    /// The live deployments' resolved configurations, sorted by name.
    pub fn deployments(&self) -> Vec<(String, Arc<SessionConfig>)> {
        self.inner
            .deployments
            .read()
            .unwrap()
            .iter()
            .map(|(name, dep)| (name.clone(), Arc::clone(&dep.config)))
            .collect()
    }

    /// The one rule for "which deployment is the default": the
    /// earliest-deployed live name (hot reloads keep a name's rank).
    fn default_deployed(&self) -> Option<Arc<Deployed>> {
        self.inner
            .deployments
            .read()
            .unwrap()
            .values()
            .min_by_key(|dep| dep.seq)
            .cloned()
    }

    /// The default deployment's name (see [`ModelHub::default_session`]
    /// for the selection rule).
    pub fn default_model(&self) -> Option<String> {
        self.default_deployed()
            .map(|dep| dep.config.model.clone())
    }

    /// A session handle on a named deployment.
    pub fn session(&self, name: &str) -> Result<Session, ImagineError> {
        let dep = self
            .inner
            .deployments
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| ImagineError::UnknownModel { model: name.to_string() })?;
        Ok(Session::over(self.clone(), dep))
    }

    /// A session handle on the default deployment (the earliest
    /// still-deployed model name; hot reloads keep a name's rank).
    pub fn default_session(&self) -> Result<Session, ImagineError> {
        let dep = self
            .default_deployed()
            .ok_or_else(|| ImagineError::UnknownModel {
                model: "<no models deployed>".to_string(),
            })?;
        Ok(Session::over(self.clone(), dep))
    }

    /// Graceful-shutdown barrier: blocks until everything enqueued on
    /// the engine before this call has executed and been answered.
    pub fn drain(&self) -> Result<(), ImagineError> {
        self.inner.engine.drain().map_err(ImagineError::engine)
    }
}

/// An in-flight inference submitted through [`Session::submit`].
pub struct PendingInference(Pending);

impl PendingInference {
    /// Block until the logits arrive.
    pub fn wait(self) -> Result<Vec<f32>, ImagineError> {
        self.0.wait().map_err(ImagineError::engine)
    }

    /// Non-blocking poll: `None` while the batch is still in flight.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>, ImagineError>> {
        self.0.try_wait().map(|r| r.map_err(ImagineError::engine))
    }
}

/// A cheap handle routing inference to one deployment of a
/// [`ModelHub`], optionally at a per-handle precision override. Cloning
/// is an `Arc` bump; all handles share the hub's engine worker pool.
#[derive(Clone)]
pub struct Session {
    hub: ModelHub,
    dep: Arc<Deployed>,
    /// Per-handle (r_in, r_out) override; `None` routes at the
    /// deployment's default precision.
    precision: Option<(u32, u32)>,
    /// The deployment config with this handle's effective precision
    /// resolved.
    config: Arc<SessionConfig>,
}

impl Session {
    /// Start building a single-model session over an in-memory model
    /// (a one-deployment [`ModelHub`] under the hood).
    pub fn builder(model: NetworkModel) -> SessionBuilder {
        SessionBuilder::new(Deployment::new(model))
    }

    pub(crate) fn over(hub: ModelHub, dep: Arc<Deployed>) -> Session {
        let config = Arc::clone(&dep.config);
        Session { hub, dep, precision: None, config }
    }

    /// Re-target this handle to a (r_in, r_out) operating point. Cheap:
    /// no backend is rebuilt — the deployed backend re-shapes itself
    /// (from a pristine model copy) when a batch at this precision is
    /// dispatched, so the logits are bit-identical to a dedicated
    /// session built at this precision. Re-shaping also rebuilds the
    /// backend's packed-weight caches for the new precision (the one
    /// cache-rebuild event besides deploy itself); batches at an
    /// unchanged precision keep hitting the existing packs.
    pub fn with_precision(&self, r_in: u32, r_out: u32) -> Result<Session, ImagineError> {
        validate_precision(r_in, r_out)?;
        let mut config = (*self.dep.config).clone();
        config.precision = Some((r_in, r_out));
        retarget_summaries(&mut config.layers, config.precision);
        Ok(Session {
            hub: self.hub.clone(),
            dep: Arc::clone(&self.dep),
            precision: Some((r_in, r_out)),
            config: Arc::new(config),
        })
    }

    /// The hub this session routes into.
    pub fn hub(&self) -> &ModelHub {
        &self.hub
    }

    /// The deployment name this session routes to.
    pub fn model(&self) -> &str {
        &self.config.model
    }

    /// Whether this handle still points at the live deployment of its
    /// name (false once the model was undeployed or replaced).
    pub fn is_live(&self) -> bool {
        self.hub
            .inner
            .deployments
            .read()
            .unwrap()
            .get(&self.config.model)
            .map(|dep| dep.id)
            == Some(self.dep.id)
    }

    fn key(&self) -> RouteKey {
        RouteKey::new(self.dep.id, self.precision.or(self.dep.default_precision))
    }

    /// The resolved configuration this session runs with.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Expected flattened input length per image.
    pub fn input_len(&self) -> usize {
        self.config.input_len
    }

    /// The model's natural input shape.
    pub fn input_shape(&self) -> &[usize] {
        &self.config.input_shape
    }

    /// Per-layer structure of the served model (resolved precision) —
    /// pairs with the per-layer costs in [`Session::snapshot`].
    pub fn layers(&self) -> &[LayerSummary] {
        &self.config.layers
    }

    /// Human-readable backend description.
    pub fn describe(&self) -> &str {
        &self.config.engine
    }

    fn check_image(&self, image: &[f32], index: usize) -> Result<(), ImagineError> {
        if image.len() != self.config.input_len {
            return Err(ImagineError::Input {
                message: format!(
                    "image {index}: expected {} values, got {}",
                    self.config.input_len,
                    image.len()
                ),
            });
        }
        Ok(())
    }

    /// Blocking single-image inference → logits. Concurrent callers on
    /// the same (deployment, precision) key are coalesced into engine
    /// batches.
    pub fn infer_one(&self, image: Vec<f32>) -> Result<Vec<f32>, ImagineError> {
        self.check_image(&image, 0)?;
        self.hub
            .inner
            .engine
            .infer(self.key(), image)
            .map_err(ImagineError::engine)
    }

    /// Run a whole batch as one backend dispatch (deterministic die
    /// split on the analog backend, regardless of concurrent traffic).
    /// Copies the batch; use [`Session::infer_batch_owned`] on hot paths
    /// that can hand the images over.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, ImagineError> {
        self.infer_batch_owned(images.to_vec())
    }

    /// [`Session::infer_batch`] without the copy: takes ownership of the
    /// images and moves them straight into the engine queue.
    pub fn infer_batch_owned(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>, ImagineError> {
        for (i, image) in images.iter().enumerate() {
            self.check_image(image, i)?;
        }
        self.hub
            .inner
            .engine
            .infer_batch(self.key(), images)
            .map_err(ImagineError::engine)
    }

    /// Asynchronous submission: enqueue now, [`PendingInference::wait`]
    /// later. The engine queue coalesces outstanding same-key
    /// submissions.
    pub fn submit(&self, image: Vec<f32>) -> Result<PendingInference, ImagineError> {
        self.check_image(&image, 0)?;
        self.hub
            .inner
            .engine
            .submit(self.key(), image)
            .map(PendingInference)
            .map_err(ImagineError::engine)
    }

    /// This deployment's engine counters plus its backend's modeled
    /// accelerator cost. Fails with [`ImagineError::UnknownModel`] once
    /// the deployment is gone.
    pub fn snapshot(&self) -> Result<EngineSnapshot, ImagineError> {
        self.hub
            .inner
            .engine
            .snapshot(self.dep.id)
            .map_err(ImagineError::engine)?
            .ok_or_else(|| ImagineError::UnknownModel {
                model: self.config.model.clone(),
            })
    }
}
