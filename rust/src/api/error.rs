//! Typed errors for the public [`Session`](super::Session) boundary.
//!
//! Inside the crate the layers keep using the lightweight `anyhow`-style
//! context chains; everything that crosses the facade is converted into
//! one [`ImagineError`] variant so callers (the CLI, the server, external
//! embedders) can match on failure classes instead of grepping strings.

use super::session::BackendKind;
use std::fmt;

/// Every way a [`Session`](super::Session) can fail, from builder
/// validation to a dead inference engine.
#[derive(Debug)]
pub enum ImagineError {
    /// A `SessionBuilder` knob failed validation (precision out of
    /// range, zero batch, …).
    InvalidConfig {
        field: &'static str,
        message: String,
    },
    /// A textual option (backend, precision, supply, corner) did not
    /// parse.
    Parse {
        what: &'static str,
        value: String,
        expected: &'static str,
    },
    /// Model artifacts could not be loaded.
    ModelLoad { model: String, message: String },
    /// No deployment with this name in the [`ModelHub`](super::ModelHub)
    /// (never deployed, undeployed, or replaced since the handle was
    /// taken).
    UnknownModel { model: String },
    /// The requested backend cannot run in this build or environment
    /// (e.g. PJRT without the `pjrt` feature or an artifact directory).
    BackendUnavailable {
        backend: BackendKind,
        reason: String,
    },
    /// An inference input was malformed (wrong length, non-finite).
    Input { message: String },
    /// The engine failed at runtime (backend error, dispatcher gone).
    Engine { message: String },
    /// The CIM-aware trainer rejected its configuration or data, or a
    /// training-time evaluation/lowering failed.
    Train { message: String },
    /// The cluster router shed this request: every replica of the model
    /// is at its in-flight cap and the router-side overflow queue is
    /// full (or the queued wait timed out). Clients should back off and
    /// retry; the request was never dispatched to a worker.
    Overloaded { model: String, queue_depth: usize },
    /// No healthy worker currently hosts this model (all its replicas
    /// are down and failover has not yet re-placed it).
    NoHealthyWorkers { model: String },
}

impl ImagineError {
    /// Wrap an engine-layer error crossing the facade boundary.
    pub(crate) fn engine(e: anyhow::Error) -> Self {
        ImagineError::Engine { message: format!("{e:#}") }
    }

    /// Wrap a trainer-layer error crossing the facade boundary.
    pub(crate) fn train(e: anyhow::Error) -> Self {
        ImagineError::Train { message: format!("{e:#}") }
    }

    /// Stable machine-readable code for errors the cluster router puts
    /// on the wire as a `"code"` field next to the human `"error"` text,
    /// so clients can branch (back off / fail over) without parsing
    /// prose. `None` for errors that have no protocol-level class.
    pub fn code(&self) -> Option<&'static str> {
        match self {
            ImagineError::Overloaded { .. } => Some("overloaded"),
            ImagineError::NoHealthyWorkers { .. } => Some("unavailable"),
            _ => None,
        }
    }
}

impl fmt::Display for ImagineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ImagineError::InvalidConfig { field, message } => {
                write!(f, "invalid session config ({field}): {message}")
            }
            ImagineError::Parse { what, value, expected } => {
                write!(f, "unknown {what} '{value}' (expected {expected})")
            }
            ImagineError::ModelLoad { model, message } => {
                write!(f, "loading model '{model}': {message}")
            }
            ImagineError::UnknownModel { model } => {
                write!(f, "no deployed model named '{model}'")
            }
            ImagineError::BackendUnavailable { backend, reason } => {
                write!(f, "backend '{}' unavailable: {reason}", backend.name())
            }
            ImagineError::Input { message } => write!(f, "bad inference input: {message}"),
            ImagineError::Engine { message } => write!(f, "inference engine error: {message}"),
            ImagineError::Train { message } => write!(f, "training error: {message}"),
            ImagineError::Overloaded { model, queue_depth } => {
                write!(
                    f,
                    "cluster overloaded: model '{model}' replicas at capacity \
                     (router queue bound {queue_depth} reached)"
                )
            }
            ImagineError::NoHealthyWorkers { model } => {
                write!(f, "no healthy worker for model '{model}'")
            }
        }
    }
}

impl std::error::Error for ImagineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure_class() {
        let e = ImagineError::Parse {
            what: "backend",
            value: "bogus".to_string(),
            expected: "ideal|analog|pjrt",
        };
        let s = format!("{e}");
        assert!(s.contains("backend") && s.contains("bogus") && s.contains("ideal"), "{s}");

        let e = ImagineError::BackendUnavailable {
            backend: BackendKind::Pjrt,
            reason: "no feature".to_string(),
        };
        assert!(format!("{e}").contains("pjrt"));
    }

    #[test]
    fn cluster_errors_carry_wire_codes() {
        let e = ImagineError::Overloaded { model: "m".to_string(), queue_depth: 128 };
        assert_eq!(e.code(), Some("overloaded"));
        assert!(format!("{e}").contains("overloaded"), "{e}");
        let e = ImagineError::NoHealthyWorkers { model: "m".to_string() };
        assert_eq!(e.code(), Some("unavailable"));
        assert!(format!("{e}").contains("no healthy worker"), "{e}");
        // Non-cluster errors stay code-less on the wire.
        assert_eq!(
            ImagineError::Input { message: "x".to_string() }.code(),
            None
        );
    }

    #[test]
    fn converts_into_anyhow_at_the_cli_boundary() {
        fn cli() -> anyhow::Result<()> {
            Err(ImagineError::Input { message: "too short".to_string() })?;
            Ok(())
        }
        let err = cli().unwrap_err();
        assert!(format!("{err}").contains("too short"), "{err}");
    }
}
