//! The [`Session`] facade: one precision-aware builder over every
//! backend.
//!
//! IMAGINE's headline feature is workload-adaptive 1-to-8b precision;
//! this module makes that knob (plus supply, corner, backend and the
//! batching/parallelism controls) the crate's user-facing contract:
//!
//! ```no_run
//! use imagine::api::{BackendKind, Session};
//! use imagine::config::params::MacroParams;
//! use imagine::coordinator::manifest::NetworkModel;
//!
//! let p = MacroParams::paper();
//! let model = NetworkModel::synthetic_mlp(&[144, 32, 10], 8, 4, 8, 7, &p);
//! let session = Session::builder(model)
//!     .backend(BackendKind::Analog)
//!     .precision(4, 4)
//!     .seed(2024)
//!     .build()?;
//! let logits = session.infer_one(vec![0.5; 144])?;
//! # Ok::<(), imagine::api::ImagineError>(())
//! ```
//!
//! Every frontend — `imagine run`, `imagine serve`, the examples — goes
//! through this one path, so a backend constructed from the CLI is the
//! same backend the server and the tests exercise.

use super::error::ImagineError;
use super::registry;
use crate::config::params::{Corner, MacroParams, Supply};
use crate::coordinator::manifest::{Layer, NetworkModel};
use crate::engine::{default_workers, EngineConfig, EngineHandle, EngineSnapshot, Pending};
use crate::util::json::{arr_usize, obj, Json};
use crate::util::stats::AtomicHistogram;
use std::sync::Arc;

/// Which inference backend a [`Session`] drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Batched closed-form macro contract (fast, bit-exact vs the python
    /// oracle).
    Ideal,
    /// Pool of circuit-behavioral simulated dies (mismatch + noise +
    /// corners, deterministic per-die seeds).
    Analog,
    /// AOT-compiled HLO artifact on the PJRT runtime (needs the `pjrt`
    /// feature and an artifact directory).
    Pjrt,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] = [BackendKind::Ideal, BackendKind::Analog, BackendKind::Pjrt];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Ideal => "ideal",
            BackendKind::Analog => "analog",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse a backend name; rejects anything outside the registry.
    pub fn parse(s: &str) -> Result<BackendKind, ImagineError> {
        for kind in BackendKind::ALL {
            if s.eq_ignore_ascii_case(kind.name()) {
                return Ok(kind);
            }
        }
        Err(ImagineError::Parse {
            what: "backend",
            value: s.to_string(),
            expected: "ideal|analog|pjrt",
        })
    }

    /// The backend `--backend auto` resolves to for a model in `dir`:
    /// PJRT when this build can run the HLO artifact, otherwise the
    /// batched ideal engine.
    pub fn auto_for(dir: &str, name: &str) -> BackendKind {
        let hlo = std::path::Path::new(dir).join(format!("{name}.hlo.txt"));
        if cfg!(feature = "pjrt") && hlo.exists() {
            BackendKind::Pjrt
        } else {
            BackendKind::Ideal
        }
    }
}

/// Parse a `--precision` value: `R` (both sides) or `R_IN,R_OUT`
/// (`:`/`/` also accepted), bits in 1..=8.
pub fn parse_precision(s: &str) -> Result<(u32, u32), ImagineError> {
    let err = || ImagineError::Parse {
        what: "precision",
        value: s.to_string(),
        expected: "R or R_IN,R_OUT with bits in 1..=8 (e.g. 4 or 4,8)",
    };
    let (a, b) = match s.split_once(|c: char| c == ',' || c == ':' || c == '/') {
        Some((a, b)) => (a, b),
        None => (s, s),
    };
    let r_in: u32 = a.trim().parse().map_err(|_| err())?;
    let r_out: u32 = b.trim().parse().map_err(|_| err())?;
    if !(1..=8).contains(&r_in) || !(1..=8).contains(&r_out) {
        return Err(err());
    }
    Ok((r_in, r_out))
}

/// Parse a `--supply` value: `nominal`, `low-power`, or an explicit
/// `VDDL/VDDH` volt pair like `0.35/0.7`.
pub fn parse_supply(s: &str) -> Result<Supply, ImagineError> {
    match s {
        "nominal" | "0.4/0.8" => return Ok(Supply::NOMINAL),
        "low-power" | "low" | "lp" | "0.3/0.6" => return Ok(Supply::LOW_POWER),
        _ => {}
    }
    if let Some((l, h)) = s.split_once('/') {
        if let (Ok(vddl), Ok(vddh)) = (l.trim().parse::<f64>(), h.trim().parse::<f64>()) {
            if vddl > 0.0 && vddh >= vddl {
                return Ok(Supply::new(vddl, vddh));
            }
        }
    }
    Err(ImagineError::Parse {
        what: "supply",
        value: s.to_string(),
        expected: "nominal|low-power|VDDL/VDDH (e.g. 0.35/0.7)",
    })
}

/// Parse a `--corner` value (case-insensitive): tt|ff|ss|fs|sf.
pub fn parse_corner(s: &str) -> Result<Corner, ImagineError> {
    for corner in Corner::ALL {
        if s.eq_ignore_ascii_case(corner.name()) {
            return Ok(corner);
        }
    }
    Err(ImagineError::Parse {
        what: "corner",
        value: s.to_string(),
        expected: "tt|ff|ss|fs|sf",
    })
}

/// Re-shape a model to a new (r_in, r_out) operating point, preserving
/// each layer's real-valued full-scale range: the input quantization
/// grid is re-spread over the same activation range and the post-ADC
/// gain is rescaled so recentered outputs keep their magnitude — the
/// software analogue of the paper's distribution-aware data reshaping
/// when the precision knob moves. Weight precision (`r_w`) is a storage
/// property of the compiled model and is left untouched.
///
/// Callers must keep `r_in`/`r_out` in 1..=8 (the macro's range);
/// [`SessionBuilder::build`] validates this before applying.
pub fn apply_precision(model: &mut NetworkModel, r_in: u32, r_out: u32) {
    for layer in &mut model.layers {
        let old_m = ((1u32 << layer.cfg.r_in) - 1) as f32;
        let new_m = ((1u32 << r_in) - 1) as f32;
        let old_half = (1u32 << (layer.cfg.r_out - 1)) as f32;
        let new_half = (1u32 << (r_out - 1)) as f32;
        layer.a_scale *= old_m / new_m;
        layer.out_gain *= old_half / new_half;
        layer.cfg.r_in = r_in;
        layer.cfg.r_out = r_out;
    }
}

/// Per-layer structure summary of the model a [`Session`] serves — what
/// the server's `graph_info` command reports alongside the engine's
/// per-layer modeled [`LayerCost`](crate::energy::system::LayerCost).
/// Captured at build time (after any precision reshaping), so it
/// reflects the *resolved* operating point, and kept independent of the
/// weights so the session does not retain the model tensors.
#[derive(Clone, Debug)]
pub struct LayerSummary {
    pub name: String,
    /// `dense` or `conv3`.
    pub kind: &'static str,
    /// Dense: input features; conv: input channels.
    pub in_features: usize,
    /// Dense: outputs; conv: output channels.
    pub out_features: usize,
    /// Physical macro rows (padded to DP-unit multiples).
    pub rows: usize,
    pub r_in: u32,
    pub r_out: u32,
    /// ABN gain.
    pub gamma: f64,
    pub relu: bool,
    /// `none`, `max2`, `avg2` or `gap`.
    pub pool: &'static str,
}

impl LayerSummary {
    fn from_layer(layer: &Layer) -> LayerSummary {
        LayerSummary {
            name: layer.name.clone(),
            kind: layer.kind.name(),
            in_features: layer.in_features,
            out_features: layer.out_features,
            rows: layer.rows,
            r_in: layer.cfg.r_in,
            r_out: layer.cfg.r_out,
            gamma: layer.cfg.gamma,
            relu: layer.relu,
            pool: layer.pool.name(),
        }
    }

    /// JSON form for the server's `graph_info` command.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("kind", Json::Str(self.kind.to_string())),
            ("in_features", Json::Num(self.in_features as f64)),
            ("out_features", Json::Num(self.out_features as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("r_in", Json::Num(self.r_in as f64)),
            ("r_out", Json::Num(self.r_out as f64)),
            ("gamma", Json::Num(self.gamma)),
            ("relu", Json::Bool(self.relu)),
            ("pool", Json::Str(self.pool.to_string())),
        ])
    }
}

/// The resolved configuration of a built [`Session`] — what the server's
/// versioned `info` command reports.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub model: String,
    pub input_shape: Vec<usize>,
    pub input_len: usize,
    pub backend: BackendKind,
    /// The (r_in, r_out) override, if one was applied (`None` keeps the
    /// per-layer manifest precision).
    pub precision: Option<(u32, u32)>,
    pub supply: Supply,
    pub corner: Corner,
    pub batch: usize,
    pub workers: usize,
    pub flush_micros: u64,
    pub seed: u64,
    /// Human-readable backend description from the engine.
    pub engine: String,
    /// Per-layer structure of the served model (resolved precision).
    pub layers: Vec<LayerSummary>,
}

impl SessionConfig {
    /// JSON form for the server's `info` protocol command.
    pub fn to_json(&self) -> Json {
        let precision = match self.precision {
            Some((r_in, r_out)) => obj(vec![
                ("r_in", Json::Num(r_in as f64)),
                ("r_out", Json::Num(r_out as f64)),
            ]),
            None => Json::Null,
        };
        obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("backend", Json::Str(self.backend.name().to_string())),
            ("input_shape", arr_usize(&self.input_shape)),
            ("input_len", Json::Num(self.input_len as f64)),
            ("precision", precision),
            (
                "supply",
                obj(vec![
                    ("vddl", Json::Num(self.supply.vddl)),
                    ("vddh", Json::Num(self.supply.vddh)),
                ]),
            ),
            ("corner", Json::Str(self.corner.name().to_string())),
            ("batch", Json::Num(self.batch as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("flush_micros", Json::Num(self.flush_micros as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("engine", Json::Str(self.engine.clone())),
        ])
    }

    /// One-line summary for logs.
    pub fn render(&self) -> String {
        let precision = match self.precision {
            Some((r_in, r_out)) => format!("r_in={r_in} r_out={r_out}"),
            None => "manifest per-layer".to_string(),
        };
        format!(
            "{} via {} [{}] | precision {} | supply {:.2}/{:.2} V | corner {} | \
             batch {} x {} workers | flush {} us | seed {}",
            self.model,
            self.backend.name(),
            self.engine,
            precision,
            self.supply.vddl,
            self.supply.vddh,
            self.corner.name(),
            self.batch,
            self.workers,
            self.flush_micros,
            self.seed
        )
    }
}

/// Builder for a [`Session`]; start from [`Session::builder`] (in-memory
/// model) or [`SessionBuilder::from_artifacts`] (compiled artifacts).
pub struct SessionBuilder {
    model: NetworkModel,
    artifacts: Option<(String, String)>,
    params: Option<MacroParams>,
    backend: BackendKind,
    precision: Option<(u32, u32)>,
    supply: Option<Supply>,
    corner: Option<Corner>,
    batch: usize,
    workers: usize,
    flush_micros: u64,
    seed: u64,
    noise: bool,
    calibrate: bool,
    occupancy: Option<Arc<AtomicHistogram>>,
}

impl SessionBuilder {
    fn new(model: NetworkModel) -> Self {
        SessionBuilder {
            model,
            artifacts: None,
            params: None,
            backend: BackendKind::Ideal,
            precision: None,
            supply: None,
            corner: None,
            batch: 32,
            workers: default_workers(),
            flush_micros: 500,
            seed: 42,
            noise: true,
            calibrate: true,
            occupancy: None,
        }
    }

    /// Load `<dir>/<name>.manifest.json` and remember the artifact
    /// directory (so [`BackendKind::Pjrt`] can find the HLO file).
    pub fn from_artifacts(dir: &str, name: &str) -> Result<SessionBuilder, ImagineError> {
        let model = NetworkModel::load(dir, name).map_err(|e| ImagineError::ModelLoad {
            model: name.to_string(),
            message: format!("{e:#}"),
        })?;
        Ok(SessionBuilder::new(model).artifacts(dir, name))
    }

    /// Point the PJRT backend at `<dir>/<name>.hlo.txt`.
    pub fn artifacts(mut self, dir: &str, name: &str) -> Self {
        self.artifacts = Some((dir.to_string(), name.to_string()));
        self
    }

    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = kind;
        self
    }

    /// Override every layer's (r_in, r_out) operating point; see
    /// [`apply_precision`].
    pub fn precision(mut self, r_in: u32, r_out: u32) -> Self {
        self.precision = Some((r_in, r_out));
        self
    }

    pub fn supply(mut self, supply: Supply) -> Self {
        self.supply = Some(supply);
        self
    }

    pub fn corner(mut self, corner: Corner) -> Self {
        self.corner = Some(corner);
        self
    }

    /// Base macro parameters (defaults to [`MacroParams::paper`]);
    /// `supply`/`corner` settings apply on top.
    pub fn params(mut self, params: MacroParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Maximum images per coalesced engine batch (≥ 1).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Worker threads (matmul splits / analog dies) (≥ 1).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Dispatcher flush window for partial batches [µs].
    pub fn flush_micros(mut self, micros: u64) -> Self {
        self.flush_micros = micros;
        self
    }

    /// Base die seed for the analog backend (die `d` derives its own).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Temporal noise on/off (analog backend).
    pub fn noise(mut self, on: bool) -> Self {
        self.noise = on;
        self
    }

    /// Run SA-offset calibration before inference (analog backend).
    pub fn calibrate(mut self, on: bool) -> Self {
        self.calibrate = on;
        self
    }

    /// Histogram receiving the size of every dispatched batch (the
    /// server wires its `Stats` in here).
    pub fn occupancy(mut self, histogram: Arc<AtomicHistogram>) -> Self {
        self.occupancy = Some(histogram);
        self
    }

    /// Validate the configuration, reshape the model if a precision
    /// override is set, and start the engine through the backend
    /// registry.
    pub fn build(self) -> Result<Session, ImagineError> {
        if let Some((r_in, r_out)) = self.precision {
            if !(1..=8).contains(&r_in) || !(1..=8).contains(&r_out) {
                return Err(ImagineError::InvalidConfig {
                    field: "precision",
                    message: format!("r_in={r_in} r_out={r_out} outside the macro's 1..=8 range"),
                });
            }
        }
        if self.batch == 0 {
            return Err(ImagineError::InvalidConfig {
                field: "batch",
                message: "batch must be >= 1".to_string(),
            });
        }
        if self.workers == 0 {
            return Err(ImagineError::InvalidConfig {
                field: "workers",
                message: "workers must be >= 1".to_string(),
            });
        }

        let mut model = self.model;
        if let Some((r_in, r_out)) = self.precision {
            apply_precision(&mut model, r_in, r_out);
        }
        let mut params = self.params.unwrap_or_else(MacroParams::paper);
        if let Some(supply) = self.supply {
            params.supply = supply;
        }
        if let Some(corner) = self.corner {
            params.corner = corner;
        }
        let (supply, corner) = (params.supply, params.corner);

        let model_name = model.name.clone();
        let input_shape = model.input_shape.clone();
        let input_len = input_shape.iter().product();
        let layers = model.layers.iter().map(LayerSummary::from_layer).collect();
        let cfg = EngineConfig {
            batch: self.batch,
            workers: self.workers,
            flush_micros: self.flush_micros,
        };
        let handle = registry::start(
            registry::BackendSpec {
                kind: self.backend,
                model,
                params,
                seed: self.seed,
                noise: self.noise,
                calibrate: self.calibrate,
                workers: self.workers,
                artifacts: self.artifacts,
            },
            cfg,
            self.occupancy,
        )?;
        let config = SessionConfig {
            model: model_name,
            input_shape,
            input_len,
            backend: self.backend,
            precision: self.precision,
            supply,
            corner,
            batch: self.batch,
            workers: self.workers,
            flush_micros: self.flush_micros,
            seed: self.seed,
            engine: handle.describe().to_string(),
            layers,
        };
        Ok(Session { handle, config: Arc::new(config) })
    }
}

/// An in-flight inference submitted through [`Session::submit`].
pub struct PendingInference(Pending);

impl PendingInference {
    /// Block until the logits arrive.
    pub fn wait(self) -> Result<Vec<f32>, ImagineError> {
        self.0.wait().map_err(ImagineError::engine)
    }

    /// Non-blocking poll: `None` while the batch is still in flight.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>, ImagineError>> {
        self.0.try_wait().map(|r| r.map_err(ImagineError::engine))
    }
}

/// A running inference session: a configured backend behind the engine
/// work-queue, shared by every caller thread (cheap to clone).
#[derive(Clone)]
pub struct Session {
    handle: EngineHandle,
    config: Arc<SessionConfig>,
}

impl Session {
    /// Start building a session over an in-memory model.
    pub fn builder(model: NetworkModel) -> SessionBuilder {
        SessionBuilder::new(model)
    }

    /// Wrap an already-started engine (tests and embedders plugging
    /// custom [`BatchBackend`](crate::engine::BatchBackend)s).
    pub fn from_handle(handle: EngineHandle, config: SessionConfig) -> Session {
        Session { handle, config: Arc::new(config) }
    }

    /// The resolved configuration this session runs with.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Expected flattened input length per image.
    pub fn input_len(&self) -> usize {
        self.config.input_len
    }

    /// The model's natural input shape.
    pub fn input_shape(&self) -> &[usize] {
        &self.config.input_shape
    }

    /// Per-layer structure of the served model (resolved precision) —
    /// pairs with the per-layer costs in [`Session::snapshot`].
    pub fn layers(&self) -> &[LayerSummary] {
        &self.config.layers
    }

    /// Human-readable backend description.
    pub fn describe(&self) -> &str {
        &self.config.engine
    }

    /// The underlying engine handle (server plumbing).
    pub fn engine(&self) -> &EngineHandle {
        &self.handle
    }

    fn check_image(&self, image: &[f32], index: usize) -> Result<(), ImagineError> {
        if image.len() != self.config.input_len {
            return Err(ImagineError::Input {
                message: format!(
                    "image {index}: expected {} values, got {}",
                    self.config.input_len,
                    image.len()
                ),
            });
        }
        Ok(())
    }

    /// Blocking single-image inference → logits. Concurrent callers are
    /// coalesced into engine batches.
    pub fn infer_one(&self, image: Vec<f32>) -> Result<Vec<f32>, ImagineError> {
        self.check_image(&image, 0)?;
        self.handle.infer(image).map_err(ImagineError::engine)
    }

    /// Run a whole batch as one backend dispatch (deterministic die
    /// split on the analog backend, regardless of concurrent traffic).
    /// Copies the batch; use [`Session::infer_batch_owned`] on hot paths
    /// that can hand the images over.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, ImagineError> {
        self.infer_batch_owned(images.to_vec())
    }

    /// [`Session::infer_batch`] without the copy: takes ownership of the
    /// images and moves them straight into the engine queue.
    pub fn infer_batch_owned(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>, ImagineError> {
        for (i, image) in images.iter().enumerate() {
            self.check_image(image, i)?;
        }
        self.handle
            .infer_batch(images)
            .map_err(ImagineError::engine)
    }

    /// Asynchronous submission: enqueue now, [`PendingInference::wait`]
    /// later. The engine queue coalesces outstanding submissions.
    pub fn submit(&self, image: Vec<f32>) -> Result<PendingInference, ImagineError> {
        self.check_image(&image, 0)?;
        self.handle
            .submit(image)
            .map(PendingInference)
            .map_err(ImagineError::engine)
    }

    /// Engine counters plus the backend's modeled accelerator cost.
    pub fn snapshot(&self) -> Result<EngineSnapshot, ImagineError> {
        self.handle.snapshot().map_err(ImagineError::engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_roundtrips() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(BackendKind::parse("bogus").is_err());
    }

    #[test]
    fn auto_backend_defaults_to_ideal_without_artifacts() {
        assert_eq!(
            BackendKind::auto_for("/nonexistent", "nope"),
            BackendKind::Ideal
        );
    }

    #[test]
    fn precision_parses_single_and_pair() {
        assert_eq!(parse_precision("4").unwrap(), (4, 4));
        assert_eq!(parse_precision("4,8").unwrap(), (4, 8));
        assert_eq!(parse_precision("1:8").unwrap(), (1, 8));
        assert!(parse_precision("0").is_err());
        assert!(parse_precision("9").is_err());
        assert!(parse_precision("four").is_err());
    }

    #[test]
    fn supply_and_corner_parse() {
        assert_eq!(parse_supply("nominal").unwrap(), Supply::NOMINAL);
        assert_eq!(parse_supply("low-power").unwrap(), Supply::LOW_POWER);
        let s = parse_supply("0.35/0.7").unwrap();
        assert!((s.vddl - 0.35).abs() < 1e-12 && (s.vddh - 0.7).abs() < 1e-12);
        assert!(parse_supply("high").is_err());
        assert!(parse_supply("0.8/0.4").is_err(), "vddh below vddl");
        assert_eq!(parse_corner("ss").unwrap(), Corner::Ss);
        assert_eq!(parse_corner("TT").unwrap(), Corner::Tt);
        assert!(parse_corner("xx").is_err());
    }

    #[test]
    fn sessions_expose_layer_summaries_at_resolved_precision() {
        let p = MacroParams::paper();
        let model = NetworkModel::synthetic_mlp(&[72, 24, 6], 8, 4, 8, 4, &p);
        let session = Session::builder(model)
            .precision(4, 6)
            .workers(1)
            .build()
            .unwrap();
        let layers = session.layers();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].kind, "dense");
        assert_eq!((layers[0].in_features, layers[0].out_features), (72, 24));
        // Summaries are captured after apply_precision.
        assert!(layers.iter().all(|l| l.r_in == 4 && l.r_out == 6));
        assert!(layers[0].relu && !layers[1].relu);
        assert_eq!(layers[1].pool, "none");
        let j = layers[1].to_json().to_string_compact();
        assert!(j.contains("\"kind\":\"dense\""), "{j}");
        assert!(j.contains("\"r_out\":6"), "{j}");
    }

    #[test]
    fn apply_precision_preserves_full_scale() {
        let p = MacroParams::paper();
        let mut model = NetworkModel::synthetic_mlp(&[36, 4], 8, 4, 8, 1, &p);
        let full_scale_in: Vec<f32> = model
            .layers
            .iter()
            .map(|l| l.a_scale * ((1u32 << l.cfg.r_in) - 1) as f32)
            .collect();
        let full_scale_out: Vec<f32> = model
            .layers
            .iter()
            .map(|l| l.out_gain * (1u32 << (l.cfg.r_out - 1)) as f32)
            .collect();
        apply_precision(&mut model, 2, 3);
        for (i, l) in model.layers.iter().enumerate() {
            assert_eq!((l.cfg.r_in, l.cfg.r_out), (2, 3));
            let fs_in = l.a_scale * ((1u32 << l.cfg.r_in) - 1) as f32;
            let fs_out = l.out_gain * (1u32 << (l.cfg.r_out - 1)) as f32;
            assert!((fs_in - full_scale_in[i]).abs() < 1e-6, "layer {i}");
            assert!((fs_out - full_scale_out[i]).abs() < 1e-6, "layer {i}");
        }
    }
}
