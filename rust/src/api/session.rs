//! Session configuration: the precision-aware knob surface shared by
//! the [`ModelHub`](super::ModelHub) and the single-model
//! [`SessionBuilder`] facade.
//!
//! IMAGINE's headline feature is workload-adaptive 1-to-8b precision;
//! this module holds the knobs that express it — [`BackendKind`], the
//! `--precision/--supply/--corner` parsers, the distribution-aware
//! [`apply_precision`] reshaping, and the resolved [`SessionConfig`] the
//! server's `info` command reports. The single-model path is a builder
//! over a one-deployment hub:
//!
//! ```no_run
//! use imagine::api::{BackendKind, Session};
//! use imagine::config::params::MacroParams;
//! use imagine::coordinator::manifest::NetworkModel;
//!
//! let p = MacroParams::paper();
//! let model = NetworkModel::synthetic_mlp(&[144, 32, 10], 8, 4, 8, 7, &p);
//! let session = Session::builder(model)
//!     .backend(BackendKind::Analog)
//!     .precision(4, 4)
//!     .seed(2024)
//!     .build()?;
//! let logits = session.infer_one(vec![0.5; 144])?;
//! # Ok::<(), imagine::api::ImagineError>(())
//! ```
//!
//! Every frontend — `imagine run`, `imagine serve`, the examples — goes
//! through the hub, so a backend constructed from the CLI is the same
//! backend the server and the tests exercise.

use super::error::ImagineError;
use super::hub::{Deployment, ModelHub, Session};
use crate::config::params::{Corner, MacroParams, Supply};
use crate::coordinator::manifest::{Layer, NetworkModel};
use crate::engine::default_workers;
use crate::util::json::{arr_usize, obj, Json};
use crate::util::stats::AtomicHistogram;
use std::sync::Arc;

/// Which inference backend a deployment drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Batched closed-form macro contract (fast, bit-exact vs the python
    /// oracle).
    Ideal,
    /// Pool of circuit-behavioral simulated dies (mismatch + noise +
    /// corners, deterministic per-die seeds).
    Analog,
    /// AOT-compiled HLO artifact on the PJRT runtime (needs the `pjrt`
    /// feature and an artifact directory).
    Pjrt,
}

impl BackendKind {
    /// Every backend the registry knows, in resolution order.
    pub const ALL: [BackendKind; 3] = [BackendKind::Ideal, BackendKind::Analog, BackendKind::Pjrt];

    /// The CLI/protocol spelling (`ideal` / `analog` / `pjrt`).
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Ideal => "ideal",
            BackendKind::Analog => "analog",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse a backend name; rejects anything outside the registry.
    pub fn parse(s: &str) -> Result<BackendKind, ImagineError> {
        for kind in BackendKind::ALL {
            if s.eq_ignore_ascii_case(kind.name()) {
                return Ok(kind);
            }
        }
        Err(ImagineError::Parse {
            what: "backend",
            value: s.to_string(),
            expected: "ideal|analog|pjrt",
        })
    }

    /// [`BackendKind::auto_resolve`] for a deployment that also wants a
    /// (r_in, r_out) precision override: the HLO artifact's arithmetic
    /// is fixed at compile time, so `auto` + precision must pick the
    /// re-targetable ideal engine even when a PJRT artifact is runnable
    /// — "auto" exists to pick a *workable* backend, and the reason
    /// string records the trade.
    pub fn auto_resolve_at(
        dir: &str,
        name: &str,
        precision: Option<(u32, u32)>,
    ) -> (BackendKind, String) {
        let (kind, note) = BackendKind::auto_resolve(dir, name);
        if kind == BackendKind::Pjrt && precision.is_some() {
            return (
                BackendKind::Ideal,
                "auto: a precision override was requested but the HLO artifact's \
                 arithmetic is fixed at compile time — picked the batched ideal \
                 engine instead"
                    .to_string(),
            );
        }
        (kind, note)
    }

    /// Resolve `--backend auto` for a model in `dir`, and say *why*:
    /// PJRT when this build can run the HLO artifact, otherwise the
    /// batched ideal engine. The reason string names the decisive fact
    /// (feature compiled out vs missing `.hlo.txt`) so a resolved-config
    /// report never hides a silent fallback.
    pub fn auto_resolve(dir: &str, name: &str) -> (BackendKind, String) {
        let hlo = std::path::Path::new(dir).join(format!("{name}.hlo.txt"));
        let have_hlo = hlo.exists();
        let hlo = hlo.display();
        if crate::runtime::PJRT_AVAILABLE && have_hlo {
            (
                BackendKind::Pjrt,
                format!("auto: running the PJRT HLO artifact at {hlo}"),
            )
        } else if crate::runtime::PJRT_AVAILABLE {
            (
                BackendKind::Ideal,
                format!("auto: no HLO artifact at {hlo} — fell back to the batched ideal engine"),
            )
        } else if have_hlo {
            (
                BackendKind::Ideal,
                format!(
                    "auto: HLO artifact present at {hlo} but this build cannot run the PJRT \
                     runtime (pjrt+xla features) — fell back to the batched ideal engine"
                ),
            )
        } else {
            (
                BackendKind::Ideal,
                format!(
                    "auto: PJRT runtime not compiled in (pjrt+xla features) and no HLO \
                     artifact at {hlo} — using the batched ideal engine"
                ),
            )
        }
    }

    /// The backend `--backend auto` resolves to for a model in `dir`
    /// (see [`BackendKind::auto_resolve`] for the reasoned variant).
    pub fn auto_for(dir: &str, name: &str) -> BackendKind {
        BackendKind::auto_resolve(dir, name).0
    }
}

/// Patch layer summaries to a resolved (r_in, r_out) operating point —
/// the one place deploy-time defaults and per-handle overrides share,
/// so the two reporting paths cannot drift.
pub(crate) fn retarget_summaries(layers: &mut [LayerSummary], precision: Option<(u32, u32)>) {
    if let Some((r_in, r_out)) = precision {
        for layer in layers {
            layer.r_in = r_in;
            layer.r_out = r_out;
        }
    }
}

/// Check a (r_in, r_out) pair against the macro's 1..=8 range.
pub(crate) fn validate_precision(r_in: u32, r_out: u32) -> Result<(), ImagineError> {
    if !(1..=8).contains(&r_in) || !(1..=8).contains(&r_out) {
        return Err(ImagineError::InvalidConfig {
            field: "precision",
            message: format!("r_in={r_in} r_out={r_out} outside the macro's 1..=8 range"),
        });
    }
    Ok(())
}

/// Parse a `--precision` value: `R` (both sides) or `R_IN,R_OUT`
/// (`:`/`/` also accepted), bits in 1..=8.
pub fn parse_precision(s: &str) -> Result<(u32, u32), ImagineError> {
    let err = || ImagineError::Parse {
        what: "precision",
        value: s.to_string(),
        expected: "R or R_IN,R_OUT with bits in 1..=8 (e.g. 4 or 4,8)",
    };
    let (a, b) = match s.split_once(|c: char| c == ',' || c == ':' || c == '/') {
        Some((a, b)) => (a, b),
        None => (s, s),
    };
    let r_in: u32 = a.trim().parse().map_err(|_| err())?;
    let r_out: u32 = b.trim().parse().map_err(|_| err())?;
    if !(1..=8).contains(&r_in) || !(1..=8).contains(&r_out) {
        return Err(err());
    }
    Ok((r_in, r_out))
}

/// Parse a `--supply` value: `nominal`, `low-power`, or an explicit
/// `VDDL/VDDH` volt pair like `0.35/0.7`.
pub fn parse_supply(s: &str) -> Result<Supply, ImagineError> {
    match s {
        "nominal" | "0.4/0.8" => return Ok(Supply::NOMINAL),
        "low-power" | "low" | "lp" | "0.3/0.6" => return Ok(Supply::LOW_POWER),
        _ => {}
    }
    if let Some((l, h)) = s.split_once('/') {
        if let (Ok(vddl), Ok(vddh)) = (l.trim().parse::<f64>(), h.trim().parse::<f64>()) {
            if vddl > 0.0 && vddh >= vddl {
                return Ok(Supply::new(vddl, vddh));
            }
        }
    }
    Err(ImagineError::Parse {
        what: "supply",
        value: s.to_string(),
        expected: "nominal|low-power|VDDL/VDDH (e.g. 0.35/0.7)",
    })
}

/// Parse a `--corner` value (case-insensitive): tt|ff|ss|fs|sf.
pub fn parse_corner(s: &str) -> Result<Corner, ImagineError> {
    for corner in Corner::ALL {
        if s.eq_ignore_ascii_case(corner.name()) {
            return Ok(corner);
        }
    }
    Err(ImagineError::Parse {
        what: "corner",
        value: s.to_string(),
        expected: "tt|ff|ss|fs|sf",
    })
}

/// Re-shape a model to a new (r_in, r_out) operating point, preserving
/// each layer's real-valued full-scale range — the software analogue of
/// the paper's distribution-aware data reshaping when the precision knob
/// moves (see [`NetworkModel::retarget_precision`], which this
/// delegates to). Weight precision (`r_w`) is a storage property of the
/// compiled model and is left untouched.
///
/// Callers must keep `r_in`/`r_out` in 1..=8 (the macro's range); the
/// hub and builders validate this before applying. The engine backends
/// reuse the same reshaping per (deployment, precision) route key, which
/// is what makes a per-request precision override bit-identical to a
/// session built at that precision.
pub fn apply_precision(model: &mut NetworkModel, r_in: u32, r_out: u32) {
    model.retarget_precision(r_in, r_out);
}

/// Per-layer structure summary of the model a session serves — what the
/// server's `graph_info` command reports alongside the engine's
/// per-layer modeled [`LayerCost`](crate::energy::system::LayerCost).
/// Captured at deploy time at the deployment's default operating point
/// (and re-patched per precision-override handle), so it reflects the
/// *resolved* precision, and kept independent of the weights so sessions
/// do not retain the model tensors.
#[derive(Clone, Debug)]
pub struct LayerSummary {
    /// Layer name from the manifest (e.g. `conv0`, `fc1`).
    pub name: String,
    /// `dense` or `conv3`.
    pub kind: &'static str,
    /// Dense: input features; conv: input channels.
    pub in_features: usize,
    /// Dense: outputs; conv: output channels.
    pub out_features: usize,
    /// Physical macro rows (padded to DP-unit multiples).
    pub rows: usize,
    /// Resolved input precision in bits (1..=8).
    pub r_in: u32,
    /// Resolved ADC output precision in bits (1..=8).
    pub r_out: u32,
    /// ABN gain.
    pub gamma: f64,
    /// Whether a ReLU follows in the post-ADC digital datapath.
    pub relu: bool,
    /// `none`, `max2`, `avg2` or `gap`.
    pub pool: &'static str,
}

impl LayerSummary {
    pub(crate) fn from_layer(layer: &Layer) -> LayerSummary {
        LayerSummary {
            name: layer.name.clone(),
            kind: layer.kind.name(),
            in_features: layer.in_features,
            out_features: layer.out_features,
            rows: layer.rows,
            r_in: layer.cfg.r_in,
            r_out: layer.cfg.r_out,
            gamma: layer.cfg.gamma,
            relu: layer.relu,
            pool: layer.pool.name(),
        }
    }

    /// JSON form for the server's `graph_info` command.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("kind", Json::Str(self.kind.to_string())),
            ("in_features", Json::Num(self.in_features as f64)),
            ("out_features", Json::Num(self.out_features as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("r_in", Json::Num(self.r_in as f64)),
            ("r_out", Json::Num(self.r_out as f64)),
            ("gamma", Json::Num(self.gamma)),
            ("relu", Json::Bool(self.relu)),
            ("pool", Json::Str(self.pool.to_string())),
        ])
    }
}

/// The resolved configuration of a deployment (and of the session
/// handles over it) — what the server's versioned `info` command
/// reports.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// The deployment name this configuration is served under.
    pub model: String,
    /// Input shape from the manifest (e.g. `[784]` or `[3, 16, 16]`).
    pub input_shape: Vec<usize>,
    /// Flattened input length (the product of `input_shape`).
    pub input_len: usize,
    /// The backend actually serving this deployment.
    pub backend: BackendKind,
    /// Why this backend was chosen when it was resolved (`--backend
    /// auto`) rather than requested — never a silent fallback.
    pub backend_note: Option<String>,
    /// The session's effective (r_in, r_out) operating point (`None`
    /// keeps the per-layer manifest precision).
    pub precision: Option<(u32, u32)>,
    /// Supply point of the simulated silicon.
    pub supply: Supply,
    /// Process corner of the simulated silicon.
    pub corner: Corner,
    /// Maximum images per coalesced engine batch.
    pub batch: usize,
    /// Engine worker threads (analog: simulated dies).
    pub workers: usize,
    /// Partial-batch flush window of the dispatcher, in microseconds.
    pub flush_micros: u64,
    /// Engine base seed (analog die seeds derive from it).
    pub seed: u64,
    /// Human-readable backend description from the engine.
    pub engine: String,
    /// Per-layer structure of the served model (resolved precision).
    pub layers: Vec<LayerSummary>,
}

impl SessionConfig {
    /// JSON form for the server's `info` protocol command.
    pub fn to_json(&self) -> Json {
        let precision = match self.precision {
            Some((r_in, r_out)) => obj(vec![
                ("r_in", Json::Num(r_in as f64)),
                ("r_out", Json::Num(r_out as f64)),
            ]),
            None => Json::Null,
        };
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("backend", Json::Str(self.backend.name().to_string())),
            ("input_shape", arr_usize(&self.input_shape)),
            ("input_len", Json::Num(self.input_len as f64)),
            ("precision", precision),
            (
                "supply",
                obj(vec![
                    ("vddl", Json::Num(self.supply.vddl)),
                    ("vddh", Json::Num(self.supply.vddh)),
                ]),
            ),
            ("corner", Json::Str(self.corner.name().to_string())),
            ("batch", Json::Num(self.batch as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("flush_micros", Json::Num(self.flush_micros as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("engine", Json::Str(self.engine.clone())),
        ];
        if let Some(note) = &self.backend_note {
            pairs.push(("backend_note", Json::Str(note.clone())));
        }
        obj(pairs)
    }

    /// One-line summary for logs.
    pub fn render(&self) -> String {
        let precision = match self.precision {
            Some((r_in, r_out)) => format!("r_in={r_in} r_out={r_out}"),
            None => "manifest per-layer".to_string(),
        };
        let mut line = format!(
            "{} via {} [{}] | precision {} | supply {:.2}/{:.2} V | corner {} | \
             batch {} x {} workers | flush {} us | seed {}",
            self.model,
            self.backend.name(),
            self.engine,
            precision,
            self.supply.vddl,
            self.supply.vddh,
            self.corner.name(),
            self.batch,
            self.workers,
            self.flush_micros,
            self.seed
        );
        if let Some(note) = &self.backend_note {
            line.push_str(&format!(" | {note}"));
        }
        line
    }
}

/// Builder for a single-model [`Session`]: a [`Deployment`] spec plus
/// the engine knobs, deployed into a private one-model
/// [`ModelHub`](super::ModelHub) at [`SessionBuilder::build`]. Start
/// from [`Session::builder`] (in-memory model) or
/// [`SessionBuilder::from_artifacts`] (compiled artifacts). Multi-model
/// serving builds the hub directly and deploys named specs instead.
pub struct SessionBuilder {
    spec: Deployment,
    batch: usize,
    workers: usize,
    flush_micros: u64,
    seed: u64,
    occupancy: Option<Arc<AtomicHistogram>>,
}

impl SessionBuilder {
    pub(crate) fn new(spec: Deployment) -> Self {
        SessionBuilder {
            spec,
            batch: 32,
            workers: default_workers(),
            flush_micros: 500,
            seed: 42,
            occupancy: None,
        }
    }

    /// Load `<dir>/<name>.manifest.json` and remember the artifact
    /// directory (so [`BackendKind::Pjrt`] can find the HLO file).
    pub fn from_artifacts(dir: &str, name: &str) -> Result<SessionBuilder, ImagineError> {
        Ok(SessionBuilder::new(Deployment::from_artifacts(dir, name)?))
    }

    /// Point the PJRT backend at `<dir>/<name>.hlo.txt`.
    pub fn artifacts(mut self, dir: &str, name: &str) -> Self {
        self.spec = self.spec.artifacts(dir, name);
        self
    }

    /// Select the inference backend ([`BackendKind::Ideal`] default).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.spec = self.spec.backend(kind);
        self
    }

    /// Why the backend was chosen, when resolved via
    /// [`BackendKind::auto_resolve`]; surfaces in the `info` output.
    pub fn backend_note(mut self, note: impl Into<String>) -> Self {
        self.spec = self.spec.backend_note(note);
        self
    }

    /// Override every layer's (r_in, r_out) operating point; see
    /// [`apply_precision`].
    pub fn precision(mut self, r_in: u32, r_out: u32) -> Self {
        self.spec = self.spec.precision(r_in, r_out);
        self
    }

    /// Supply point of the simulated silicon.
    pub fn supply(mut self, supply: Supply) -> Self {
        self.spec = self.spec.supply(supply);
        self
    }

    /// Process corner of the simulated silicon.
    pub fn corner(mut self, corner: Corner) -> Self {
        self.spec = self.spec.corner(corner);
        self
    }

    /// Base macro parameters (defaults to [`MacroParams::paper`]);
    /// `supply`/`corner` settings apply on top.
    pub fn params(mut self, params: MacroParams) -> Self {
        self.spec = self.spec.params(params);
        self
    }

    /// Maximum images per coalesced engine batch (≥ 1).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Worker threads (matmul splits / analog dies) (≥ 1).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Dispatcher flush window for partial batches [µs].
    pub fn flush_micros(mut self, micros: u64) -> Self {
        self.flush_micros = micros;
        self
    }

    /// Base die seed for the analog backend (die `d` derives its own).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Temporal noise on/off (analog backend).
    pub fn noise(mut self, on: bool) -> Self {
        self.spec = self.spec.noise(on);
        self
    }

    /// Run SA-offset calibration before inference (analog backend).
    pub fn calibrate(mut self, on: bool) -> Self {
        self.spec = self.spec.calibrate(on);
        self
    }

    /// Histogram receiving the size of every dispatched batch (the
    /// server wires its `Stats` in here).
    pub fn occupancy(mut self, histogram: Arc<AtomicHistogram>) -> Self {
        self.occupancy = Some(histogram);
        self
    }

    /// Validate the configuration, start a one-deployment hub and
    /// return the session handle over it.
    pub fn build(self) -> Result<Session, ImagineError> {
        let mut hub = ModelHub::builder()
            .batch(self.batch)
            .workers(self.workers)
            .flush_micros(self.flush_micros)
            .seed(self.seed);
        if let Some(histogram) = self.occupancy {
            hub = hub.occupancy(histogram);
        }
        let hub = hub.build()?;
        let name = self.spec.model_name().to_string();
        hub.deploy(&name, self.spec)?;
        hub.session(&name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_roundtrips() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(BackendKind::parse("bogus").is_err());
    }

    #[test]
    fn auto_backend_defaults_to_ideal_with_a_reason() {
        let (kind, reason) = BackendKind::auto_resolve("/nonexistent", "nope");
        assert_eq!(kind, BackendKind::Ideal);
        // The reason names the decisive fact, not just the outcome.
        assert!(
            reason.contains("pjrt") || reason.contains("HLO"),
            "uninformative reason: {reason}"
        );
        assert!(reason.contains("/nonexistent"), "{reason}");
        assert_eq!(BackendKind::auto_for("/nonexistent", "nope"), kind);
    }

    #[test]
    fn auto_resolution_never_picks_pjrt_for_a_precision_override() {
        // auto + precision must land on a re-targetable backend; on a
        // pjrt-less build that is ideal either way, but the contract is
        // asserted for both spellings (the pjrt-capable case is covered
        // by auto_resolve_at's kind check itself).
        for precision in [None, Some((4, 4)), Some((1, 8))] {
            let (kind, reason) = BackendKind::auto_resolve_at("/nonexistent", "nope", precision);
            assert_eq!(kind, BackendKind::Ideal, "{reason}");
            assert_ne!(kind, BackendKind::Pjrt);
        }
    }

    #[test]
    fn precision_parses_single_and_pair() {
        assert_eq!(parse_precision("4").unwrap(), (4, 4));
        assert_eq!(parse_precision("4,8").unwrap(), (4, 8));
        assert_eq!(parse_precision("1:8").unwrap(), (1, 8));
        assert!(parse_precision("0").is_err());
        assert!(parse_precision("9").is_err());
        assert!(parse_precision("four").is_err());
    }

    #[test]
    fn supply_and_corner_parse() {
        assert_eq!(parse_supply("nominal").unwrap(), Supply::NOMINAL);
        assert_eq!(parse_supply("low-power").unwrap(), Supply::LOW_POWER);
        let s = parse_supply("0.35/0.7").unwrap();
        assert!((s.vddl - 0.35).abs() < 1e-12 && (s.vddh - 0.7).abs() < 1e-12);
        assert!(parse_supply("high").is_err());
        assert!(parse_supply("0.8/0.4").is_err(), "vddh below vddl");
        assert_eq!(parse_corner("ss").unwrap(), Corner::Ss);
        assert_eq!(parse_corner("TT").unwrap(), Corner::Tt);
        assert!(parse_corner("xx").is_err());
    }

    #[test]
    fn sessions_expose_layer_summaries_at_resolved_precision() {
        let p = MacroParams::paper();
        let model = NetworkModel::synthetic_mlp(&[72, 24, 6], 8, 4, 8, 4, &p);
        let session = Session::builder(model)
            .precision(4, 6)
            .workers(1)
            .build()
            .unwrap();
        let layers = session.layers();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].kind, "dense");
        assert_eq!((layers[0].in_features, layers[0].out_features), (72, 24));
        // Summaries are captured at the resolved operating point.
        assert!(layers.iter().all(|l| l.r_in == 4 && l.r_out == 6));
        assert!(layers[0].relu && !layers[1].relu);
        assert_eq!(layers[1].pool, "none");
        let j = layers[1].to_json().to_string_compact();
        assert!(j.contains("\"kind\":\"dense\""), "{j}");
        assert!(j.contains("\"r_out\":6"), "{j}");
    }

    #[test]
    fn apply_precision_preserves_full_scale() {
        let p = MacroParams::paper();
        let mut model = NetworkModel::synthetic_mlp(&[36, 4], 8, 4, 8, 1, &p);
        let full_scale_in: Vec<f32> = model
            .layers
            .iter()
            .map(|l| l.a_scale * ((1u32 << l.cfg.r_in) - 1) as f32)
            .collect();
        let full_scale_out: Vec<f32> = model
            .layers
            .iter()
            .map(|l| l.out_gain * (1u32 << (l.cfg.r_out - 1)) as f32)
            .collect();
        apply_precision(&mut model, 2, 3);
        for (i, l) in model.layers.iter().enumerate() {
            assert_eq!((l.cfg.r_in, l.cfg.r_out), (2, 3));
            let fs_in = l.a_scale * ((1u32 << l.cfg.r_in) - 1) as f32;
            let fs_out = l.out_gain * (1u32 << (l.cfg.r_out - 1)) as f32;
            assert!((fs_in - full_scale_in[i]).abs() < 1e-6, "layer {i}");
            assert!((fs_out - full_scale_out[i]).abs() < 1e-6, "layer {i}");
        }
    }
}
