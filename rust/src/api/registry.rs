//! The backend registry — the single place a [`BackendKind`] becomes a
//! backend factory for the shared engine dispatcher.
//!
//! Before the facade existed, `main.rs` and the server each hand-wired
//! their own `NetworkModel + MacroParams + backend` match (and the
//! server could not reach the analog backend at all). Every frontend now
//! funnels through [`factory`]: the CLI, `imagine serve`, the examples
//! and the tests all construct backends identically, and an unknown or
//! unavailable backend fails with a typed error instead of a silent
//! fallback. The [`ModelHub`](super::ModelHub) hands the returned
//! factory to [`EngineHandle::deploy`](crate::engine::EngineHandle),
//! which runs it on the dispatcher thread (so non-`Send` backends like
//! the PJRT client work unchanged).

use super::error::ImagineError;
use super::session::BackendKind;
use crate::config::params::MacroParams;
use crate::coordinator::manifest::NetworkModel;
use crate::engine::{AnalogPool, BackendFactory, BatchBackend, BatchIdeal};
use crate::runtime::Runtime;
use anyhow::Result;

/// Everything a backend constructor may need; the hub fills this from a
/// deployment's resolved configuration.
pub(crate) struct BackendSpec {
    pub kind: BackendKind,
    pub model: NetworkModel,
    pub params: MacroParams,
    pub seed: u64,
    pub noise: bool,
    pub calibrate: bool,
    pub workers: usize,
    /// `(dir, name)` of the artifact directory — required by the PJRT
    /// backend to locate `<dir>/<name>.hlo.txt`.
    pub artifacts: Option<(String, String)>,
}

/// PJRT-backed batch backend: executes the AOT HLO artifact per image on
/// the dispatcher thread (the PJRT client is a single-threaded C handle,
/// which is why the factory constructs it *on* the dispatcher).
struct PjrtBackend {
    runtime: Runtime,
    model_name: String,
    /// `[1, input_shape...]`.
    input_shape: Vec<usize>,
}

impl BatchBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        images
            .iter()
            .map(|im| self.runtime.run_f32(&self.model_name, im, &self.input_shape))
            .collect()
    }

    fn describe(&self) -> String {
        format!("PJRT/HLO artifact '{}'", self.model_name)
    }

    // The default `retarget` applies: the artifact's arithmetic is
    // baked in, so explicit precision overrides are declined.
}

/// Build the backend factory for a spec. This is the only constructor
/// path in the crate: one match over [`BackendKind`], shared by the CLI,
/// the server and the examples. Static prerequisites (the PJRT artifact
/// directory) are checked here so callers get a typed error before the
/// dispatcher is involved.
///
/// `Send` backends (ideal, analog) are constructed *here*, on the
/// caller's thread, and the factory merely hands the finished backend
/// over — a hot deploy of an analog pool (die fabrication + SA
/// calibration) must not stall the shared dispatcher and every other
/// tenant's traffic. Only the PJRT client, which is genuinely
/// single-threaded and non-`Send`, is built on the dispatcher.
pub(crate) fn factory(spec: BackendSpec) -> Result<BackendFactory, ImagineError> {
    let kind = spec.kind;
    Ok(match kind {
        BackendKind::Ideal => {
            let BackendSpec { model, params, workers, .. } = spec;
            let backend =
                BatchIdeal::new(model, params, workers).map_err(|e| map_start_error(kind, e))?;
            Box::new(move || Ok(Box::new(backend) as Box<dyn BatchBackend>))
        }
        BackendKind::Analog => {
            let BackendSpec { model, params, seed, noise, calibrate, workers, .. } = spec;
            let backend = AnalogPool::new(model, params, seed, noise, calibrate, workers)
                .map_err(|e| map_start_error(kind, e))?;
            Box::new(move || Ok(Box::new(backend) as Box<dyn BatchBackend>))
        }
        BackendKind::Pjrt => {
            let Some((dir, name)) = spec.artifacts else {
                return Err(ImagineError::BackendUnavailable {
                    backend: kind,
                    reason: "the PJRT backend needs an artifact directory \
                             (Deployment::from_artifacts / --dir)"
                        .to_string(),
                });
            };
            let hlo = std::path::Path::new(&dir).join(format!("{name}.hlo.txt"));
            let mut input_shape = vec![1usize];
            input_shape.extend(&spec.model.input_shape);
            Box::new(move || {
                let mut runtime = Runtime::new()?;
                runtime.load_hlo_text(&name, &hlo)?;
                Ok(Box::new(PjrtBackend { runtime, model_name: name, input_shape })
                    as Box<dyn BatchBackend>)
            })
        }
    })
}

/// Classify a backend start failure crossing the facade boundary.
pub(crate) fn map_start_error(kind: BackendKind, e: anyhow::Error) -> ImagineError {
    match kind {
        // A PJRT start failure is an availability problem (stub runtime,
        // missing/broken HLO) — never silently fall back to a simulator
        // that would serve numerically different logits.
        BackendKind::Pjrt => ImagineError::BackendUnavailable {
            backend: kind,
            reason: format!("{e:#}"),
        },
        _ => ImagineError::Engine { message: format!("{e:#}") },
    }
}
