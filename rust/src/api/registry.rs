//! The backend registry — the single place a [`BackendKind`] becomes a
//! running engine.
//!
//! Before the facade existed, `main.rs` and the server each hand-wired
//! their own `NetworkModel + MacroParams + backend` match (and the
//! server could not reach the analog backend at all). Every frontend now
//! funnels through [`start`]: the CLI, `imagine serve`, the examples and
//! the tests all construct backends identically, and an unknown or
//! unavailable backend fails with a typed error instead of a silent
//! fallback.

use super::error::ImagineError;
use super::session::BackendKind;
use crate::config::params::MacroParams;
use crate::coordinator::manifest::NetworkModel;
use crate::engine::{self, AnalogPool, BatchBackend, BatchIdeal, EngineConfig, EngineHandle};
use crate::runtime::Runtime;
use crate::util::stats::AtomicHistogram;
use anyhow::Result;
use std::sync::Arc;

/// Everything a backend constructor may need; the session builder fills
/// this from its resolved configuration.
pub(crate) struct BackendSpec {
    pub kind: BackendKind,
    pub model: NetworkModel,
    pub params: MacroParams,
    pub seed: u64,
    pub noise: bool,
    pub calibrate: bool,
    pub workers: usize,
    /// `(dir, name)` of the artifact directory — required by the PJRT
    /// backend to locate `<dir>/<name>.hlo.txt`.
    pub artifacts: Option<(String, String)>,
}

/// PJRT-backed batch backend: executes the AOT HLO artifact per image on
/// the dispatcher thread (the PJRT client is a single-threaded C handle,
/// which is why the factory constructs it *on* the dispatcher).
struct PjrtBackend {
    runtime: Runtime,
    model_name: String,
    /// `[1, input_shape...]`.
    input_shape: Vec<usize>,
}

impl BatchBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        images
            .iter()
            .map(|im| self.runtime.run_f32(&self.model_name, im, &self.input_shape))
            .collect()
    }

    fn describe(&self) -> String {
        format!("PJRT/HLO artifact '{}'", self.model_name)
    }
}

/// Start the engine for a backend spec. This is the only constructor
/// path in the crate: one match over [`BackendKind`], shared by the CLI,
/// the server and the examples.
pub(crate) fn start(
    spec: BackendSpec,
    cfg: EngineConfig,
    occupancy: Option<Arc<AtomicHistogram>>,
) -> Result<EngineHandle, ImagineError> {
    let kind = spec.kind;
    let started = match kind {
        BackendKind::Ideal => {
            let BackendSpec { model, params, workers, .. } = spec;
            engine::start(
                move || {
                    Ok(Box::new(BatchIdeal::new(model, params, workers)?)
                        as Box<dyn BatchBackend>)
                },
                cfg,
                occupancy,
            )
        }
        BackendKind::Analog => {
            let BackendSpec { model, params, seed, noise, calibrate, workers, .. } = spec;
            engine::start(
                move || {
                    Ok(Box::new(AnalogPool::new(
                        model, params, seed, noise, calibrate, workers,
                    )?) as Box<dyn BatchBackend>)
                },
                cfg,
                occupancy,
            )
        }
        BackendKind::Pjrt => {
            let Some((dir, name)) = spec.artifacts else {
                return Err(ImagineError::BackendUnavailable {
                    backend: kind,
                    reason: "the PJRT backend needs an artifact directory \
                             (SessionBuilder::from_artifacts / --dir)"
                        .to_string(),
                });
            };
            let hlo = std::path::Path::new(&dir).join(format!("{name}.hlo.txt"));
            let mut input_shape = vec![1usize];
            input_shape.extend(&spec.model.input_shape);
            engine::start(
                move || {
                    let mut runtime = Runtime::new()?;
                    runtime.load_hlo_text(&name, &hlo)?;
                    Ok(Box::new(PjrtBackend { runtime, model_name: name, input_shape })
                        as Box<dyn BatchBackend>)
                },
                cfg,
                occupancy,
            )
        }
    };
    started.map_err(|e| match kind {
        // A PJRT start failure is an availability problem (stub runtime,
        // missing/broken HLO) — never silently fall back to a simulator
        // that would serve numerically different logits.
        BackendKind::Pjrt => ImagineError::BackendUnavailable {
            backend: kind,
            reason: format!("{e:#}"),
        },
        _ => ImagineError::Engine { message: format!("{e:#}") },
    })
}
