//! Stub PJRT runtime for builds that cannot run HLO artifacts.
//!
//! API-compatible with the real `pjrt::Runtime`: every constructor and
//! execution entry point returns a descriptive error instead of running,
//! so the rest of the stack (server engine selection, CLI backends,
//! examples) compiles unchanged and degrades gracefully at runtime. The
//! error names the missing half — the `pjrt` feature, or the `xla`
//! bindings dependency it drives.

use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str = if cfg!(feature = "pjrt") {
    "PJRT runtime unavailable: the `pjrt` feature is compiled in but the `xla` bindings \
     dependency/feature is not (vendor the xla crate and build with --features pjrt,xla); \
     use the ideal/analog backends instead"
} else {
    "PJRT runtime unavailable: built without the `pjrt` cargo feature \
     (requires the vendored `xla` bindings); use the ideal/analog backends instead"
};

/// Placeholder for the PJRT CPU client + compiled-model registry.
pub struct Runtime {
    _priv: (),
}

impl Runtime {
    pub fn new() -> Result<Self> {
        bail!("{UNAVAILABLE}");
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load_hlo_text(&mut self, _name: &str, _path: impl AsRef<Path>) -> Result<()> {
        bail!("{UNAVAILABLE}");
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    pub fn model_names(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn compile_seconds(&self, _name: &str) -> Option<f64> {
        None
    }

    pub fn run_f32(&self, _name: &str, _input: &[f32], _in_dims: &[usize]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn run_i32(&self, _name: &str, _input: &[i32], _in_dims: &[usize]) -> Result<Vec<i32>> {
        bail!("{UNAVAILABLE}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = Runtime::new().err().expect("stub must not construct");
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
