//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path — python-free.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* (not serialized
//! proto — xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids)
//! → `HloModuleProto::from_text_file` → compile on the CPU PJRT client →
//! execute with `Literal` buffers. Computations are compiled once and
//! cached by name.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled executable plus bookkeeping.
pub struct LoadedModel {
    pub name: String,
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
    /// Compile wall time (perf accounting).
    pub compile_seconds: f64,
}

/// The runtime: one PJRT CPU client + a registry of compiled models.
pub struct Runtime {
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, models: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
        self.models.insert(
            name.to_string(),
            LoadedModel {
                name: name.to_string(),
                path: path.to_path_buf(),
                exe,
                compile_seconds: t0.elapsed().as_secs_f64(),
            },
        );
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.models.contains_key(name)
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn compile_seconds(&self, name: &str) -> Option<f64> {
        self.models.get(name).map(|m| m.compile_seconds)
    }

    fn exec_literals(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let model = self
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not loaded"))?;
        let result = model
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of '{name}': {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        lit.to_tuple1().map_err(|e| anyhow!("untupling '{name}': {e:?}"))
    }

    /// Execute a model taking one f32 tensor and returning one f32 tensor.
    pub fn run_f32(
        &self,
        name: &str,
        input: &[f32],
        in_dims: &[usize],
    ) -> Result<Vec<f32>> {
        let dims: Vec<i64> = in_dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshaping input: {e:?}"))?;
        let out = self.exec_literals(name, &[lit])?;
        out.to_vec::<f32>().map_err(|e| anyhow!("reading f32 output: {e:?}"))
    }

    /// Execute a model taking one i32 tensor and returning one i32 tensor.
    pub fn run_i32(
        &self,
        name: &str,
        input: &[i32],
        in_dims: &[usize],
    ) -> Result<Vec<i32>> {
        let dims: Vec<i64> = in_dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshaping input: {e:?}"))?;
        let out = self.exec_literals(name, &[lit])?;
        out.to_vec::<i32>().map_err(|e| anyhow!("reading i32 output: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests live in rust/tests/runtime_integration.rs — they need
    // the artifacts/ directory produced by `make artifacts`.
}
