//! PJRT-based runtime for AOT-compiled model artifacts (request path).
//!
//! The real binding (`pjrt.rs`, behind the `pjrt` cargo feature) drives
//! the `xla` (xla_extension) CPU client. The default build is fully
//! offline and ships [`stub::Runtime`] instead: same API, but
//! `Runtime::new()` reports that the PJRT path is unavailable so callers
//! (server engine selection, `imagine run --backend pjrt`) can fall back
//! to the rust executor engine with a clear message.

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;
