//! PJRT-based runtime for AOT-compiled model artifacts (request path).
//!
//! The real binding (`pjrt.rs`) drives the `xla` (xla_extension) CPU
//! client and needs two things: the `pjrt` cargo feature (the runtime
//! surface) *and* the `xla` cargo feature (the vendored bindings crate,
//! added to the dependency set by hand — the default build environment
//! is offline). Every other combination ships [`stub::Runtime`]: same
//! API, but `Runtime::new()` reports exactly which half is missing so
//! callers (server engine selection, `imagine run --backend pjrt`) fall
//! back to the rust executor engine with a clear message. This split is
//! what lets CI build `--features pjrt` without the bindings and keep
//! the feature-gated code paths from rotting unbuilt.

#[cfg(all(feature = "pjrt", feature = "xla"))]
pub mod pjrt;
#[cfg(all(feature = "pjrt", feature = "xla"))]
pub use pjrt::Runtime;

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
pub mod stub;
#[cfg(not(all(feature = "pjrt", feature = "xla")))]
pub use stub::Runtime;

/// Whether this build can actually execute HLO artifacts (both the
/// `pjrt` surface and the `xla` bindings compiled in). `--backend auto`
/// resolution keys off this, not the raw feature flags.
pub const PJRT_AVAILABLE: bool = cfg!(all(feature = "pjrt", feature = "xla"));
