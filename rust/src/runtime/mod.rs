//! PJRT-based runtime for AOT-compiled model artifacts (request path).

pub mod pjrt;

pub use pjrt::Runtime;
