//! Layer-to-macro scheduling (§IV): fit checking, column tiling, weight
//! reload accounting, and per-layer cycle/energy planning.
//!
//! The scheduler turns a [`NetworkModel`] into a sequence of macro
//! *passes* — each pass holds one weight tile resident in the CIM-SRAM —
//! and prices the plan with the pipeline and energy models. It is what
//! the `imagine plan` CLI prints and what the end-to-end example uses to
//! report accelerator-level numbers.

use crate::coordinator::manifest::{Kind, Layer, NetworkModel};
use crate::config::params::MacroParams;
use crate::dataflow::pipeline::{dram_weight_cycles, LayerShape};
use crate::energy::system::{layer_cost, LayerCost};

/// One scheduled layer.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub name: String,
    pub shape: LayerShape,
    /// Column passes (weight tiles) needed for all outputs.
    pub col_passes: usize,
    /// Weight bits moved per reload of this layer's tiles.
    pub weight_bits: u64,
    /// DRAM cycles to (re)load weights at a 32b off-chip bus (§IV).
    pub reload_cycles: u64,
    /// Steady-state cost of one image through this layer.
    pub cost: LayerCost,
    /// Whether the layer's rows fit the macro in a single row tile.
    pub fits_rows: bool,
    /// Input-dominated (Eq. 9) vs output-dominated (Eq. 10).
    pub input_dominated: bool,
}

/// Full network plan.
#[derive(Clone, Debug)]
pub struct NetworkPlan {
    pub layers: Vec<LayerPlan>,
    pub total: LayerCost,
    pub total_reload_cycles: u64,
}

/// Spatial dims tracker for conv chains.
fn out_dims(layer: &Layer, h: usize, w: usize) -> (usize, usize) {
    match layer.kind {
        Kind::Dense => (1, 1),
        Kind::Conv3 => {
            let (oh, ow) = (h.div_ceil(layer.stride), w.div_ceil(layer.stride));
            match layer.pool {
                crate::coordinator::manifest::Pool::Max2
                | crate::coordinator::manifest::Pool::Avg2 => (oh / 2, ow / 2),
                crate::coordinator::manifest::Pool::Gap => (1, 1),
                crate::coordinator::manifest::Pool::None => (oh, ow),
            }
        }
    }
}

/// Build the plan for a model on the given macro parameters.
pub fn plan(model: &NetworkModel, p: &MacroParams) -> NetworkPlan {
    let mut layers = Vec::new();
    let mut total = LayerCost::default();
    let mut total_reload = 0u64;

    let (mut h, mut w) = match model.input_shape.len() {
        3 => (model.input_shape[1], model.input_shape[2]),
        _ => (1, 1),
    };

    for layer in &model.layers {
        let (conv_oh, conv_ow) = match layer.kind {
            Kind::Conv3 => (h.div_ceil(layer.stride), w.div_ceil(layer.stride)),
            Kind::Dense => (1, 1),
        };
        let shape = match layer.kind {
            Kind::Dense => LayerShape::fc(
                layer.in_features,
                layer.out_features,
                layer.cfg.r_in,
                layer.cfg.r_out,
            ),
            Kind::Conv3 => LayerShape::conv(
                layer.in_features,
                layer.out_features,
                layer.cfg.r_in,
                layer.cfg.r_out,
                conv_oh,
                conv_ow,
            ),
        };
        let col_passes = layer.out_features.div_ceil(p.n_blocks());
        let weight_bits = (layer.rows * layer.out_features * layer.cfg.r_w as usize) as u64;
        let reload_cycles = dram_weight_cycles(weight_bits, 32);
        let cost = layer_cost(p, &shape, &layer.cfg, col_passes, true);
        total.accumulate(&cost);
        total_reload += reload_cycles;
        layers.push(LayerPlan {
            name: layer.name.clone(),
            shape,
            col_passes,
            weight_bits,
            reload_cycles,
            cost,
            fits_rows: layer.rows <= p.n_rows,
            input_dominated: shape.input_dominated(),
        });
        let (nh, nw) = out_dims(layer, h, w);
        h = nh;
        w = nw;
    }
    NetworkPlan { layers, total, total_reload_cycles: total_reload }
}

impl NetworkPlan {
    /// Human-readable table (the `imagine plan` output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "layer        passes  cycles      in-dom  E_macro[nJ]  E_dig[nJ]  E_leak[nJ]\n",
        );
        for l in &self.layers {
            s.push_str(&format!(
                "{:<12} {:>6}  {:>10}  {:>6}  {:>11.3}  {:>9.3}  {:>10.3}\n",
                l.name,
                l.col_passes,
                l.cost.cycles,
                if l.input_dominated { "yes" } else { "no" },
                l.cost.e_macro * 1e9,
                l.cost.e_digital * 1e9,
                l.cost.e_leak * 1e9,
            ));
        }
        s.push_str(&format!(
            "TOTAL: {} cycles, {:.3} µJ/image, {:.1} GOPS eff, EE {:.1} TOPS/W (8b-norm)\n",
            self.total.cycles,
            self.total.e_total() * 1e6,
            self.total.throughput_8b() / 1e9,
            self.total.ee_8b() / 1e12,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    // Plans over real manifests are exercised in rust/tests/e2e_network.rs.
}
