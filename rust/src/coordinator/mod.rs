//! The L3 coordinator: model loading, layer scheduling, the network
//! executor (ideal + circuit-accurate backends) and the inference server.

pub mod executor;
pub mod manifest;
pub mod scheduler;
pub mod server;
