//! Batch inference server — the deployable face of the coordinator.
//!
//! A line-delimited JSON protocol over TCP: each request line is
//! `{"image": [f32...]}` (length must match the model's input shape) and
//! each response line is `{"logits": [...], "class": k, "micros": t}`.
//! `{"cmd": "stats"}` returns aggregate counters; `{"cmd": "quit"}`
//! closes the connection.
//!
//! The server runs the AOT/PJRT functional path by default (python-free
//! request path), with the ideal-contract executor as a fallback when no
//! HLO artifact is available. std::net + a thread per connection — the
//! vendored dependency set has no tokio, and the workload is compute-
//! bound on the PJRT call anyway.

use crate::coordinator::executor::{Backend, Executor};
use crate::coordinator::manifest::NetworkModel;
use crate::config::params::MacroParams;
use crate::runtime::Runtime;
use crate::util::json::{arr_f64, obj, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Aggregate serving statistics.
#[derive(Default, Debug)]
pub struct Stats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub total_micros: AtomicU64,
}

impl Stats {
    pub fn snapshot_json(&self) -> Json {
        let n = self.requests.load(Ordering::Relaxed);
        let us = self.total_micros.load(Ordering::Relaxed);
        obj(vec![
            ("requests", Json::Num(n as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "mean_latency_micros",
                Json::Num(if n > 0 { us as f64 / n as f64 } else { 0.0 }),
            ),
        ])
    }
}

/// Inference engine behind the server: PJRT artifact or rust executor.
pub enum Engine {
    Pjrt {
        runtime: Runtime,
        model_name: String,
        input_shape: Vec<usize>,
    },
    Sim(Mutex<Executor>),
}

impl Engine {
    /// Build from artifacts: prefer `<name>.hlo.txt`, fall back to the
    /// ideal-contract executor on the manifest.
    pub fn from_artifacts(dir: &str, name: &str) -> Result<Engine> {
        let hlo = std::path::Path::new(dir).join(format!("{name}.hlo.txt"));
        let model = NetworkModel::load(dir, name)?;
        if hlo.exists() {
            let mut runtime = Runtime::new()?;
            runtime.load_hlo_text(name, &hlo)?;
            let mut input_shape = vec![1usize];
            input_shape.extend(&model.input_shape);
            Ok(Engine::Pjrt { runtime, model_name: name.to_string(), input_shape })
        } else {
            let exec = Executor::new(model, MacroParams::paper(), Backend::Ideal)?;
            Ok(Engine::Sim(Mutex::new(exec)))
        }
    }

    pub fn input_len(&self) -> usize {
        match self {
            Engine::Pjrt { input_shape, .. } => input_shape.iter().product(),
            Engine::Sim(e) => e.lock().unwrap().model.input_shape.iter().product(),
        }
    }

    pub fn infer(&self, image: &[f32]) -> Result<Vec<f32>> {
        match self {
            Engine::Pjrt { runtime, model_name, input_shape } => {
                runtime.run_f32(model_name, image, input_shape)
            }
            Engine::Sim(exec) => exec.lock().unwrap().forward(image),
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Handle one request line; returns the response line (never fails the
/// connection — errors are reported in-band).
pub fn handle_line(engine: &Engine, stats: &Stats, line: &str) -> Option<String> {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(
                obj(vec![("error", Json::Str(format!("bad json: {e}")))]).to_string_compact(),
            );
        }
    };
    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Some(stats.snapshot_json().to_string_compact()),
            "quit" => None,
            other => Some(
                obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))])
                    .to_string_compact(),
            ),
        };
    }
    let image: Option<Vec<f32>> = parsed.get("image").and_then(Json::as_arr).map(|a| {
        a.iter()
            .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
            .collect()
    });
    let image = match image {
        Some(v) if v.len() == engine.input_len() && v.iter().all(|x| x.is_finite()) => v,
        _ => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(
                obj(vec![(
                    "error",
                    Json::Str(format!(
                        "expected 'image' with {} finite values",
                        engine.input_len()
                    )),
                )])
                .to_string_compact(),
            );
        }
    };
    let t0 = std::time::Instant::now();
    match engine.infer(&image) {
        Ok(logits) => {
            let us = t0.elapsed().as_micros() as u64;
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.total_micros.fetch_add(us, Ordering::Relaxed);
            Some(
                obj(vec![
                    ("logits", arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                    ("class", Json::Num(argmax(&logits) as f64)),
                    ("micros", Json::Num(us as f64)),
                ])
                .to_string_compact(),
            )
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            Some(obj(vec![("error", Json::Str(format!("{e:#}")))]).to_string_compact())
        }
    }
}

fn serve_conn(engine: &Engine, stats: &Stats, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone().context("cloning stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match handle_line(engine, stats, &line) {
            Some(resp) => {
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            None => break, // quit
        }
    }
    eprintln!("connection closed: {peer:?}");
    Ok(())
}

/// Run the server (blocks). Connections are handled sequentially on the
/// accept thread: the PJRT client is a single-threaded C handle (!Send),
/// and inference is compute-bound on it anyway. `max_conns` stops after
/// N connections when Some — used by the integration test.
pub fn serve(engine: Engine, addr: &str, max_conns: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("imagine server listening on {addr}");
    let stats = Stats::default();
    let mut conns = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        if let Err(err) = serve_conn(&engine, &stats, stream) {
            eprintln!("connection error: {err:#}");
        }
        conns += 1;
        if let Some(max) = max_conns {
            if conns >= max {
                break;
            }
        }
    }
    eprintln!("server stats: {}", stats.snapshot_json().to_string_compact());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn stats_snapshot() {
        let s = Stats::default();
        s.requests.fetch_add(4, Ordering::Relaxed);
        s.total_micros.fetch_add(400, Ordering::Relaxed);
        let j = s.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("mean_latency_micros").unwrap().as_f64(), Some(100.0));
    }

    #[test]
    fn bad_json_is_reported_in_band() {
        // Engine-independent error paths (no artifacts needed): feed a
        // request that fails to parse.
        let s = Stats::default();
        // A fake engine would require artifacts; the json-error path
        // short-circuits before touching the engine, so exercising it via
        // a null pointer is not possible in safe rust — instead this is
        // covered in the integration test. Here we only check parsing of
        // the cmd dispatch plumbing.
        let _ = &s;
        assert!(Json::parse("{nope").is_err());
    }
}
