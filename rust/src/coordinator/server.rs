//! Multi-tenant batch inference server — the deployable face of the
//! coordinator.
//!
//! ### Protocol (version 3)
//!
//! Line-delimited JSON over TCP. One process serves many named models
//! over one shared engine ([`ModelHub`]); every inference request may
//! name its model and its (r_in, r_out) precision. Requests:
//!
//! * `{"image": [f32...], "model": "mnist", "precision": "2,4"}` — run
//!   inference. `model` falls back to the default deployment (the
//!   earliest still-deployed model) and `precision` (a number `R` or a
//!   string `"R_IN,R_OUT"`) falls back to the deployment's default;
//!   per-request precision produces logits bit-identical to a dedicated
//!   session built at that precision. Response
//!   `{"model": "mnist", "logits": [...], "class": k, "micros": t}`
//!   (non-finite logits are serialized as `null` — JSON has no NaN);
//! * `{"cmd": "models"}` — the deployment registry: the default model
//!   plus every deployment's backend, shapes, default precision and
//!   served image count;
//! * `{"cmd": "deploy", "name": "m2", "dir": "artifacts", "manifest":
//!   "mlp784", "backend": "auto", "precision": 4}` — hot-load a model
//!   from tensorfile artifacts while traffic flows (`manifest` defaults
//!   to `name`; deploying over an existing name is a hot reload);
//! * `{"cmd": "undeploy", "name": "m2"}` — unload a model; concurrent
//!   connections stay up, requests to the gone model get in-band errors;
//! * `{"cmd": "info", "model": ..., "precision": ...}` — one
//!   deployment's resolved configuration (including *why* `--backend
//!   auto` chose its backend), plus live engine counters and the modeled
//!   accelerator energy;
//! * `{"cmd": "graph_info", "model": ...}` — a served model's layer
//!   graph with per-layer modeled accelerator cost;
//! * `{"cmd": "stats"}` — aggregate serving counters and latency /
//!   batch-occupancy percentiles, plus the live `queue_depth` gauge and
//!   raw `latency_buckets` a cluster router consumes for back-pressure
//!   and fleet-wide percentile merges;
//! * `{"cmd": "quit"}` — close this connection;
//! * `{"cmd": "shutdown"}` — gracefully stop the whole server: stop
//!   accepting, let in-flight requests finish, drain the engine queue,
//!   then return from `serve` (SIGINT does the same in `imagine serve`).
//!
//! Errors are reported in-band as `{"error": "..."}` lines.
//!
//! Concurrency model: every connection gets its own handler thread, and
//! all handlers share one [`ModelHub`] into the engine layer's
//! work-queue scheduler — concurrent requests coalesce per (deployment,
//! precision) key instead of serializing on a global executor lock.

use crate::api::{parse_precision, Deployment, ImagineError, ModelHub, Session};
use crate::util::json::{arr_usize, obj, Json};
use crate::util::stats::{argmax_f32 as argmax, pow2_bounds, AtomicHistogram};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Version of the line-JSON protocol, reported by `info` and `stats`.
pub const PROTOCOL_VERSION: u32 = 3;

/// How long connection handlers block in `read` before checking the
/// server stop flag (bounds graceful-shutdown latency for idle
/// connections).
const READ_POLL: Duration = Duration::from_millis(250);

/// Upper bound on a blocked response write: generous enough for a slow
/// reader, but a client that stops draining its socket cannot pin a
/// handler thread (and with it, graceful shutdown) forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Aggregate serving statistics: counters plus latency / batch-occupancy
/// histograms (p50/p99, not just the mean).
#[derive(Debug)]
pub struct Stats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub total_micros: AtomicU64,
    /// Inference requests currently executing (the worker's queue depth
    /// as seen by a cluster router's back-pressure probes).
    pub inflight: AtomicU64,
    /// Per-request end-to-end latency [µs].
    pub latency: AtomicHistogram,
    /// Images per dispatched batch (shared with the engine dispatcher).
    pub occupancy: Arc<AtomicHistogram>,
}

impl Default for Stats {
    fn default() -> Self {
        Self {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            total_micros: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            // 1 µs .. ~67 s in power-of-two buckets.
            latency: AtomicHistogram::new(pow2_bounds(26)),
            // Batch sizes 1 .. 1024.
            occupancy: Arc::new(AtomicHistogram::new(pow2_bounds(10))),
        }
    }
}

impl Stats {
    pub fn snapshot_json(&self) -> Json {
        let n = self.requests.load(Ordering::Relaxed);
        let us = self.total_micros.load(Ordering::Relaxed);
        obj(vec![
            ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
            ("requests", Json::Num(n as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "mean_latency_micros",
                Json::Num(if n > 0 { us as f64 / n as f64 } else { 0.0 }),
            ),
            ("p50_latency_micros", Json::Num(self.latency.percentile(50.0) as f64)),
            ("p99_latency_micros", Json::Num(self.latency.percentile(99.0) as f64)),
            // Raw latency buckets + live queue depth: what a cluster
            // router needs for fleet-wide percentile merges and
            // back-pressure (see util::stats::merge_histogram_buckets).
            (
                "queue_depth",
                Json::Num(self.inflight.load(Ordering::Relaxed) as f64),
            ),
            (
                "latency_buckets",
                crate::util::stats::buckets_to_json(&self.latency.nonzero_buckets()),
            ),
            ("batches", Json::Num(self.occupancy.count() as f64)),
            ("mean_batch_occupancy", Json::Num(self.occupancy.mean())),
            (
                "p99_batch_occupancy",
                Json::Num(self.occupancy.percentile(99.0) as f64),
            ),
        ])
    }

    /// Multi-line human-readable summary (printed at `serve` shutdown).
    pub fn render_summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests {}  errors {}  mean latency {:.1} us  p50 {} us  p99 {} us\n",
            self.requests.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            {
                let n = self.requests.load(Ordering::Relaxed);
                let us = self.total_micros.load(Ordering::Relaxed);
                if n > 0 { us as f64 / n as f64 } else { 0.0 }
            },
            self.latency.percentile(50.0),
            self.latency.percentile(99.0),
        ));
        s.push_str(&format!(
            "batches {}  occupancy mean {:.2}  p99 {}\n",
            self.occupancy.count(),
            self.occupancy.mean(),
            self.occupancy.percentile(99.0),
        ));
        if self.occupancy.count() > 0 {
            s.push_str("batch-occupancy buckets (<=bound: count):");
            for (bound, count) in self.occupancy.nonzero_buckets() {
                if bound == u64::MAX {
                    s.push_str(&format!("  >1024: {count}"));
                } else {
                    s.push_str(&format!("  <={bound}: {count}"));
                }
            }
            s.push('\n');
        }
        s
    }
}

/// Everything the connection handlers share: the hub, the counters, and
/// the graceful-shutdown flag.
pub struct ServerState {
    hub: ModelHub,
    pub stats: Stats,
    stop: AtomicBool,
}

impl ServerState {
    pub fn new(hub: ModelHub, stats: Stats) -> ServerState {
        ServerState { hub, stats, stop: AtomicBool::new(false) }
    }

    pub fn hub(&self) -> &ModelHub {
        &self.hub
    }

    /// Ask the server to shut down gracefully: stop accepting, finish
    /// in-flight requests, drain the engine, return from `serve`.
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// Per-connection cache of routed session handles, keyed by the
/// request's (model, precision) pair (`None` model = the default
/// deployment). Handles are revalidated against the hub so a hot
/// reload or undeploy is picked up on the next request. Lookups are
/// allocation-free on the steady-state hit path (named models probe a
/// `&str`-borrowable map; a key `String` is built only on a miss).
#[derive(Default)]
pub struct SessionCache {
    named: HashMap<String, HashMap<Option<(u32, u32)>, Session>>,
    default: HashMap<Option<(u32, u32)>, Session>,
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    fn resolve(
        &mut self,
        hub: &ModelHub,
        model: Option<&str>,
        precision: Option<(u32, u32)>,
    ) -> Result<Session, ImagineError> {
        let cached = match model {
            Some(name) => self.named.get(name).and_then(|m| m.get(&precision)),
            None => self.default.get(&precision),
        };
        if let Some(session) = cached {
            if session.is_live() {
                return Ok(session.clone());
            }
        }
        let base = match model {
            Some(name) => hub.session(name)?,
            None => hub.default_session()?,
        };
        let session = match precision {
            Some((r_in, r_out)) => base.with_precision(r_in, r_out)?,
            None => base,
        };
        match model {
            Some(name) => {
                self.named
                    .entry(name.to_string())
                    .or_default()
                    .insert(precision, session.clone());
            }
            None => {
                self.default.insert(precision, session.clone());
            }
        }
        Ok(session)
    }
}

fn error_json(message: impl std::fmt::Display) -> String {
    obj(vec![("error", Json::Str(format!("{message}")))]).to_string_compact()
}

/// The request's precision override: a number `R` or a string
/// `"R_IN,R_OUT"`; absent/null = the deployment default. Shared with
/// the cluster router, which parses the same wire shape.
pub(crate) fn request_precision(parsed: &Json) -> Result<Option<(u32, u32)>, ImagineError> {
    match parsed.get("precision") {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => parse_precision(s).map(Some),
        Some(other) => match other.as_usize() {
            Some(r) => parse_precision(&r.to_string()).map(Some),
            None => Err(ImagineError::Parse {
                what: "precision",
                value: other.to_string_compact(),
                expected: "R or \"R_IN,R_OUT\" with bits in 1..=8",
            }),
        },
    }
}

/// The `info` command: one deployment's resolved configuration + its
/// live engine counters.
fn info_json(session: &Session) -> Json {
    let mut map = match session.config().to_json() {
        Json::Obj(map) => map,
        // lint:allow(request-path-panic) SessionConfig::to_json structurally returns Json::Obj
        _ => unreachable!("SessionConfig::to_json returns an object"),
    };
    map.insert("protocol".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    if let Ok(snap) = session.snapshot() {
        map.insert("images".to_string(), Json::Num(snap.images as f64));
        map.insert("batches".to_string(), Json::Num(snap.batches as f64));
        if let Some(cost) = snap.cost {
            if cost.e_total() > 0.0 {
                map.insert(
                    "modeled_energy_uj".to_string(),
                    Json::Num(cost.e_total() * 1e6),
                );
                map.insert(
                    "modeled_ee_tops_w_8b".to_string(),
                    Json::Num(cost.ee_8b() / 1e12),
                );
            }
        }
    }
    Json::Obj(map)
}

/// The `graph_info` command: a served layer graph plus the engine's
/// per-layer modeled accelerator cost (accumulated over the images
/// executed so far — zero until the first inference).
fn graph_info_json(session: &Session) -> Json {
    let snap = session.snapshot().ok();
    let layer_costs = snap.as_ref().and_then(|s| s.layer_costs.as_deref());
    let layers: Vec<Json> = session
        .config()
        .layers
        .iter()
        .enumerate()
        .map(|(i, summary)| {
            let mut map = match summary.to_json() {
                Json::Obj(map) => map,
                // lint:allow(request-path-panic) LayerSummary::to_json structurally returns Json::Obj
                _ => unreachable!("LayerSummary::to_json returns an object"),
            };
            if let Some(cost) = layer_costs.and_then(|c| c.get(i)) {
                map.insert("cycles".to_string(), Json::Num(cost.cycles as f64));
                map.insert(
                    "modeled_energy_uj".to_string(),
                    Json::Num(cost.e_total() * 1e6),
                );
                if cost.e_total() > 0.0 {
                    map.insert(
                        "modeled_ee_tops_w_8b".to_string(),
                        Json::Num(cost.ee_8b() / 1e12),
                    );
                }
            }
            Json::Obj(map)
        })
        .collect();
    obj(vec![
        ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
        ("model", Json::Str(session.model().to_string())),
        ("input_shape", arr_usize(session.input_shape())),
        ("n_layers", Json::Num(layers.len() as f64)),
        ("layers", Json::Arr(layers)),
        (
            "images",
            Json::Num(snap.map(|s| s.images).unwrap_or(0) as f64),
        ),
    ])
}

/// The `models` command: the deployment registry.
fn models_json(hub: &ModelHub) -> Json {
    let models: Vec<Json> = hub
        .deployments()
        .into_iter()
        .map(|(name, config)| {
            let images = hub
                .session(&name)
                .ok()
                .and_then(|s| s.snapshot().ok())
                .map(|s| s.images)
                .unwrap_or(0);
            let precision = match config.precision {
                Some((r_in, r_out)) => obj(vec![
                    ("r_in", Json::Num(r_in as f64)),
                    ("r_out", Json::Num(r_out as f64)),
                ]),
                None => Json::Null,
            };
            let mut pairs = vec![
                ("name", Json::Str(name)),
                ("backend", Json::Str(config.backend.name().to_string())),
                ("input_shape", arr_usize(&config.input_shape)),
                ("input_len", Json::Num(config.input_len as f64)),
                ("precision", precision),
                ("images", Json::Num(images as f64)),
            ];
            if let Some(note) = &config.backend_note {
                pairs.push(("backend_note", Json::Str(note.clone())));
            }
            obj(pairs)
        })
        .collect();
    obj(vec![
        ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
        (
            "default",
            hub.default_model().map(Json::Str).unwrap_or(Json::Null),
        ),
        ("n_models", Json::Num(models.len() as f64)),
        ("models", Json::Arr(models)),
    ])
}

/// The `deploy` command: hot-load a model from tensorfile artifacts.
fn cmd_deploy(state: &ServerState, parsed: &Json) -> Result<String, ImagineError> {
    let Some(name) = parsed.get("name").and_then(Json::as_str) else {
        return Err(ImagineError::InvalidConfig {
            field: "name",
            message: "deploy needs a \"name\"".to_string(),
        });
    };
    let dir = parsed.get("dir").and_then(Json::as_str).unwrap_or("artifacts");
    let manifest = parsed.get("manifest").and_then(Json::as_str).unwrap_or(name);
    let precision = request_precision(parsed)?;
    let mut spec = Deployment::from_artifacts(dir, manifest)?;
    let backend_s = parsed.get("backend").and_then(Json::as_str).unwrap_or("auto");
    if backend_s == "auto" {
        // A requested default precision steers auto away from PJRT
        // (whose arithmetic is fixed at compile time).
        let (kind, note) = crate::api::BackendKind::auto_resolve_at(dir, manifest, precision);
        spec = spec.backend(kind).backend_note(note);
    } else {
        spec = spec.backend(crate::api::BackendKind::parse(backend_s)?);
    }
    if let Some((r_in, r_out)) = precision {
        spec = spec.precision(r_in, r_out);
    }
    if let Some(seed) = parsed.get("seed").and_then(Json::as_usize) {
        spec = spec.seed(seed as u64);
    }
    state.hub.deploy(name, spec)?;
    let config = state.hub.session(name)?.config().clone();
    let mut map = match config.to_json() {
        Json::Obj(map) => map,
        // lint:allow(request-path-panic) SessionConfig::to_json structurally returns Json::Obj
        _ => unreachable!("SessionConfig::to_json returns an object"),
    };
    map.insert("protocol".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    map.insert("deployed".to_string(), Json::Str(name.to_string()));
    Ok(Json::Obj(map).to_string_compact())
}

/// Handle one request line; returns the response line, or `None` to
/// close the connection (`quit`). Never fails the connection — errors
/// are reported in-band.
pub fn handle_line(state: &ServerState, cache: &mut SessionCache, line: &str) -> Option<String> {
    let stats = &state.stats;
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(error_json(format!("bad json: {e}")));
        }
    };
    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        let model = parsed.get("model").and_then(Json::as_str);
        return match cmd {
            "info" | "graph_info" => {
                let precision = match request_precision(&parsed) {
                    Ok(p) => p,
                    Err(e) => {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        return Some(error_json(e));
                    }
                };
                match cache.resolve(&state.hub, model, precision) {
                    Ok(session) if cmd == "info" => {
                        Some(info_json(&session).to_string_compact())
                    }
                    Ok(session) => Some(graph_info_json(&session).to_string_compact()),
                    Err(e) => {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        Some(error_json(e))
                    }
                }
            }
            "models" => Some(models_json(&state.hub).to_string_compact()),
            "deploy" => match cmd_deploy(state, &parsed) {
                Ok(resp) => Some(resp),
                Err(e) => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    Some(error_json(e))
                }
            },
            "undeploy" => {
                let Some(name) = parsed.get("name").and_then(Json::as_str) else {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    return Some(error_json("undeploy needs a \"name\""));
                };
                match state.hub.undeploy(name) {
                    Ok(()) => Some(
                        obj(vec![
                            ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
                            ("undeployed", Json::Str(name.to_string())),
                        ])
                        .to_string_compact(),
                    ),
                    Err(e) => {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        Some(error_json(e))
                    }
                }
            }
            "stats" => Some(stats.snapshot_json().to_string_compact()),
            "shutdown" => {
                state.request_stop();
                Some(
                    obj(vec![
                        ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
                        ("shutting_down", Json::Bool(true)),
                    ])
                    .to_string_compact(),
                )
            }
            "quit" => None,
            other => Some(error_json(format!("unknown cmd '{other}'"))),
        };
    }

    // Inference request: optional per-request model + precision routing.
    let model = parsed.get("model").and_then(Json::as_str);
    let precision = match request_precision(&parsed) {
        Ok(p) => p,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(error_json(e));
        }
    };
    let session = match cache.resolve(&state.hub, model, precision) {
        Ok(s) => s,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(error_json(e));
        }
    };
    let image: Option<Vec<f32>> = parsed.get("image").and_then(Json::as_arr).map(|a| {
        a.iter()
            .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
            .collect()
    });
    let image = match image {
        Some(v) if v.len() == session.input_len() && v.iter().all(|x| x.is_finite()) => v,
        _ => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(error_json(format!(
                "expected 'image' with {} finite values",
                session.input_len()
            )));
        }
    };
    let t0 = std::time::Instant::now();
    stats.inflight.fetch_add(1, Ordering::Relaxed);
    let inferred = session.infer_one(image);
    stats.inflight.fetch_sub(1, Ordering::Relaxed);
    match inferred {
        Ok(logits) => {
            let us = t0.elapsed().as_micros() as u64;
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.total_micros.fetch_add(us, Ordering::Relaxed);
            stats.latency.record(us);
            // JSON has no NaN/Inf: serialize non-finite logits as null.
            let logits_json = Json::Arr(
                logits
                    .iter()
                    .map(|&v| {
                        if v.is_finite() { Json::Num(v as f64) } else { Json::Null }
                    })
                    .collect(),
            );
            Some(
                obj(vec![
                    ("model", Json::Str(session.model().to_string())),
                    ("logits", logits_json),
                    ("class", Json::Num(argmax(&logits) as f64)),
                    ("micros", Json::Num(us as f64)),
                ])
                .to_string_compact(),
            )
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            Some(error_json(e))
        }
    }
}

fn serve_conn(state: &ServerState, stream: TcpStream) -> Result<()> {
    // Bounded reads so idle connections notice a graceful shutdown, and
    // bounded writes so a client that stops reading responses cannot
    // pin this handler (a timed-out write drops the connection).
    stream
        .set_read_timeout(Some(READ_POLL))
        .context("setting read timeout")?;
    stream
        .set_write_timeout(Some(WRITE_TIMEOUT))
        .context("setting write timeout")?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    let mut reader = BufReader::new(stream);
    let mut cache = SessionCache::new();
    // Accumulate raw bytes, not a String: read_line's UTF-8 guard
    // discards everything a call appended when a timeout lands mid
    // multi-byte character, silently corrupting the request stream.
    // read_until keeps partial bytes across timeouts; UTF-8 is only
    // decoded once a full line is in hand.
    let mut line = Vec::new();
    loop {
        match reader.read_until(b'\n', &mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {
                let quit = {
                    let text = String::from_utf8_lossy(&line);
                    let text = text.trim();
                    if text.is_empty() {
                        false
                    } else {
                        match handle_line(state, &mut cache, text) {
                            Some(resp) => {
                                writer.write_all(resp.as_bytes())?;
                                writer.write_all(b"\n")?;
                                false
                            }
                            None => true,
                        }
                    }
                };
                if quit {
                    break;
                }
                line.clear();
                // A busy connection must also observe a graceful stop:
                // finish the request just handled, then close, instead
                // of out-running the read-timeout check forever.
                if state.stop_requested() {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // `line` keeps any bytes already read; the next
                // read_until call appends the rest of the request.
                if state.stop_requested() {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Serve on an already-bound listener (tests bind port 0 and pass the
/// listener in). Each connection runs on its own thread sharing the
/// state's hub; `max_conns` stops *accepting* after N connections. The
/// loop also stops when [`ServerState::request_stop`] fires (the
/// `shutdown` command or SIGINT); either way it waits for the in-flight
/// handlers to finish and drains the engine queue before returning —
/// queued work is never abandoned.
pub fn serve_listener(
    state: &ServerState,
    listener: TcpListener,
    max_conns: Option<usize>,
) -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("setting listener non-blocking")?;
    std::thread::scope(|scope| -> Result<()> {
        let mut conns = 0usize;
        loop {
            if state.stop_requested() {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    // The accepted socket must block (with the read
                    // timeout serve_conn sets). A failure here is a
                    // per-connection problem — drop the socket, keep
                    // serving everyone else.
                    if let Err(e) = stream.set_nonblocking(false) {
                        eprintln!("accept error (set_nonblocking): {e}");
                        continue;
                    }
                    scope.spawn(move || {
                        let peer = stream.peer_addr().ok();
                        if let Err(err) = serve_conn(state, stream) {
                            eprintln!("connection error ({peer:?}): {err:#}");
                        }
                    });
                    conns += 1;
                    if let Some(max) = max_conns {
                        if conns >= max {
                            break;
                        }
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    std::thread::sleep(Duration::from_millis(25));
                }
                // A transient accept failure (ECONNABORTED, EMFILE under
                // load) must not tear down the server and its live
                // connections.
                Err(e) => {
                    eprintln!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
        Ok(())
    })?;
    // Every handler has exited; drain whatever is still queued in the
    // engine (async submissions, work enqueued right before shutdown).
    if let Err(e) = state.hub.drain() {
        eprintln!("engine drain error: {e}");
    }
    sigint_release(state);
    eprintln!(
        "server stats: {}",
        state.stats.snapshot_json().to_string_compact()
    );
    eprint!("{}", state.stats.render_summary());
    Ok(())
}

/// Bind `addr` and serve (blocks until `max_conns` is reached or a stop
/// is requested, then drains gracefully).
pub fn serve(state: &ServerState, addr: &str, max_conns: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr().context("resolving bound address")?;
    // Machine-readable readiness line on stdout (the human log goes to
    // stderr): spawners — the cluster router, test harnesses, scripts —
    // bind `--addr host:0` and parse the ephemeral port from this line.
    // Explicitly flushed: stdout is block-buffered when piped, and a
    // spawner blocks on this exact line.
    {
        let mut out = std::io::stdout();
        let _ = writeln!(out, "READY port={}", local.port());
        let _ = out.flush();
    }
    eprintln!(
        "imagine server listening on {addr} ({local}), serving {:?} (default {:?})",
        state.hub.models(),
        state.hub.default_model(),
    );
    serve_listener(state, listener, max_conns)
}

/// Anything SIGINT can gracefully stop: the worker server
/// ([`ServerState`]) or the cluster router
/// ([`Router`](crate::cluster::Router)). The watcher thread only needs
/// "ask it to stop" and "has it already been asked".
pub trait StopTarget: Send + Sync {
    /// Ask the target to shut down gracefully.
    fn request_stop(&self);
    /// Whether a stop has already been requested.
    fn stop_requested(&self) -> bool;
}

impl StopTarget for ServerState {
    fn request_stop(&self) {
        ServerState::request_stop(self);
    }
    fn stop_requested(&self) -> bool {
        ServerState::stop_requested(self)
    }
}

#[cfg(unix)]
static SIGINT_HIT: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
#[cfg(unix)]
static SIGINT_ACTIVE: std::sync::Mutex<Option<Arc<dyn StopTarget>>> = std::sync::Mutex::new(None);

/// Install a SIGINT handler that requests a graceful stop (drain
/// in-flight work, then return from the serve loop) instead of killing
/// the process with queued work. A second Ctrl-C while a stop is
/// already in progress force-quits (exit 130) — the drain may be stuck
/// behind a wedged batch. One watcher thread serves the whole process:
/// re-installing for a later server re-points it, and the serve loop
/// releases the registration (dropping the target) when it returns, so
/// a Ctrl-C with no server running exits instead of being swallowed.
/// No-op off unix.
#[cfg(unix)]
pub fn install_sigint_stop(target: Arc<dyn StopTarget>) {
    static WATCHER: std::sync::Once = std::sync::Once::new();
    extern "C" fn on_sigint(_sig: i32) {
        // Only async-signal-safe work here: set the flag, nothing else.
        SIGINT_HIT.store(true, Ordering::SeqCst);
    }
    extern "C" {
        // libc is linked by std on unix; declare the one symbol we need
        // rather than pulling a crate into the vendored dependency set.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    *SIGINT_ACTIVE.lock().unwrap() = Some(target);
    WATCHER.call_once(|| {
        const SIGINT: i32 = 2;
        // SAFETY: `signal` is the libc function declared above; the
        // handler is an `extern "C" fn` that only stores to an atomic
        // (async-signal-safe), and registration happens once under
        // `Once` before any signal can be consumed by the watcher.
        let _ = unsafe { signal(SIGINT, on_sigint) };
        std::thread::spawn(|| loop {
            // swap, not load: consume each signal exactly once.
            if SIGINT_HIT.swap(false, Ordering::SeqCst) {
                let active = SIGINT_ACTIVE.lock().unwrap().clone();
                match active {
                    Some(target) if !target.stop_requested() => {
                        eprintln!(
                            "SIGINT: draining in-flight work, shutting down \
                             (Ctrl-C again to force quit)..."
                        );
                        target.request_stop();
                    }
                    // Stop already in progress (wedged drain?) or no
                    // server registered: behave like an unhandled ^C.
                    _ => {
                        eprintln!("SIGINT: exiting immediately");
                        std::process::exit(130);
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(100));
        });
    });
}

#[cfg(not(unix))]
pub fn install_sigint_stop(_target: Arc<dyn StopTarget>) {}

/// Drop the SIGINT registration if it points at `target` — called when
/// its serve loop returns, so the watcher does not retain a dead hub or
/// swallow signals meant for nobody.
pub(crate) fn sigint_release(target: &dyn StopTarget) {
    #[cfg(unix)]
    {
        let mut active = SIGINT_ACTIVE.lock().unwrap();
        if let Some(current) = active.as_ref() {
            let cur = Arc::as_ptr(current) as *const ();
            if std::ptr::eq(cur, target as *const dyn StopTarget as *const ()) {
                *active = None;
            }
        }
    }
    #[cfg(not(unix))]
    let _ = target;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BackendKind, ModelHub, SessionConfig};
    use crate::config::params::{Corner, MacroParams, Supply};
    use crate::coordinator::manifest::NetworkModel;
    use crate::engine::BatchBackend;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // Regression: partial_cmp().unwrap() used to panic here, killing
        // the connection handler on any NaN from the analog backend.
        assert_eq!(argmax(&[0.1, f32::NAN, 0.3]), 1); // NaN tops the total order
        assert_eq!(argmax(&[f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    fn test_config(input_len: usize) -> SessionConfig {
        SessionConfig {
            model: "test".to_string(),
            input_shape: vec![input_len],
            input_len,
            backend: BackendKind::Ideal,
            backend_note: None,
            precision: None,
            supply: Supply::NOMINAL,
            corner: Corner::Tt,
            batch: 2,
            workers: 1,
            flush_micros: 50,
            seed: 0,
            engine: "test backend".to_string(),
            layers: Vec::new(),
        }
    }

    fn state_over(hub: ModelHub) -> ServerState {
        ServerState::new(hub, Stats::default())
    }

    #[test]
    fn nan_logits_yield_a_wellformed_response() {
        struct NanBackend;
        impl BatchBackend for NanBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn forward_batch(&mut self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(images.iter().map(|_| vec![f32::NAN, 0.5, f32::NAN]).collect())
            }
        }
        let hub = ModelHub::builder().batch(2).workers(1).flush_micros(50).build().unwrap();
        hub.deploy_custom("test", test_config(2), || {
            Ok(Box::new(NanBackend) as Box<dyn BatchBackend>)
        })
        .unwrap();
        let state = state_over(hub);
        let mut cache = SessionCache::new();
        let resp = handle_line(&state, &mut cache, r#"{"image": [0.1, 0.2]}"#).unwrap();
        // The response must stay parseable JSON (NaN logits become null)
        // and carry a class instead of panicking the handler.
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("class").unwrap().as_f64(), Some(2.0), "{resp}");
        assert_eq!(j.get("model").unwrap().as_str(), Some("test"), "{resp}");
        let logits = j.get("logits").unwrap().as_arr().unwrap();
        assert_eq!(logits[0], Json::Null);
        assert_eq!(logits[1].as_f64(), Some(0.5));
    }

    #[test]
    fn graph_info_reports_layers_and_per_layer_costs() {
        let p = MacroParams::paper();
        let model = NetworkModel::synthetic_mlp(&[36, 12, 3], 8, 4, 8, 2, &p);
        let session = crate::api::Session::builder(model).workers(1).batch(2).build().unwrap();
        let state = state_over(session.hub().clone());
        let mut cache = SessionCache::new();

        let resp = handle_line(&state, &mut cache, r#"{"cmd": "graph_info"}"#).unwrap();
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("protocol").unwrap().as_f64(), Some(PROTOCOL_VERSION as f64));
        assert_eq!(j.get("n_layers").unwrap().as_f64(), Some(2.0));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("kind").unwrap().as_str(), Some("dense"));
        assert_eq!(layers[0].get("out_features").unwrap().as_f64(), Some(12.0));
        // No images run yet: per-layer accumulated cost is zero.
        assert_eq!(layers[0].get("modeled_energy_uj").unwrap().as_f64(), Some(0.0));

        // After one inference the per-layer costs become non-zero and
        // (summed) match the aggregate snapshot cost.
        handle_line(
            &state,
            &mut cache,
            &format!("{{\"image\": {:?}}}", vec![0.5f32; 36]),
        )
        .unwrap();
        let resp = handle_line(&state, &mut cache, r#"{"cmd": "graph_info"}"#).unwrap();
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("images").unwrap().as_f64(), Some(1.0));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        let per_layer_sum: f64 = layers
            .iter()
            .map(|l| l.get("modeled_energy_uj").unwrap().as_f64().unwrap())
            .sum();
        assert!(per_layer_sum > 0.0);
        let snap = session.snapshot().unwrap();
        let total = snap.cost.unwrap().e_total() * 1e6;
        assert!(
            (per_layer_sum - total).abs() < 1e-9 * total.max(1.0),
            "{per_layer_sum} vs {total}"
        );
    }

    #[test]
    fn models_deploy_and_per_request_routing_through_handle_line() {
        let p = MacroParams::paper();
        let hub = ModelHub::builder().batch(4).workers(1).build().unwrap();
        hub.deploy(
            "a",
            crate::api::Deployment::new(NetworkModel::synthetic_mlp(&[12, 3], 8, 4, 8, 5, &p)),
        )
        .unwrap();
        hub.deploy(
            "b",
            crate::api::Deployment::new(NetworkModel::synthetic_mlp(&[20, 4], 8, 4, 8, 6, &p))
                .precision(4, 4),
        )
        .unwrap();
        let state = state_over(hub);
        let mut cache = SessionCache::new();

        // models lists both, default is the first deployed.
        let resp = handle_line(&state, &mut cache, r#"{"cmd": "models"}"#).unwrap();
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("default").unwrap().as_str(), Some("a"));
        assert_eq!(j.get("n_models").unwrap().as_f64(), Some(2.0));

        // No model field → default deployment a (12 inputs).
        let resp =
            handle_line(&state, &mut cache, &format!("{{\"image\": {:?}}}", vec![0.5f32; 12]))
                .unwrap();
        assert!(resp.contains("\"model\":\"a\""), "{resp}");
        // Explicit model + per-request precision → routed to b.
        let resp = handle_line(
            &state,
            &mut cache,
            &format!("{{\"model\": \"b\", \"precision\": 2, \"image\": {:?}}}", vec![0.5f32; 20]),
        )
        .unwrap();
        assert!(resp.contains("\"model\":\"b\""), "{resp}");

        // Unknown model and bad precision error in-band.
        let resp = handle_line(
            &state,
            &mut cache,
            &format!("{{\"model\": \"zzz\", \"image\": {:?}}}", vec![0.5f32; 12]),
        )
        .unwrap();
        assert!(resp.contains("error") && resp.contains("zzz"), "{resp}");
        let resp = handle_line(
            &state,
            &mut cache,
            &format!("{{\"precision\": 9, \"image\": {:?}}}", vec![0.5f32; 12]),
        )
        .unwrap();
        assert!(resp.contains("error"), "{resp}");

        // Undeploy the default; the other model takes over as default.
        let resp = handle_line(&state, &mut cache, r#"{"cmd": "undeploy", "name": "a"}"#).unwrap();
        assert!(resp.contains("\"undeployed\":\"a\""), "{resp}");
        let resp = handle_line(&state, &mut cache, r#"{"cmd": "models"}"#).unwrap();
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("default").unwrap().as_str(), Some("b"));
        // The cached default-route session is revalidated, not reused.
        let resp =
            handle_line(&state, &mut cache, &format!("{{\"image\": {:?}}}", vec![0.5f32; 20]))
                .unwrap();
        assert!(resp.contains("\"model\":\"b\""), "{resp}");
    }

    #[test]
    fn shutdown_command_requests_stop() {
        let hub = ModelHub::builder().workers(1).build().unwrap();
        let state = state_over(hub);
        let mut cache = SessionCache::new();
        assert!(!state.stop_requested());
        let resp = handle_line(&state, &mut cache, r#"{"cmd": "shutdown"}"#).unwrap();
        assert!(resp.contains("shutting_down"), "{resp}");
        assert!(state.stop_requested());
    }

    #[test]
    fn stats_snapshot() {
        let s = Stats::default();
        s.requests.fetch_add(4, Ordering::Relaxed);
        s.total_micros.fetch_add(400, Ordering::Relaxed);
        let j = s.snapshot_json();
        assert_eq!(j.get("protocol").unwrap().as_f64(), Some(PROTOCOL_VERSION as f64));
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("mean_latency_micros").unwrap().as_f64(), Some(100.0));
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(0.0));
        // Router-facing fields: live queue depth + raw latency buckets.
        assert_eq!(j.get("queue_depth").unwrap().as_f64(), Some(0.0));
        s.inflight.fetch_add(3, Ordering::Relaxed);
        s.latency.record(12);
        let j = s.snapshot_json();
        assert_eq!(j.get("queue_depth").unwrap().as_f64(), Some(3.0));
        let buckets =
            crate::util::stats::buckets_from_json(j.get("latency_buckets"));
        assert_eq!(buckets, vec![(16, 1)]);
    }

    #[test]
    fn stats_histograms_feed_percentiles() {
        let s = Stats::default();
        for us in [10u64, 20, 30, 40, 1000] {
            s.latency.record(us);
        }
        s.occupancy.record(1);
        s.occupancy.record(8);
        let j = s.snapshot_json();
        assert!(j.get("p50_latency_micros").unwrap().as_f64().unwrap() >= 20.0);
        assert!(j.get("p99_latency_micros").unwrap().as_f64().unwrap() >= 1000.0);
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(2.0));
        assert!((j.get("mean_batch_occupancy").unwrap().as_f64().unwrap() - 4.5).abs() < 1e-9);
        let summary = s.render_summary();
        assert!(summary.contains("occupancy"), "{summary}");
    }

    #[test]
    fn bad_json_is_reported_in_band() {
        assert!(Json::parse("{nope").is_err());
    }
}
