//! Batch inference server — the deployable face of the coordinator.
//!
//! ### Protocol (version 2)
//!
//! Line-delimited JSON over TCP. Requests:
//!
//! * `{"image": [f32...]}` — run inference (length must match the
//!   model's input length); response
//!   `{"logits": [...], "class": k, "micros": t}` (non-finite logits are
//!   serialized as `null` — JSON has no NaN);
//! * `{"cmd": "info"}` — the active session configuration: protocol
//!   version, model, backend, precision/supply/corner, batching knobs,
//!   plus live engine counters and the modeled accelerator energy;
//! * `{"cmd": "graph_info"}` — the served model's layer graph: one entry
//!   per macro-mapped layer (kind, features, rows, r_in/r_out, γ, fused
//!   relu/pool) with the per-layer modeled accelerator cost accumulated
//!   over everything executed (cycles, energy, 8b-normalized EE);
//! * `{"cmd": "stats"}` — aggregate serving counters and latency /
//!   batch-occupancy percentiles;
//! * `{"cmd": "quit"}` — close the connection.
//!
//! Errors are reported in-band as `{"error": "..."}` lines.
//!
//! Concurrency model: every connection gets its own handler thread, and
//! all handlers share one [`Session`] into the engine layer's work-queue
//! scheduler — concurrent requests coalesce into batches instead of
//! serializing on a global executor lock. The backend behind the session
//! is whatever the caller selected through the
//! [`SessionBuilder`](crate::api::SessionBuilder) registry (`imagine
//! serve --backend ideal|analog|pjrt|auto`).

use crate::api::Session;
use crate::util::json::{arr_usize, obj, Json};
use crate::util::stats::{argmax_f32 as argmax, pow2_bounds, AtomicHistogram};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Version of the line-JSON protocol, reported by `info` and `stats`.
pub const PROTOCOL_VERSION: u32 = 2;

/// Aggregate serving statistics: counters plus latency / batch-occupancy
/// histograms (p50/p99, not just the mean).
#[derive(Debug)]
pub struct Stats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub total_micros: AtomicU64,
    /// Per-request end-to-end latency [µs].
    pub latency: AtomicHistogram,
    /// Images per dispatched batch (shared with the engine dispatcher).
    pub occupancy: Arc<AtomicHistogram>,
}

impl Default for Stats {
    fn default() -> Self {
        Self {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            total_micros: AtomicU64::new(0),
            // 1 µs .. ~67 s in power-of-two buckets.
            latency: AtomicHistogram::new(pow2_bounds(26)),
            // Batch sizes 1 .. 1024.
            occupancy: Arc::new(AtomicHistogram::new(pow2_bounds(10))),
        }
    }
}

impl Stats {
    pub fn snapshot_json(&self) -> Json {
        let n = self.requests.load(Ordering::Relaxed);
        let us = self.total_micros.load(Ordering::Relaxed);
        obj(vec![
            ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
            ("requests", Json::Num(n as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "mean_latency_micros",
                Json::Num(if n > 0 { us as f64 / n as f64 } else { 0.0 }),
            ),
            ("p50_latency_micros", Json::Num(self.latency.percentile(50.0) as f64)),
            ("p99_latency_micros", Json::Num(self.latency.percentile(99.0) as f64)),
            ("batches", Json::Num(self.occupancy.count() as f64)),
            ("mean_batch_occupancy", Json::Num(self.occupancy.mean())),
            (
                "p99_batch_occupancy",
                Json::Num(self.occupancy.percentile(99.0) as f64),
            ),
        ])
    }

    /// Multi-line human-readable summary (printed at `serve` shutdown).
    pub fn render_summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests {}  errors {}  mean latency {:.1} us  p50 {} us  p99 {} us\n",
            self.requests.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            {
                let n = self.requests.load(Ordering::Relaxed);
                let us = self.total_micros.load(Ordering::Relaxed);
                if n > 0 { us as f64 / n as f64 } else { 0.0 }
            },
            self.latency.percentile(50.0),
            self.latency.percentile(99.0),
        ));
        s.push_str(&format!(
            "batches {}  occupancy mean {:.2}  p99 {}\n",
            self.occupancy.count(),
            self.occupancy.mean(),
            self.occupancy.percentile(99.0),
        ));
        if self.occupancy.count() > 0 {
            s.push_str("batch-occupancy buckets (<=bound: count):");
            for (bound, count) in self.occupancy.nonzero_buckets() {
                if bound == u64::MAX {
                    s.push_str(&format!("  >1024: {count}"));
                } else {
                    s.push_str(&format!("  <={bound}: {count}"));
                }
            }
            s.push('\n');
        }
        s
    }
}

/// The `info` command: session configuration + live engine counters.
fn info_json(session: &Session) -> Json {
    let mut map = match session.config().to_json() {
        Json::Obj(map) => map,
        _ => unreachable!("SessionConfig::to_json returns an object"),
    };
    map.insert("protocol".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    if let Ok(snap) = session.snapshot() {
        map.insert("images".to_string(), Json::Num(snap.images as f64));
        map.insert("batches".to_string(), Json::Num(snap.batches as f64));
        if let Some(cost) = snap.cost {
            if cost.e_total() > 0.0 {
                map.insert(
                    "modeled_energy_uj".to_string(),
                    Json::Num(cost.e_total() * 1e6),
                );
                map.insert(
                    "modeled_ee_tops_w_8b".to_string(),
                    Json::Num(cost.ee_8b() / 1e12),
                );
            }
        }
    }
    Json::Obj(map)
}

/// The `graph_info` command: the served layer graph plus the engine's
/// per-layer modeled accelerator cost (accumulated over the images
/// executed so far — zero until the first inference).
fn graph_info_json(session: &Session) -> Json {
    let snap = session.snapshot().ok();
    let layer_costs = snap.as_ref().and_then(|s| s.layer_costs.as_deref());
    let layers: Vec<Json> = session
        .config()
        .layers
        .iter()
        .enumerate()
        .map(|(i, summary)| {
            let mut map = match summary.to_json() {
                Json::Obj(map) => map,
                _ => unreachable!("LayerSummary::to_json returns an object"),
            };
            if let Some(cost) = layer_costs.and_then(|c| c.get(i)) {
                map.insert("cycles".to_string(), Json::Num(cost.cycles as f64));
                map.insert(
                    "modeled_energy_uj".to_string(),
                    Json::Num(cost.e_total() * 1e6),
                );
                if cost.e_total() > 0.0 {
                    map.insert(
                        "modeled_ee_tops_w_8b".to_string(),
                        Json::Num(cost.ee_8b() / 1e12),
                    );
                }
            }
            Json::Obj(map)
        })
        .collect();
    obj(vec![
        ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
        ("model", Json::Str(session.config().model.clone())),
        ("input_shape", arr_usize(session.input_shape())),
        ("n_layers", Json::Num(layers.len() as f64)),
        ("layers", Json::Arr(layers)),
        (
            "images",
            Json::Num(snap.map(|s| s.images).unwrap_or(0) as f64),
        ),
    ])
}

/// Handle one request line; returns the response line (never fails the
/// connection — errors are reported in-band).
pub fn handle_line(session: &Session, stats: &Stats, line: &str) -> Option<String> {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(
                obj(vec![("error", Json::Str(format!("bad json: {e}")))]).to_string_compact(),
            );
        }
    };
    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "info" => Some(info_json(session).to_string_compact()),
            "graph_info" => Some(graph_info_json(session).to_string_compact()),
            "stats" => Some(stats.snapshot_json().to_string_compact()),
            "quit" => None,
            other => Some(
                obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))])
                    .to_string_compact(),
            ),
        };
    }
    let image: Option<Vec<f32>> = parsed.get("image").and_then(Json::as_arr).map(|a| {
        a.iter()
            .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
            .collect()
    });
    let image = match image {
        Some(v) if v.len() == session.input_len() && v.iter().all(|x| x.is_finite()) => v,
        _ => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(
                obj(vec![(
                    "error",
                    Json::Str(format!(
                        "expected 'image' with {} finite values",
                        session.input_len()
                    )),
                )])
                .to_string_compact(),
            );
        }
    };
    let t0 = std::time::Instant::now();
    match session.infer_one(image) {
        Ok(logits) => {
            let us = t0.elapsed().as_micros() as u64;
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.total_micros.fetch_add(us, Ordering::Relaxed);
            stats.latency.record(us);
            // JSON has no NaN/Inf: serialize non-finite logits as null.
            let logits_json = Json::Arr(
                logits
                    .iter()
                    .map(|&v| {
                        if v.is_finite() { Json::Num(v as f64) } else { Json::Null }
                    })
                    .collect(),
            );
            Some(
                obj(vec![
                    ("logits", logits_json),
                    ("class", Json::Num(argmax(&logits) as f64)),
                    ("micros", Json::Num(us as f64)),
                ])
                .to_string_compact(),
            )
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            Some(obj(vec![("error", Json::Str(format!("{e}")))]).to_string_compact())
        }
    }
}

fn serve_conn(session: &Session, stats: &Stats, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone().context("cloning stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match handle_line(session, stats, &line) {
            Some(resp) => {
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            None => break, // quit
        }
    }
    Ok(())
}

/// Serve on an already-bound listener (tests bind port 0 and pass the
/// listener in). Each connection runs on its own thread sharing one
/// session; `max_conns` stops *accepting* after N connections, then
/// waits for the in-flight handlers to finish before returning.
pub fn serve_listener(
    session: Session,
    stats: &Stats,
    listener: TcpListener,
    max_conns: Option<usize>,
) -> Result<()> {
    std::thread::scope(|scope| -> Result<()> {
        let mut conns = 0usize;
        for stream in listener.incoming() {
            // A transient accept failure (ECONNABORTED, EMFILE under load)
            // must not tear down the server and its live connections.
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("accept error: {e}");
                    continue;
                }
            };
            let conn_session = session.clone();
            scope.spawn(move || {
                let peer = stream.peer_addr().ok();
                if let Err(err) = serve_conn(&conn_session, stats, stream) {
                    eprintln!("connection error ({peer:?}): {err:#}");
                }
            });
            conns += 1;
            if let Some(max) = max_conns {
                if conns >= max {
                    break;
                }
            }
        }
        Ok(())
    })?;
    eprintln!("server stats: {}", stats.snapshot_json().to_string_compact());
    eprint!("{}", stats.render_summary());
    Ok(())
}

/// Bind `addr` and serve (blocks until `max_conns` is reached, if given).
pub fn serve(
    session: Session,
    stats: &Stats,
    addr: &str,
    max_conns: Option<usize>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "imagine server listening on {addr} ({} -> {})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_default(),
        session.describe()
    );
    serve_listener(session, stats, listener, max_conns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BackendKind, SessionConfig};
    use crate::config::params::{Corner, Supply};
    use crate::engine::{self, BatchBackend, EngineConfig};

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // Regression: partial_cmp().unwrap() used to panic here, killing
        // the connection handler on any NaN from the analog backend.
        assert_eq!(argmax(&[0.1, f32::NAN, 0.3]), 1); // NaN tops the total order
        assert_eq!(argmax(&[f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    fn test_config(input_len: usize) -> SessionConfig {
        SessionConfig {
            model: "test".to_string(),
            input_shape: vec![input_len],
            input_len,
            backend: BackendKind::Ideal,
            precision: None,
            supply: Supply::NOMINAL,
            corner: Corner::Tt,
            batch: 2,
            workers: 1,
            flush_micros: 50,
            seed: 0,
            engine: "test backend".to_string(),
            layers: Vec::new(),
        }
    }

    #[test]
    fn nan_logits_yield_a_wellformed_response() {
        struct NanBackend;
        impl BatchBackend for NanBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn forward_batch(&mut self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(images.iter().map(|_| vec![f32::NAN, 0.5, f32::NAN]).collect())
            }
        }
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 50 };
        let handle = engine::start(
            || Ok(Box::new(NanBackend) as Box<dyn BatchBackend>),
            cfg,
            None,
        )
        .unwrap();
        let session = Session::from_handle(handle, test_config(2));
        let stats = Stats::default();
        let resp = handle_line(&session, &stats, r#"{"image": [0.1, 0.2]}"#).unwrap();
        // The response must stay parseable JSON (NaN logits become null)
        // and carry a class instead of panicking the handler.
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("class").unwrap().as_f64(), Some(2.0), "{resp}");
        let logits = j.get("logits").unwrap().as_arr().unwrap();
        assert_eq!(logits[0], Json::Null);
        assert_eq!(logits[1].as_f64(), Some(0.5));
    }

    #[test]
    fn graph_info_reports_layers_and_per_layer_costs() {
        use crate::config::params::MacroParams;
        use crate::coordinator::manifest::NetworkModel;

        let p = MacroParams::paper();
        let model = NetworkModel::synthetic_mlp(&[36, 12, 3], 8, 4, 8, 2, &p);
        let session = Session::builder(model).workers(1).batch(2).build().unwrap();
        let stats = Stats::default();

        let resp = handle_line(&session, &stats, r#"{"cmd": "graph_info"}"#).unwrap();
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("protocol").unwrap().as_f64(), Some(PROTOCOL_VERSION as f64));
        assert_eq!(j.get("n_layers").unwrap().as_f64(), Some(2.0));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("kind").unwrap().as_str(), Some("dense"));
        assert_eq!(layers[0].get("out_features").unwrap().as_f64(), Some(12.0));
        // No images run yet: per-layer accumulated cost is zero.
        assert_eq!(layers[0].get("modeled_energy_uj").unwrap().as_f64(), Some(0.0));

        // After one inference the per-layer costs become non-zero and
        // (summed) match the aggregate snapshot cost.
        handle_line(&session, &stats, &format!("{{\"image\": {:?}}}", vec![0.5f32; 36]))
            .unwrap();
        let resp = handle_line(&session, &stats, r#"{"cmd": "graph_info"}"#).unwrap();
        let j = Json::parse(&resp).expect(&resp);
        assert_eq!(j.get("images").unwrap().as_f64(), Some(1.0));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        let per_layer_sum: f64 = layers
            .iter()
            .map(|l| l.get("modeled_energy_uj").unwrap().as_f64().unwrap())
            .sum();
        assert!(per_layer_sum > 0.0);
        let snap = session.snapshot().unwrap();
        let total = snap.cost.unwrap().e_total() * 1e6;
        assert!((per_layer_sum - total).abs() < 1e-9 * total.max(1.0), "{per_layer_sum} vs {total}");
    }

    #[test]
    fn stats_snapshot() {
        let s = Stats::default();
        s.requests.fetch_add(4, Ordering::Relaxed);
        s.total_micros.fetch_add(400, Ordering::Relaxed);
        let j = s.snapshot_json();
        assert_eq!(j.get("protocol").unwrap().as_f64(), Some(PROTOCOL_VERSION as f64));
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("mean_latency_micros").unwrap().as_f64(), Some(100.0));
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn stats_histograms_feed_percentiles() {
        let s = Stats::default();
        for us in [10u64, 20, 30, 40, 1000] {
            s.latency.record(us);
        }
        s.occupancy.record(1);
        s.occupancy.record(8);
        let j = s.snapshot_json();
        assert!(j.get("p50_latency_micros").unwrap().as_f64().unwrap() >= 20.0);
        assert!(j.get("p99_latency_micros").unwrap().as_f64().unwrap() >= 1000.0);
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(2.0));
        assert!((j.get("mean_batch_occupancy").unwrap().as_f64().unwrap() - 4.5).abs() < 1e-9);
        let summary = s.render_summary();
        assert!(summary.contains("occupancy"), "{summary}");
    }

    #[test]
    fn bad_json_is_reported_in_band() {
        assert!(Json::parse("{nope").is_err());
    }
}
