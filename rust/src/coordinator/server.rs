//! Batch inference server — the deployable face of the coordinator.
//!
//! A line-delimited JSON protocol over TCP: each request line is
//! `{"image": [f32...]}` (length must match the model's input shape) and
//! each response line is `{"logits": [...], "class": k, "micros": t}`.
//! `{"cmd": "stats"}` returns aggregate counters; `{"cmd": "quit"}`
//! closes the connection.
//!
//! Concurrency model: every connection gets its own handler thread, and
//! all handlers share one [`EngineHandle`] into the engine layer's
//! work-queue scheduler — concurrent requests coalesce into batches
//! instead of serializing on a global executor lock. The backend behind
//! the queue is chosen per artifacts: the PJRT runtime when an HLO
//! artifact exists (and the `pjrt` feature is built in), otherwise the
//! batched ideal-contract engine on the manifest.

use crate::config::params::MacroParams;
use crate::coordinator::manifest::NetworkModel;
use crate::engine::{self, BatchBackend, BatchIdeal, EngineConfig, EngineHandle};
use crate::runtime::Runtime;
use crate::util::json::{arr_f64, obj, Json};
use crate::util::stats::{pow2_bounds, AtomicHistogram};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Aggregate serving statistics: counters plus latency / batch-occupancy
/// histograms (p50/p99, not just the mean).
#[derive(Debug)]
pub struct Stats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub total_micros: AtomicU64,
    /// Per-request end-to-end latency [µs].
    pub latency: AtomicHistogram,
    /// Images per dispatched batch (shared with the engine dispatcher).
    pub occupancy: Arc<AtomicHistogram>,
}

impl Default for Stats {
    fn default() -> Self {
        Self {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            total_micros: AtomicU64::new(0),
            // 1 µs .. ~67 s in power-of-two buckets.
            latency: AtomicHistogram::new(pow2_bounds(26)),
            // Batch sizes 1 .. 1024.
            occupancy: Arc::new(AtomicHistogram::new(pow2_bounds(10))),
        }
    }
}

impl Stats {
    pub fn snapshot_json(&self) -> Json {
        let n = self.requests.load(Ordering::Relaxed);
        let us = self.total_micros.load(Ordering::Relaxed);
        obj(vec![
            ("requests", Json::Num(n as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "mean_latency_micros",
                Json::Num(if n > 0 { us as f64 / n as f64 } else { 0.0 }),
            ),
            ("p50_latency_micros", Json::Num(self.latency.percentile(50.0) as f64)),
            ("p99_latency_micros", Json::Num(self.latency.percentile(99.0) as f64)),
            ("batches", Json::Num(self.occupancy.count() as f64)),
            ("mean_batch_occupancy", Json::Num(self.occupancy.mean())),
            (
                "p99_batch_occupancy",
                Json::Num(self.occupancy.percentile(99.0) as f64),
            ),
        ])
    }

    /// Multi-line human-readable summary (printed at `serve` shutdown).
    pub fn render_summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests {}  errors {}  mean latency {:.1} us  p50 {} us  p99 {} us\n",
            self.requests.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            {
                let n = self.requests.load(Ordering::Relaxed);
                let us = self.total_micros.load(Ordering::Relaxed);
                if n > 0 { us as f64 / n as f64 } else { 0.0 }
            },
            self.latency.percentile(50.0),
            self.latency.percentile(99.0),
        ));
        s.push_str(&format!(
            "batches {}  occupancy mean {:.2}  p99 {}\n",
            self.occupancy.count(),
            self.occupancy.mean(),
            self.occupancy.percentile(99.0),
        ));
        if self.occupancy.count() > 0 {
            s.push_str("batch-occupancy buckets (<=bound: count):");
            for (bound, count) in self.occupancy.nonzero_buckets() {
                if bound == u64::MAX {
                    s.push_str(&format!("  >1024: {count}"));
                } else {
                    s.push_str(&format!("  <={bound}: {count}"));
                }
            }
            s.push('\n');
        }
        s
    }
}

/// PJRT-backed batch backend: executes the AOT HLO artifact per image on
/// the dispatcher thread (the PJRT client is a single-threaded C handle).
struct PjrtBackend {
    runtime: Runtime,
    model_name: String,
    /// `[1, input_shape...]`.
    input_shape: Vec<usize>,
}

impl BatchBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        images
            .iter()
            .map(|im| self.runtime.run_f32(&self.model_name, im, &self.input_shape))
            .collect()
    }

    fn describe(&self) -> String {
        format!("PJRT/HLO artifact '{}'", self.model_name)
    }
}

/// Start the inference engine for a model directory: PJRT when the HLO
/// artifact is usable, otherwise the batched ideal engine on the
/// manifest. Returns the submission handle (shareable across connection
/// threads). Pass `stats` so the dispatcher records batch occupancy.
pub fn start_engine(
    dir: &str,
    name: &str,
    cfg: EngineConfig,
    stats: &Stats,
) -> Result<EngineHandle> {
    let model = NetworkModel::load(dir, name)
        .with_context(|| format!("loading model '{name}' from {dir}"))?;
    let hlo = std::path::Path::new(dir).join(format!("{name}.hlo.txt"));
    let occupancy = Some(Arc::clone(&stats.occupancy));

    if hlo.exists() {
        let model_name = name.to_string();
        let mut input_shape = vec![1usize];
        input_shape.extend(&model.input_shape);
        let started = engine::start(
            move || {
                let mut runtime = Runtime::new()?;
                runtime.load_hlo_text(&model_name, &hlo)?;
                Ok(Box::new(PjrtBackend { runtime, model_name, input_shape })
                    as Box<dyn BatchBackend>)
            },
            cfg,
            occupancy.clone(),
        );
        match started {
            Ok(handle) => return Ok(handle),
            // Default builds ship the stub runtime: falling back to the
            // ideal engine is the expected path, not an error.
            Err(e) if !cfg!(feature = "pjrt") => {
                eprintln!("PJRT runtime unavailable ({e:#}); falling back to ideal engine");
            }
            // With the real PJRT binding compiled in, a broken HLO
            // artifact is fatal — serving numerically different logits
            // from a silent simulator fallback is worse than refusing to
            // start.
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("starting the PJRT engine for '{name}'"));
            }
        }
    }
    let params = MacroParams::paper();
    let workers = cfg.workers;
    engine::start(
        move || {
            Ok(Box::new(BatchIdeal::new(model, params, workers)?) as Box<dyn BatchBackend>)
        },
        cfg,
        occupancy,
    )
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Handle one request line; returns the response line (never fails the
/// connection — errors are reported in-band).
pub fn handle_line(engine: &EngineHandle, stats: &Stats, line: &str) -> Option<String> {
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(
                obj(vec![("error", Json::Str(format!("bad json: {e}")))]).to_string_compact(),
            );
        }
    };
    if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Some(stats.snapshot_json().to_string_compact()),
            "quit" => None,
            other => Some(
                obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))])
                    .to_string_compact(),
            ),
        };
    }
    let image: Option<Vec<f32>> = parsed.get("image").and_then(Json::as_arr).map(|a| {
        a.iter()
            .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
            .collect()
    });
    let image = match image {
        Some(v) if v.len() == engine.input_len() && v.iter().all(|x| x.is_finite()) => v,
        _ => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(
                obj(vec![(
                    "error",
                    Json::Str(format!(
                        "expected 'image' with {} finite values",
                        engine.input_len()
                    )),
                )])
                .to_string_compact(),
            );
        }
    };
    let t0 = std::time::Instant::now();
    match engine.infer(image) {
        Ok(logits) => {
            let us = t0.elapsed().as_micros() as u64;
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.total_micros.fetch_add(us, Ordering::Relaxed);
            stats.latency.record(us);
            Some(
                obj(vec![
                    ("logits", arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                    ("class", Json::Num(argmax(&logits) as f64)),
                    ("micros", Json::Num(us as f64)),
                ])
                .to_string_compact(),
            )
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            Some(obj(vec![("error", Json::Str(format!("{e:#}")))]).to_string_compact())
        }
    }
}

fn serve_conn(engine: &EngineHandle, stats: &Stats, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone().context("cloning stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match handle_line(engine, stats, &line) {
            Some(resp) => {
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            None => break, // quit
        }
    }
    Ok(())
}

/// Serve on an already-bound listener (tests bind port 0 and pass the
/// listener in). Each connection runs on its own thread; `max_conns`
/// stops *accepting* after N connections, then waits for the in-flight
/// handlers to finish before returning.
pub fn serve_listener(
    engine: EngineHandle,
    stats: &Stats,
    listener: TcpListener,
    max_conns: Option<usize>,
) -> Result<()> {
    std::thread::scope(|scope| -> Result<()> {
        let mut conns = 0usize;
        for stream in listener.incoming() {
            // A transient accept failure (ECONNABORTED, EMFILE under load)
            // must not tear down the server and its live connections.
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("accept error: {e}");
                    continue;
                }
            };
            let handle = engine.clone();
            scope.spawn(move || {
                let peer = stream.peer_addr().ok();
                if let Err(err) = serve_conn(&handle, stats, stream) {
                    eprintln!("connection error ({peer:?}): {err:#}");
                }
            });
            conns += 1;
            if let Some(max) = max_conns {
                if conns >= max {
                    break;
                }
            }
        }
        Ok(())
    })?;
    eprintln!("server stats: {}", stats.snapshot_json().to_string_compact());
    eprint!("{}", stats.render_summary());
    Ok(())
}

/// Bind `addr` and serve (blocks until `max_conns` is reached, if given).
pub fn serve(
    engine: EngineHandle,
    stats: &Stats,
    addr: &str,
    max_conns: Option<usize>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "imagine server listening on {addr} ({} -> {})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_default(),
        engine.describe()
    );
    serve_listener(engine, stats, listener, max_conns)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn stats_snapshot() {
        let s = Stats::default();
        s.requests.fetch_add(4, Ordering::Relaxed);
        s.total_micros.fetch_add(400, Ordering::Relaxed);
        let j = s.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("mean_latency_micros").unwrap().as_f64(), Some(100.0));
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn stats_histograms_feed_percentiles() {
        let s = Stats::default();
        for us in [10u64, 20, 30, 40, 1000] {
            s.latency.record(us);
        }
        s.occupancy.record(1);
        s.occupancy.record(8);
        let j = s.snapshot_json();
        assert!(j.get("p50_latency_micros").unwrap().as_f64().unwrap() >= 20.0);
        assert!(j.get("p99_latency_micros").unwrap().as_f64().unwrap() >= 1000.0);
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(2.0));
        assert!((j.get("mean_batch_occupancy").unwrap().as_f64().unwrap() - 4.5).abs() < 1e-9);
        let summary = s.render_summary();
        assert!(summary.contains("occupancy"), "{summary}");
    }

    #[test]
    fn bad_json_is_reported_in_band() {
        assert!(Json::parse("{nope").is_err());
    }
}
