//! Model manifest + weight loading (the python compile path's exports).
//!
//! `<name>.manifest.json` describes the layer graph and per-layer macro
//! configuration; `<name>.imgt` carries the physical weights (already
//! padded to DP-unit multiples and permuted to macro row order), the 5b
//! ABN offset codes and the digital scales.

use crate::analog::macro_model::OpConfig;
use crate::util::json::Json;
use crate::util::tensorfile::TensorFile;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Pooling applied after a conv layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pool {
    None,
    Max2,
    Avg2,
    Gap,
}

impl Pool {
    fn from_json(j: Option<&Json>) -> Result<Pool> {
        match j {
            None | Some(Json::Null) => Ok(Pool::None),
            Some(Json::Str(s)) => match s.as_str() {
                "max2" => Ok(Pool::Max2),
                "avg2" => Ok(Pool::Avg2),
                "gap" => Ok(Pool::Gap),
                other => bail!("unknown pool '{other}'"),
            },
            _ => bail!("invalid pool field"),
        }
    }
}

/// Layer kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Dense,
    Conv3,
}

/// One CIM-mapped layer with everything the executor needs.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: Kind,
    pub in_features: usize,
    pub out_features: usize,
    pub relu: bool,
    pub stride: usize,
    pub pool: Pool,
    pub rows: usize,
    pub cfg: OpConfig,
    /// Physical weights [rows × out_features], antipodal levels.
    pub w_phys: Vec<i32>,
    /// 5b ABN offset codes [out_features].
    pub beta: Vec<i32>,
    /// Input quantization scale (real → r_in-bit grid).
    pub a_scale: f32,
    /// Post-ADC digital gain.
    pub out_gain: f32,
}

/// A fully loaded network.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<Layer>,
    /// Training metrics recorded by the compile path (accuracy etc.).
    pub metrics: Json,
}

impl NetworkModel {
    /// Load `<dir>/<name>.manifest.json` + its weight file.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<NetworkModel> {
        let dir = dir.as_ref();
        let man_path = dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?}"))?;
        let man = Json::parse(&text).map_err(|e| anyhow!("{man_path:?}: {e}"))?;
        if man.req_str("format")? != "imagine-model-v1" {
            bail!("unsupported manifest format");
        }
        let weights_file = man.req_str("weights_file")?;
        let tf = TensorFile::load(dir.join(weights_file))?;

        let input_shape: Vec<usize> = man
            .req_arr("input_shape")?
            .iter()
            .map(|j| j.as_usize().context("input_shape entry"))
            .collect::<Result<_>>()?;

        let mut layers = Vec::new();
        for lj in man.req_arr("layers")? {
            layers.push(Self::load_layer(lj, &tf)?);
        }
        Ok(NetworkModel {
            name: man.req_str("name")?.to_string(),
            input_shape,
            layers,
            metrics: man.get("metrics").cloned().unwrap_or(Json::Null),
        })
    }

    fn load_layer(lj: &Json, tf: &TensorFile) -> Result<Layer> {
        let name = lj.req_str("name")?.to_string();
        let kind = match lj.req_str("kind")? {
            "dense" => Kind::Dense,
            "conv3" => Kind::Conv3,
            other => bail!("unknown layer kind '{other}'"),
        };
        let cfg_j = lj.get("cfg").context("missing cfg")?;
        let cfg = OpConfig {
            r_in: cfg_j.req_usize("r_in")? as u32,
            r_w: cfg_j.req_usize("r_w")? as u32,
            r_out: cfg_j.req_usize("r_out")? as u32,
            gamma: cfg_j.req_f64("gamma")?,
            connected_units: cfg_j.req_usize("connected_units")?,
            t_dp: 5e-9,
        };
        let rows = lj.req_usize("rows")?;
        let out_features = lj.req_usize("out_features")?;

        let w_t = tf.req(&format!("{name}/w_phys"))?;
        if w_t.dims != [rows, out_features] {
            bail!(
                "{name}: weight dims {:?} != [{rows}, {out_features}]",
                w_t.dims
            );
        }
        let w_phys: Vec<i32> = w_t.as_i8()?.iter().map(|&v| v as i32).collect();
        let beta: Vec<i32> = tf
            .req(&format!("{name}/beta"))?
            .as_i8()?
            .iter()
            .map(|&v| v as i32)
            .collect();
        if beta.len() != out_features {
            bail!("{name}: beta length mismatch");
        }
        let a_scale = tf.req(&format!("{name}/a_scale"))?.as_f32()?[0];
        let out_gain = tf.req(&format!("{name}/out_gain"))?.as_f32()?[0];

        Ok(Layer {
            name,
            kind,
            in_features: lj.req_usize("in_features")?,
            out_features,
            relu: lj.get("relu").and_then(Json::as_bool).unwrap_or(true),
            stride: lj.get("stride").and_then(Json::as_usize).unwrap_or(1),
            pool: Pool::from_json(lj.get("pool"))?,
            rows,
            cfg,
            w_phys,
            beta,
            a_scale,
            out_gain,
        })
    }

    /// Recorded test accuracy from the compile path, if present.
    pub fn trained_accuracy(&self) -> Option<f64> {
        self.metrics.get("test_acc").and_then(Json::as_f64)
    }

    /// Total weight bits stored in the macro across layers.
    pub fn weight_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| (l.rows * l.out_features * l.cfg.r_w as usize) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    // Loading real manifests is covered by rust/tests/e2e_network.rs
    // (requires `make artifacts`). Here: pool parsing only.
    use super::*;

    #[test]
    fn pool_parses() {
        assert_eq!(Pool::from_json(None).unwrap(), Pool::None);
        assert_eq!(Pool::from_json(Some(&Json::Null)).unwrap(), Pool::None);
        assert_eq!(
            Pool::from_json(Some(&Json::Str("max2".into()))).unwrap(),
            Pool::Max2
        );
        assert!(Pool::from_json(Some(&Json::Str("huh".into()))).is_err());
    }
}
