//! Model manifest + weight loading (the python compile path's exports).
//!
//! `<name>.manifest.json` describes the layer graph and per-layer macro
//! configuration; `<name>.imgt` carries the physical weights (already
//! padded to DP-unit multiples and permuted to macro row order), the 5b
//! ABN offset codes and the digital scales.

use crate::analog::macro_model::OpConfig;
use crate::config::params::MacroParams;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tensorfile::TensorFile;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Pooling applied after a conv layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pool {
    None,
    Max2,
    Avg2,
    Gap,
}

impl Pool {
    /// Manifest/protocol spelling of this pool stage.
    pub fn name(self) -> &'static str {
        match self {
            Pool::None => "none",
            Pool::Max2 => "max2",
            Pool::Avg2 => "avg2",
            Pool::Gap => "gap",
        }
    }

    fn from_json(j: Option<&Json>) -> Result<Pool> {
        match j {
            None | Some(Json::Null) => Ok(Pool::None),
            Some(Json::Str(s)) => match s.as_str() {
                "max2" => Ok(Pool::Max2),
                "avg2" => Ok(Pool::Avg2),
                "gap" => Ok(Pool::Gap),
                other => bail!("unknown pool '{other}'"),
            },
            _ => bail!("invalid pool field"),
        }
    }
}

/// Layer kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Dense,
    Conv3,
}

impl Kind {
    /// Manifest/protocol spelling of this layer kind.
    pub fn name(self) -> &'static str {
        match self {
            Kind::Dense => "dense",
            Kind::Conv3 => "conv3",
        }
    }
}

/// One CIM-mapped layer with everything the executor needs.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: Kind,
    pub in_features: usize,
    pub out_features: usize,
    pub relu: bool,
    pub stride: usize,
    pub pool: Pool,
    pub rows: usize,
    pub cfg: OpConfig,
    /// Physical weights [rows × out_features], antipodal levels.
    pub w_phys: Vec<i32>,
    /// 5b ABN offset codes [out_features].
    pub beta: Vec<i32>,
    /// Input quantization scale (real → r_in-bit grid).
    pub a_scale: f32,
    /// Post-ADC digital gain.
    pub out_gain: f32,
}

/// One layer's entry in a [`PrecisionProfile`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProfileEntry {
    /// Manifest layer name this entry applies to.
    pub name: String,
    /// Input (activation) precision in bits, 1..=8.
    pub r_in: u32,
    /// Output (ADC) precision in bits, 1..=8.
    pub r_out: u32,
}

/// A per-layer `(r_in, r_out)` assignment — the autotuner's product.
///
/// Serialized as the manifest's optional `"precision_profile"` section
/// (versioned; absent in legacy manifests, which deploy with their
/// uniform per-layer `cfg` untouched) so a saved deployment serves its
/// mixed-precision operating point through `ModelHub` with zero flags.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrecisionProfile {
    /// Manifest-section format version (currently 1).
    pub version: u32,
    /// One entry per CIM layer, in layer order.
    pub layers: Vec<ProfileEntry>,
}

impl PrecisionProfile {
    /// The manifest-section format version this build writes and reads.
    pub const VERSION: u32 = 1;

    /// Capture the per-layer operating points a model currently runs at.
    pub fn from_model(model: &NetworkModel) -> PrecisionProfile {
        PrecisionProfile {
            version: Self::VERSION,
            layers: model
                .layers
                .iter()
                .map(|l| ProfileEntry {
                    name: l.name.clone(),
                    r_in: l.cfg.r_in,
                    r_out: l.cfg.r_out,
                })
                .collect(),
        }
    }

    /// Per-layer `(r_in, r_out)` points in layer order.
    pub fn points(&self) -> Vec<(u32, u32)> {
        self.layers.iter().map(|e| (e.r_in, e.r_out)).collect()
    }

    /// Parse the manifest's `"precision_profile"` value.
    pub fn from_json(j: &Json) -> Result<PrecisionProfile> {
        let version = j.req_usize("version")? as u32;
        if version != Self::VERSION {
            bail!("unsupported precision_profile version {version}");
        }
        let mut layers = Vec::new();
        for e in j.req_arr("layers")? {
            let entry = ProfileEntry {
                name: e.req_str("name")?.to_string(),
                r_in: e.req_usize("r_in")? as u32,
                r_out: e.req_usize("r_out")? as u32,
            };
            for (tag, r) in [("r_in", entry.r_in), ("r_out", entry.r_out)] {
                if !(1..=8).contains(&r) {
                    bail!("precision_profile {}: {tag}={r} outside 1..=8", entry.name);
                }
            }
            layers.push(entry);
        }
        Ok(PrecisionProfile { version, layers })
    }

    /// Serialize as the manifest's `"precision_profile"` value.
    pub fn to_json(&self) -> Json {
        use crate::util::json::obj;
        obj(vec![
            ("version", Json::Num(self.version as f64)),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("name", Json::Str(e.name.clone())),
                                ("r_in", Json::Num(e.r_in as f64)),
                                ("r_out", Json::Num(e.r_out as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// A fully loaded network.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<Layer>,
    /// Training metrics recorded by the compile path (accuracy etc.).
    pub metrics: Json,
    /// Per-layer precision profile, when the model was autotuned.
    /// `None` for legacy manifests — uniform per-layer `cfg` assumed.
    pub profile: Option<PrecisionProfile>,
}

impl NetworkModel {
    /// Load `<dir>/<name>.manifest.json` + its weight file.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<NetworkModel> {
        let dir = dir.as_ref();
        let man_path = dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?}"))?;
        let man = Json::parse(&text).map_err(|e| anyhow!("{man_path:?}: {e}"))?;
        if man.req_str("format")? != "imagine-model-v1" {
            bail!("unsupported manifest format");
        }
        let weights_file = man.req_str("weights_file")?;
        let tf = TensorFile::load(dir.join(weights_file))?;

        let input_shape: Vec<usize> = man
            .req_arr("input_shape")?
            .iter()
            .map(|j| j.as_usize().context("input_shape entry"))
            .collect::<Result<_>>()?;

        let mut layers = Vec::new();
        for lj in man.req_arr("layers")? {
            layers.push(Self::load_layer(lj, &tf)?);
        }
        let profile = match man.get("precision_profile") {
            None | Some(Json::Null) => None,
            Some(j) => {
                let prof = PrecisionProfile::from_json(j)?;
                if prof.layers.len() != layers.len() {
                    bail!(
                        "precision_profile covers {} layers, manifest has {}",
                        prof.layers.len(),
                        layers.len()
                    );
                }
                for (e, l) in prof.layers.iter().zip(&layers) {
                    if e.name != l.name {
                        bail!("precision_profile entry '{}' != layer '{}'", e.name, l.name);
                    }
                }
                Some(prof)
            }
        };
        Ok(NetworkModel {
            name: man.req_str("name")?.to_string(),
            input_shape,
            layers,
            metrics: man.get("metrics").cloned().unwrap_or(Json::Null),
            profile,
        })
    }

    fn load_layer(lj: &Json, tf: &TensorFile) -> Result<Layer> {
        let name = lj.req_str("name")?.to_string();
        let kind = match lj.req_str("kind")? {
            "dense" => Kind::Dense,
            "conv3" => Kind::Conv3,
            other => bail!("unknown layer kind '{other}'"),
        };
        let cfg_j = lj.get("cfg").context("missing cfg")?;
        let cfg = OpConfig {
            r_in: cfg_j.req_usize("r_in")? as u32,
            r_w: cfg_j.req_usize("r_w")? as u32,
            r_out: cfg_j.req_usize("r_out")? as u32,
            gamma: cfg_j.req_f64("gamma")?,
            connected_units: cfg_j.req_usize("connected_units")?,
            t_dp: 5e-9,
        };
        let rows = lj.req_usize("rows")?;
        let out_features = lj.req_usize("out_features")?;

        let w_t = tf.req(&format!("{name}/w_phys"))?;
        if w_t.dims != [rows, out_features] {
            bail!(
                "{name}: weight dims {:?} != [{rows}, {out_features}]",
                w_t.dims
            );
        }
        let w_phys: Vec<i32> = w_t.as_i8()?.iter().map(|&v| v as i32).collect();
        let beta: Vec<i32> = tf
            .req(&format!("{name}/beta"))?
            .as_i8()?
            .iter()
            .map(|&v| v as i32)
            .collect();
        if beta.len() != out_features {
            bail!("{name}: beta length mismatch");
        }
        let a_scale = scalar_f32(tf, &format!("{name}/a_scale"))?;
        let out_gain = scalar_f32(tf, &format!("{name}/out_gain"))?;

        Ok(Layer {
            name,
            kind,
            in_features: lj.req_usize("in_features")?,
            out_features,
            relu: lj.get("relu").and_then(Json::as_bool).unwrap_or(true),
            stride: lj.get("stride").and_then(Json::as_usize).unwrap_or(1),
            pool: Pool::from_json(lj.get("pool"))?,
            rows,
            cfg,
            w_phys,
            beta,
            a_scale,
            out_gain,
        })
    }

    /// Random in-memory dense stack (tests/benches; no artifacts needed).
    /// `widths` is `[in, hidden.., out]`; weights are valid antipodal
    /// `r_w`-bit levels, betas span the 5b ABN range, and the scales are
    /// chosen so activations in [0, 1) exercise the full code range.
    pub fn synthetic_mlp(
        widths: &[usize],
        r_in: u32,
        r_w: u32,
        r_out: u32,
        seed: u64,
        p: &MacroParams,
    ) -> NetworkModel {
        assert!(widths.len() >= 2, "need at least input and output widths");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for (li, pair) in widths.windows(2).enumerate() {
            let last = li + 2 == widths.len();
            layers.push(Layer::synthetic_dense(
                &format!("fc{li}"),
                pair[0],
                pair[1],
                (r_in, r_w, r_out),
                !last,
                &mut rng,
                p,
            ));
        }
        NetworkModel {
            name: "synthetic_mlp".to_string(),
            input_shape: vec![widths[0]],
            layers,
            metrics: Json::Null,
            profile: None,
        }
    }

    /// Write `<dir>/<name>.manifest.json` + `<dir>/<name>.imgt` — the
    /// inverse of [`NetworkModel::load`], matching the python compile
    /// path's export format. This is what lets tests (and embedders)
    /// produce artifacts the server's `{"cmd":"deploy"}` hot-load path
    /// can pick up without the python toolchain.
    pub fn save(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        use crate::util::json::{arr_usize, obj};
        use crate::util::tensorfile::{Tensor, TensorData};

        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        let weights_file = format!("{name}.imgt");
        let mut tf = TensorFile::new();
        let mut layers_json = Vec::new();
        for layer in &self.layers {
            let w: Vec<i8> = layer
                .w_phys
                .iter()
                .map(|&v| {
                    i8::try_from(v).map_err(|_| anyhow!("{}: weight {v} outside i8", layer.name))
                })
                .collect::<Result<_>>()?;
            let beta: Vec<i8> = layer
                .beta
                .iter()
                .map(|&v| {
                    i8::try_from(v).map_err(|_| anyhow!("{}: beta {v} outside i8", layer.name))
                })
                .collect::<Result<_>>()?;
            tf.push(Tensor {
                name: format!("{}/w_phys", layer.name),
                dims: vec![layer.rows, layer.out_features],
                data: TensorData::I8(w),
            });
            tf.push(Tensor {
                name: format!("{}/beta", layer.name),
                dims: vec![layer.out_features],
                data: TensorData::I8(beta),
            });
            tf.push(Tensor {
                name: format!("{}/a_scale", layer.name),
                dims: vec![1],
                data: TensorData::F32(vec![layer.a_scale]),
            });
            tf.push(Tensor {
                name: format!("{}/out_gain", layer.name),
                dims: vec![1],
                data: TensorData::F32(vec![layer.out_gain]),
            });
            let pool = match layer.pool {
                Pool::None => Json::Null,
                p => Json::Str(p.name().to_string()),
            };
            layers_json.push(obj(vec![
                ("name", Json::Str(layer.name.clone())),
                ("kind", Json::Str(layer.kind.name().to_string())),
                ("in_features", Json::Num(layer.in_features as f64)),
                ("out_features", Json::Num(layer.out_features as f64)),
                ("relu", Json::Bool(layer.relu)),
                ("stride", Json::Num(layer.stride as f64)),
                ("pool", pool),
                ("rows", Json::Num(layer.rows as f64)),
                (
                    "cfg",
                    obj(vec![
                        ("r_in", Json::Num(layer.cfg.r_in as f64)),
                        ("r_w", Json::Num(layer.cfg.r_w as f64)),
                        ("r_out", Json::Num(layer.cfg.r_out as f64)),
                        ("gamma", Json::Num(layer.cfg.gamma)),
                        (
                            "connected_units",
                            Json::Num(layer.cfg.connected_units as f64),
                        ),
                    ]),
                ),
            ]));
        }
        tf.save(dir.join(&weights_file))?;
        let mut fields = vec![
            ("format", Json::Str("imagine-model-v1".to_string())),
            ("name", Json::Str(self.name.clone())),
            ("weights_file", Json::Str(weights_file)),
            ("input_shape", arr_usize(&self.input_shape)),
            ("layers", Json::Arr(layers_json)),
            ("metrics", self.metrics.clone()),
        ];
        if let Some(prof) = &self.profile {
            fields.push(("precision_profile", prof.to_json()));
        }
        let manifest = obj(fields);
        let man_path = dir.join(format!("{name}.manifest.json"));
        std::fs::write(&man_path, manifest.to_string_compact())
            .with_context(|| format!("writing {man_path:?}"))
    }

    /// Re-shape every layer to a new (r_in, r_out) operating point,
    /// preserving each layer's real-valued full-scale range: the input
    /// quantization grid is re-spread over the same activation range and
    /// the post-ADC gain is rescaled so recentered outputs keep their
    /// magnitude — the software analogue of the paper's
    /// distribution-aware data reshaping when the precision knob moves.
    /// Weight precision (`r_w`) is a storage property of the compiled
    /// model and is left untouched.
    ///
    /// Callers must keep `r_in`/`r_out` in 1..=8 (the macro's range);
    /// the `api` layer validates this before applying. Re-targeting is
    /// not float-associative across chained calls — to hop between
    /// operating points bit-identically, always re-target a pristine
    /// copy of the as-compiled model (what the engine backends do).
    pub fn retarget_precision(&mut self, r_in: u32, r_out: u32) {
        for layer in &mut self.layers {
            Self::retarget_layer(layer, r_in, r_out);
        }
        // The model is uniform now; a recorded mixed profile no longer
        // describes it.
        self.profile = None;
    }

    /// The per-layer body of [`NetworkModel::retarget_precision`] —
    /// distribution-aware rescaling of one layer to a new operating
    /// point. Shared with [`NetworkModel::apply_profile`].
    fn retarget_layer(layer: &mut Layer, r_in: u32, r_out: u32) {
        let old_m = ((1u32 << layer.cfg.r_in) - 1) as f32;
        let new_m = ((1u32 << r_in) - 1) as f32;
        let old_half = (1u32 << (layer.cfg.r_out - 1)) as f32;
        let new_half = (1u32 << (r_out - 1)) as f32;
        layer.a_scale *= old_m / new_m;
        layer.out_gain *= old_half / new_half;
        layer.cfg.r_in = r_in;
        layer.cfg.r_out = r_out;
    }

    /// Re-shape each layer to its own operating point from `profile`
    /// (same per-layer distribution-aware rescaling as
    /// [`NetworkModel::retarget_precision`], applied non-uniformly) and
    /// record the profile so [`NetworkModel::save`] emits it. Entry
    /// count and names must match the model's layers.
    pub fn apply_profile(&mut self, profile: &PrecisionProfile) -> Result<()> {
        if profile.layers.len() != self.layers.len() {
            bail!(
                "profile covers {} layers, model '{}' has {}",
                profile.layers.len(),
                self.name,
                self.layers.len()
            );
        }
        for (entry, layer) in profile.layers.iter().zip(&self.layers) {
            if entry.name != layer.name {
                bail!("profile entry '{}' != layer '{}'", entry.name, layer.name);
            }
            for (tag, r) in [("r_in", entry.r_in), ("r_out", entry.r_out)] {
                if !(1..=8).contains(&r) {
                    bail!("profile {}: {tag}={r} outside 1..=8", entry.name);
                }
            }
        }
        for (entry, layer) in profile.layers.iter().zip(self.layers.iter_mut()) {
            Self::retarget_layer(layer, entry.r_in, entry.r_out);
        }
        self.profile = Some(profile.clone());
        Ok(())
    }

    /// Restore the precision-dependent scalar fields (`a_scale`,
    /// `out_gain`, `cfg.r_in`, `cfg.r_out`) from `other` — same
    /// compiled topology required. The engine backends re-target with
    /// this instead of cloning the whole model: restore the pristine
    /// scalars, then [`NetworkModel::retarget_precision`] — the exact
    /// float operations a fresh pristine clone would see, without
    /// copying any weight tensor (weights are precision-independent).
    pub fn copy_precision_fields_from(&mut self, other: &NetworkModel) {
        debug_assert_eq!(self.layers.len(), other.layers.len());
        for (layer, base) in self.layers.iter_mut().zip(&other.layers) {
            layer.a_scale = base.a_scale;
            layer.out_gain = base.out_gain;
            layer.cfg.r_in = base.cfg.r_in;
            layer.cfg.r_out = base.cfg.r_out;
        }
        self.profile.clone_from(&other.profile);
    }

    /// Recorded test accuracy from the compile path, if present.
    pub fn trained_accuracy(&self) -> Option<f64> {
        self.metrics.get("test_acc").and_then(Json::as_f64)
    }

    /// Total weight bits stored in the macro across layers.
    pub fn weight_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| (l.rows * l.out_features * l.cfg.r_w as usize) as u64)
            .sum()
    }
}

impl Layer {
    fn synthetic_cfg(
        (r_in, r_w, r_out): (u32, u32, u32),
        rows: usize,
        p: &MacroParams,
    ) -> OpConfig {
        // γ chosen so a random-weight DP distribution spreads over many
        // ADC codes instead of collapsing onto the mid-code.
        OpConfig {
            r_in,
            r_w,
            r_out,
            gamma: 16.0,
            connected_units: (rows / p.rows_per_unit).max(1),
            t_dp: 5e-9,
        }
    }

    fn synthetic_scales(r_in: u32, r_out: u32) -> (f32, f32) {
        let m = ((1u32 << r_in) - 1) as f32;
        let half = (1u32 << (r_out - 1)) as f32;
        // a_scale maps [0, 1) activations onto the full input grid; the
        // output gain re-normalizes codes back into roughly [−1, 1].
        (1.0 / m, 1.0 / half)
    }

    /// Random dense layer sized/padded the way the compile path pads
    /// (rows rounded up to whole DP units).
    pub fn synthetic_dense(
        name: &str,
        in_features: usize,
        out_features: usize,
        bits: (u32, u32, u32),
        relu: bool,
        rng: &mut Rng,
        p: &MacroParams,
    ) -> Layer {
        let rows = in_features.div_ceil(p.rows_per_unit) * p.rows_per_unit;
        assert!(rows <= p.n_rows, "dense layer does not fit the macro rows");
        let (r_in, r_w, r_out) = bits;
        let (a_scale, out_gain) = Self::synthetic_scales(r_in, r_out);
        Layer {
            name: name.to_string(),
            kind: Kind::Dense,
            in_features,
            out_features,
            relu,
            stride: 1,
            pool: Pool::None,
            rows,
            cfg: Self::synthetic_cfg(bits, rows, p),
            w_phys: synthetic_weights(rng, rows * out_features, r_w),
            beta: synthetic_betas(rng, out_features),
            a_scale,
            out_gain,
        }
    }

    /// Random 3×3 conv layer in the macro's im2col row order.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic_conv3(
        name: &str,
        c_in: usize,
        c_out: usize,
        stride: usize,
        pool: Pool,
        bits: (u32, u32, u32),
        rng: &mut Rng,
        p: &MacroParams,
    ) -> Layer {
        let units = c_in.div_ceil(4);
        let rows = units * p.rows_per_unit;
        assert!(rows <= p.n_rows, "conv layer does not fit the macro rows");
        let (r_in, r_w, r_out) = bits;
        let (a_scale, out_gain) = Self::synthetic_scales(r_in, r_out);
        Layer {
            name: name.to_string(),
            kind: Kind::Conv3,
            in_features: c_in,
            out_features: c_out,
            relu: true,
            stride,
            pool,
            rows,
            cfg: Self::synthetic_cfg(bits, rows, p),
            w_phys: synthetic_weights(rng, rows * c_out, r_w),
            beta: synthetic_betas(rng, c_out),
            a_scale,
            out_gain,
        }
    }
}

/// First element of a 1-element f32 tensor — a corrupt weight file with
/// an empty scale tensor must be a typed error, not an index panic (the
/// cluster failover path re-loads manifests while serving traffic).
fn scalar_f32(tf: &TensorFile, name: &str) -> Result<f32> {
    let v = tf.req(name)?.as_f32()?;
    v.first()
        .copied()
        .ok_or_else(|| anyhow!("tensor '{name}' is empty (expected 1 scalar)"))
}

/// Valid antipodal `r_w`-bit weight levels: odd values in [−(2^r_w−1), 2^r_w−1].
fn synthetic_weights(rng: &mut Rng, n: usize, r_w: u32) -> Vec<i32> {
    let max = (1i32 << r_w) - 1;
    (0..n).map(|_| 2 * rng.below(1u64 << r_w) as i32 - max).collect()
}

/// 5b ABN offset codes in the manifest's [−16, 15] range.
fn synthetic_betas(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.int_range(-16, 15) as i32).collect()
}

#[cfg(test)]
mod tests {
    // Loading real manifests is covered by rust/tests/e2e_network.rs
    // (requires `make artifacts`). Here: pool parsing only.
    use super::*;

    #[test]
    fn synthetic_models_are_manifest_valid() {
        let p = MacroParams::paper();
        let m = NetworkModel::synthetic_mlp(&[100, 40, 10], 8, 4, 8, 3, &p);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.input_shape, vec![100]);
        assert!(m.layers[0].relu && !m.layers[1].relu);
        for l in &m.layers {
            assert_eq!(l.rows % p.rows_per_unit, 0);
            assert_eq!(l.cfg.connected_units, l.rows / p.rows_per_unit);
            assert_eq!(l.w_phys.len(), l.rows * l.out_features);
            assert_eq!(l.beta.len(), l.out_features);
            let mx = (1 << l.cfg.r_w) - 1;
            assert!(l.w_phys.iter().all(|&w| w.abs() <= mx && (w + mx) % 2 == 0));
            assert!(l.beta.iter().all(|&b| (-16..=15).contains(&b)));
        }
        let mut rng = Rng::new(9);
        let conv = Layer::synthetic_conv3("c0", 5, 12, 2, Pool::Max2, (4, 2, 6), &mut rng, &p);
        assert_eq!(conv.rows, 2 * p.rows_per_unit); // ceil(5/4) = 2 units
        assert_eq!(conv.cfg.connected_units, 2);
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        // The rust-side exporter (what the server's hot-deploy tests
        // feed) must round-trip through load bit-exactly.
        let p = MacroParams::paper();
        let m = NetworkModel::synthetic_mlp(&[30, 12, 5], 8, 4, 8, 21, &p);
        let dir = std::env::temp_dir().join(format!("imagine_manifest_rt_{}", std::process::id()));
        m.save(&dir, "rt").unwrap();
        let loaded = NetworkModel::load(&dir, "rt").unwrap();
        assert_eq!(loaded.name, m.name);
        assert_eq!(loaded.input_shape, m.input_shape);
        assert_eq!(loaded.layers.len(), m.layers.len());
        for (a, b) in loaded.layers.iter().zip(&m.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(
                (a.in_features, a.out_features, a.rows),
                (b.in_features, b.out_features, b.rows)
            );
            assert_eq!((a.relu, a.stride, a.pool), (b.relu, b.stride, b.pool));
            assert_eq!(
                (a.cfg.r_in, a.cfg.r_w, a.cfg.r_out, a.cfg.connected_units),
                (b.cfg.r_in, b.cfg.r_w, b.cfg.r_out, b.cfg.connected_units)
            );
            assert_eq!(a.cfg.gamma, b.cfg.gamma);
            assert_eq!(a.w_phys, b.w_phys);
            assert_eq!(a.beta, b.beta);
            assert_eq!(a.a_scale.to_bits(), b.a_scale.to_bits());
            assert_eq!(a.out_gain.to_bits(), b.out_gain.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_artifacts_load_as_typed_errors_not_panics() {
        // Failover re-deploys read artifacts at the worst possible time;
        // every corruption mode must come back as Err.
        let p = MacroParams::paper();
        let m = NetworkModel::synthetic_mlp(&[20, 8, 4], 8, 4, 8, 7, &p);
        let dir =
            std::env::temp_dir().join(format!("imagine_manifest_corrupt_{}", std::process::id()));
        m.save(&dir, "c").unwrap();
        let imgt = dir.join("c.imgt");
        let good = std::fs::read(&imgt).unwrap();

        // Truncated weight file (half the bytes).
        std::fs::write(&imgt, &good[..good.len() / 2]).unwrap();
        assert!(NetworkModel::load(&dir, "c").is_err());

        // Empty weight file.
        std::fs::write(&imgt, b"").unwrap();
        assert!(NetworkModel::load(&dir, "c").is_err());

        // Garbage weight file (right length, wrong magic).
        std::fs::write(&imgt, vec![0xA5u8; good.len()]).unwrap();
        assert!(NetworkModel::load(&dir, "c").is_err());

        // Empty a_scale tensor: rebuild the tensorfile with fc0/a_scale
        // as a 0-element tensor — must be a typed error, not `[0]`.
        let orig = TensorFile::read_from(&mut good.as_slice()).unwrap();
        let mut tf = TensorFile::new();
        for t in &orig.tensors {
            let mut t = t.clone();
            if t.name == "fc0/a_scale" {
                t.dims = vec![0];
                t.data = crate::util::tensorfile::TensorData::F32(Vec::new());
            }
            tf.push(t);
        }
        tf.save(&imgt).unwrap();
        let err = NetworkModel::load(&dir, "c").unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");

        // Truncated manifest JSON.
        std::fs::write(&imgt, &good).unwrap();
        let man_path = dir.join("c.manifest.json");
        let man = std::fs::read_to_string(&man_path).unwrap();
        std::fs::write(&man_path, &man[..man.len() / 2]).unwrap();
        assert!(NetworkModel::load(&dir, "c").is_err());

        // Restore and confirm the fixture still loads.
        std::fs::write(&man_path, &man).unwrap();
        assert!(NetworkModel::load(&dir, "c").is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn precision_profile_saves_loads_and_validates() {
        let p = MacroParams::paper();
        let mut m = NetworkModel::synthetic_mlp(&[30, 12, 5], 8, 4, 8, 33, &p);
        let prof = PrecisionProfile {
            version: PrecisionProfile::VERSION,
            layers: vec![
                ProfileEntry { name: "fc0".into(), r_in: 6, r_out: 4 },
                ProfileEntry { name: "fc1".into(), r_in: 4, r_out: 8 },
            ],
        };
        m.apply_profile(&prof).unwrap();
        assert_eq!(m.layers[0].cfg.r_in, 6);
        assert_eq!(m.layers[1].cfg.r_out, 8);
        let dir = std::env::temp_dir().join(format!("imagine_profile_rt_{}", std::process::id()));
        m.save(&dir, "prof").unwrap();
        let loaded = NetworkModel::load(&dir, "prof").unwrap();
        assert_eq!(loaded.profile.as_ref(), Some(&prof));
        assert_eq!(loaded.layers[0].cfg.r_in, 6);
        assert_eq!(loaded.layers[0].a_scale.to_bits(), m.layers[0].a_scale.to_bits());

        // Mismatched entry name / count / range must be typed errors.
        let mut bad = prof.clone();
        bad.layers[0].name = "nope".into();
        assert!(m.apply_profile(&bad).is_err());
        let mut bad = prof.clone();
        bad.layers.pop();
        assert!(m.apply_profile(&bad).is_err());
        let mut bad = prof.clone();
        bad.layers[1].r_in = 9;
        assert!(m.apply_profile(&bad).is_err());

        // Uniform retarget invalidates a recorded mixed profile.
        let mut u = loaded.clone();
        u.retarget_precision(4, 4);
        assert!(u.profile.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pool_parses() {
        assert_eq!(Pool::from_json(None).unwrap(), Pool::None);
        assert_eq!(Pool::from_json(Some(&Json::Null)).unwrap(), Pool::None);
        assert_eq!(
            Pool::from_json(Some(&Json::Str("max2".into()))).unwrap(),
            Pool::Max2
        );
        assert!(Pool::from_json(Some(&Json::Str("huh".into()))).is_err());
    }
}
