//! Network executor — the characterization path.
//!
//! Runs a loaded [`NetworkModel`] image-by-image through either
//!
//! * [`Backend::Ideal`] — the closed-form macro contract (bit-exact with
//!   the python oracle and the AOT HLO), or
//! * [`Backend::Analog`] — the full circuit-behavioral [`CimMacro`]
//!   simulator (mismatch, noise, corners, settling), which is what the
//!   silicon-fidelity experiments use.
//!
//! Either way the executor books dataflow cycles and energy through the
//! pipeline/energy models, so an end-to-end run reports accuracy *and*
//! the accelerator-level throughput/efficiency — the CERBERUS measurement
//! setup in software.


use crate::analog::macro_model::CimMacro;
use crate::config::params::MacroParams;
use crate::coordinator::manifest::{Kind, Layer, NetworkModel, Pool};
use crate::dataflow::im2col;
use crate::dataflow::pipeline::LayerShape;
use crate::energy::system::{layer_cost, LayerCost};
use anyhow::Result;

/// Execution backend.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Closed-form ideal contract (fast; bit-exact vs python/HLO).
    Ideal,
    /// Circuit-behavioral simulation of one fabricated die.
    Analog {
        seed: u64,
        /// Temporal noise on/off.
        noise: bool,
        /// Run SA-offset calibration before inference (§III.E).
        calibrate: bool,
    },
}

/// Per-layer analog state: one simulated die per column pass.
struct AnalogPass {
    mac: CimMacro,
    /// Output range [start, end) of this pass.
    out_start: usize,
    out_end: usize,
}

struct LayerState {
    passes: Vec<AnalogPass>,
}

/// The executor.
pub struct Executor {
    pub model: NetworkModel,
    pub params: MacroParams,
    backend: Backend,
    analog: Vec<LayerState>,
    /// Accumulated dataflow cost over everything executed.
    pub cost: LayerCost,
    /// Images executed.
    pub images: u64,
}

impl Executor {
    pub fn new(model: NetworkModel, params: MacroParams, backend: Backend) -> Result<Self> {
        let mut analog = Vec::new();
        if let Backend::Analog { seed, noise, calibrate } = &backend {
            for (li, layer) in model.layers.iter().enumerate() {
                let outs_per_pass = params.n_blocks().min(256 / layer.cfg.r_w as usize);
                let mut passes = Vec::new();
                let mut start = 0;
                while start < layer.out_features {
                    let end = (start + outs_per_pass).min(layer.out_features);
                    let mut mac = CimMacro::new(
                        params.clone(),
                        seed.wrapping_add(li as u64 * 1000 + start as u64),
                    );
                    mac.noise = *noise;
                    // Load this pass's weight slice [rows × (end-start)].
                    let n_out = end - start;
                    let mut w = vec![0i32; layer.rows * n_out];
                    for r in 0..layer.rows {
                        for oc in 0..n_out {
                            w[r * n_out + oc] =
                                layer.w_phys[r * layer.out_features + start + oc];
                        }
                    }
                    mac.load_weights(&w, n_out, layer.cfg.r_w);
                    // Program the ABN offsets.
                    for oc in 0..n_out {
                        let adc_col =
                            oc * params.cols_per_block + (layer.cfg.r_w as usize - 1);
                        mac.adcs[adc_col].abn_offset_code = layer.beta[start + oc];
                    }
                    if *calibrate {
                        mac.calibrate_all();
                    }
                    passes.push(AnalogPass { mac, out_start: start, out_end: end });
                    start = end;
                }
                analog.push(LayerState { passes });
            }
        }
        Ok(Self {
            model,
            params,
            backend,
            analog,
            cost: LayerCost::default(),
            images: 0,
        })
    }

    /// Run one image (flattened input in its natural shape) → logits.
    pub fn forward(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let mut act = x.to_vec();
        let mut shape: Vec<usize> = self.model.input_shape.clone();
        let n_layers = self.model.layers.len();
        for li in 0..n_layers {
            let layer = self.model.layers[li].clone();
            let (out, out_shape) = self.forward_layer(li, &layer, &act, &shape)?;
            act = out;
            shape = out_shape;
        }
        self.images += 1;
        Ok(act)
    }

    fn forward_layer(
        &mut self,
        li: usize,
        layer: &Layer,
        act: &[f32],
        shape: &[usize],
    ) -> Result<(Vec<f32>, Vec<usize>)> {
        let m = ((1u32 << layer.cfg.r_in) - 1) as f32;
        let pad_val = (((1u32 << layer.cfg.r_in)) / 2) as u8; // (M+1)/2
        let quant = |v: f32| -> u8 { (v / layer.a_scale).round().clamp(0.0, m) as u8 };

        match layer.kind {
            Kind::Dense => {
                let xq: Vec<u8> = act.iter().map(|&v| quant(v)).collect();
                let mut rows = xq;
                rows.resize(layer.rows, pad_val);
                let codes = self.run_macro(li, layer, &rows)?;
                let out = self.post_adc(layer, &codes);
                self.book_cost_dense(layer);
                Ok((out, vec![layer.out_features]))
            }
            Kind::Conv3 => {
                let (c, h, w) = (shape[0], shape[1], shape[2]);
                debug_assert_eq!(c, layer.in_features);
                let xq: Vec<u8> = act.iter().map(|&v| quant(v)).collect();
                let (row_vecs, oh, ow) =
                    im2col::im2col_image(&xq, c, h, w, layer.stride, pad_val);
                // Pad each pixel's rows to the layer's physical row count.
                let mut fmap = vec![0f32; layer.out_features * oh * ow];
                for (pix, rv) in row_vecs.iter().enumerate() {
                    let mut rows = rv.clone();
                    rows.resize(layer.rows, pad_val);
                    let codes = self.run_macro(li, layer, &rows)?;
                    let vals = self.post_adc(layer, &codes);
                    let (py, px) = (pix / ow, pix % ow);
                    for (oc, &v) in vals.iter().enumerate() {
                        fmap[oc * oh * ow + py * ow + px] = v;
                    }
                }
                let (pooled, ph, pw) = apply_pool(&fmap, layer.out_features, oh, ow, layer.pool);
                self.book_cost_conv(layer, oh, ow);
                if layer.pool == Pool::Gap {
                    Ok((pooled, vec![layer.out_features]))
                } else {
                    Ok((pooled, vec![layer.out_features, ph, pw]))
                }
            }
        }
    }

    /// One macro invocation over all column passes → codes [out_features].
    fn run_macro(&mut self, li: usize, layer: &Layer, rows: &[u8]) -> Result<Vec<u32>> {
        match &self.backend {
            Backend::Ideal => Ok(ideal_codes(&self.params, layer, rows)),
            Backend::Analog { .. } => {
                let state = &mut self.analog[li];
                let mut codes = vec![0u32; layer.out_features];
                for pass in state.passes.iter_mut() {
                    let n_out = pass.out_end - pass.out_start;
                    let out = pass.mac.matvec(rows, n_out, &layer.cfg);
                    codes[pass.out_start..pass.out_end].copy_from_slice(&out);
                }
                Ok(codes)
            }
        }
    }

    fn post_adc(&self, layer: &Layer, codes: &[u32]) -> Vec<f32> {
        post_adc(layer, codes)
    }

    fn col_passes(&self, layer: &Layer) -> usize {
        let outs_per_pass = self.params.n_blocks();
        layer.out_features.div_ceil(outs_per_pass)
    }

    fn book_cost_dense(&mut self, layer: &Layer) {
        let shape = LayerShape::fc(
            layer.in_features,
            layer.out_features,
            layer.cfg.r_in,
            layer.cfg.r_out,
        );
        let c = layer_cost(&self.params, &shape, &layer.cfg, self.col_passes(layer), true);
        self.cost.accumulate(&c);
    }

    fn book_cost_conv(&mut self, layer: &Layer, oh: usize, ow: usize) {
        let shape = LayerShape::conv(
            layer.in_features,
            layer.out_features,
            layer.cfg.r_in,
            layer.cfg.r_out,
            oh,
            ow,
        );
        let c = layer_cost(&self.params, &shape, &layer.cfg, self.col_passes(layer), true);
        self.cost.accumulate(&c);
    }
}

/// Post-ADC digital stage shared by the per-image executor and the
/// batched engine: offset-binary recentering, output gain, optional ReLU.
pub fn post_adc(layer: &Layer, codes: &[u32]) -> Vec<f32> {
    let half = (1u32 << (layer.cfg.r_out - 1)) as f32;
    codes.iter().map(|&c| post_adc_code(layer, half, c)).collect()
}

/// One output of [`post_adc`], with `half = 2^(r_out−1)` hoisted by the
/// caller — the allocation-free form the chunk-pipelined engine streams
/// codes through. Same float expression, so bit-identical by
/// construction.
#[inline]
pub fn post_adc_code(layer: &Layer, half: f32, code: u32) -> f32 {
    let v = (code as f32 - half) * layer.out_gain;
    if layer.relu {
        v.max(0.0)
    } else {
        v
    }
}

/// Per-layer constants of the closed-form macro contract (the python
/// oracle's Eq. 7 path). Factoring them out lets the batched engine map
/// integer dot products to ADC codes through the *same* float expression
/// as [`ideal_codes`], so both paths are bit-identical by construction.
#[derive(Clone, Copy, Debug)]
pub struct IdealContract {
    /// M = 2^r_in − 1 (antipodal input recentering constant).
    pub m: i64,
    dv_scale: f64,
    lsb: f64,
    half: f64,
    top: f64,
    beta_volts_per_code: f64,
}

impl IdealContract {
    pub fn new(p: &MacroParams, layer: &Layer) -> Self {
        let cfg = &layer.cfg;
        let rin_eff = if cfg.r_in > 1 { cfg.r_in } else { 0 };
        let rw_eff = if cfg.r_w > 1 { cfg.r_w } else { 0 };
        IdealContract {
            m: (1i64 << cfg.r_in) - 1,
            dv_scale: p.alpha_eff(layer.rows) * p.supply.vddl
                / (1u64 << (rin_eff + rw_eff)) as f64,
            lsb: p.adc_lsb(cfg.r_out, cfg.gamma),
            half: (1u64 << (cfg.r_out - 1)) as f64,
            top: (1u64 << cfg.r_out) as f64 - 1.0,
            // One 5b ABN offset code moves the DPL by range/16 — the
            // same step the circuit-level ADC model applies.
            beta_volts_per_code: p.abn_offset_range / 16.0,
        }
    }

    /// ADC code for a signed dot product Σ (2X−M)·W and ABN offset `beta`.
    #[inline]
    pub fn code(&self, dot: i64, beta: i32) -> u32 {
        let dv = self.dv_scale * dot as f64 + beta as f64 * self.beta_volts_per_code;
        (self.half + dv / self.lsb).floor().clamp(0.0, self.top) as u32
    }
}

/// Closed-form codes (the python oracle's contract) for one row vector.
pub fn ideal_codes(p: &MacroParams, layer: &Layer, rows: &[u8]) -> Vec<u32> {
    let contract = IdealContract::new(p, layer);
    let m = contract.m;
    let mut out = Vec::with_capacity(layer.out_features);
    for oc in 0..layer.out_features {
        let mut dot: i64 = 0;
        for (r, &x) in rows.iter().enumerate() {
            let w = layer.w_phys[r * layer.out_features + oc] as i64;
            dot += (2 * x as i64 - m) * w;
        }
        out.push(contract.code(dot, layer.beta[oc]));
    }
    out
}

/// Pooling on a CHW feature map.
pub fn apply_pool(
    fmap: &[f32],
    c: usize,
    h: usize,
    w: usize,
    pool: Pool,
) -> (Vec<f32>, usize, usize) {
    let mut out = Vec::new();
    let (ph, pw) = apply_pool_into(fmap, c, h, w, pool, &mut out);
    (out, ph, pw)
}

/// [`apply_pool`] appending the pooled map to a caller-owned buffer —
/// the allocation-free form the chunk-pipelined engine uses. Values are
/// produced in the exact element order (and by the exact float
/// expressions) of the allocating form.
pub fn apply_pool_into(
    fmap: &[f32],
    c: usize,
    h: usize,
    w: usize,
    pool: Pool,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    match pool {
        Pool::None => {
            out.extend_from_slice(fmap);
            (h, w)
        }
        Pool::Gap => {
            for ch in 0..c {
                let s: f32 = fmap[ch * h * w..(ch + 1) * h * w].iter().sum();
                out.push(s / (h * w) as f32);
            }
            (1, 1)
        }
        Pool::Max2 | Pool::Avg2 => {
            let (h2, w2) = ((h / 2) * 2, (w / 2) * 2);
            let (ph, pw) = (h2 / 2, w2 / 2);
            for ch in 0..c {
                for py in 0..ph {
                    for px in 0..pw {
                        let vals = [
                            fmap[ch * h * w + (2 * py) * w + 2 * px],
                            fmap[ch * h * w + (2 * py) * w + 2 * px + 1],
                            fmap[ch * h * w + (2 * py + 1) * w + 2 * px],
                            fmap[ch * h * w + (2 * py + 1) * w + 2 * px + 1],
                        ];
                        out.push(if pool == Pool::Max2 {
                            vals.iter().cloned().fold(f32::MIN, f32::max)
                        } else {
                            vals.iter().sum::<f32>() / 4.0
                        });
                    }
                }
            }
            (ph, pw)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_max2_and_avg2() {
        // 1 channel, 2×2.
        let fmap = [1.0, 2.0, 3.0, 4.0];
        let (mx, h, w) = apply_pool(&fmap, 1, 2, 2, Pool::Max2);
        assert_eq!((h, w), (1, 1));
        assert_eq!(mx, vec![4.0]);
        let (av, _, _) = apply_pool(&fmap, 1, 2, 2, Pool::Avg2);
        assert_eq!(av, vec![2.5]);
    }

    #[test]
    fn pool_gap() {
        let fmap = [1.0, 3.0, 5.0, 7.0, 2.0, 2.0, 2.0, 2.0];
        let (g, _, _) = apply_pool(&fmap, 2, 2, 2, Pool::Gap);
        assert_eq!(g, vec![4.0, 2.0]);
    }

    #[test]
    fn pool_crops_odd_dims() {
        // 3×3 map → 1×1 after max2 (floor crop), matching python.
        let fmap: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let (mx, h, w) = apply_pool(&fmap, 1, 3, 3, Pool::Max2);
        assert_eq!((h, w), (1, 1));
        assert_eq!(mx, vec![4.0]); // max of the top-left 2×2
    }
}
