//! Multi-die analog backend: one cloned [`CimMacro`] pipeline per worker.
//!
//! The circuit-behavioral simulator is inherently sequential per die (the
//! noise RNG chain threads through every conversion), so batched analog
//! runs scale by *fabricating more dies*: worker `d` owns a full
//! per-layer pass pipeline seeded with a deterministic per-die seed, and
//! a batch of images is split contiguously across dies. Worker 0 uses the
//! base seed unchanged, so a single-worker pool reproduces the historical
//! `Executor` + `Backend::Analog` results image for image; additional
//! dies model exactly what multi-macro silicon would do — independent
//! mismatch draws per die.

use crate::config::params::MacroParams;
use crate::coordinator::executor::{Backend, Executor};
use crate::coordinator::manifest::NetworkModel;
use crate::energy::system::LayerCost;
use anyhow::{anyhow, Result};

/// Per-die seed stride (odd 64-bit mix constant, so die seeds never
/// collide for d < 2^63). Shared with the equivalent-noise probe
/// ([`super::noise`]) so a probed die `d` is the same fabrication the
/// pool's worker `d` would serve with.
pub(crate) const DIE_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// A pool of independently-fabricated simulated dies.
pub struct AnalogPool {
    dies: Vec<Executor>,
    params: MacroParams,
    /// Pristine copy of the as-fabricated model. Precision re-targeting
    /// re-shapes from here (never from an already-reshaped model, float
    /// rescaling is not associative) and only touches the model each die
    /// serves — the fabricated die state itself (mismatch draws, loaded
    /// weights, ABN offsets, SA calibration) depends only on
    /// precision-independent layer fields (`rows`, `w_phys`, `beta`,
    /// `r_w`), which is what makes the re-target cheap: no re-fab, no
    /// re-calibration, seeds and RNG chains untouched.
    base: NetworkModel,
    /// Per-layer modeled cost of one image at the current operating
    /// point (data-independent; the same bookings every die makes).
    per_layer_image: Vec<LayerCost>,
    /// Per-layer cost accumulated over everything executed (booked per
    /// batch at the precision it actually ran at).
    accum_layers: Vec<LayerCost>,
    /// Images executed (across all dies).
    pub images: u64,
}

impl AnalogPool {
    /// Fabricate `workers` dies. Die `d` is seeded `seed + d·stride`
    /// (die 0 keeps `seed` exactly — bit-compatible with the per-image
    /// executor path).
    pub fn new(
        model: NetworkModel,
        params: MacroParams,
        seed: u64,
        noise: bool,
        calibrate: bool,
        workers: usize,
    ) -> Result<Self> {
        let workers = workers.max(1);
        let per_layer_image = crate::engine::ideal::network_layer_costs(&model, &params);
        let accum_layers = vec![LayerCost::default(); model.layers.len()];
        let dies = (0..workers)
            .map(|d| {
                Executor::new(
                    model.clone(),
                    params.clone(),
                    Backend::Analog {
                        seed: seed.wrapping_add(DIE_SEED_STRIDE.wrapping_mul(d as u64)),
                        noise,
                        calibrate,
                    },
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            base: model,
            params,
            dies,
            per_layer_image,
            accum_layers,
            images: 0,
        })
    }

    /// Re-shape every die's served model to (r_in, r_out), or back to
    /// the as-fabricated precision (`None`). The dies themselves are
    /// untouched — see the `base` field docs — so a pool re-targeted to
    /// some point behaves exactly like a pool freshly fabricated at that
    /// point (same seeds, same mismatch, same calibration). Only the
    /// per-layer precision scalars move (restored from base, then
    /// re-derived through the same reshaping a fresh model would get):
    /// no weight tensor is cloned, so interleaved multi-precision
    /// traffic re-targets in O(dies × layers).
    pub fn retarget(&mut self, precision: Option<(u32, u32)>) {
        for die in &mut self.dies {
            die.model.copy_precision_fields_from(&self.base);
            if let Some((r_in, r_out)) = precision {
                die.model.retarget_precision(r_in, r_out);
            }
        }
        self.per_layer_image =
            crate::engine::ideal::network_layer_costs(&self.dies[0].model, &self.params);
    }

    pub fn n_dies(&self) -> usize {
        self.dies.len()
    }

    pub fn input_len(&self) -> usize {
        self.dies[0].model.input_shape.iter().product()
    }

    /// Aggregate dataflow/energy cost across all dies.
    pub fn cost(&self) -> LayerCost {
        let mut total = LayerCost::default();
        for die in &self.dies {
            total.accumulate(&die.cost);
        }
        total
    }

    /// Per-layer modeled cost accumulated over everything executed.
    pub fn layer_costs(&self) -> Vec<LayerCost> {
        self.accum_layers.clone()
    }

    /// Run a batch of images, split contiguously across the dies; results
    /// come back in submission order.
    pub fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        self.forward_batch_into(images, &mut out)?;
        Ok(out)
    }

    /// [`Self::forward_batch`] writing into a caller-owned buffer
    /// (capacity reused across batches): die `d` fills its contiguous
    /// slice of `out` in place, so no intermediate per-die result
    /// vectors are assembled and re-spliced per batch. On error the
    /// buffer's contents are unspecified (errors are still reported in
    /// die order, matching the historical path).
    pub fn forward_batch_into(
        &mut self,
        images: &[Vec<f32>],
        out: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        out.resize_with(images.len(), Vec::new);
        if images.is_empty() {
            return Ok(());
        }
        let n_dies = self.dies.len().min(images.len());
        let chunk = images.len().div_ceil(n_dies);
        let mut statuses: Vec<Result<()>> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            let spans = images.chunks(chunk).zip(out.chunks_mut(chunk));
            for ((imgs, slots), die) in spans.zip(self.dies.iter_mut()) {
                handles.push(s.spawn(move || -> Result<()> {
                    for (slot, im) in slots.iter_mut().zip(imgs) {
                        *slot = die.forward(im)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                statuses.push(
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("analog worker panicked"))),
                );
            }
        });
        for status in statuses {
            status?;
        }
        let n = images.len() as u64;
        self.images += n;
        for (acc, per_image) in self.accum_layers.iter_mut().zip(&self.per_layer_image) {
            acc.accumulate(&per_image.scaled(n));
        }
        Ok(())
    }
}
