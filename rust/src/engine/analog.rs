//! Multi-die analog backend: one cloned [`CimMacro`] pipeline per worker.
//!
//! The circuit-behavioral simulator is inherently sequential per die (the
//! noise RNG chain threads through every conversion), so batched analog
//! runs scale by *fabricating more dies*: worker `d` owns a full
//! per-layer pass pipeline seeded with a deterministic per-die seed, and
//! a batch of images is split contiguously across dies. Worker 0 uses the
//! base seed unchanged, so a single-worker pool reproduces the historical
//! `Executor` + `Backend::Analog` results image for image; additional
//! dies model exactly what multi-macro silicon would do — independent
//! mismatch draws per die.

use crate::config::params::MacroParams;
use crate::coordinator::executor::{Backend, Executor};
use crate::coordinator::manifest::NetworkModel;
use crate::energy::system::LayerCost;
use anyhow::{anyhow, Result};

/// Per-die seed stride (odd 64-bit mix constant, so die seeds never
/// collide for d < 2^63).
const DIE_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// A pool of independently-fabricated simulated dies.
pub struct AnalogPool {
    dies: Vec<Executor>,
    /// Per-layer modeled cost of one image (data-independent; the same
    /// bookings every die makes as it executes).
    per_layer_image: Vec<LayerCost>,
    /// Images executed (across all dies).
    pub images: u64,
}

impl AnalogPool {
    /// Fabricate `workers` dies. Die `d` is seeded `seed + d·stride`
    /// (die 0 keeps `seed` exactly — bit-compatible with the per-image
    /// executor path).
    pub fn new(
        model: NetworkModel,
        params: MacroParams,
        seed: u64,
        noise: bool,
        calibrate: bool,
        workers: usize,
    ) -> Result<Self> {
        let workers = workers.max(1);
        let per_layer_image = crate::engine::ideal::network_layer_costs(&model, &params);
        let dies = (0..workers)
            .map(|d| {
                Executor::new(
                    model.clone(),
                    params.clone(),
                    Backend::Analog {
                        seed: seed.wrapping_add(DIE_SEED_STRIDE.wrapping_mul(d as u64)),
                        noise,
                        calibrate,
                    },
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dies, per_layer_image, images: 0 })
    }

    pub fn n_dies(&self) -> usize {
        self.dies.len()
    }

    pub fn input_len(&self) -> usize {
        self.dies[0].model.input_shape.iter().product()
    }

    /// Aggregate dataflow/energy cost across all dies.
    pub fn cost(&self) -> LayerCost {
        let mut total = LayerCost::default();
        for die in &self.dies {
            total.accumulate(&die.cost);
        }
        total
    }

    /// Accumulated per-layer modeled cost (the per-image bookings scaled
    /// by the images executed across all dies).
    pub fn layer_costs(&self) -> Vec<LayerCost> {
        self.per_layer_image
            .iter()
            .map(|c| c.scaled(self.images))
            .collect()
    }

    /// Run a batch of images, split contiguously across the dies; results
    /// come back in submission order.
    pub fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let n_dies = self.dies.len().min(images.len());
        let chunk = images.len().div_ceil(n_dies);
        let mut per_die: Vec<Result<Vec<Vec<f32>>>> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (die, imgs) in self.dies.iter_mut().zip(images.chunks(chunk)) {
                handles.push(s.spawn(move || -> Result<Vec<Vec<f32>>> {
                    imgs.iter().map(|im| die.forward(im)).collect()
                }));
            }
            for h in handles {
                per_die.push(
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("analog worker panicked"))),
                );
            }
        });
        let mut out = Vec::with_capacity(images.len());
        for r in per_die {
            out.extend(r?);
        }
        self.images += images.len() as u64;
        Ok(out)
    }
}
