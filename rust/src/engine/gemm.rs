//! Batched matrix kernels for the engine layer — plain std, no BLAS.
//!
//! Two shapes cover every hot path:
//!
//! * [`matmul_i32`] — `C[v][o] = Σ_r A[v][r] · W[r][o]` with `W` row-major
//!   `[rows × out]` (the manifest's physical weight layout). The kernel
//!   register-blocks four batch vectors per weight pass, so each weight
//!   element loaded from memory feeds four MACs — this is the software
//!   analogue of the macro amortizing one array activation across a whole
//!   wavefront, and it is where the batch≥4 throughput win comes from.
//! * [`rowdot_f64`] — `C[v][o] = Σ_k X[v][k] · W[o][k]` with `W` stored
//!   one row per *output* (the MLP training layout used by `cim_eval`).
//!   Accumulation order over `k` is ascending, so results are
//!   bit-identical to the historical per-image loops.
//!
//! Both kernels split the batch dimension across scoped worker threads;
//! with a single worker (or a single vector) they degrade to the plain
//! serial loop with no thread overhead.
//!
//! These are the **scalar reference** kernels: [`super::kernels`]
//! dispatches between them, the SIMD tiles, and the low-precision
//! bit-plane engine, and every alternate path is tested bit-identical
//! to the functions in this module. New call sites should go through
//! `engine::kernels` so they inherit precision/ISA-adaptive dispatch.

/// `C[v][o] = Σ_r a[v*rows + r] * w[r*n_out + o]` over `n_vec` vectors.
pub fn matmul_i32(
    a: &[i32],
    w: &[i32],
    n_vec: usize,
    rows: usize,
    n_out: usize,
    workers: usize,
) -> Vec<i32> {
    assert_eq!(a.len(), n_vec * rows);
    assert_eq!(w.len(), rows * n_out);
    let mut out = vec![0i32; n_vec * n_out];
    if n_vec == 0 || n_out == 0 {
        return out;
    }
    let workers = workers.clamp(1, n_vec);
    let chunk_vecs = n_vec.div_ceil(workers);
    if workers == 1 {
        matmul_i32_chunk(a, w, rows, n_out, &mut out);
        return out;
    }
    std::thread::scope(|s| {
        for (a_chunk, out_chunk) in a
            .chunks(chunk_vecs * rows)
            .zip(out.chunks_mut(chunk_vecs * n_out))
        {
            s.spawn(move || matmul_i32_chunk(a_chunk, w, rows, n_out, out_chunk));
        }
    });
    out
}

pub(crate) fn matmul_i32_chunk(a: &[i32], w: &[i32], rows: usize, n_out: usize, out: &mut [i32]) {
    let n_vec = a.len() / rows;
    let mut v = 0;
    // Four batch vectors per weight pass.
    while v + 4 <= n_vec {
        let (b0, rest) = out[v * n_out..(v + 4) * n_out].split_at_mut(n_out);
        let (b1, rest) = rest.split_at_mut(n_out);
        let (b2, b3) = rest.split_at_mut(n_out);
        for r in 0..rows {
            let wr = &w[r * n_out..(r + 1) * n_out];
            let s0 = a[v * rows + r];
            let s1 = a[(v + 1) * rows + r];
            let s2 = a[(v + 2) * rows + r];
            let s3 = a[(v + 3) * rows + r];
            for o in 0..n_out {
                let wv = wr[o];
                b0[o] += s0 * wv;
                b1[o] += s1 * wv;
                b2[o] += s2 * wv;
                b3[o] += s3 * wv;
            }
        }
        v += 4;
    }
    // Remainder vectors one at a time.
    while v < n_vec {
        let bo = &mut out[v * n_out..(v + 1) * n_out];
        for r in 0..rows {
            let wr = &w[r * n_out..(r + 1) * n_out];
            let s = a[v * rows + r];
            for o in 0..n_out {
                bo[o] += s * wr[o];
            }
        }
        v += 1;
    }
}

/// Assemble the signed antipodal row factors for a batch of quantized
/// CHW images lowered through the streaming im2col: every output pixel
/// of every image becomes one row vector in the macro's physical row
/// order (padded to `rows` with the mid-rail constant, whose factor is
/// `2·(M+1)/2 − M = +1`). Returns `(sx [n_img·oh·ow × rows], oh, ow)`.
///
/// This is the conv-side batch prep shared by [`conv3x3_batch`] and the
/// ideal engine backend — the software image of the input shift
/// register feeding the array one 128b beat at a time (§IV).
pub fn conv3x3_signed_rows(
    images_q: &[Vec<u8>],
    c: usize,
    h: usize,
    w: usize,
    stride: usize,
    r_in: u32,
    rows: usize,
) -> (Vec<i32>, usize, usize) {
    let (mut oh, mut ow) = (0usize, 0usize);
    let mut sx = Vec::new();
    for (i, xq) in images_q.iter().enumerate() {
        (oh, ow) = conv3x3_signed_rows_into(xq, c, h, w, stride, r_in, rows, &mut sx);
        if i == 0 {
            sx.reserve(images_q.len().saturating_sub(1) * oh * ow * rows);
        }
    }
    (sx, oh, ow)
}

/// Per-image core of [`conv3x3_signed_rows`]: appends the signed row
/// factors for **one** quantized CHW image to `sx` and returns
/// `(oh, ow)`. The direct-conv kernel (`kernels::conv3x3_direct`)
/// streams the batch through a per-worker scratch buffer with this,
/// instead of materializing the whole-batch `[(img·oh·ow) × rows]`
/// matrix.
///
/// The row factors are computed straight from the CHW image through a
/// per-call row map (macro row → channel/tap, from
/// [`crate::dataflow::im2col::row_order`] semantics) held in the
/// thread-local scratch arena — no per-pixel patch vectors are
/// materialized, so the conv hot path stays allocation-free once the
/// arena is warm. Bit-identical to lowering through
/// [`crate::dataflow::im2col::im2col_image`]: padding rows carry the
/// mid-rail constant, out-of-image taps the zero-pad value.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_signed_rows_into(
    xq: &[u8],
    c: usize,
    h: usize,
    w: usize,
    stride: usize,
    r_in: u32,
    rows: usize,
    sx: &mut Vec<i32>,
) -> (usize, usize) {
    assert_eq!(xq.len(), c * h * w);
    let m = (1i32 << r_in) - 1;
    let pad = ((1u32 << r_in) / 2) as u8;
    let s_pad = 2 * pad as i32 - m;
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    // Macro row → packed (channel, tap) descriptor, or −1 for a padding
    // row (feature slot past the real channel count, or row past the
    // im2col extent). Encoding: ch·16 + dy·4 + dx.
    let n_rows = c.div_ceil(4) * 36;
    let mut rowmap = crate::engine::arena::take_i32(rows);
    for r in 0..rows {
        let ch = 4 * (r / 36) + r % 4;
        let tap = (r % 36) / 4;
        rowmap.push(if r < n_rows && ch < c {
            (ch * 16 + (tap / 3) * 4 + tap % 3) as i32
        } else {
            -1
        });
    }
    sx.reserve(oh * ow * rows);
    for oy in 0..oh {
        let by = (oy * stride) as isize - 1;
        for ox in 0..ow {
            let bx = (ox * stride) as isize - 1;
            for &e in rowmap.iter() {
                if e < 0 {
                    sx.push(s_pad);
                    continue;
                }
                let iy = by + ((e >> 2) & 3) as isize;
                let ix = bx + (e & 3) as isize;
                let inside = iy >= 0 && ix >= 0 && iy < h as isize && ix < w as isize;
                let q = if inside {
                    let ch = (e >> 4) as usize;
                    xq[ch * h * w + iy as usize * w + ix as usize] as i32
                } else {
                    0
                };
                sx.push(2 * q - m);
            }
        }
    }
    crate::engine::arena::put_i32(rowmap);
    (oh, ow)
}

/// Whole-batch 3×3 convolution on the macro's integer contract: im2col
/// row assembly ([`conv3x3_signed_rows`]) followed by one blocked
/// [`matmul_i32`] pass against the physical weights `[rows × n_out]`.
/// Returns the signed dot products `[(img,pixel) × n_out]` plus the
/// output spatial dims — the caller applies the ADC/ABN contract.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_batch(
    images_q: &[Vec<u8>],
    c: usize,
    h: usize,
    w: usize,
    stride: usize,
    r_in: u32,
    w_phys: &[i32],
    rows: usize,
    n_out: usize,
    workers: usize,
) -> (Vec<i32>, usize, usize) {
    let (sx, oh, ow) = conv3x3_signed_rows(images_q, c, h, w, stride, r_in, rows);
    let n_vec = images_q.len() * oh * ow;
    let dots = matmul_i32(&sx, w_phys, n_vec, rows, n_out, workers);
    (dots, oh, ow)
}

/// `C[v][o] = Σ_k x[v*k_dim + k] * w[o*k_dim + k]` over `n_vec` vectors.
pub fn rowdot_f64(
    x: &[f64],
    w: &[f64],
    n_vec: usize,
    k_dim: usize,
    n_out: usize,
    workers: usize,
) -> Vec<f64> {
    assert_eq!(x.len(), n_vec * k_dim);
    assert_eq!(w.len(), n_out * k_dim);
    let mut out = vec![0f64; n_vec * n_out];
    if n_vec == 0 || n_out == 0 {
        return out;
    }
    let workers = workers.clamp(1, n_vec);
    let chunk_vecs = n_vec.div_ceil(workers);
    if workers == 1 {
        rowdot_f64_chunk(x, w, k_dim, n_out, &mut out);
        return out;
    }
    std::thread::scope(|s| {
        for (x_chunk, out_chunk) in x
            .chunks(chunk_vecs * k_dim)
            .zip(out.chunks_mut(chunk_vecs * n_out))
        {
            s.spawn(move || rowdot_f64_chunk(x_chunk, w, k_dim, n_out, out_chunk));
        }
    });
    out
}

fn rowdot_f64_chunk(x: &[f64], w: &[f64], k_dim: usize, n_out: usize, out: &mut [f64]) {
    let n_vec = x.len() / k_dim;
    for v in 0..n_vec {
        let xv = &x[v * k_dim..(v + 1) * k_dim];
        let bo = &mut out[v * n_out..(v + 1) * n_out];
        for (o, acc) in bo.iter_mut().enumerate() {
            let wo = &w[o * k_dim..(o + 1) * k_dim];
            let mut dot = 0f64;
            for k in 0..k_dim {
                dot += xv[k] * wo[k];
            }
            *acc = dot;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_i32(a: &[i32], w: &[i32], n_vec: usize, rows: usize, n_out: usize) -> Vec<i32> {
        let mut out = vec![0i32; n_vec * n_out];
        for v in 0..n_vec {
            for o in 0..n_out {
                let mut acc = 0i32;
                for r in 0..rows {
                    acc += a[v * rows + r] * w[r * n_out + o];
                }
                out[v * n_out + o] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_i32_matches_naive_all_remainders() {
        let mut rng = Rng::new(1);
        for n_vec in [0usize, 1, 2, 3, 4, 5, 7, 8, 13] {
            for workers in [1usize, 2, 3, 8] {
                let (rows, n_out) = (29, 11);
                let a: Vec<i32> =
                    (0..n_vec * rows).map(|_| rng.int_range(-255, 255) as i32).collect();
                let w: Vec<i32> =
                    (0..rows * n_out).map(|_| rng.int_range(-15, 15) as i32).collect();
                let got = matmul_i32(&a, &w, n_vec, rows, n_out, workers);
                let want = naive_i32(&a, &w, n_vec, rows, n_out);
                assert_eq!(got, want, "n_vec={n_vec} workers={workers}");
            }
        }
    }

    #[test]
    fn conv3x3_batch_matches_per_pixel_assembly() {
        let mut rng = Rng::new(3);
        let (c, h, w, stride, r_in) = (3usize, 5usize, 5usize, 1usize, 4u32);
        let rows = 2 * 36; // ceil(3/4) = 1 unit of real rows, padded to 2
        let n_out = 6;
        let images_q: Vec<Vec<u8>> = (0..3)
            .map(|_| (0..c * h * w).map(|_| rng.below(16) as u8).collect())
            .collect();
        let w_phys: Vec<i32> =
            (0..rows * n_out).map(|_| rng.int_range(-15, 15) as i32).collect();
        let (dots, oh, ow) =
            conv3x3_batch(&images_q, c, h, w, stride, r_in, &w_phys, rows, n_out, 2);
        assert_eq!((oh, ow), (5, 5));
        assert_eq!(dots.len(), images_q.len() * oh * ow * n_out);
        // Cross-check one pixel against a direct per-row accumulation.
        let m = (1i32 << r_in) - 1;
        let pad = ((1u32 << r_in) / 2) as u8;
        let (rvs, _, _) =
            crate::dataflow::im2col::im2col_image(&images_q[1], c, h, w, stride, pad);
        let pix = 7;
        for o in 0..n_out {
            let mut acc = 0i32;
            for r in 0..rows {
                let q = rvs[pix].get(r).copied().unwrap_or(pad);
                acc += (2 * q as i32 - m) * w_phys[r * n_out + o];
            }
            assert_eq!(dots[(oh * ow + pix) * n_out + o], acc, "o={o}");
        }
    }

    #[test]
    fn signed_rows_match_im2col_lowering() {
        // The direct row-map lowering must agree with the reference
        // patch-vector path for partial DP units (c=5), strided images,
        // padded row tails (rows > units·36) and truncated row budgets.
        let mut rng = Rng::new(9);
        let cases = [
            (5usize, 4usize, 4usize, 1usize, 4u32, 72usize),
            (2, 5, 5, 2, 2, 40),
            (3, 4, 4, 1, 4, 20),
        ];
        for (c, h, w, stride, r_in, rows) in cases {
            let xq: Vec<u8> = (0..c * h * w).map(|_| rng.below(1u64 << r_in) as u8).collect();
            let mut sx = Vec::new();
            let (oh, ow) = conv3x3_signed_rows_into(&xq, c, h, w, stride, r_in, rows, &mut sx);
            let m = (1i32 << r_in) - 1;
            let pad = ((1u32 << r_in) / 2) as u8;
            let (rvs, oh2, ow2) = crate::dataflow::im2col::im2col_image(&xq, c, h, w, stride, pad);
            assert_eq!((oh, ow), (oh2, ow2), "c={c} stride={stride}");
            let mut want = Vec::new();
            for rv in &rvs {
                for r in 0..rows {
                    let q = rv.get(r).copied().unwrap_or(pad);
                    want.push(2 * q as i32 - m);
                }
            }
            assert_eq!(sx, want, "c={c} stride={stride} rows={rows}");
        }
    }

    #[test]
    fn rowdot_matches_naive_and_is_order_stable() {
        let mut rng = Rng::new(2);
        let (n_vec, k_dim, n_out) = (9, 33, 5);
        let x: Vec<f64> = (0..n_vec * k_dim).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let w: Vec<f64> = (0..n_out * k_dim).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let serial = rowdot_f64(&x, &w, n_vec, k_dim, n_out, 1);
        let parallel = rowdot_f64(&x, &w, n_vec, k_dim, n_out, 4);
        // Same ascending-k accumulation order per element → bit-identical.
        assert_eq!(serial, parallel);
        for v in 0..n_vec {
            for o in 0..n_out {
                let mut dot = 0f64;
                for k in 0..k_dim {
                    dot += x[v * k_dim + k] * w[o * k_dim + k];
                }
                assert_eq!(serial[v * n_out + o], dot);
            }
        }
    }
}
