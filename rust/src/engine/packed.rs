//! Persistent packed-weight caches for the engine and graph hot paths.
//!
//! The silicon keeps weights stationary in the array — packing them is
//! a deploy-time cost, not a per-batch one. Before this module the
//! software paid the opposite way around: every `forward_batch` re-ran
//! `BitPlanes::pack` (bit-plane u64 planes + validity masks) and every
//! graph/trainer forward re-derived the kernel-layout i32 weight matrix
//! from the f32 training layout. Both forms are pure functions of the
//! weights and the layer's input precision, so they are built **once**
//! at deploy/retarget time and shared read-only across workers and
//! batches:
//!
//! * [`PackedWeights`] — per physical layer: the pre-packed
//!   [`kernels::BitPlanes`] for the layer's current `r_in`, threaded
//!   into the gemm/conv dispatch so the bit-plane tier skips re-packing.
//!   Rebuilt by `BatchIdeal::retarget` on precision hops (the pack is
//!   keyed to `r_in`).
//! * [`NodeKernel`] — per quantized graph node: the `[k × n_out]`
//!   row-major i32 matrix (integer fast path) or the f64 rowdot layout
//!   (fallback), replacing the per-forward `quantized_rowmajor_i32`
//!   conversion. Rebuilt by the trainer's `refresh_weights` after every
//!   optimizer step.
//!
//! Cache consistency is by construction: both forms are derived through
//! the *same* eligibility predicates the per-call path used
//! (`BitPlanes::pack`, `quantized_dot_fits_i32`), so kernel selection —
//! and therefore bit-exact output — is unchanged; only the redundant
//! re-derivation disappears.

use super::kernels::{self, BitPlanes};

/// Read-only packed forms of one physical layer's `[rows × n_out]`
/// weight matrix, built once per (deployment, precision).
#[derive(Clone, Debug)]
pub struct PackedWeights {
    r_in: u32,
    bitplanes: Option<BitPlanes>,
}

impl PackedWeights {
    /// Pack `w` for a layer running at input precision `r_in`. The
    /// bit-plane form is built exactly when auto-selection could route
    /// to the bit-plane tier (`r_in` within the auto gate and weights
    /// antipodal-eligible) — mirroring `select_gemm`, so a cache hit
    /// can never change which kernel runs.
    pub fn build(w: &[i32], rows: usize, n_out: usize, r_in: u32) -> Self {
        let bitplanes = if kernels::bitplane_auto_rin(r_in) {
            BitPlanes::pack(w, rows, n_out, r_in)
        } else {
            None
        };
        PackedWeights { r_in, bitplanes }
    }

    /// The input precision this pack is keyed to.
    pub fn r_in(&self) -> u32 {
        self.r_in
    }

    /// The pre-packed bit-planes, if this layer is bit-plane eligible.
    pub fn bitplanes(&self) -> Option<&BitPlanes> {
        self.bitplanes.as_ref()
    }
}

/// Cached kernel-side form of a quantized graph node's weights (the
/// trainer/graph `[n_out × k]` f32 layout resolved into whichever
/// kernel layout its forward will actually use).
#[derive(Clone, Debug)]
pub enum NodeKernel {
    /// Exact-integer fast path: `[k × n_out]` row-major i32, `max |w|`
    /// (the overflow-bound witness) and — when the node's `r_in` is in
    /// the bit-plane auto gate and the weights are antipodal-eligible —
    /// the pre-packed bit-planes for the popcount tier.
    I32 {
        wi: Vec<i32>,
        wmax: i32,
        planes: Option<BitPlanes>,
    },
    /// f64 rowdot fallback (non-integral or implausibly large weights).
    F64 { w64: Vec<f64> },
}

impl NodeKernel {
    /// Resolve the kernel form for weights `w_q` at input precision
    /// `r_in` — the same decision the per-call path made
    /// (`quantized_rowmajor_i32` + `quantized_dot_fits_i32`), hoisted
    /// to build/refresh time.
    pub fn build(w_q: &[f32], n_out: usize, k_dim: usize, r_in: u32) -> Self {
        match kernels::quantized_rowmajor_i32(w_q, n_out, k_dim)
            .filter(|&(_, wmax)| kernels::quantized_dot_fits_i32(k_dim, r_in, wmax))
        {
            Some((wi, wmax)) => {
                let planes = if kernels::bitplane_auto_rin(r_in) {
                    BitPlanes::pack(&wi, k_dim, n_out, r_in)
                } else {
                    None
                };
                NodeKernel::I32 { wi, wmax, planes }
            }
            None => NodeKernel::F64 { w64: w_q.iter().map(|&v| v as f64).collect() },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_weights_key_to_rin() {
        // Antipodal weights, big enough matrix for the bit-plane tier.
        let w = vec![3i32; 64 * 8];
        let low = PackedWeights::build(&w, 64, 8, 1);
        assert_eq!(low.r_in(), 1);
        assert!(low.bitplanes().is_some());
        // Outside the auto gate no pack is kept.
        let high = PackedWeights::build(&w, 64, 8, 8);
        assert!(high.bitplanes().is_none());
        // Ineligible weights never pack.
        let even = vec![2i32; 64 * 8];
        assert!(PackedWeights::build(&even, 64, 8, 1).bitplanes().is_none());
    }

    #[test]
    fn node_kernel_resolves_like_the_per_call_path() {
        let wq = [1.0f32, -3.0, 15.0, 0.0];
        match NodeKernel::build(&wq, 2, 2, 8) {
            NodeKernel::I32 { wi, wmax, planes } => {
                assert_eq!(wi, vec![1, 15, -3, 0]);
                assert_eq!(wmax, 15);
                assert!(planes.is_none(), "r_in=8 is outside the auto gate");
            }
            NodeKernel::F64 { .. } => panic!("integral weights must take the i32 path"),
        }
        let frac = [0.5f32, 1.0];
        assert!(matches!(NodeKernel::build(&frac, 1, 2, 8), NodeKernel::F64 { .. }));
    }
}
