//! The batched multi-die execution engine — the shared inference layer
//! between the dataflow/analog models below and the coordinator above.
//!
//! The paper's macro hits 0.15–8 POPS/W by amortizing conversion and
//! accumulation across a whole 1152×256 array per cycle; this layer does
//! the software equivalent for the reproduction's hot path, replacing the
//! image-by-image, dot-by-dot inference walk:
//!
//! * [`gemm`] — the scalar reference kernels (one weight pass per four
//!   batch vectors, split across worker threads);
//! * [`kernels`] — precision/ISA-adaptive dispatch over the gemm/conv
//!   hot path: portable-SIMD and `std::arch` AVX2/NEON tiles (behind
//!   the `simd` feature with runtime detection), a bit-plane popcount
//!   engine at `r_in ∈ {1,2}` that makes software cost scale with input
//!   precision like the silicon, and a direct conv3x3 that skips the
//!   whole-batch im2col buffer — all bit-identical to [`gemm`];
//! * [`arena`] — thread-local high-water-mark scratch pools: im2col
//!   rows, input bit-plane packs and intermediate activations are taken
//!   and returned per call instead of re-allocated, so the steady-state
//!   hot path performs no allocations (pinned by
//!   `tests/alloc_steady_state.rs`);
//! * [`packed`] — persistent packed-weight caches built once at
//!   deploy/retarget (bit-plane planes + validity masks, kernel-layout
//!   i32 matrices) and shared read-only across workers and batches,
//!   mirroring the macro's weight-stationary arrays;
//! * [`ideal`] — [`BatchIdeal`]: whole-batch closed-form contract
//!   evaluation, bit-identical to the per-image executor; batches run
//!   chunk-pipelined (each worker carries a fixed chunk of images
//!   through *all* layers) instead of through full-batch layer
//!   barriers;
//! * [`analog`] — [`AnalogPool`]: one cloned circuit-behavioral die per
//!   worker with deterministic per-die seeds;
//! * [`noise`] — the equivalent-output-noise probe: measure the analog
//!   backend's temporal + fixed-pattern σ at a supply/corner, which the
//!   CIM-aware trainer injects back into its forward passes;
//! * [`queue`] — the multi-tenant work-queue scheduler ([`start`],
//!   [`EngineHandle`]): concurrent callers submit single images tagged
//!   with a [`RouteKey`] (deployment id + requested precision), a
//!   dispatcher coalesces same-key jobs into batches (configurable size +
//!   flush interval), [`BatchBackend::retarget`]s the deployment's
//!   backend when the requested (r_in, r_out) point changes, and runs the
//!   batch. Backends are installed/removed at runtime
//!   ([`EngineHandle::deploy`] / [`EngineHandle::undeploy`]) — this is
//!   what the `ModelHub` serves every tenant through, instead of one
//!   engine (and one precision) per process.

// `missing_docs` enforcement (see lib.rs): the kernel dispatch layer is
// part of the documented public surface; the other engine submodules are
// internals-with-pub-items and opt out for now.
#[allow(missing_docs)]
pub mod analog;
#[allow(missing_docs)]
pub mod arena;
#[allow(missing_docs)]
pub mod gemm;
#[allow(missing_docs)]
pub mod ideal;
pub mod kernels;
#[allow(missing_docs)]
pub mod noise;
#[allow(missing_docs)]
pub mod packed;
#[allow(missing_docs)]
pub mod queue;

pub use analog::AnalogPool;
pub use ideal::BatchIdeal;
pub use noise::NoiseStats;
pub use queue::{
    default_workers, start, BackendFactory, BatchBackend, DeploymentId, EngineConfig,
    EngineHandle, EngineSnapshot, Pending, RouteKey,
};
