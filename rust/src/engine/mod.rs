//! The batched multi-die execution engine — the shared inference layer
//! between the dataflow/analog models below and the coordinator above.
//!
//! The paper's macro hits 0.15–8 POPS/W by amortizing conversion and
//! accumulation across a whole 1152×256 array per cycle; this layer does
//! the software equivalent for the reproduction's hot path, replacing the
//! image-by-image, dot-by-dot inference walk:
//!
//! * [`gemm`] — blocked batch kernels (one weight pass per four batch
//!   vectors, split across worker threads);
//! * [`ideal`] — [`BatchIdeal`]: whole-batch closed-form contract
//!   evaluation, bit-identical to the per-image executor;
//! * [`analog`] — [`AnalogPool`]: one cloned circuit-behavioral die per
//!   worker with deterministic per-die seeds;
//! * [`queue`] — the work-queue scheduler ([`start`], [`EngineHandle`]):
//!   concurrent callers submit single images, a dispatcher coalesces them
//!   into batches (configurable size + flush interval) and runs whichever
//!   [`BatchBackend`] is plugged in. This is what `imagine serve` uses
//!   instead of a global `Mutex<Executor>`.

pub mod analog;
pub mod gemm;
pub mod ideal;
pub mod queue;

pub use analog::AnalogPool;
pub use ideal::BatchIdeal;
pub use queue::{
    default_workers, start, BatchBackend, EngineConfig, EngineHandle, EngineSnapshot, Pending,
};
