//! Thread-local scratch arenas for the engine hot path.
//!
//! The macro streams activations through weight-stationary arrays
//! without ever re-allocating its line buffers; the software engine
//! mirrors that with per-thread, high-water-mark buffer pools. A
//! `take_*` call pops a previously returned buffer (empty, capacity
//! retained) or creates a fresh one; `put_*` clears it and pushes it
//! back. Capacities only grow, so after one warm-up batch every
//! steady-state `take_*`/`put_*` pair on a live thread is
//! allocation-free — the invariant `tests/alloc_steady_state.rs` pins
//! with a counting global allocator.
//!
//! # Discipline
//!
//! * Pools are **thread-local**: buffers taken on a thread must be put
//!   back on the same thread. Scoped worker threads get their own pools
//!   that live for the batch they serve; the long-lived dispatcher (or
//!   a `workers = 1` caller) keeps its pool across requests, which is
//!   where the zero-allocation steady state holds.
//! * `take_*` returns an **empty** vector with at least the requested
//!   capacity — callers `resize`/`extend` it themselves (both are
//!   alloc-free within capacity).
//! * Buffers are never shrunk or freed while the thread lives
//!   ("reset, never freed"): the pool converges to the largest shapes
//!   the thread has processed.

use std::cell::RefCell;

#[derive(Default)]
struct Pools {
    u8s: Vec<Vec<u8>>,
    u32s: Vec<Vec<u32>>,
    u64s: Vec<Vec<u64>>,
    i32s: Vec<Vec<i32>>,
    f32s: Vec<Vec<f32>>,
    f64s: Vec<Vec<f64>>,
}

thread_local! {
    static POOLS: RefCell<Pools> = RefCell::new(Pools::default());
}

macro_rules! arena_pool {
    ($take:ident, $put:ident, $field:ident, $t:ty) => {
        /// Take an empty scratch buffer with capacity ≥ `cap` from this
        /// thread's pool (allocating only if the pool has never held one
        /// this large).
        pub fn $take(cap: usize) -> Vec<$t> {
            let mut v = POOLS.with(|p| p.borrow_mut().$field.pop()).unwrap_or_default();
            v.clear();
            v.reserve(cap);
            v
        }

        /// Return a scratch buffer to this thread's pool (cleared,
        /// capacity retained).
        pub fn $put(v: Vec<$t>) {
            let mut v = v;
            v.clear();
            POOLS.with(|p| p.borrow_mut().$field.push(v));
        }
    };
}

arena_pool!(take_u8, put_u8, u8s, u8);
arena_pool!(take_u32, put_u32, u32s, u32);
arena_pool!(take_u64, put_u64, u64s, u64);
arena_pool!(take_i32, put_i32, i32s, i32);
arena_pool!(take_f32, put_f32, f32s, f32);
arena_pool!(take_f64, put_f64, f64s, f64);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_retains_capacity() {
        let mut v = take_i32(1000);
        let cap = v.capacity();
        assert!(cap >= 1000);
        v.extend(0..100);
        put_i32(v);
        // The same (empty) buffer comes back, no matter the requested cap.
        let v2 = take_i32(10);
        assert_eq!(v2.capacity(), cap);
        assert!(v2.is_empty());
        put_i32(v2);
    }

    #[test]
    fn pools_grow_to_concurrent_demand() {
        let a = take_u8(16);
        let b = take_u8(16);
        put_u8(a);
        put_u8(b);
        let a2 = take_u8(16);
        let b2 = take_u8(16);
        assert!(a2.capacity() >= 16 && b2.capacity() >= 16);
        put_u8(a2);
        put_u8(b2);
    }
}
