//! Batched ideal backend: whole-batch closed-form contract evaluation.
//!
//! The per-image [`Executor`](crate::coordinator::executor::Executor)
//! walks one dot product at a time with column-strided weight access. This
//! backend lowers a whole batch of inputs (and, for conv layers, every
//! im2col patch of every image) into one matrix of signed input factors
//! per layer and evaluates `codes = contract(X · W)` through the
//! precision/ISA-adaptive [`kernels`](crate::engine::kernels) dispatch —
//! SIMD tiles at high precision, the bit-plane popcount engine at
//! `r_in ≤ 2`, and a streaming direct conv that never materializes the
//! whole-batch im2col matrix — split across worker threads.
//!
//! Bit-exactness: the integer dot products are order-independent, and the
//! float mapping from dot product to ADC code goes through the *same*
//! [`IdealContract::code`] expression the per-image path uses, so outputs
//! are bit-identical to `Executor` with [`Backend::Ideal`] (asserted by
//! `tests/engine_equivalence.rs`).

use crate::config::params::MacroParams;
use crate::coordinator::executor::{apply_pool, post_adc, IdealContract};
use crate::coordinator::manifest::{Kind, Layer, NetworkModel, Pool};
use crate::dataflow::pipeline::LayerShape;
use crate::energy::system::{layer_cost, LayerCost};
use crate::engine::kernels;
use anyhow::{ensure, Result};

/// The batched ideal-contract inference backend.
pub struct BatchIdeal {
    pub model: NetworkModel,
    pub params: MacroParams,
    /// Worker threads for the batched matmuls.
    pub workers: usize,
    /// Pristine copy of the as-constructed model: precision re-targeting
    /// always starts from here, never from an already-reshaped model, so
    /// hopping between operating points stays bit-identical to a backend
    /// freshly built at each point (float rescaling is not associative).
    base: NetworkModel,
    contracts: Vec<IdealContract>,
    /// Per-layer dataflow/energy cost of one image at the *current*
    /// operating point (data-independent).
    per_layer_image: Vec<LayerCost>,
    /// Dataflow/energy cost of one image through the whole network at
    /// the current operating point.
    per_image_cost: LayerCost,
    /// Per-layer cost accumulated over everything executed (booked at
    /// dispatch time, so mixed-precision traffic accumulates each batch
    /// at the precision it actually ran at).
    accum_layers: Vec<LayerCost>,
    /// Accumulated cost over everything executed.
    pub cost: LayerCost,
    /// Images executed.
    pub images: u64,
}

impl BatchIdeal {
    /// The blocked kernel accumulates in i32 (twice the SIMD lanes of
    /// i64). The executor path accumulates in i64, so guard the
    /// worst-case |Σ (2X−M)·W| per layer up front: any layer a sane
    /// manifest produces (r_in ≤ 8, |W| ≤ 15, ≤ 1152 rows → ≤ 4.4M)
    /// fits with ~500× headroom; a corrupt one fails loudly instead of
    /// silently wrapping away the bit-exactness contract. `precision`
    /// checks a prospective (r_in, r_out) re-target point (a wider r_in
    /// raises the bound) *before* any state is touched, keeping
    /// re-targeting all-or-nothing.
    fn validate_at(model: &NetworkModel, precision: Option<(u32, u32)>) -> Result<()> {
        for layer in &model.layers {
            let r_in = precision.map(|(r_in, _)| r_in).unwrap_or(layer.cfg.r_in);
            ensure!(
                r_in <= 16,
                "layer {}: r_in {r_in} out of range for the batched engine",
                layer.name
            );
            let m = (1i128 << r_in) - 1;
            let w_max = layer.w_phys.iter().map(|w| (*w as i128).abs()).max().unwrap_or(0);
            let worst = layer.rows as i128 * m * w_max;
            ensure!(
                worst <= i32::MAX as i128,
                "layer {}: worst-case dot product {worst} exceeds the i32 \
                 accumulator range ({} rows, M={m}, |W|max={w_max})",
                layer.name,
                layer.rows
            );
        }
        Ok(())
    }

    pub fn new(model: NetworkModel, params: MacroParams, workers: usize) -> Result<Self> {
        Self::validate_at(&model, None)?;
        let contracts = model
            .layers
            .iter()
            .map(|l| IdealContract::new(&params, l))
            .collect();
        let per_layer_image = network_layer_costs(&model, &params);
        let per_image_cost = sum_costs(&per_layer_image);
        let accum_layers = vec![LayerCost::default(); model.layers.len()];
        Ok(Self {
            base: model.clone(),
            model,
            params,
            workers: workers.max(1),
            contracts,
            per_layer_image,
            per_image_cost,
            accum_layers,
            cost: LayerCost::default(),
            images: 0,
        })
    }

    /// Re-shape the served model to (r_in, r_out), or back to its
    /// as-constructed precision (`None`), re-deriving the per-layer
    /// contracts and cost bookings. Always reshapes from the pristine
    /// base operating point — restoring the base scalars and replaying
    /// [`NetworkModel::retarget_precision`] performs the exact float
    /// operations a fresh clone would see, so the results after any
    /// sequence of re-targets are bit-identical to a `BatchIdeal` built
    /// directly at the requested point, without cloning any weight
    /// tensor (re-targeting is O(layers), so interleaved multi-precision
    /// traffic does not thrash). All-or-nothing: a point that fails
    /// validation leaves the backend untouched.
    pub fn retarget(&mut self, precision: Option<(u32, u32)>) -> Result<()> {
        Self::validate_at(&self.base, precision)?;
        self.model.copy_precision_fields_from(&self.base);
        if let Some((r_in, r_out)) = precision {
            self.model.retarget_precision(r_in, r_out);
        }
        self.contracts = self
            .model
            .layers
            .iter()
            .map(|l| IdealContract::new(&self.params, l))
            .collect();
        self.per_layer_image = network_layer_costs(&self.model, &self.params);
        self.per_image_cost = sum_costs(&self.per_layer_image);
        Ok(())
    }

    pub fn input_len(&self) -> usize {
        self.model.input_shape.iter().product()
    }

    /// Per-layer modeled cost accumulated over everything executed —
    /// what the engine probe reports.
    pub fn layer_costs(&self) -> Vec<LayerCost> {
        self.accum_layers.clone()
    }

    /// Run a batch of images (each in the model's natural input layout)
    /// through the whole network; returns per-image logits.
    pub fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let input_len = self.input_len();
        for (i, im) in images.iter().enumerate() {
            ensure!(
                im.len() == input_len,
                "image {i}: expected {input_len} values, got {}",
                im.len()
            );
        }
        let mut acts: Vec<Vec<f32>> = images.to_vec();
        let mut shape = self.model.input_shape.clone();
        for li in 0..self.model.layers.len() {
            let layer = &self.model.layers[li];
            let contract = &self.contracts[li];
            let (next, next_shape) =
                forward_layer_batch(layer, contract, &acts, &shape, self.workers);
            acts = next;
            shape = next_shape;
        }
        let n = images.len() as u64;
        self.images += n;
        self.cost.accumulate(&self.per_image_cost.scaled(n));
        for (acc, per_image) in self.accum_layers.iter_mut().zip(&self.per_layer_image) {
            acc.accumulate(&per_image.scaled(n));
        }
        Ok(acts)
    }
}

/// Quantize one activation vector to the layer's unsigned input grid and
/// expand to signed antipodal factors `2X − M`, padded to the physical row
/// count with the mid-rail constant — exactly the executor's row prep.
fn signed_rows(layer: &Layer, contract: &IdealContract, act: &[f32], out: &mut Vec<i32>) {
    let m_f = ((1u32 << layer.cfg.r_in) - 1) as f32;
    let m = contract.m as i32;
    let pad = ((1u32 << layer.cfg.r_in) / 2) as i32;
    for &v in act.iter().take(layer.rows) {
        let q = (v / layer.a_scale).round().clamp(0.0, m_f) as u8;
        out.push(2 * q as i32 - m);
    }
    for _ in act.len()..layer.rows {
        out.push(2 * pad - m);
    }
}

fn forward_layer_batch(
    layer: &Layer,
    contract: &IdealContract,
    acts: &[Vec<f32>],
    shape: &[usize],
    workers: usize,
) -> (Vec<Vec<f32>>, Vec<usize>) {
    let n_img = acts.len();
    let n_out = layer.out_features;
    match layer.kind {
        Kind::Dense => {
            let mut sx = Vec::with_capacity(n_img * layer.rows);
            for act in acts {
                signed_rows(layer, contract, act, &mut sx);
            }
            let dots = kernels::matmul_i32(
                &sx,
                &layer.w_phys,
                n_img,
                layer.rows,
                n_out,
                workers,
                Some(layer.cfg.r_in),
            );
            let outs = dots
                .chunks(n_out)
                .map(|d| {
                    let codes: Vec<u32> = d
                        .iter()
                        .zip(&layer.beta)
                        .map(|(&dot, &beta)| contract.code(dot as i64, beta))
                        .collect();
                    post_adc(layer, &codes)
                })
                .collect();
            (outs, vec![n_out])
        }
        Kind::Conv3 => {
            let (c, h, w) = (shape[0], shape[1], shape[2]);
            debug_assert_eq!(c, layer.in_features);
            let m_f = ((1u32 << layer.cfg.r_in) - 1) as f32;

            // Quantize every image, then stream the batch through the
            // direct conv kernel — per-worker im2col scratch instead of
            // the whole-batch row matrix, dispatched per precision/ISA.
            let images_q: Vec<Vec<u8>> = acts
                .iter()
                .map(|act| {
                    act.iter()
                        .map(|&v| (v / layer.a_scale).round().clamp(0.0, m_f) as u8)
                        .collect()
                })
                .collect();
            let (dots, oh, ow) = kernels::conv3x3_direct(
                &images_q,
                c,
                h,
                w,
                layer.stride,
                layer.cfg.r_in,
                &layer.w_phys,
                layer.rows,
                n_out,
                workers,
            );
            let n_pix = oh * ow;

            let mut outs = Vec::with_capacity(n_img);
            let mut out_shape = vec![n_out, oh, ow];
            for img in 0..n_img {
                let mut fmap = vec![0f32; n_out * n_pix];
                for pix in 0..n_pix {
                    let d = &dots[(img * n_pix + pix) * n_out..(img * n_pix + pix + 1) * n_out];
                    let codes: Vec<u32> = d
                        .iter()
                        .zip(&layer.beta)
                        .map(|(&dot, &beta)| contract.code(dot as i64, beta))
                        .collect();
                    let vals = post_adc(layer, &codes);
                    let (py, px) = (pix / ow, pix % ow);
                    for (oc, &v) in vals.iter().enumerate() {
                        fmap[oc * n_pix + py * ow + px] = v;
                    }
                }
                let (pooled, ph, pw) = apply_pool(&fmap, n_out, oh, ow, layer.pool);
                out_shape = if layer.pool == Pool::Gap {
                    vec![n_out]
                } else {
                    vec![n_out, ph, pw]
                };
                outs.push(pooled);
            }
            (outs, out_shape)
        }
    }
}

/// Per-layer dataflow/energy cost of one image through the network —
/// the same bookings the per-image executor makes, computed once up
/// front (they depend only on the layer shapes, not the data). This is
/// what the engine probe and the server's `graph_info` command report
/// layer by layer.
pub fn network_layer_costs(model: &NetworkModel, p: &MacroParams) -> Vec<LayerCost> {
    let mut costs = Vec::with_capacity(model.layers.len());
    let mut shape = model.input_shape.clone();
    for layer in &model.layers {
        let col_passes = layer.out_features.div_ceil(p.n_blocks());
        match layer.kind {
            Kind::Dense => {
                let ls = LayerShape::fc(
                    layer.in_features,
                    layer.out_features,
                    layer.cfg.r_in,
                    layer.cfg.r_out,
                );
                costs.push(layer_cost(p, &ls, &layer.cfg, col_passes, true));
                shape = vec![layer.out_features];
            }
            Kind::Conv3 => {
                let (h, w) = (shape[1], shape[2]);
                let (oh, ow) = (h.div_ceil(layer.stride), w.div_ceil(layer.stride));
                let ls = LayerShape::conv(
                    layer.in_features,
                    layer.out_features,
                    layer.cfg.r_in,
                    layer.cfg.r_out,
                    oh,
                    ow,
                );
                costs.push(layer_cost(p, &ls, &layer.cfg, col_passes, true));
                shape = match layer.pool {
                    Pool::Gap => vec![layer.out_features],
                    // Mirrors apply_pool's floor-crop: ph = (oh/2*2)/2.
                    Pool::Max2 | Pool::Avg2 => vec![layer.out_features, oh / 2, ow / 2],
                    Pool::None => vec![layer.out_features, oh, ow],
                };
            }
        }
    }
    costs
}

fn sum_costs(costs: &[LayerCost]) -> LayerCost {
    let mut total = LayerCost::default();
    for c in costs {
        total.accumulate(c);
    }
    total
}

/// Dataflow/energy cost of one image through the whole network.
pub fn network_image_cost(model: &NetworkModel, p: &MacroParams) -> LayerCost {
    sum_costs(&network_layer_costs(model, p))
}
