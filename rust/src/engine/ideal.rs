//! Batched ideal backend: whole-batch closed-form contract evaluation.
//!
//! The per-image [`Executor`](crate::coordinator::executor::Executor)
//! walks one dot product at a time with column-strided weight access. This
//! backend lowers a whole batch of inputs (and, for conv layers, every
//! im2col patch of every image) into one matrix of signed input factors
//! per layer and evaluates `codes = contract(X · W)` through the
//! precision/ISA-adaptive [`kernels`](crate::engine::kernels) dispatch —
//! SIMD tiles at high precision, the bit-plane popcount engine at
//! `r_in ≤ 2`, and a streaming direct conv that never materializes the
//! whole-batch im2col matrix — split across worker threads.
//!
//! # Steady-state execution model
//!
//! [`BatchIdeal::forward_batch`] is **chunk-pipelined**: the batch is cut
//! into fixed [`PIPELINE_CHUNK`]-image chunks on a grid that depends only
//! on the batch size, and each worker thread carries its chunks through
//! *all* layers depth-first — while one worker runs layer `k+1` of chunk
//! `i`, another is still in layer `k` of chunk `j`. Deep graphs stop
//! paying full-batch layer barriers, per-chunk activations stay
//! cache-resident across layers, and one thread-spawn per batch replaces
//! one per layer. Per-image results are data-independent of each other
//! and integer dots are order-independent, so chunked execution is
//! bit-identical to the barriered reference
//! ([`BatchIdeal::forward_batch_barriered`]) for every worker count —
//! asserted by `tests/engine_equivalence.rs`.
//!
//! Weight-side packs ([`PackedWeights`]) are built once at construction
//! and rebuilt on [`BatchIdeal::retarget`] (the bit-plane pack is keyed
//! to `r_in`); all per-batch scratch comes from the thread-local
//! [`arena`](crate::engine::arena), so a warm `forward_batch_into` call
//! performs no allocations (`tests/alloc_steady_state.rs`).
//!
//! Bit-exactness: the integer dot products are order-independent, and the
//! float mapping from dot product to ADC code goes through the *same*
//! [`IdealContract::code`] expression the per-image path uses, so outputs
//! are bit-identical to `Executor` with [`Backend::Ideal`] (asserted by
//! `tests/engine_equivalence.rs`).

use crate::config::params::MacroParams;
use crate::coordinator::executor::{
    apply_pool, apply_pool_into, post_adc, post_adc_code, IdealContract,
};
use crate::coordinator::manifest::{Kind, Layer, NetworkModel, Pool};
use crate::dataflow::pipeline::LayerShape;
use crate::energy::system::{layer_cost, LayerCost};
use crate::engine::packed::PackedWeights;
use crate::engine::{arena, kernels};
use anyhow::{ensure, Result};

/// Images per pipeline chunk. Four matches the register blocking of the
/// portable/SIMD gemm tiles (4 batch vectors per weight pass) and the
/// bit-plane tier's minimum vector count, so a full chunk always
/// dispatches to the same kernel the whole batch would have.
pub const PIPELINE_CHUNK: usize = 4;

/// The batched ideal-contract inference backend.
pub struct BatchIdeal {
    pub model: NetworkModel,
    pub params: MacroParams,
    /// Worker threads for the batched matmuls.
    pub workers: usize,
    /// Pristine copy of the as-constructed model: precision re-targeting
    /// always starts from here, never from an already-reshaped model, so
    /// hopping between operating points stays bit-identical to a backend
    /// freshly built at each point (float rescaling is not associative).
    base: NetworkModel,
    contracts: Vec<IdealContract>,
    /// Per-layer deploy-time weight packs at the *current* operating
    /// point (the bit-plane pack is keyed to `r_in`), shared read-only
    /// across workers and batches.
    packed: Vec<PackedWeights>,
    /// Per-layer (input, output) activation shapes — data-independent,
    /// computed once so chunk workers never re-derive them.
    io_shapes: Vec<(Vec<usize>, Vec<usize>)>,
    /// Largest flat activation length any layer boundary sees (sizes the
    /// chunk double-buffers).
    max_act_len: usize,
    /// Per-layer dataflow/energy cost of one image at the *current*
    /// operating point (data-independent).
    per_layer_image: Vec<LayerCost>,
    /// Dataflow/energy cost of one image through the whole network at
    /// the current operating point.
    per_image_cost: LayerCost,
    /// Per-layer cost accumulated over everything executed (booked at
    /// dispatch time, so mixed-precision traffic accumulates each batch
    /// at the precision it actually ran at).
    accum_layers: Vec<LayerCost>,
    /// Accumulated cost over everything executed.
    pub cost: LayerCost,
    /// Images executed.
    pub images: u64,
}

impl BatchIdeal {
    /// The blocked kernel accumulates in i32 (twice the SIMD lanes of
    /// i64). The executor path accumulates in i64, so guard the
    /// worst-case |Σ (2X−M)·W| per layer up front: any layer a sane
    /// manifest produces (r_in ≤ 8, |W| ≤ 15, ≤ 1152 rows → ≤ 4.4M)
    /// fits with ~500× headroom; a corrupt one fails loudly instead of
    /// silently wrapping away the bit-exactness contract. `precision`
    /// checks a prospective (r_in, r_out) re-target point (a wider r_in
    /// raises the bound) *before* any state is touched, keeping
    /// re-targeting all-or-nothing.
    fn validate_at(model: &NetworkModel, precision: Option<(u32, u32)>) -> Result<()> {
        for layer in &model.layers {
            let r_in = precision.map(|(r_in, _)| r_in).unwrap_or(layer.cfg.r_in);
            ensure!(
                r_in <= 16,
                "layer {}: r_in {r_in} out of range for the batched engine",
                layer.name
            );
            let m = (1i128 << r_in) - 1;
            let w_max = layer.w_phys.iter().map(|w| (*w as i128).abs()).max().unwrap_or(0);
            let worst = layer.rows as i128 * m * w_max;
            ensure!(
                worst <= i32::MAX as i128,
                "layer {}: worst-case dot product {worst} exceeds the i32 \
                 accumulator range ({} rows, M={m}, |W|max={w_max})",
                layer.name,
                layer.rows
            );
        }
        Ok(())
    }

    pub fn new(model: NetworkModel, params: MacroParams, workers: usize) -> Result<Self> {
        Self::validate_at(&model, None)?;
        let contracts = model
            .layers
            .iter()
            .map(|l| IdealContract::new(&params, l))
            .collect();
        let packed = pack_layers(&model);
        let io_shapes = layer_io_shapes(&model);
        let max_act_len = max_boundary_len(&model, &io_shapes);
        let per_layer_image = network_layer_costs(&model, &params);
        let per_image_cost = sum_costs(&per_layer_image);
        let accum_layers = vec![LayerCost::default(); model.layers.len()];
        Ok(Self {
            base: model.clone(),
            model,
            params,
            workers: workers.max(1),
            contracts,
            packed,
            io_shapes,
            max_act_len,
            per_layer_image,
            per_image_cost,
            accum_layers,
            cost: LayerCost::default(),
            images: 0,
        })
    }

    /// Re-shape the served model to (r_in, r_out), or back to its
    /// as-constructed precision (`None`), re-deriving the per-layer
    /// contracts, weight packs and cost bookings. Always reshapes from
    /// the pristine base operating point — restoring the base scalars
    /// and replaying [`NetworkModel::retarget_precision`] performs the
    /// exact float operations a fresh clone would see, so the results
    /// after any sequence of re-targets are bit-identical to a
    /// `BatchIdeal` built directly at the requested point, without
    /// cloning any weight tensor (re-targeting is O(layers), so
    /// interleaved multi-precision traffic does not thrash). The
    /// bit-plane weight pack is keyed to `r_in`, so a precision hop
    /// invalidates and rebuilds it here — never mid-batch.
    /// All-or-nothing: a point that fails validation leaves the backend
    /// untouched.
    pub fn retarget(&mut self, precision: Option<(u32, u32)>) -> Result<()> {
        Self::validate_at(&self.base, precision)?;
        self.model.copy_precision_fields_from(&self.base);
        if let Some((r_in, r_out)) = precision {
            self.model.retarget_precision(r_in, r_out);
        }
        self.contracts = self
            .model
            .layers
            .iter()
            .map(|l| IdealContract::new(&self.params, l))
            .collect();
        self.packed = pack_layers(&self.model);
        self.per_layer_image = network_layer_costs(&self.model, &self.params);
        self.per_image_cost = sum_costs(&self.per_layer_image);
        Ok(())
    }

    pub fn input_len(&self) -> usize {
        self.model.input_shape.iter().product()
    }

    /// Per-layer modeled cost accumulated over everything executed —
    /// what the engine probe reports.
    pub fn layer_costs(&self) -> Vec<LayerCost> {
        self.accum_layers.clone()
    }

    /// Run a batch of images (each in the model's natural input layout)
    /// through the whole network; returns per-image logits.
    pub fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        self.forward_batch_into(images, &mut out)?;
        Ok(out)
    }

    /// [`Self::forward_batch`] writing into a caller-owned output buffer
    /// (outer and inner capacities reused) — with a warm buffer and warm
    /// thread-local arenas this is the zero-allocation steady-state
    /// entry point.
    pub fn forward_batch_into(
        &mut self,
        images: &[Vec<f32>],
        out: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        let input_len = self.input_len();
        for (i, im) in images.iter().enumerate() {
            ensure!(
                im.len() == input_len,
                "image {i}: expected {input_len} values, got {}",
                im.len()
            );
        }
        let n = images.len();
        // lint:allow(hot-path-alloc) empty Vec::new allocates nothing; warm slots reuse capacity
        out.resize_with(n, Vec::new);
        if n == 0 {
            return Ok(());
        }
        let n_chunks = n.div_ceil(PIPELINE_CHUNK);
        let workers = self.workers.clamp(1, n_chunks);
        let this: &Self = self;
        if workers == 1 {
            for (imgs, outs) in images.chunks(PIPELINE_CHUNK).zip(out.chunks_mut(PIPELINE_CHUNK)) {
                this.run_chunk(imgs, outs);
            }
        } else {
            // Contiguous spans of whole chunks per worker: the chunk
            // grid (and therefore every per-chunk kernel selection) is a
            // function of `n` alone, so results are worker-invariant.
            let span = n_chunks.div_ceil(workers) * PIPELINE_CHUNK;
            std::thread::scope(|s| {
                for (img_span, out_span) in images.chunks(span).zip(out.chunks_mut(span)) {
                    s.spawn(move || {
                        for (imgs, outs) in img_span
                            .chunks(PIPELINE_CHUNK)
                            .zip(out_span.chunks_mut(PIPELINE_CHUNK))
                        {
                            this.run_chunk(imgs, outs);
                        }
                    });
                }
            });
        }
        self.book_cost(n as u64);
        Ok(())
    }

    /// Reference execution through full-batch layer barriers (the
    /// pre-pipeline path): every layer runs over the whole batch before
    /// the next starts, through the unpacked kernel entry points. Kept
    /// as the bit-identity oracle the chunk pipeline is tested against;
    /// books cost identically to [`Self::forward_batch`].
    pub fn forward_batch_barriered(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let input_len = self.input_len();
        for (i, im) in images.iter().enumerate() {
            ensure!(
                im.len() == input_len,
                "image {i}: expected {input_len} values, got {}",
                im.len()
            );
        }
        let mut acts: Vec<Vec<f32>> = images.to_vec();
        let mut shape = self.model.input_shape.clone();
        for li in 0..self.model.layers.len() {
            let layer = &self.model.layers[li];
            let contract = &self.contracts[li];
            let (next, next_shape) =
                forward_layer_batch(layer, contract, &acts, &shape, self.workers);
            acts = next;
            shape = next_shape;
        }
        self.book_cost(images.len() as u64);
        Ok(acts)
    }

    fn book_cost(&mut self, n: u64) {
        self.images += n;
        self.cost.accumulate(&self.per_image_cost.scaled(n));
        for (acc, per_image) in self.accum_layers.iter_mut().zip(&self.per_layer_image) {
            acc.accumulate(&per_image.scaled(n));
        }
    }

    /// Carry one chunk of images through every layer depth-first, using
    /// double-buffered flat activations from the thread-local arena.
    fn run_chunk(&self, imgs: &[Vec<f32>], outs: &mut [Vec<f32>]) {
        let n = imgs.len();
        let mut cur = arena::take_f32(n * self.max_act_len);
        let mut next = arena::take_f32(n * self.max_act_len);
        for im in imgs {
            cur.extend_from_slice(im);
        }
        let mut cur_len = self.input_len();
        for (li, layer) in self.model.layers.iter().enumerate() {
            let (in_shape, out_shape) = &self.io_shapes[li];
            let out_len = out_shape.iter().product();
            forward_layer_chunk(
                layer,
                &self.contracts[li],
                &self.packed[li],
                in_shape,
                &cur,
                n,
                cur_len,
                &mut next,
            );
            std::mem::swap(&mut cur, &mut next);
            cur_len = out_len;
        }
        for (slot, row) in outs.iter_mut().zip(cur.chunks_exact(cur_len)) {
            slot.clear();
            slot.extend_from_slice(row);
        }
        arena::put_f32(cur);
        arena::put_f32(next);
    }
}

/// Quantize one activation vector to the layer's unsigned input grid and
/// expand to signed antipodal factors `2X − M`, padded to the physical row
/// count with the mid-rail constant — exactly the executor's row prep.
fn signed_rows(layer: &Layer, contract: &IdealContract, act: &[f32], out: &mut Vec<i32>) {
    let m_f = ((1u32 << layer.cfg.r_in) - 1) as f32;
    let m = contract.m as i32;
    let pad = ((1u32 << layer.cfg.r_in) / 2) as i32;
    for &v in act.iter().take(layer.rows) {
        let q = (v / layer.a_scale).round().clamp(0.0, m_f) as u8;
        out.push(2 * q as i32 - m);
    }
    for _ in act.len()..layer.rows {
        out.push(2 * pad - m);
    }
}

/// One layer over one flat `[n_img × in_len]` chunk of activations,
/// appending exactly `n_img · out_len` values to `next`. All scratch is
/// arena-backed; the weight side comes from the deploy-time pack. The
/// arithmetic — quantization, signed expansion, integer dots, contract
/// code, post-ADC, pooling — is operation-for-operation the barriered
/// path's, so outputs are bit-identical.
#[allow(clippy::too_many_arguments)]
fn forward_layer_chunk(
    layer: &Layer,
    contract: &IdealContract,
    packed: &PackedWeights,
    in_shape: &[usize],
    acts: &[f32],
    n_img: usize,
    in_len: usize,
    next: &mut Vec<f32>,
) {
    let n_out = layer.out_features;
    let half = (1u32 << (layer.cfg.r_out - 1)) as f32;
    next.clear();
    match layer.kind {
        Kind::Dense => {
            let mut sx = arena::take_i32(n_img * layer.rows);
            for act in acts[..n_img * in_len].chunks_exact(in_len) {
                signed_rows(layer, contract, act, &mut sx);
            }
            let mut dots = arena::take_i32(n_img * n_out);
            kernels::matmul_i32_packed_into(
                &sx,
                &layer.w_phys,
                n_img,
                layer.rows,
                n_out,
                1,
                Some(layer.cfg.r_in),
                packed.bitplanes(),
                &mut dots,
            );
            for d in dots.chunks_exact(n_out.max(1)) {
                for (&dot, &beta) in d.iter().zip(&layer.beta) {
                    let code = contract.code(dot as i64, beta);
                    next.push(post_adc_code(layer, half, code));
                }
            }
            arena::put_i32(dots);
            arena::put_i32(sx);
        }
        Kind::Conv3 => {
            let (c, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
            debug_assert_eq!(c, layer.in_features);
            let m_f = ((1u32 << layer.cfg.r_in) - 1) as f32;
            let mut images_q = arena::take_u8(n_img * in_len);
            for &v in &acts[..n_img * in_len] {
                images_q.push((v / layer.a_scale).round().clamp(0.0, m_f) as u8);
            }
            let mut dots = arena::take_i32(0);
            let (oh, ow) = kernels::conv3x3_direct_packed_into(
                &images_q,
                n_img,
                c,
                h,
                w,
                layer.stride,
                layer.cfg.r_in,
                &layer.w_phys,
                layer.rows,
                n_out,
                1,
                packed.bitplanes(),
                &mut dots,
            );
            let n_pix = oh * ow;
            let mut fmap = arena::take_f32(n_out * n_pix);
            for img in 0..n_img {
                fmap.clear();
                fmap.resize(n_out * n_pix, 0.0);
                for pix in 0..n_pix {
                    let d = &dots[(img * n_pix + pix) * n_out..(img * n_pix + pix + 1) * n_out];
                    let (py, px) = (pix / ow, pix % ow);
                    for (oc, (&dot, &beta)) in d.iter().zip(&layer.beta).enumerate() {
                        let code = contract.code(dot as i64, beta);
                        fmap[oc * n_pix + py * ow + px] = post_adc_code(layer, half, code);
                    }
                }
                apply_pool_into(&fmap, n_out, oh, ow, layer.pool, next);
            }
            arena::put_f32(fmap);
            arena::put_i32(dots);
            arena::put_u8(images_q);
        }
    }
}

fn forward_layer_batch(
    layer: &Layer,
    contract: &IdealContract,
    acts: &[Vec<f32>],
    shape: &[usize],
    workers: usize,
) -> (Vec<Vec<f32>>, Vec<usize>) {
    let n_img = acts.len();
    let n_out = layer.out_features;
    match layer.kind {
        Kind::Dense => {
            let mut sx = Vec::with_capacity(n_img * layer.rows);
            for act in acts {
                signed_rows(layer, contract, act, &mut sx);
            }
            let dots = kernels::matmul_i32(
                &sx,
                &layer.w_phys,
                n_img,
                layer.rows,
                n_out,
                workers,
                Some(layer.cfg.r_in),
            );
            let outs = dots
                .chunks(n_out)
                .map(|d| {
                    let codes: Vec<u32> = d
                        .iter()
                        .zip(&layer.beta)
                        .map(|(&dot, &beta)| contract.code(dot as i64, beta))
                        .collect();
                    post_adc(layer, &codes)
                })
                .collect();
            (outs, vec![n_out])
        }
        Kind::Conv3 => {
            let (c, h, w) = (shape[0], shape[1], shape[2]);
            debug_assert_eq!(c, layer.in_features);
            let m_f = ((1u32 << layer.cfg.r_in) - 1) as f32;

            // Quantize every image, then stream the batch through the
            // direct conv kernel — per-worker im2col scratch instead of
            // the whole-batch row matrix, dispatched per precision/ISA.
            let images_q: Vec<Vec<u8>> = acts
                .iter()
                .map(|act| {
                    act.iter()
                        .map(|&v| (v / layer.a_scale).round().clamp(0.0, m_f) as u8)
                        .collect()
                })
                .collect();
            let (dots, oh, ow) = kernels::conv3x3_direct(
                &images_q,
                c,
                h,
                w,
                layer.stride,
                layer.cfg.r_in,
                &layer.w_phys,
                layer.rows,
                n_out,
                workers,
            );
            let n_pix = oh * ow;

            let mut outs = Vec::with_capacity(n_img);
            let mut out_shape = vec![n_out, oh, ow];
            for img in 0..n_img {
                let mut fmap = vec![0f32; n_out * n_pix];
                for pix in 0..n_pix {
                    let d = &dots[(img * n_pix + pix) * n_out..(img * n_pix + pix + 1) * n_out];
                    let codes: Vec<u32> = d
                        .iter()
                        .zip(&layer.beta)
                        .map(|(&dot, &beta)| contract.code(dot as i64, beta))
                        .collect();
                    let vals = post_adc(layer, &codes);
                    let (py, px) = (pix / ow, pix % ow);
                    for (oc, &v) in vals.iter().enumerate() {
                        fmap[oc * n_pix + py * ow + px] = v;
                    }
                }
                let (pooled, ph, pw) = apply_pool(&fmap, n_out, oh, ow, layer.pool);
                out_shape = if layer.pool == Pool::Gap {
                    vec![n_out]
                } else {
                    vec![n_out, ph, pw]
                };
                outs.push(pooled);
            }
            (outs, out_shape)
        }
    }
}

/// Deploy-time weight packs for every layer at its current `r_in`.
fn pack_layers(model: &NetworkModel) -> Vec<PackedWeights> {
    model
        .layers
        .iter()
        .map(|l| PackedWeights::build(&l.w_phys, l.rows, l.out_features, l.cfg.r_in))
        .collect()
}

/// Data-independent (input, output) activation shape of every layer —
/// the same walk the cost model does, shared by the chunk pipeline so
/// workers never re-derive shapes per batch.
fn layer_io_shapes(model: &NetworkModel) -> Vec<(Vec<usize>, Vec<usize>)> {
    let mut io = Vec::with_capacity(model.layers.len());
    let mut shape = model.input_shape.clone();
    for layer in &model.layers {
        let next = match layer.kind {
            Kind::Dense => vec![layer.out_features],
            Kind::Conv3 => {
                let (h, w) = (shape[1], shape[2]);
                let (oh, ow) = (h.div_ceil(layer.stride), w.div_ceil(layer.stride));
                match layer.pool {
                    Pool::Gap => vec![layer.out_features],
                    // Mirrors apply_pool's floor-crop: ph = (oh/2*2)/2.
                    Pool::Max2 | Pool::Avg2 => vec![layer.out_features, oh / 2, ow / 2],
                    Pool::None => vec![layer.out_features, oh, ow],
                }
            }
        };
        io.push((shape.clone(), next.clone()));
        shape = next;
    }
    io
}

/// Largest flat activation length crossing any layer boundary.
fn max_boundary_len(model: &NetworkModel, io: &[(Vec<usize>, Vec<usize>)]) -> usize {
    let mut max: usize = model.input_shape.iter().product();
    for (_, out_shape) in io {
        max = max.max(out_shape.iter().product());
    }
    max
}

/// Per-layer dataflow/energy cost of one image through the network —
/// the same bookings the per-image executor makes, computed once up
/// front (they depend only on the layer shapes, not the data). This is
/// what the engine probe and the server's `graph_info` command report
/// layer by layer.
pub fn network_layer_costs(model: &NetworkModel, p: &MacroParams) -> Vec<LayerCost> {
    let points: Vec<(u32, u32)> = model
        .layers
        .iter()
        .map(|l| (l.cfg.r_in, l.cfg.r_out))
        .collect();
    network_layer_costs_at(model, p, &points)
}

/// [`network_layer_costs`] with per-layer `(r_in, r_out)` operating
/// points overriding each layer's own `cfg` — the autotuner's per-
/// candidate energy accounting: one compiled model, re-costed at any
/// per-layer precision assignment without re-lowering. A layer's cost
/// depends only on its own shape and operating point, so a sweep builds
/// an exact per-layer × per-point memo from calls to this.
///
/// # Panics
///
/// Panics if `points.len() != model.layers.len()` (an internal-misuse
/// guard, matching the slice-length contracts of the engine layer).
pub fn network_layer_costs_at(
    model: &NetworkModel,
    p: &MacroParams,
    points: &[(u32, u32)],
) -> Vec<LayerCost> {
    assert_eq!(points.len(), model.layers.len(), "one (r_in, r_out) point per layer");
    let mut costs = Vec::with_capacity(model.layers.len());
    let mut shape = model.input_shape.clone();
    for (layer, &(r_in, r_out)) in model.layers.iter().zip(points) {
        let mut cfg = layer.cfg;
        cfg.r_in = r_in;
        cfg.r_out = r_out;
        let col_passes = layer.out_features.div_ceil(p.n_blocks());
        match layer.kind {
            Kind::Dense => {
                let ls = LayerShape::fc(layer.in_features, layer.out_features, r_in, r_out);
                costs.push(layer_cost(p, &ls, &cfg, col_passes, true));
                shape = vec![layer.out_features];
            }
            Kind::Conv3 => {
                let (h, w) = (shape[1], shape[2]);
                let (oh, ow) = (h.div_ceil(layer.stride), w.div_ceil(layer.stride));
                let ls =
                    LayerShape::conv(layer.in_features, layer.out_features, r_in, r_out, oh, ow);
                costs.push(layer_cost(p, &ls, &cfg, col_passes, true));
                shape = match layer.pool {
                    Pool::Gap => vec![layer.out_features],
                    // Mirrors apply_pool's floor-crop: ph = (oh/2*2)/2.
                    Pool::Max2 | Pool::Avg2 => vec![layer.out_features, oh / 2, ow / 2],
                    Pool::None => vec![layer.out_features, oh, ow],
                };
            }
        }
    }
    costs
}

fn sum_costs(costs: &[LayerCost]) -> LayerCost {
    let mut total = LayerCost::default();
    for c in costs {
        total.accumulate(c);
    }
    total
}

/// Dataflow/energy cost of one image through the whole network.
pub fn network_image_cost(model: &NetworkModel, p: &MacroParams) -> LayerCost {
    sum_costs(&network_layer_costs(model, p))
}
