//! Multi-tenant work-queue scheduler: coalesce concurrent requests into
//! batches, routed per (deployment, precision) key.
//!
//! The serving problem the old `Mutex<Executor>` design had: N concurrent
//! clients fully serialize, each paying the whole per-image cost, while
//! the batched backends get *cheaper* per image as the batch grows. The
//! scheduler inverts that: connection handlers submit single images into
//! a queue and block on a per-request reply channel; one dispatcher
//! thread drains the queue into batches of up to `batch` images (waiting
//! at most `flush_micros` after the first arrival) and runs the whole
//! batch through the backend at once.
//!
//! Since the ModelHub redesign, one dispatcher serves *many* backends: a
//! [`RouteKey`] names the deployment and the requested (r_in, r_out)
//! operating point, jobs only coalesce with jobs of the same key, and the
//! dispatcher [`BatchBackend::retarget`]s a deployment's backend when the
//! key's precision differs from the point it is currently shaped at.
//! Backends are installed and removed at runtime with
//! [`EngineHandle::deploy`] / [`EngineHandle::undeploy`] without stopping
//! the dispatcher, and [`EngineHandle::drain`] is the graceful-shutdown
//! barrier: it resolves once everything enqueued before it has executed.
//!
//! Backends are constructed *on* the dispatcher thread from a `Send`
//! factory closure, so non-`Send` backends (the PJRT client is a
//! single-threaded C handle) work unchanged — they simply live and die on
//! the dispatcher.

use crate::energy::system::LayerCost;
use crate::util::stats::AtomicHistogram;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Identifies one deployed backend inside the dispatcher. The hub above
/// maps names to ids; ids are never reused, so a stale handle to an
/// undeployed (or replaced) model fails cleanly instead of hitting the
/// wrong tenant.
pub type DeploymentId = u64;

/// Where a request is routed: which deployment, at which (r_in, r_out)
/// operating point. `None` precision means the model's as-deployed
/// manifest precision. Jobs coalesce into one batch only when their
/// whole key matches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RouteKey {
    pub dep: DeploymentId,
    pub precision: Option<(u32, u32)>,
}

impl RouteKey {
    pub fn new(dep: DeploymentId, precision: Option<(u32, u32)>) -> RouteKey {
        RouteKey { dep, precision }
    }
}

/// Constructor closure for a deployment's backend; runs on the
/// dispatcher thread (so the backend itself need not be `Send`).
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn BatchBackend>> + Send>;

/// A pluggable batch-inference backend (ideal, analog pool, PJRT, …).
pub trait BatchBackend {
    /// Expected flattened input length per image.
    fn input_len(&self) -> usize;

    /// Run a batch; returns one output vector per input image, in order.
    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Human-readable backend description (for logs).
    fn describe(&self) -> String {
        "batch backend".to_string()
    }

    /// Images executed so far (for engine snapshots). Backends that do
    /// not track it report 0.
    fn images(&self) -> u64 {
        0
    }

    /// Modeled accelerator cost accumulated so far, if this backend
    /// models the accelerator (the PJRT path does not).
    fn model_cost(&self) -> Option<LayerCost> {
        None
    }

    /// Per-layer breakdown of [`BatchBackend::model_cost`], in network
    /// layer order, if this backend models the accelerator layer by
    /// layer (what `{"cmd":"graph_info"}` serves).
    fn model_layer_costs(&self) -> Option<Vec<LayerCost>> {
        None
    }

    /// Re-shape the served model to the (r_in, r_out) operating point
    /// (`None` = back to the as-deployed manifest precision) without
    /// rebuilding the backend — die state, seeds and calibration are
    /// preserved. Implementations must re-shape from a pristine copy of
    /// the deployed model so hopping between precisions never
    /// accumulates float error (the per-request-precision contract:
    /// results stay bit-identical to a backend freshly built at that
    /// point). The default declines any explicit precision, which is
    /// correct for backends with baked-in arithmetic (PJRT artifacts).
    fn retarget(&mut self, precision: Option<(u32, u32)>) -> Result<()> {
        match precision {
            None => Ok(()),
            Some((r_in, r_out)) => Err(anyhow!(
                "this backend cannot re-target precision (requested r_in={r_in} r_out={r_out})"
            )),
        }
    }
}

// Trait impls delegate to the inherent methods (inherent methods win name
// resolution, so these do not recurse).
impl BatchBackend for crate::engine::ideal::BatchIdeal {
    fn input_len(&self) -> usize {
        self.input_len()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.forward_batch(images)
    }

    fn describe(&self) -> String {
        format!(
            "batched ideal contract ({}, {} workers)",
            self.model.name, self.workers
        )
    }

    fn images(&self) -> u64 {
        self.images
    }

    fn model_cost(&self) -> Option<LayerCost> {
        Some(self.cost)
    }

    fn model_layer_costs(&self) -> Option<Vec<LayerCost>> {
        Some(self.layer_costs())
    }

    fn retarget(&mut self, precision: Option<(u32, u32)>) -> Result<()> {
        self.retarget(precision)
    }
}

impl BatchBackend for crate::engine::analog::AnalogPool {
    fn input_len(&self) -> usize {
        self.input_len()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.forward_batch(images)
    }

    fn describe(&self) -> String {
        format!("analog die pool ({} dies)", self.n_dies())
    }

    fn images(&self) -> u64 {
        self.images
    }

    fn model_cost(&self) -> Option<LayerCost> {
        Some(self.cost())
    }

    fn model_layer_costs(&self) -> Option<Vec<LayerCost>> {
        Some(self.layer_costs())
    }

    fn retarget(&mut self, precision: Option<(u32, u32)>) -> Result<()> {
        self.retarget(precision);
        Ok(())
    }
}

/// Batching/parallelism knobs shared by the CLI and the server.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Maximum images per coalesced batch.
    pub batch: usize,
    /// Worker threads (matmul rows / analog dies).
    pub workers: usize,
    /// How long the dispatcher waits for more requests after the first
    /// one arrives before flushing a partial batch [µs].
    pub flush_micros: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            batch: 32,
            workers: default_workers(),
            flush_micros: 500,
        }
    }
}

/// Available hardware parallelism (≥ 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

struct Job {
    image: Vec<f32>,
    resp: mpsc::Sender<std::result::Result<Vec<f32>, String>>,
}

/// Read-only per-deployment state reported by the dispatcher on request.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    /// Images executed by this deployment's backend so far.
    pub images: u64,
    /// Batches dispatched to this deployment so far.
    pub batches: u64,
    /// Modeled accelerator cost, if the backend models one.
    pub cost: Option<LayerCost>,
    /// Per-layer breakdown of `cost` in network layer order, if the
    /// backend models the accelerator layer by layer.
    pub layer_costs: Option<Vec<LayerCost>>,
}

enum Msg {
    /// A single image to coalesce with concurrent same-key submissions.
    One { key: RouteKey, job: Job },
    /// A caller-assembled batch, executed exactly as submitted (never
    /// merged with other traffic — keeps multi-die splits deterministic).
    Batch {
        key: RouteKey,
        images: Vec<Vec<f32>>,
        resp: mpsc::Sender<std::result::Result<Vec<Vec<f32>>, String>>,
    },
    /// Per-deployment snapshot request (`None` reply = not deployed),
    /// answered between dispatches.
    Probe {
        dep: DeploymentId,
        resp: mpsc::Sender<Option<EngineSnapshot>>,
    },
    /// Install a backend under `dep`; the factory runs on the dispatcher
    /// thread and the reply carries (input_len, describe) on success.
    /// A default `precision` is probed (retargeted) immediately, so a
    /// backend that cannot serve it fails the deploy instead of failing
    /// every subsequent request.
    Deploy {
        dep: DeploymentId,
        precision: Option<(u32, u32)>,
        factory: BackendFactory,
        resp: mpsc::Sender<std::result::Result<(usize, String), String>>,
    },
    /// Remove a backend; reply says whether it existed.
    Undeploy {
        dep: DeploymentId,
        resp: mpsc::Sender<bool>,
    },
    /// Graceful-shutdown barrier: acked once everything enqueued before
    /// it has been executed.
    Drain { resp: mpsc::Sender<()> },
}

impl Msg {
    /// Messages that stop the coalescing scan: they must execute in
    /// queue order relative to the batches around them (a job enqueued
    /// after an `Undeploy` must not be served by the removed backend;
    /// `Drain` must not overtake work).
    fn is_barrier(&self) -> bool {
        matches!(
            self,
            Msg::Batch { .. } | Msg::Deploy { .. } | Msg::Undeploy { .. } | Msg::Drain { .. }
        )
    }
}

/// An in-flight single-image inference returned by
/// [`EngineHandle::submit`]; resolve it with [`Pending::wait`].
pub struct Pending {
    rx: mpsc::Receiver<std::result::Result<Vec<f32>, String>>,
}

impl Pending {
    /// Block until the logits arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("inference engine dropped the request")),
        }
    }

    /// Non-blocking poll: `None` while the batch is still in flight.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(Ok(v)) => Some(Ok(v)),
            Ok(Err(e)) => Some(Err(anyhow!("{e}"))),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("inference engine dropped the request")))
            }
        }
    }
}

/// Cloneable handle for submitting inference requests and managing
/// deployments on the shared dispatcher.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    batches: Arc<AtomicU64>,
}

impl EngineHandle {
    /// Batches dispatched so far, across all deployments.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    fn send(&self, msg: Msg) -> Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| anyhow!("inference engine has shut down"))
    }

    /// Install a backend under `dep` (replacing nothing — ids are unique
    /// by construction). Blocks until the factory ran on the dispatcher;
    /// returns the backend's (input_len, description). If `precision`
    /// is set it is retargeted immediately — the deploy fails up front
    /// when the backend cannot serve its own default operating point.
    pub fn deploy(
        &self,
        dep: DeploymentId,
        precision: Option<(u32, u32)>,
        factory: BackendFactory,
    ) -> Result<(usize, String)> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Deploy { dep, precision, factory, resp: rtx })?;
        match rrx.recv() {
            Ok(Ok(info)) => Ok(info),
            Ok(Err(e)) => Err(anyhow!("engine backend failed to start: {e}")),
            Err(_) => Err(anyhow!("inference engine dropped the deploy request")),
        }
    }

    /// Remove a deployment's backend; returns whether it existed.
    /// Requests already coalescing ahead of this message still complete.
    pub fn undeploy(&self, dep: DeploymentId) -> Result<bool> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Undeploy { dep, resp: rtx })?;
        rrx.recv()
            .map_err(|_| anyhow!("inference engine dropped the undeploy request"))
    }

    /// Enqueue one image without blocking; the dispatcher coalesces
    /// concurrent same-key submissions into batches.
    pub fn submit(&self, key: RouteKey, image: Vec<f32>) -> Result<Pending> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::One { key, job: Job { image, resp: rtx } })?;
        Ok(Pending { rx: rrx })
    }

    /// Blocking single-image inference (the dispatcher coalesces
    /// concurrent same-key callers into batches).
    pub fn infer(&self, key: RouteKey, image: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(key, image)?.wait()
    }

    /// Run a caller-assembled batch as one backend dispatch. Unlike a
    /// series of [`EngineHandle::submit`] calls, the batch is executed
    /// exactly as submitted (no timing-dependent coalescing), so
    /// seed-sensitive backends split it across dies deterministically.
    pub fn infer_batch(&self, key: RouteKey, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Batch { key, images, resp: rtx })?;
        match rrx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("inference engine dropped the request")),
        }
    }

    /// Ask the dispatcher for a deployment's image/batch counters and
    /// its backend's modeled accelerator cost. `Ok(None)` means the
    /// deployment does not exist (never did, or was undeployed). Blocks
    /// while a batch is executing (answered between dispatches).
    pub fn snapshot(&self, dep: DeploymentId) -> Result<Option<EngineSnapshot>> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Probe { dep, resp: rtx })?;
        rrx.recv()
            .map_err(|_| anyhow!("inference engine dropped the snapshot request"))
    }

    /// Graceful-shutdown barrier: blocks until every request enqueued
    /// before this call has been executed and answered.
    pub fn drain(&self) -> Result<()> {
        let (rtx, rrx) = mpsc::channel();
        self.send(Msg::Drain { resp: rtx })?;
        rrx.recv()
            .map_err(|_| anyhow!("inference engine dropped the drain request"))
    }
}

/// Start an empty dispatcher (no deployments yet); install backends with
/// [`EngineHandle::deploy`]. The scheduler shuts down when every
/// [`EngineHandle`] clone has been dropped. `occupancy` (if given)
/// records the size of every dispatched batch.
pub fn start(cfg: EngineConfig, occupancy: Option<Arc<AtomicHistogram>>) -> Result<EngineHandle> {
    let (tx, rx) = mpsc::channel::<Msg>();
    let batch = cfg.batch.max(1);
    let flush = Duration::from_micros(cfg.flush_micros);
    let batches = Arc::new(AtomicU64::new(0));
    let batches_worker = Arc::clone(&batches);

    std::thread::Builder::new()
        .name("engine-dispatch".to_string())
        .spawn(move || {
            dispatch_loop(&rx, batch, flush, &batches_worker, occupancy);
        })
        .map_err(|e| anyhow!("spawning dispatcher: {e}"))?;
    Ok(EngineHandle { tx, batches })
}

/// One deployed backend plus the dispatcher's bookkeeping for it.
struct Tenant {
    backend: Box<dyn BatchBackend>,
    /// The (r_in, r_out) point the backend is currently shaped at
    /// (`None` = as-deployed manifest precision).
    current: Option<(u32, u32)>,
    /// Batches dispatched to this deployment.
    batches: u64,
}

fn answer_probe(
    tenants: &HashMap<DeploymentId, Tenant>,
    dep: DeploymentId,
    tx: mpsc::Sender<Option<EngineSnapshot>>,
) {
    let snap = tenants.get(&dep).map(|t| EngineSnapshot {
        images: t.backend.images(),
        batches: t.batches,
        cost: t.backend.model_cost(),
        layer_costs: t.backend.model_layer_costs(),
    });
    let _ = tx.send(snap);
}

/// Run one batch for a route key: look the tenant up, re-target its
/// precision if the key asks for a different operating point, execute.
fn run_batch(
    tenants: &mut HashMap<DeploymentId, Tenant>,
    key: RouteKey,
    images: &[Vec<f32>],
    batches: &AtomicU64,
    occupancy: &Option<Arc<AtomicHistogram>>,
) -> std::result::Result<Vec<Vec<f32>>, String> {
    let tenant = tenants.get_mut(&key.dep).ok_or_else(|| {
        format!(
            "model deployment {} is not loaded (undeployed or replaced mid-request)",
            key.dep
        )
    })?;
    if tenant.current != key.precision {
        tenant
            .backend
            .retarget(key.precision)
            .map_err(|e| format!("re-targeting precision: {e:#}"))?;
        tenant.current = key.precision;
    }
    batches.fetch_add(1, Ordering::Relaxed);
    tenant.batches += 1;
    if let Some(h) = occupancy {
        h.record(images.len() as u64);
    }
    tenant
        .backend
        .forward_batch(images)
        .map_err(|e| format!("{e:#}"))
}

/// Pull same-key single-image jobs out of the backlog (preserving the
/// relative order of everything else) until `jobs` reaches `batch` —
/// but never past a parked barrier: a job that arrived after an
/// `Undeploy`/`Drain` must not jump ahead of it. Returns whether the
/// backlog holds a barrier (the caller then stops coalescing fresh
/// channel traffic too, so queue order is preserved end to end).
fn take_same_key(
    backlog: &mut VecDeque<Msg>,
    key: RouteKey,
    jobs: &mut Vec<Job>,
    batch: usize,
) -> bool {
    let mut rest = VecDeque::with_capacity(backlog.len());
    let mut blocked = false;
    while let Some(msg) = backlog.pop_front() {
        match msg {
            Msg::One { key: k, job } if !blocked && k == key && jobs.len() < batch => {
                jobs.push(job)
            }
            other => {
                blocked = blocked || other.is_barrier();
                rest.push_back(other);
            }
        }
    }
    *backlog = rest;
    blocked
}

fn dispatch_loop(
    rx: &mpsc::Receiver<Msg>,
    batch: usize,
    flush: Duration,
    batches: &AtomicU64,
    occupancy: Option<Arc<AtomicHistogram>>,
) {
    let mut tenants: HashMap<DeploymentId, Tenant> = HashMap::new();
    // Messages pulled off the channel while coalescing a different key:
    // handled in arrival order on the following turns.
    let mut backlog: VecDeque<Msg> = VecDeque::new();
    loop {
        let next = match backlog.pop_front() {
            Some(msg) => msg,
            None => match rx.recv() {
                Ok(msg) => msg,
                Err(_) => return, // all handles dropped
            },
        };
        let (key, first) = match next {
            Msg::Probe { dep, resp } => {
                answer_probe(&tenants, dep, resp);
                continue;
            }
            Msg::Deploy { dep, precision, factory, resp } => {
                let reply = factory()
                    .and_then(|mut backend| {
                        // Probe the default operating point now: a
                        // backend that declines it must fail the
                        // deploy, not every later request.
                        if precision.is_some() {
                            backend.retarget(precision)?;
                        }
                        Ok(backend)
                    })
                    .map(|backend| {
                        let info = (backend.input_len(), backend.describe());
                        tenants.insert(
                            dep,
                            Tenant { backend, current: precision, batches: 0 },
                        );
                        info
                    });
                let _ = resp.send(reply.map_err(|e| format!("{e:#}")));
                continue;
            }
            Msg::Undeploy { dep, resp } => {
                let _ = resp.send(tenants.remove(&dep).is_some());
                continue;
            }
            Msg::Drain { resp } => {
                // The queue is FIFO and every earlier message has been
                // fully executed by the time this one is handled, so the
                // ack itself is the barrier.
                let _ = resp.send(());
                continue;
            }
            Msg::Batch { key, images, resp } => {
                if images.is_empty() {
                    let _ = resp.send(Ok(Vec::new()));
                    continue;
                }
                let out = run_batch(&mut tenants, key, &images, batches, &occupancy);
                let _ = resp.send(out);
                continue;
            }
            Msg::One { key, job } => (key, job),
        };

        let mut jobs = vec![first];
        // Same-key jobs parked earlier (while another key coalesced)
        // join this batch first; a barrier already parked in the
        // backlog stops all further coalescing for this turn.
        let mut barrier = take_same_key(&mut backlog, key, &mut jobs, batch);
        // Opportunistically drain whatever is already queued — a
        // concurrent same-key burst coalesces with no waiting at all;
        // other keys park in the backlog, barriers stop the scan.
        while jobs.len() < batch && !barrier {
            match rx.try_recv() {
                Ok(Msg::One { key: k, job }) if k == key => jobs.push(job),
                Ok(Msg::Probe { dep, resp }) => answer_probe(&tenants, dep, resp),
                Ok(other) => {
                    barrier = other.is_barrier();
                    backlog.push_back(other);
                }
                Err(_) => break,
            }
        }
        // Lone request with nothing else pending: probe briefly for
        // company instead of paying the whole flush window — a lock-step
        // single client must not gain a `flush`-sized latency floor on
        // every request.
        if backlog.is_empty() && !barrier && jobs.len() == 1 && batch > 1 {
            let deadline = Instant::now() + flush / 8;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::One { key: k, job }) if k == key => {
                        jobs.push(job);
                        break;
                    }
                    Ok(Msg::Probe { dep, resp }) => answer_probe(&tenants, dep, resp),
                    Ok(other) => {
                        backlog.push_back(other);
                        break;
                    }
                    Err(_) => break,
                }
            }
        }
        // Once ≥ 2 same-key requests showed up there is real
        // concurrency: keep collecting until the batch fills or the
        // flush window closes — but never while other work waits.
        if backlog.is_empty() && !barrier && jobs.len() > 1 {
            let deadline = Instant::now() + flush;
            while jobs.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::One { key: k, job }) if k == key => jobs.push(job),
                    Ok(Msg::Probe { dep, resp }) => answer_probe(&tenants, dep, resp),
                    Ok(other) => {
                        backlog.push_back(other);
                        break;
                    }
                    Err(_) => break,
                }
            }
        }

        // Move the images out of the jobs — no per-image copies on the
        // serving hot path.
        let mut images = Vec::with_capacity(jobs.len());
        let mut responders = Vec::with_capacity(jobs.len());
        for job in jobs {
            images.push(job.image);
            responders.push(job.resp);
        }
        match run_batch(&mut tenants, key, &images, batches, &occupancy) {
            Ok(outputs) => {
                for (resp, out) in responders.into_iter().zip(outputs) {
                    let _ = resp.send(Ok(out));
                }
            }
            Err(msg) => {
                for resp in responders {
                    let _ = resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy backend: output = [sum of inputs, batch size at execution,
    /// r_in the backend is currently shaped at (0 = manifest)].
    struct SumBackend {
        len: usize,
        r_in: u32,
    }

    impl BatchBackend for SumBackend {
        fn input_len(&self) -> usize {
            self.len
        }

        fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(images
                .iter()
                .map(|im| {
                    vec![
                        im.iter().sum::<f32>(),
                        images.len() as f32,
                        self.r_in as f32,
                    ]
                })
                .collect())
        }

        fn describe(&self) -> String {
            "sum".to_string()
        }

        fn retarget(&mut self, precision: Option<(u32, u32)>) -> Result<()> {
            self.r_in = precision.map(|(r_in, _)| r_in).unwrap_or(0);
            Ok(())
        }
    }

    fn sum_factory(len: usize) -> BackendFactory {
        Box::new(move || Ok(Box::new(SumBackend { len, r_in: 0 }) as Box<dyn BatchBackend>))
    }

    fn key(dep: DeploymentId) -> RouteKey {
        RouteKey::new(dep, None)
    }

    #[test]
    fn scheduler_roundtrip_and_shutdown() {
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 200 };
        let handle = start(cfg, None).unwrap();
        let (input_len, desc) = handle.deploy(1, None, sum_factory(3)).unwrap();
        assert_eq!((input_len, desc.as_str()), (3, "sum"));
        let out = handle.infer(key(1), vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out[0], 6.0);
        assert!(handle.batches() >= 1);
        drop(handle); // dispatcher exits once all handles are gone
    }

    #[test]
    fn scheduler_coalesces_concurrent_requests() {
        let occupancy = Arc::new(crate::util::stats::AtomicHistogram::new(
            crate::util::stats::pow2_bounds(8),
        ));
        let cfg = EngineConfig { batch: 16, workers: 1, flush_micros: 50_000 };
        let handle = start(cfg, Some(Arc::clone(&occupancy))).unwrap();
        handle.deploy(1, None, sum_factory(1)).unwrap();
        let n_clients = 8;
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|i| {
                    let h = handle.clone();
                    s.spawn(move || h.infer(key(1), vec![i as f32]).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()[1]).collect()
        });
        // All 8 ran; with a 50 ms flush window at least one batch must
        // have coalesced more than one request.
        assert_eq!(results.len(), n_clients);
        assert!(occupancy.count() >= 1);
        assert!(
            results.iter().any(|&b| b > 1.0),
            "no coalescing observed: {results:?}"
        );
    }

    #[test]
    fn batches_only_coalesce_within_a_route_key() {
        let cfg = EngineConfig { batch: 16, workers: 1, flush_micros: 50_000 };
        let handle = start(cfg, None).unwrap();
        handle.deploy(1, None, sum_factory(1)).unwrap();
        handle.deploy(2, None, sum_factory(1)).unwrap();
        // Mixed keys: two deployments plus one precision override on
        // deployment 1 — all submitted before anything dispatches.
        let keys = [
            key(1),
            key(2),
            RouteKey::new(1, Some((2, 2))),
            key(1),
            key(2),
            RouteKey::new(1, Some((2, 2))),
        ];
        let pending: Vec<_> = keys
            .iter()
            .map(|&k| handle.submit(k, vec![1.0]).unwrap())
            .collect();
        let outs: Vec<Vec<f32>> = pending.into_iter().map(|p| p.wait().unwrap()).collect();
        // Every response saw only its own key's batch (≤ 2 images here),
        // and the precision override reached the backend via retarget.
        for (k, out) in keys.iter().zip(&outs) {
            assert!(out[1] <= 2.0, "cross-key coalescing: {outs:?}");
            let expect_r = k.precision.map(|(r, _)| r).unwrap_or(0) as f32;
            assert_eq!(out[2], expect_r, "key {k:?} got {out:?}");
        }
    }

    #[test]
    fn factory_error_is_reported() {
        let handle = start(EngineConfig::default(), None).unwrap();
        let err = handle
            .deploy(1, None, Box::new(|| Err(anyhow!("no artifacts"))))
            .err()
            .unwrap();
        assert!(format!("{err}").contains("no artifacts"), "{err}");
        // The failed deploy left nothing behind.
        assert!(handle.snapshot(1).unwrap().is_none());
    }

    #[test]
    fn unknown_deployment_errors_in_band() {
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle = start(cfg, None).unwrap();
        let err = handle.infer(key(9), vec![0.0]).err().unwrap();
        assert!(format!("{err}").contains("not loaded"), "{err}");
        let err = handle.infer_batch(key(9), vec![vec![0.0]]).err().unwrap();
        assert!(format!("{err}").contains("not loaded"), "{err}");
    }

    #[test]
    fn undeploy_removes_and_redeploy_works_without_restart() {
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle = start(cfg, None).unwrap();
        handle.deploy(1, None, sum_factory(1)).unwrap();
        handle.infer(key(1), vec![2.0]).unwrap();
        assert!(handle.undeploy(1).unwrap());
        assert!(!handle.undeploy(1).unwrap(), "second undeploy is a no-op");
        assert!(handle.infer(key(1), vec![2.0]).is_err());
        assert!(handle.snapshot(1).unwrap().is_none());
        // A new id takes over without restarting the dispatcher.
        handle.deploy(2, None, sum_factory(1)).unwrap();
        assert_eq!(handle.infer(key(2), vec![2.0]).unwrap()[0], 2.0);
    }

    #[test]
    fn backend_error_propagates_to_caller() {
        struct FailBackend;
        impl BatchBackend for FailBackend {
            fn input_len(&self) -> usize {
                1
            }
            fn forward_batch(&mut self, _: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                Err(anyhow!("die melted"))
            }
        }
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle = start(cfg, None).unwrap();
        handle
            .deploy(1, None, Box::new(|| Ok(Box::new(FailBackend) as Box<dyn BatchBackend>)))
            .unwrap();
        let err = handle.infer(key(1), vec![0.0]).err().unwrap();
        assert!(format!("{err}").contains("die melted"), "{err}");
    }

    #[test]
    fn retarget_refusal_errors_without_poisoning_the_tenant() {
        struct FixedBackend;
        impl BatchBackend for FixedBackend {
            fn input_len(&self) -> usize {
                1
            }
            fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                Ok(images.iter().map(|_| vec![1.0]).collect())
            }
            // Default retarget: declines any explicit precision.
        }
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle = start(cfg, None).unwrap();
        handle
            .deploy(1, None, Box::new(|| Ok(Box::new(FixedBackend) as Box<dyn BatchBackend>)))
            .unwrap();
        let err = handle
            .infer(RouteKey::new(1, Some((4, 4))), vec![0.0])
            .err()
            .unwrap();
        assert!(format!("{err}").contains("re-target"), "{err}");
        // Default-precision traffic still flows.
        assert_eq!(handle.infer(key(1), vec![0.0]).unwrap(), vec![1.0]);
        // Deploying such a backend WITH a default precision fails the
        // deploy itself (the point is probed up front), leaving nothing
        // behind.
        let err = handle
            .deploy(
                2,
                Some((4, 4)),
                Box::new(|| Ok(Box::new(FixedBackend) as Box<dyn BatchBackend>)),
            )
            .err()
            .unwrap();
        assert!(format!("{err}").contains("re-target"), "{err}");
        assert!(handle.snapshot(2).unwrap().is_none());
    }

    #[test]
    fn whole_batch_message_is_dispatched_as_one() {
        let occupancy = Arc::new(crate::util::stats::AtomicHistogram::new(
            crate::util::stats::pow2_bounds(8),
        ));
        // batch=2 caps *coalescing*, not caller-assembled batches.
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle = start(cfg, Some(Arc::clone(&occupancy))).unwrap();
        handle.deploy(1, None, sum_factory(1)).unwrap();
        let images: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        let outs = handle.infer_batch(key(1), images).unwrap();
        assert_eq!(outs.len(), 5);
        // Every output saw the full 5-image batch in one dispatch.
        assert!(outs.iter().all(|o| o[1] == 5.0), "{outs:?}");
        assert_eq!(handle.batches(), 1);
        assert_eq!(occupancy.count(), 1);
        // Empty batches short-circuit without a dispatch.
        assert!(handle.infer_batch(key(1), Vec::new()).unwrap().is_empty());
        assert_eq!(handle.batches(), 1);
    }

    #[test]
    fn submit_resolves_asynchronously_and_drain_is_a_barrier() {
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 100 };
        let handle = start(cfg, None).unwrap();
        handle.deploy(1, None, sum_factory(2)).unwrap();
        let pending: Vec<_> = (0..3)
            .map(|i| handle.submit(key(1), vec![i as f32, 1.0]).unwrap())
            .collect();
        // Drain resolves only after everything enqueued before it ran.
        handle.drain().unwrap();
        for (i, p) in pending.into_iter().enumerate() {
            let out = p.try_wait().expect("resolved before drain ack").unwrap();
            assert_eq!(out[0], i as f32 + 1.0);
        }
    }

    #[test]
    fn snapshot_reports_per_deployment_counters() {
        struct Counting {
            images: u64,
        }
        impl BatchBackend for Counting {
            fn input_len(&self) -> usize {
                1
            }
            fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                self.images += images.len() as u64;
                Ok(images.iter().map(|_| vec![0.0]).collect())
            }
            fn images(&self) -> u64 {
                self.images
            }
        }
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 100 };
        let handle = start(cfg, None).unwrap();
        for dep in [1u64, 2] {
            handle
                .deploy(
                    dep,
                    None,
                    Box::new(|| Ok(Box::new(Counting { images: 0 }) as Box<dyn BatchBackend>)),
                )
                .unwrap();
        }
        let snap = handle.snapshot(1).unwrap().unwrap();
        assert_eq!((snap.images, snap.batches), (0, 0));
        assert!(snap.cost.is_none());
        handle.infer_batch(key(1), vec![vec![0.0], vec![1.0]]).unwrap();
        // Counters are per deployment: 2 never ran anything.
        let snap = handle.snapshot(1).unwrap().unwrap();
        assert_eq!((snap.images, snap.batches), (2, 1));
        let snap = handle.snapshot(2).unwrap().unwrap();
        assert_eq!((snap.images, snap.batches), (0, 0));
    }
}
