//! Work-queue scheduler: coalesce concurrent requests into batches.
//!
//! The serving problem the old `Mutex<Executor>` design had: N concurrent
//! clients fully serialize, each paying the whole per-image cost, while
//! the batched backends get *cheaper* per image as the batch grows. The
//! scheduler inverts that: connection handlers submit single images into
//! a queue and block on a per-request reply channel; one dispatcher
//! thread drains the queue into batches of up to `batch` images (waiting
//! at most `flush_micros` after the first arrival) and runs the whole
//! batch through the backend at once.
//!
//! The backend is constructed *on* the dispatcher thread from a `Send`
//! factory closure, so non-`Send` backends (the PJRT client is a
//! single-threaded C handle) work unchanged — they simply live and die on
//! the dispatcher.

use crate::util::stats::AtomicHistogram;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A pluggable batch-inference backend (ideal, analog pool, PJRT, …).
pub trait BatchBackend {
    /// Expected flattened input length per image.
    fn input_len(&self) -> usize;

    /// Run a batch; returns one output vector per input image, in order.
    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Human-readable backend description (for logs).
    fn describe(&self) -> String {
        "batch backend".to_string()
    }
}

// Trait impls delegate to the inherent methods (inherent methods win name
// resolution, so these do not recurse).
impl BatchBackend for crate::engine::ideal::BatchIdeal {
    fn input_len(&self) -> usize {
        self.input_len()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.forward_batch(images)
    }

    fn describe(&self) -> String {
        format!(
            "batched ideal contract ({}, {} workers)",
            self.model.name, self.workers
        )
    }
}

impl BatchBackend for crate::engine::analog::AnalogPool {
    fn input_len(&self) -> usize {
        self.input_len()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.forward_batch(images)
    }

    fn describe(&self) -> String {
        format!("analog die pool ({} dies)", self.n_dies())
    }
}

/// Batching/parallelism knobs shared by the CLI and the server.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Maximum images per coalesced batch.
    pub batch: usize,
    /// Worker threads (matmul rows / analog dies).
    pub workers: usize,
    /// How long the dispatcher waits for more requests after the first
    /// one arrives before flushing a partial batch [µs].
    pub flush_micros: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            batch: 32,
            workers: default_workers(),
            flush_micros: 500,
        }
    }
}

/// Available hardware parallelism (≥ 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

struct Job {
    image: Vec<f32>,
    resp: mpsc::Sender<std::result::Result<Vec<f32>, String>>,
}

/// Cloneable handle for submitting inference requests to the dispatcher.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
    input_len: usize,
    describe: String,
    batches: Arc<AtomicU64>,
}

impl EngineHandle {
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn describe(&self) -> &str {
        &self.describe
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Blocking single-image inference (the dispatcher coalesces
    /// concurrent callers into batches).
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Job { image, resp: rtx })
            .map_err(|_| anyhow!("inference engine has shut down"))?;
        match rrx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("inference engine dropped the request")),
        }
    }
}

/// Start the dispatcher. `factory` runs on the dispatcher thread (so the
/// backend itself need not be `Send`); construction errors are reported
/// synchronously. The scheduler shuts down when every [`EngineHandle`]
/// clone has been dropped. `occupancy` (if given) records the size of
/// every dispatched batch.
pub fn start<F>(
    factory: F,
    cfg: EngineConfig,
    occupancy: Option<Arc<AtomicHistogram>>,
) -> Result<EngineHandle>
where
    F: FnOnce() -> Result<Box<dyn BatchBackend>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Job>();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(usize, String), String>>();
    let batch = cfg.batch.max(1);
    let flush = Duration::from_micros(cfg.flush_micros);
    let batches = Arc::new(AtomicU64::new(0));
    let batches_worker = Arc::clone(&batches);

    std::thread::Builder::new()
        .name("engine-dispatch".to_string())
        .spawn(move || {
            let mut backend = match factory() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok((b.input_len(), b.describe())));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            dispatch_loop(&mut *backend, &rx, batch, flush, &batches_worker, occupancy);
        })
        .map_err(|e| anyhow!("spawning dispatcher: {e}"))?;

    match ready_rx.recv() {
        Ok(Ok((input_len, describe))) => Ok(EngineHandle { tx, input_len, describe, batches }),
        Ok(Err(e)) => Err(anyhow!("engine backend failed to start: {e}")),
        Err(_) => Err(anyhow!("engine dispatcher died during startup")),
    }
}

fn dispatch_loop(
    backend: &mut dyn BatchBackend,
    rx: &mpsc::Receiver<Job>,
    batch: usize,
    flush: Duration,
    batches: &AtomicU64,
    occupancy: Option<Arc<AtomicHistogram>>,
) {
    loop {
        // Block for the first request of the next batch.
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => return, // all handles dropped
        };
        let mut jobs = vec![first];
        // Opportunistically drain whatever is already queued — a
        // concurrent burst coalesces with no waiting at all.
        while jobs.len() < batch {
            match rx.try_recv() {
                Ok(job) => jobs.push(job),
                Err(_) => break,
            }
        }
        // Lone request: probe briefly for company instead of paying the
        // whole flush window — a lock-step single client must not gain a
        // `flush`-sized latency floor on every request.
        if jobs.len() == 1 && batch > 1 {
            if let Ok(job) = rx.recv_timeout(flush / 8) {
                jobs.push(job);
            }
        }
        // Once ≥ 2 requests showed up there is real concurrency: keep
        // collecting until the batch fills or the flush window closes.
        if jobs.len() > 1 {
            let deadline = Instant::now() + flush;
            while jobs.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(job) => jobs.push(job),
                    Err(_) => break,
                }
            }
        }

        // Move the images out of the jobs — no per-image copies on the
        // serving hot path.
        let mut images = Vec::with_capacity(jobs.len());
        let mut responders = Vec::with_capacity(jobs.len());
        for job in jobs {
            images.push(job.image);
            responders.push(job.resp);
        }
        batches.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = &occupancy {
            h.record(images.len() as u64);
        }
        match backend.forward_batch(&images) {
            Ok(outputs) => {
                for (resp, out) in responders.into_iter().zip(outputs) {
                    let _ = resp.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for resp in responders {
                    let _ = resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy backend: output = [sum of inputs, batch size at execution].
    struct SumBackend {
        len: usize,
    }

    impl BatchBackend for SumBackend {
        fn input_len(&self) -> usize {
            self.len
        }

        fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(images
                .iter()
                .map(|im| vec![im.iter().sum::<f32>(), images.len() as f32])
                .collect())
        }

        fn describe(&self) -> String {
            "sum".to_string()
        }
    }

    #[test]
    fn scheduler_roundtrip_and_shutdown() {
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 200 };
        let handle =
            start(|| Ok(Box::new(SumBackend { len: 3 }) as Box<dyn BatchBackend>), cfg, None)
                .unwrap();
        assert_eq!(handle.input_len(), 3);
        assert_eq!(handle.describe(), "sum");
        let out = handle.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out[0], 6.0);
        assert!(handle.batches() >= 1);
        drop(handle); // dispatcher exits once all handles are gone
    }

    #[test]
    fn scheduler_coalesces_concurrent_requests() {
        let occupancy = Arc::new(crate::util::stats::AtomicHistogram::new(
            crate::util::stats::pow2_bounds(8),
        ));
        let cfg = EngineConfig { batch: 16, workers: 1, flush_micros: 50_000 };
        let handle = start(
            || Ok(Box::new(SumBackend { len: 1 }) as Box<dyn BatchBackend>),
            cfg,
            Some(Arc::clone(&occupancy)),
        )
        .unwrap();
        let n_clients = 8;
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|i| {
                    let h = handle.clone();
                    s.spawn(move || h.infer(vec![i as f32]).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()[1]).collect()
        });
        // All 8 ran; with a 50 ms flush window at least one batch must
        // have coalesced more than one request.
        assert_eq!(results.len(), n_clients);
        assert!(occupancy.count() >= 1);
        assert!(
            results.iter().any(|&b| b > 1.0),
            "no coalescing observed: {results:?}"
        );
    }

    #[test]
    fn factory_error_is_reported() {
        let cfg = EngineConfig::default();
        let err = start(|| Err(anyhow!("no artifacts")), cfg, None).err().unwrap();
        assert!(format!("{err}").contains("no artifacts"), "{err}");
    }

    #[test]
    fn backend_error_propagates_to_caller() {
        struct FailBackend;
        impl BatchBackend for FailBackend {
            fn input_len(&self) -> usize {
                1
            }
            fn forward_batch(&mut self, _: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                Err(anyhow!("die melted"))
            }
        }
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle =
            start(|| Ok(Box::new(FailBackend) as Box<dyn BatchBackend>), cfg, None).unwrap();
        let err = handle.infer(vec![0.0]).err().unwrap();
        assert!(format!("{err}").contains("die melted"), "{err}");
    }
}
