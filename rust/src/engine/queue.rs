//! Work-queue scheduler: coalesce concurrent requests into batches.
//!
//! The serving problem the old `Mutex<Executor>` design had: N concurrent
//! clients fully serialize, each paying the whole per-image cost, while
//! the batched backends get *cheaper* per image as the batch grows. The
//! scheduler inverts that: connection handlers submit single images into
//! a queue and block on a per-request reply channel; one dispatcher
//! thread drains the queue into batches of up to `batch` images (waiting
//! at most `flush_micros` after the first arrival) and runs the whole
//! batch through the backend at once.
//!
//! The backend is constructed *on* the dispatcher thread from a `Send`
//! factory closure, so non-`Send` backends (the PJRT client is a
//! single-threaded C handle) work unchanged — they simply live and die on
//! the dispatcher.

use crate::energy::system::LayerCost;
use crate::util::stats::AtomicHistogram;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A pluggable batch-inference backend (ideal, analog pool, PJRT, …).
pub trait BatchBackend {
    /// Expected flattened input length per image.
    fn input_len(&self) -> usize;

    /// Run a batch; returns one output vector per input image, in order.
    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Human-readable backend description (for logs).
    fn describe(&self) -> String {
        "batch backend".to_string()
    }

    /// Images executed so far (for engine snapshots). Backends that do
    /// not track it report 0.
    fn images(&self) -> u64 {
        0
    }

    /// Modeled accelerator cost accumulated so far, if this backend
    /// models the accelerator (the PJRT path does not).
    fn model_cost(&self) -> Option<LayerCost> {
        None
    }

    /// Per-layer breakdown of [`BatchBackend::model_cost`], in network
    /// layer order, if this backend models the accelerator layer by
    /// layer (what `{"cmd":"graph_info"}` serves).
    fn model_layer_costs(&self) -> Option<Vec<LayerCost>> {
        None
    }
}

// Trait impls delegate to the inherent methods (inherent methods win name
// resolution, so these do not recurse).
impl BatchBackend for crate::engine::ideal::BatchIdeal {
    fn input_len(&self) -> usize {
        self.input_len()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.forward_batch(images)
    }

    fn describe(&self) -> String {
        format!(
            "batched ideal contract ({}, {} workers)",
            self.model.name, self.workers
        )
    }

    fn images(&self) -> u64 {
        self.images
    }

    fn model_cost(&self) -> Option<LayerCost> {
        Some(self.cost)
    }

    fn model_layer_costs(&self) -> Option<Vec<LayerCost>> {
        Some(self.layer_costs())
    }
}

impl BatchBackend for crate::engine::analog::AnalogPool {
    fn input_len(&self) -> usize {
        self.input_len()
    }

    fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.forward_batch(images)
    }

    fn describe(&self) -> String {
        format!("analog die pool ({} dies)", self.n_dies())
    }

    fn images(&self) -> u64 {
        self.images
    }

    fn model_cost(&self) -> Option<LayerCost> {
        Some(self.cost())
    }

    fn model_layer_costs(&self) -> Option<Vec<LayerCost>> {
        Some(self.layer_costs())
    }
}

/// Batching/parallelism knobs shared by the CLI and the server.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Maximum images per coalesced batch.
    pub batch: usize,
    /// Worker threads (matmul rows / analog dies).
    pub workers: usize,
    /// How long the dispatcher waits for more requests after the first
    /// one arrives before flushing a partial batch [µs].
    pub flush_micros: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            batch: 32,
            workers: default_workers(),
            flush_micros: 500,
        }
    }
}

/// Available hardware parallelism (≥ 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

struct Job {
    image: Vec<f32>,
    resp: mpsc::Sender<std::result::Result<Vec<f32>, String>>,
}

/// Read-only state reported by the dispatcher on request.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    /// Images executed by the backend so far.
    pub images: u64,
    /// Batches dispatched so far.
    pub batches: u64,
    /// Modeled accelerator cost, if the backend models one.
    pub cost: Option<LayerCost>,
    /// Per-layer breakdown of `cost` in network layer order, if the
    /// backend models the accelerator layer by layer.
    pub layer_costs: Option<Vec<LayerCost>>,
}

struct Probe {
    images: u64,
    cost: Option<LayerCost>,
    layer_costs: Option<Vec<LayerCost>>,
}

enum Msg {
    /// A single image to coalesce with concurrent submissions.
    One(Job),
    /// A caller-assembled batch, executed exactly as submitted (never
    /// merged with other traffic — keeps multi-die splits deterministic).
    Batch {
        images: Vec<Vec<f32>>,
        resp: mpsc::Sender<std::result::Result<Vec<Vec<f32>>, String>>,
    },
    /// Snapshot request, answered between dispatches.
    Probe(mpsc::Sender<Probe>),
}

/// An in-flight single-image inference returned by
/// [`EngineHandle::submit`]; resolve it with [`Pending::wait`].
pub struct Pending {
    rx: mpsc::Receiver<std::result::Result<Vec<f32>, String>>,
}

impl Pending {
    /// Block until the logits arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("inference engine dropped the request")),
        }
    }

    /// Non-blocking poll: `None` while the batch is still in flight.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(Ok(v)) => Some(Ok(v)),
            Ok(Err(e)) => Some(Err(anyhow!("{e}"))),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("inference engine dropped the request")))
            }
        }
    }
}

/// Cloneable handle for submitting inference requests to the dispatcher.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
    input_len: usize,
    describe: String,
    batches: Arc<AtomicU64>,
}

impl EngineHandle {
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn describe(&self) -> &str {
        &self.describe
    }

    /// Batches dispatched so far.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Enqueue one image without blocking; the dispatcher coalesces
    /// concurrent submissions into batches.
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::One(Job { image, resp: rtx }))
            .map_err(|_| anyhow!("inference engine has shut down"))?;
        Ok(Pending { rx: rrx })
    }

    /// Blocking single-image inference (the dispatcher coalesces
    /// concurrent callers into batches).
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(image)?.wait()
    }

    /// Run a caller-assembled batch as one backend dispatch. Unlike a
    /// series of [`EngineHandle::submit`] calls, the batch is executed
    /// exactly as submitted (no timing-dependent coalescing), so
    /// seed-sensitive backends split it across dies deterministically.
    pub fn infer_batch(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Batch { images, resp: rtx })
            .map_err(|_| anyhow!("inference engine has shut down"))?;
        match rrx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("inference engine dropped the request")),
        }
    }

    /// Ask the dispatcher for its current image/batch counters and the
    /// backend's modeled accelerator cost. Blocks while a batch is
    /// executing (answered between dispatches).
    pub fn snapshot(&self) -> Result<EngineSnapshot> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Probe(rtx))
            .map_err(|_| anyhow!("inference engine has shut down"))?;
        let probe = rrx
            .recv()
            .map_err(|_| anyhow!("inference engine dropped the snapshot request"))?;
        Ok(EngineSnapshot {
            images: probe.images,
            batches: self.batches(),
            cost: probe.cost,
            layer_costs: probe.layer_costs,
        })
    }
}

/// Start the dispatcher. `factory` runs on the dispatcher thread (so the
/// backend itself need not be `Send`); construction errors are reported
/// synchronously. The scheduler shuts down when every [`EngineHandle`]
/// clone has been dropped. `occupancy` (if given) records the size of
/// every dispatched batch.
pub fn start<F>(
    factory: F,
    cfg: EngineConfig,
    occupancy: Option<Arc<AtomicHistogram>>,
) -> Result<EngineHandle>
where
    F: FnOnce() -> Result<Box<dyn BatchBackend>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Msg>();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(usize, String), String>>();
    let batch = cfg.batch.max(1);
    let flush = Duration::from_micros(cfg.flush_micros);
    let batches = Arc::new(AtomicU64::new(0));
    let batches_worker = Arc::clone(&batches);

    std::thread::Builder::new()
        .name("engine-dispatch".to_string())
        .spawn(move || {
            let mut backend = match factory() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok((b.input_len(), b.describe())));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            dispatch_loop(&mut *backend, &rx, batch, flush, &batches_worker, occupancy);
        })
        .map_err(|e| anyhow!("spawning dispatcher: {e}"))?;

    match ready_rx.recv() {
        Ok(Ok((input_len, describe))) => Ok(EngineHandle { tx, input_len, describe, batches }),
        Ok(Err(e)) => Err(anyhow!("engine backend failed to start: {e}")),
        Err(_) => Err(anyhow!("engine dispatcher died during startup")),
    }
}

fn answer_probe(backend: &dyn BatchBackend, tx: mpsc::Sender<Probe>) {
    let _ = tx.send(Probe {
        images: backend.images(),
        cost: backend.model_cost(),
        layer_costs: backend.model_layer_costs(),
    });
}

fn dispatch_loop(
    backend: &mut dyn BatchBackend,
    rx: &mpsc::Receiver<Msg>,
    batch: usize,
    flush: Duration,
    batches: &AtomicU64,
    occupancy: Option<Arc<AtomicHistogram>>,
) {
    // A whole-batch message that arrived while singles were being
    // coalesced: flushed singles first, then handled on the next turn.
    let mut backlog: Option<Msg> = None;
    loop {
        let next = match backlog.take() {
            Some(msg) => msg,
            None => match rx.recv() {
                Ok(msg) => msg,
                Err(_) => return, // all handles dropped
            },
        };
        let first = match next {
            Msg::Probe(tx) => {
                answer_probe(backend, tx);
                continue;
            }
            Msg::Batch { images, resp } => {
                if images.is_empty() {
                    let _ = resp.send(Ok(Vec::new()));
                    continue;
                }
                batches.fetch_add(1, Ordering::Relaxed);
                if let Some(h) = &occupancy {
                    h.record(images.len() as u64);
                }
                let out = backend
                    .forward_batch(&images)
                    .map_err(|e| format!("{e:#}"));
                let _ = resp.send(out);
                continue;
            }
            Msg::One(job) => job,
        };

        let mut jobs = vec![first];
        // Opportunistically drain whatever is already queued — a
        // concurrent burst coalesces with no waiting at all.
        while backlog.is_none() && jobs.len() < batch {
            match rx.try_recv() {
                Ok(Msg::One(job)) => jobs.push(job),
                Ok(Msg::Probe(tx)) => answer_probe(backend, tx),
                Ok(msg @ Msg::Batch { .. }) => backlog = Some(msg),
                Err(_) => break,
            }
        }
        // Lone request: probe briefly for company instead of paying the
        // whole flush window — a lock-step single client must not gain a
        // `flush`-sized latency floor on every request.
        if backlog.is_none() && jobs.len() == 1 && batch > 1 {
            let deadline = Instant::now() + flush / 8;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::One(job)) => {
                        jobs.push(job);
                        break;
                    }
                    Ok(Msg::Probe(tx)) => answer_probe(backend, tx),
                    Ok(msg @ Msg::Batch { .. }) => {
                        backlog = Some(msg);
                        break;
                    }
                    Err(_) => break,
                }
            }
        }
        // Once ≥ 2 requests showed up there is real concurrency: keep
        // collecting until the batch fills or the flush window closes.
        if backlog.is_none() && jobs.len() > 1 {
            let deadline = Instant::now() + flush;
            while jobs.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::One(job)) => jobs.push(job),
                    Ok(Msg::Probe(tx)) => answer_probe(backend, tx),
                    Ok(msg @ Msg::Batch { .. }) => {
                        backlog = Some(msg);
                        break;
                    }
                    Err(_) => break,
                }
            }
        }

        // Move the images out of the jobs — no per-image copies on the
        // serving hot path.
        let mut images = Vec::with_capacity(jobs.len());
        let mut responders = Vec::with_capacity(jobs.len());
        for job in jobs {
            images.push(job.image);
            responders.push(job.resp);
        }
        batches.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = &occupancy {
            h.record(images.len() as u64);
        }
        match backend.forward_batch(&images) {
            Ok(outputs) => {
                for (resp, out) in responders.into_iter().zip(outputs) {
                    let _ = resp.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for resp in responders {
                    let _ = resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy backend: output = [sum of inputs, batch size at execution].
    struct SumBackend {
        len: usize,
    }

    impl BatchBackend for SumBackend {
        fn input_len(&self) -> usize {
            self.len
        }

        fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(images
                .iter()
                .map(|im| vec![im.iter().sum::<f32>(), images.len() as f32])
                .collect())
        }

        fn describe(&self) -> String {
            "sum".to_string()
        }
    }

    #[test]
    fn scheduler_roundtrip_and_shutdown() {
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 200 };
        let handle =
            start(|| Ok(Box::new(SumBackend { len: 3 }) as Box<dyn BatchBackend>), cfg, None)
                .unwrap();
        assert_eq!(handle.input_len(), 3);
        assert_eq!(handle.describe(), "sum");
        let out = handle.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out[0], 6.0);
        assert!(handle.batches() >= 1);
        drop(handle); // dispatcher exits once all handles are gone
    }

    #[test]
    fn scheduler_coalesces_concurrent_requests() {
        let occupancy = Arc::new(crate::util::stats::AtomicHistogram::new(
            crate::util::stats::pow2_bounds(8),
        ));
        let cfg = EngineConfig { batch: 16, workers: 1, flush_micros: 50_000 };
        let handle = start(
            || Ok(Box::new(SumBackend { len: 1 }) as Box<dyn BatchBackend>),
            cfg,
            Some(Arc::clone(&occupancy)),
        )
        .unwrap();
        let n_clients = 8;
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|i| {
                    let h = handle.clone();
                    s.spawn(move || h.infer(vec![i as f32]).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()[1]).collect()
        });
        // All 8 ran; with a 50 ms flush window at least one batch must
        // have coalesced more than one request.
        assert_eq!(results.len(), n_clients);
        assert!(occupancy.count() >= 1);
        assert!(
            results.iter().any(|&b| b > 1.0),
            "no coalescing observed: {results:?}"
        );
    }

    #[test]
    fn factory_error_is_reported() {
        let cfg = EngineConfig::default();
        let err = start(|| Err(anyhow!("no artifacts")), cfg, None).err().unwrap();
        assert!(format!("{err}").contains("no artifacts"), "{err}");
    }

    #[test]
    fn backend_error_propagates_to_caller() {
        struct FailBackend;
        impl BatchBackend for FailBackend {
            fn input_len(&self) -> usize {
                1
            }
            fn forward_batch(&mut self, _: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                Err(anyhow!("die melted"))
            }
        }
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle =
            start(|| Ok(Box::new(FailBackend) as Box<dyn BatchBackend>), cfg, None).unwrap();
        let err = handle.infer(vec![0.0]).err().unwrap();
        assert!(format!("{err}").contains("die melted"), "{err}");
    }

    #[test]
    fn whole_batch_message_is_dispatched_as_one() {
        let occupancy = Arc::new(crate::util::stats::AtomicHistogram::new(
            crate::util::stats::pow2_bounds(8),
        ));
        // batch=2 caps *coalescing*, not caller-assembled batches.
        let cfg = EngineConfig { batch: 2, workers: 1, flush_micros: 100 };
        let handle = start(
            || Ok(Box::new(SumBackend { len: 1 }) as Box<dyn BatchBackend>),
            cfg,
            Some(Arc::clone(&occupancy)),
        )
        .unwrap();
        let images: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        let outs = handle.infer_batch(images).unwrap();
        assert_eq!(outs.len(), 5);
        // Every output saw the full 5-image batch in one dispatch.
        assert!(outs.iter().all(|o| o[1] == 5.0), "{outs:?}");
        assert_eq!(handle.batches(), 1);
        assert_eq!(occupancy.count(), 1);
        // Empty batches short-circuit without a dispatch.
        assert!(handle.infer_batch(Vec::new()).unwrap().is_empty());
        assert_eq!(handle.batches(), 1);
    }

    #[test]
    fn submit_resolves_asynchronously() {
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 100 };
        let handle =
            start(|| Ok(Box::new(SumBackend { len: 2 }) as Box<dyn BatchBackend>), cfg, None)
                .unwrap();
        let pending: Vec<_> = (0..3)
            .map(|i| handle.submit(vec![i as f32, 1.0]).unwrap())
            .collect();
        for (i, p) in pending.into_iter().enumerate() {
            assert_eq!(p.wait().unwrap()[0], i as f32 + 1.0);
        }
    }

    #[test]
    fn snapshot_reports_backend_counters() {
        struct Counting {
            images: u64,
        }
        impl BatchBackend for Counting {
            fn input_len(&self) -> usize {
                1
            }
            fn forward_batch(&mut self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                self.images += images.len() as u64;
                Ok(images.iter().map(|_| vec![0.0]).collect())
            }
            fn images(&self) -> u64 {
                self.images
            }
        }
        let cfg = EngineConfig { batch: 4, workers: 1, flush_micros: 100 };
        let handle = start(
            || Ok(Box::new(Counting { images: 0 }) as Box<dyn BatchBackend>),
            cfg,
            None,
        )
        .unwrap();
        let snap = handle.snapshot().unwrap();
        assert_eq!((snap.images, snap.batches), (0, 0));
        assert!(snap.cost.is_none());
        handle.infer_batch(vec![vec![0.0], vec![1.0]]).unwrap();
        let snap = handle.snapshot().unwrap();
        assert_eq!((snap.images, snap.batches), (2, 1));
    }
}
