//! Equivalent-output-noise characterization of the analog backend — the
//! software image of measuring a fabricated die.
//!
//! The paper's training story hinges on "including the post-silicon
//! equivalent noise within a CIM-aware CNN training framework": you
//! measure what the silicon actually does to a conversion (thermal kT/C,
//! SA decision noise, residual offsets, mismatch) as one equivalent σ at
//! the ADC output, then inject that σ during training.
//! [`probe_equivalent_noise`] performs the measurement against the
//! circuit-behavioral simulator at the configured supply/corner: it
//! fabricates a few independent dies (the same deterministic per-die
//! seeding [`AnalogPool`](super::AnalogPool) uses), replays fixed inputs
//! through each, and splits the observed code spread into a *temporal*
//! component (repeat-to-repeat on one die) and a *fixed-pattern*
//! component (die-to-die after averaging out the temporal part).
//!
//! `nn::train` consumes [`NoiseStats::total_lsb`] when the trainer is
//! configured with `NoiseInjection::Probe`, closing the
//! characterize → train → deploy loop inside one binary.

use crate::config::params::MacroParams;
use crate::coordinator::executor::{Backend, Executor};
use crate::coordinator::manifest::NetworkModel;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};

/// The probe's measurement: equivalent output noise in ADC LSB.
#[derive(Clone, Copy, Debug)]
pub struct NoiseStats {
    /// Repeat-to-repeat spread on one die (temporal noise).
    pub sigma_temporal_lsb: f64,
    /// Die-to-die spread of the per-die mean (mismatch / fixed-pattern
    /// residue after calibration).
    pub sigma_mismatch_lsb: f64,
    pub dies: usize,
    pub repeats: usize,
}

impl NoiseStats {
    /// The combined equivalent σ a single conversion sees (the two
    /// components are independent).
    pub fn total_lsb(&self) -> f64 {
        (self.sigma_temporal_lsb.powi(2) + self.sigma_mismatch_lsb.powi(2)).sqrt()
    }
}

/// Probe the analog backend's equivalent output noise at `(r_in, r_out)`
/// under `p`'s supply/corner with the default die/repeat budget.
pub fn probe_equivalent_noise(
    p: &MacroParams,
    r_in: u32,
    r_out: u32,
    seed: u64,
) -> Result<NoiseStats> {
    probe_equivalent_noise_with(p, r_in, r_out, seed, 2, 8)
}

/// [`probe_equivalent_noise`] with an explicit measurement budget.
/// Deterministic for a given `(p, r_in, r_out, seed, dies, repeats)`.
pub fn probe_equivalent_noise_with(
    p: &MacroParams,
    r_in: u32,
    r_out: u32,
    seed: u64,
    dies: usize,
    repeats: usize,
) -> Result<NoiseStats> {
    ensure!(dies >= 1, "need at least one die");
    ensure!(repeats >= 2, "need at least two repeats to estimate a spread");
    ensure!(
        (1..=8).contains(&r_in) && (1..=8).contains(&r_out),
        "precision r_in={r_in} r_out={r_out} outside the macro's 1..=8 range"
    );

    // A single dense probe layer (4 DP units, no ReLU so negative codes
    // are observable); γ=16 spreads random-weight DP voltages over many
    // codes instead of collapsing onto mid-code.
    const N_IN: usize = 144;
    const N_OUT: usize = 16;
    const N_IMAGES: usize = 4;
    let model = NetworkModel::synthetic_mlp(&[N_IN, N_OUT], r_in, 4, r_out, seed ^ 0xA5A5, p);
    let out_gain = f64::from(model.layers[0].out_gain);
    // The executor emits `(code − half)·out_gain`, so recovered values
    // live in `[−half, half − 1]`.
    let half = (1u64 << (r_out - 1)) as f64;

    let mut img_rng = Rng::new(seed ^ 0x0B5E_0B5E_0B5E_0B5E);
    let images: Vec<Vec<f32>> = (0..N_IMAGES)
        .map(|_| (0..N_IN).map(|_| img_rng.uniform() as f32).collect())
        .collect();

    // codes[die][image][rep][o]
    let mut codes = vec![vec![vec![[0f64; N_OUT]; repeats]; N_IMAGES]; dies];
    for (d, die_codes) in codes.iter_mut().enumerate() {
        let die_seed = seed.wrapping_add(super::analog::DIE_SEED_STRIDE.wrapping_mul(d as u64));
        let mut die = Executor::new(
            model.clone(),
            p.clone(),
            Backend::Analog { seed: die_seed, noise: true, calibrate: true },
        )
        .context("fabricating probe die")?;
        for (img, reps) in images.iter().zip(die_codes.iter_mut()) {
            for rep in reps.iter_mut() {
                let out = die.forward(img)?;
                for (o, &v) in out.iter().enumerate() {
                    // Outputs are affine in the code; the slope is the
                    // post-ADC gain, so this recovers spreads in LSB.
                    rep[o] = f64::from(v) / out_gain;
                }
            }
        }
    }

    // Temporal σ: per (die, image, output) spread over repeats, skipping
    // rail-saturated outputs whose spread is clipped away.
    let mut t_sq = 0.0;
    let mut t_n = 0usize;
    let mut per_die_mean = vec![vec![[0f64; N_OUT]; N_IMAGES]; dies];
    for d in 0..dies {
        for i in 0..N_IMAGES {
            for o in 0..N_OUT {
                let vals: Vec<f64> = (0..repeats).map(|r| codes[d][i][r][o]).collect();
                let mean = vals.iter().sum::<f64>() / repeats as f64;
                per_die_mean[d][i][o] = mean;
                let (lo, hi) = vals.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &v| {
                    (lo.min(v), hi.max(v))
                });
                if lo <= -half + 1.0 || hi >= half - 2.0 {
                    continue; // railed at least once: spread is censored
                }
                let sq: f64 = vals.iter().map(|v| (v - mean) * (v - mean)).sum();
                t_sq += sq / (repeats - 1) as f64;
                t_n += 1;
            }
        }
    }
    ensure!(t_n > 0, "every probe output railed; cannot estimate temporal noise");
    let sigma_temporal = (t_sq / t_n as f64).sqrt();

    // Fixed-pattern σ: spread of the per-die means across dies.
    let mut m_sq = 0.0;
    let mut m_n = 0usize;
    if dies >= 2 {
        for i in 0..N_IMAGES {
            for o in 0..N_OUT {
                let means: Vec<f64> = (0..dies).map(|d| per_die_mean[d][i][o]).collect();
                let mean = means.iter().sum::<f64>() / dies as f64;
                let var =
                    means.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (dies - 1) as f64;
                m_sq += var;
                m_n += 1;
            }
        }
    }
    let sigma_mismatch = if m_n > 0 { (m_sq / m_n as f64).sqrt() } else { 0.0 };

    Ok(NoiseStats {
        sigma_temporal_lsb: sigma_temporal,
        sigma_mismatch_lsb: sigma_mismatch,
        dies,
        repeats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_is_deterministic_and_positive() {
        let p = MacroParams::paper();
        // r_out = 8: the finest LSB, so the temporal spread is never
        // quantized away entirely.
        let a = probe_equivalent_noise_with(&p, 8, 8, 7, 1, 4).unwrap();
        let b = probe_equivalent_noise_with(&p, 8, 8, 7, 1, 4).unwrap();
        assert_eq!(a.sigma_temporal_lsb.to_bits(), b.sigma_temporal_lsb.to_bits());
        assert!(a.sigma_temporal_lsb > 0.0, "analog backend must show temporal noise");
        assert!(a.total_lsb() >= a.sigma_temporal_lsb);
        assert_eq!(a.sigma_mismatch_lsb, 0.0, "one die has no die-to-die spread");
    }

    #[test]
    fn probe_rejects_bad_budgets() {
        let p = MacroParams::paper();
        assert!(probe_equivalent_noise_with(&p, 8, 6, 7, 0, 4).is_err());
        assert!(probe_equivalent_noise_with(&p, 8, 6, 7, 1, 1).is_err());
        assert!(probe_equivalent_noise_with(&p, 9, 6, 7, 1, 4).is_err());
    }
}
