//! Precision- and ISA-adaptive kernel dispatch for the engine hot path.
//!
//! The macro's headline property is throughput that *scales with input
//! precision* (0.15–8 POPS/W from 8b down to 1b, §VI): the array
//! accumulates input bit-planes serially, so a 1b input costs 1/8th of
//! an 8b input. The scalar kernels in [`super::gemm`] pay the same i32
//! cost at every `r_in`, which flattens exactly the curve the paper is
//! about. This module restores it in software with three kernel
//! families behind one dispatch point:
//!
//! * **Scalar** — the reference kernels from [`super::gemm`], always
//!   available, the bit-identity oracle every other path is tested
//!   against.
//! * **SIMD** — `Portable` is a lane-blocked form (8×i32 / 4×f64
//!   accumulator tiles) written so LLVM autovectorizes it on any
//!   target; `Avx2` / `Neon` are explicit `std::arch` intrinsics
//!   compiled only under the `simd` cargo feature and *selected* only
//!   when runtime detection (`is_x86_feature_detected!` /
//!   `is_aarch64_feature_detected!`) confirms the ISA, with automatic
//!   fallback to `Portable` otherwise.
//! * **BitPlane** — the software image of the macro's input-serial
//!   accumulation, used at `r_in ∈ {1,2}`: input factors and weight
//!   levels are packed into per-row `u64` masks and each dot product
//!   becomes a handful of XOR/AND/popcount passes, so cost scales with
//!   `r_in` like the silicon does (see [`matmul_i32`] for the math).
//!
//! # Bit-identity contract
//!
//! Every path returns results **bit-identical** to the scalar
//! reference — a hard equality, not a tolerance:
//!
//! * i32 accumulation is exact and associative (two's-complement
//!   wrapping), so any re-ordering (SIMD lanes, bit-plane algebra,
//!   thread splits) produces the same words.
//! * The f64 [`rowdot_f64`] lane kernel assigns one *output* per lane
//!   and accumulates ascending-`k` within the lane — the exact
//!   floating-point operation sequence of the scalar loop per output —
//!   so no float addition is ever re-associated. (Rust never contracts
//!   `a*b + c` into an FMA implicitly, so lane and scalar code compile
//!   to the same rounding behaviour.)
//!
//! `tests/kernel_equivalence.rs` asserts both properties across shapes,
//! remainder classes, worker counts and the full `r_in` grid, in both
//! the default and `--features simd` builds.
//!
//! # Selection rules ([`select_gemm`])
//!
//! | Condition (checked in order) | Path |
//! |---|---|
//! | `r_in ≤ 2`, `n_vec ≥ 4`, `rows ≥ 32`, weights all odd-or-zero with `|w| ≤ 15` | `BitPlane` |
//! | `n_out ≥ 8`, `simd` feature on, AVX2 detected at runtime | `Avx2` |
//! | `n_out ≥ 8`, `simd` feature on, NEON detected at runtime | `Neon` |
//! | `n_out ≥ 8` | `Portable` |
//! | otherwise | `Scalar` |
//!
//! The weight eligibility rule matches the two layouts that reach the
//! kernels: physical manifest weights are antipodal levels
//! `{±1, ±3, …, ±15}` (all odd), and graph/trainer quantized weights
//! are those levels *or exactly 0* on `permute_conv_rows` padding rows.
//! Zero rows are excluded from the popcount via a per-output validity
//! mask rather than rejected.
//!
//! Callers that cannot name an input precision pass `r_in = None` and
//! get the SIMD/scalar tier only.

use super::gemm;

/// Re-export of the scalar im2col row assembly so the graph executor
/// and the trainer route through this dispatch hub instead of calling
/// the `gemm` reference module directly (the `dispatch-discipline`
/// lint rule keeps `gemm::` call sites confined to this module, tests
/// and benches).
pub use super::gemm::conv3x3_signed_rows;

/// Antipodal weight level bound for the 4b weight path (`R_W = 4`,
/// levels `2k − 15` for `k ∈ 0..16`).
const W_LEVEL_MAX: i32 = 15;
/// Number of weight bit-planes (`R_W`).
const W_PLANES: usize = 4;
/// Auto-selection only uses the bit-plane engine where it clearly wins.
const BITPLANE_MAX_RIN: u32 = 2;
/// Forced bit-plane execution (benches, tests) is valid up to 8b input.
const BITPLANE_RIN_LIMIT: u32 = 8;
const BITPLANE_MIN_VECS: usize = 4;
const BITPLANE_MIN_ROWS: usize = 32;
/// i32 lane-tile width of the portable/AVX2 kernels.
const I32_LANES: usize = 8;
/// f64 lane-tile width of the portable rowdot kernel.
const F64_LANES: usize = 4;

// ---------------------------------------------------------------------------
// ISA capability detection
// ---------------------------------------------------------------------------

/// Which explicit-SIMD instruction sets this process may use. Without
/// the `simd` cargo feature both flags are `false` and dispatch stops
/// at the portable tier — the forced-fallback behaviour the tests pin.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Caps {
    /// x86-64 AVX2 available (feature-compiled and CPU-reported).
    pub avx2: bool,
    /// aarch64 NEON available (feature-compiled and CPU-reported).
    pub neon: bool,
}

/// Runtime ISA detection, evaluated once per process. Compiled to
/// `Caps::default()` unless the `simd` feature is enabled *and* the
/// target architecture has an explicit kernel.
pub fn caps() -> Caps {
    static CAPS: std::sync::OnceLock<Caps> = std::sync::OnceLock::new();
    *CAPS.get_or_init(detect_caps)
}

fn detect_caps() -> Caps {
    #[allow(unused_mut)]
    let mut caps = Caps::default();
    #[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
    {
        caps.avx2 = is_x86_feature_detected!("avx2");
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        caps.neon = std::arch::is_aarch64_feature_detected!("neon");
    }
    caps
}

/// Name of the explicit ISA the dispatcher would use, if any — what the
/// benches print so a run is attributable to a kernel tier.
pub fn explicit_isa() -> Option<&'static str> {
    let c = caps();
    if c.avx2 {
        Some("avx2")
    } else if c.neon {
        Some("neon")
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Kernel paths and selection
// ---------------------------------------------------------------------------

/// One concrete kernel implementation the dispatcher can route a call
/// to. `Avx2`/`Neon` exist as variants on every target so selection
/// logic is testable anywhere; [`path_available`] reports whether a
/// variant can actually execute in this build/process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Reference kernels from [`super::gemm`].
    Scalar,
    /// Lane-blocked autovectorizable kernel (any target, any build).
    Portable,
    /// Explicit AVX2 intrinsics (`simd` feature + runtime detection).
    Avx2,
    /// Explicit NEON intrinsics (`simd` feature + runtime detection).
    Neon,
    /// Input-serial bit-plane popcount engine for low `r_in`.
    BitPlane,
}

impl KernelPath {
    /// Stable lowercase label for bench output and logs.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Portable => "portable",
            KernelPath::Avx2 => "avx2",
            KernelPath::Neon => "neon",
            KernelPath::BitPlane => "bitplane",
        }
    }
}

/// Whether `path` can execute in this build on this machine.
pub fn path_available(path: KernelPath) -> bool {
    match path {
        KernelPath::Scalar | KernelPath::Portable | KernelPath::BitPlane => true,
        KernelPath::Avx2 => caps().avx2,
        KernelPath::Neon => caps().neon,
    }
}

/// True when every weight is representable by the 4-plane antipodal
/// decomposition: an odd level with `|w| ≤ 15`, or exactly 0 (a
/// `permute_conv_rows` padding row, excluded via the validity mask).
pub fn weights_bitplane_eligible(w: &[i32]) -> bool {
    w.iter().all(|&v| v == 0 || (v.abs() <= W_LEVEL_MAX && (v & 1) != 0))
}

/// Whether auto-selection can route calls at this input precision to
/// the bit-plane tier — the `r_in` gate of [`select_gemm`], exposed so
/// the deploy-time weight cache ([`super::packed`]) packs exactly the
/// layers the dispatcher could use a pack for.
pub fn bitplane_auto_rin(r_in: u32) -> bool {
    (1..=BITPLANE_MAX_RIN).contains(&r_in)
}

/// [`select_gemm`] with injected [`Caps`] — lets tests pin the
/// selection table without depending on the host CPU.
pub fn select_gemm_with(
    caps: Caps,
    r_in: Option<u32>,
    rows: usize,
    n_out: usize,
    n_vec: usize,
    w: &[i32],
) -> KernelPath {
    let bitplane_ok = r_in.is_some_and(|r| (1..=BITPLANE_MAX_RIN).contains(&r))
        && n_vec >= BITPLANE_MIN_VECS
        && rows >= BITPLANE_MIN_ROWS
        && weights_bitplane_eligible(w);
    if bitplane_ok {
        return KernelPath::BitPlane;
    }
    if n_out >= I32_LANES {
        if caps.avx2 {
            return KernelPath::Avx2;
        }
        if caps.neon {
            return KernelPath::Neon;
        }
        return KernelPath::Portable;
    }
    KernelPath::Scalar
}

/// Pick the i32 gemm kernel for a call shape (see the module-level
/// selection table). `r_in = None` disables the bit-plane tier.
pub fn select_gemm(
    r_in: Option<u32>,
    rows: usize,
    n_out: usize,
    n_vec: usize,
    w: &[i32],
) -> KernelPath {
    select_gemm_with(caps(), r_in, rows, n_out, n_vec, w)
}

// ---------------------------------------------------------------------------
// Dispatching i32 gemm
// ---------------------------------------------------------------------------

/// Precision-aware drop-in for [`gemm::matmul_i32`]:
/// `C[v][o] = Σ_r a[v·rows + r] · w[r·n_out + o]`, bit-identical to the
/// scalar kernel on every path.
///
/// # Bit-plane math (`r_in ≤ 2` tier)
///
/// With `M = 2^r_in − 1`, an antipodal input factor decomposes over the
/// bits of its level `q` as `s = 2q − M = Σ_b 2^b (2q_b − 1)`, and a 4b
/// antipodal weight over the bits of `k = (w + 15)/2` as
/// `w = Σ_j 2^j (2k_j − 1)`. Each `(b, j)` pair is a ±1 dot product,
/// which over packed `u64` masks `A_b`, `C_j` and a validity mask `Z`
/// (1 for rows with a nonzero weight, 0 for padding) is
/// `pop(Z) − 2·pop((A_b ⊕ C_j) & Z)`. Summing with the binary weights:
///
/// ```text
/// dot[o] = 15 · M · pop(Z[o]) − 2 · Σ_b 2^b Σ_j 2^j pop((A_b ⊕ C_j[o]) & Z[o])
/// ```
///
/// — `r_in · 4` popcount passes per output instead of `rows`
/// multiply-adds, i.e. cost proportional to the input bit-width,
/// mirroring the macro's input-serial accumulation. All quantities are
/// exact integers, so the result equals the scalar i32 kernel bit for
/// bit. A vector whose entries are not valid antipodal factors for
/// `r_in` (wrong parity or out of range) silently falls back to the
/// scalar kernel for that vector only.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i32(
    a: &[i32],
    w: &[i32],
    n_vec: usize,
    rows: usize,
    n_out: usize,
    workers: usize,
    r_in: Option<u32>,
) -> Vec<i32> {
    assert_eq!(a.len(), n_vec * rows);
    assert_eq!(w.len(), rows * n_out);
    let path = select_gemm(r_in, rows, n_out, n_vec, w);
    matmul_i32_path(path, a, w, n_vec, rows, n_out, workers, r_in)
}

/// Run the i32 gemm through one specific [`KernelPath`], or `None` if
/// that path cannot execute here (missing ISA, or `BitPlane` with
/// ineligible weights / no `r_in`). Benches and the equivalence tests
/// use this to pit paths against each other on identical inputs.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i32_with(
    path: KernelPath,
    a: &[i32],
    w: &[i32],
    n_vec: usize,
    rows: usize,
    n_out: usize,
    workers: usize,
    r_in: Option<u32>,
) -> Option<Vec<i32>> {
    assert_eq!(a.len(), n_vec * rows);
    assert_eq!(w.len(), rows * n_out);
    if !path_available(path) {
        return None;
    }
    if path == KernelPath::BitPlane {
        let r = r_in?;
        if !(1..=BITPLANE_RIN_LIMIT).contains(&r) || !weights_bitplane_eligible(w) {
            return None;
        }
    }
    Some(matmul_i32_path(path, a, w, n_vec, rows, n_out, workers, r_in))
}

/// [`matmul_i32`] writing into a caller-owned buffer (resized to
/// `n_vec · n_out`, capacity reused), optionally reusing a pre-packed
/// weight-side [`BitPlanes`] built at deploy time. The cached pack is
/// honoured only when the selector chose the bit-plane path *and* the
/// pack is keyed to this call's `r_in` — any mismatch falls back to
/// packing in-call, so a stale cache can degrade performance but never
/// change results. This is the steady-state entry point: with a warm
/// cache and warm [`super::arena`] pools it performs no allocations.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i32_packed_into(
    a: &[i32],
    w: &[i32],
    n_vec: usize,
    rows: usize,
    n_out: usize,
    workers: usize,
    r_in: Option<u32>,
    packed: Option<&BitPlanes>,
    out: &mut Vec<i32>,
) {
    assert_eq!(a.len(), n_vec * rows);
    assert_eq!(w.len(), rows * n_out);
    out.clear();
    out.resize(n_vec * n_out, 0);
    if n_vec == 0 || n_out == 0 {
        return;
    }
    let selected = select_gemm(r_in, rows, n_out, n_vec, w);
    let cached = packed.filter(|bp| selected == KernelPath::BitPlane && r_in == Some(bp.r_in));
    let (path, prep) = if cached.is_some() {
        (KernelPath::BitPlane, None)
    } else {
        prepare_gemm(selected, w, rows, n_out, n_vec, r_in)
    };
    let bp = cached.or_else(|| prep.as_ref());
    run_gemm_split(path, bp, a, w, n_vec, rows, n_out, workers, out);
}

#[allow(clippy::too_many_arguments)]
fn matmul_i32_path(
    path: KernelPath,
    a: &[i32],
    w: &[i32],
    n_vec: usize,
    rows: usize,
    n_out: usize,
    workers: usize,
    r_in: Option<u32>,
) -> Vec<i32> {
    let mut out = vec![0i32; n_vec * n_out];
    if n_vec == 0 || n_out == 0 {
        return out;
    }
    // Weight-side preparation is done once and shared by every worker
    // chunk, so bit-plane packing is amortized across the whole batch.
    let (path, prep) = prepare_gemm(path, w, rows, n_out, n_vec, r_in);
    let bp = prep.as_ref();
    run_gemm_split(path, bp, a, w, n_vec, rows, n_out, workers, &mut out);
    out
}

/// Split the batch dimension over scoped worker threads (fixed
/// `ceil(n_vec / workers)` chunk grid) and run the resolved kernel on
/// each chunk. i32 accumulation is exact, so the split is bit-neutral.
#[allow(clippy::too_many_arguments)]
fn run_gemm_split(
    path: KernelPath,
    bp: Option<&BitPlanes>,
    a: &[i32],
    w: &[i32],
    n_vec: usize,
    rows: usize,
    n_out: usize,
    workers: usize,
    out: &mut [i32],
) {
    let workers = workers.clamp(1, n_vec);
    if workers == 1 {
        run_gemm_chunk(path, bp, a, w, rows, n_out, out);
        return;
    }
    let chunk_vecs = n_vec.div_ceil(workers);
    std::thread::scope(|s| {
        for (a_chunk, out_chunk) in a
            .chunks(chunk_vecs * rows)
            .zip(out.chunks_mut(chunk_vecs * n_out))
        {
            s.spawn(move || run_gemm_chunk(path, bp, a_chunk, w, rows, n_out, out_chunk));
        }
    });
}

/// Resolve the weight-side state for `path`; demotes `BitPlane` to the
/// best SIMD tier if packing turns out impossible (defensive — the
/// selector already checked eligibility).
fn prepare_gemm(
    path: KernelPath,
    w: &[i32],
    rows: usize,
    n_out: usize,
    n_vec: usize,
    r_in: Option<u32>,
) -> (KernelPath, Option<BitPlanes>) {
    if path != KernelPath::BitPlane {
        return (path, None);
    }
    match r_in.and_then(|r| BitPlanes::pack(w, rows, n_out, r)) {
        Some(bp) => (KernelPath::BitPlane, Some(bp)),
        None => (select_gemm(None, rows, n_out, n_vec, w), None),
    }
}

fn run_gemm_chunk(
    path: KernelPath,
    bp: Option<&BitPlanes>,
    a: &[i32],
    w: &[i32],
    rows: usize,
    n_out: usize,
    out: &mut [i32],
) {
    match path {
        KernelPath::Scalar => gemm::matmul_i32_chunk(a, w, rows, n_out, out),
        KernelPath::Portable => portable_i32_chunk(a, w, rows, n_out, out),
        KernelPath::BitPlane => {
            bitplane_chunk(bp.expect("bit-plane prep missing"), a, w, rows, n_out, out)
        }
        #[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
        // SAFETY: `Avx2` is only selected (or accepted by
        // `path_available`) after `is_x86_feature_detected!("avx2")`.
        KernelPath::Avx2 => unsafe { x86::matmul_i32_chunk_avx2(a, w, rows, n_out, out) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: `Neon` is only selected after runtime NEON detection.
        KernelPath::Neon => unsafe { arm::matmul_i32_chunk_neon(a, w, rows, n_out, out) },
        #[cfg(not(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64"))))]
        KernelPath::Avx2 => portable_i32_chunk(a, w, rows, n_out, out),
        #[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
        KernelPath::Neon => portable_i32_chunk(a, w, rows, n_out, out),
    }
}

// ---------------------------------------------------------------------------
// Portable lane-blocked i32 kernel
// ---------------------------------------------------------------------------

/// Lane-blocked i32 gemm: 8-wide output tiles × 4 batch vectors, the
/// shape LLVM autovectorizes into full-width vector FMAs on any target
/// (and the exact shape the explicit AVX2 kernel hand-writes).
/// i32 addition is associative, so this is bit-identical to scalar.
fn portable_i32_chunk(a: &[i32], w: &[i32], rows: usize, n_out: usize, out: &mut [i32]) {
    let n_vec = a.len() / rows;
    let mut v = 0;
    while v + 4 <= n_vec {
        portable_i32_vecs::<4>(a, w, rows, n_out, v, out);
        v += 4;
    }
    while v < n_vec {
        portable_i32_vecs::<1>(a, w, rows, n_out, v, out);
        v += 1;
    }
}

fn portable_i32_vecs<const B: usize>(
    a: &[i32],
    w: &[i32],
    rows: usize,
    n_out: usize,
    v: usize,
    out: &mut [i32],
) {
    let mut oc = 0;
    while oc + I32_LANES <= n_out {
        let mut acc = [[0i32; I32_LANES]; B];
        for r in 0..rows {
            let wv: &[i32; I32_LANES] =
                w[r * n_out + oc..r * n_out + oc + I32_LANES].try_into().unwrap();
            for (b, acc_b) in acc.iter_mut().enumerate() {
                let s = a[(v + b) * rows + r];
                for (lane, &wl) in acc_b.iter_mut().zip(wv.iter()) {
                    *lane += s * wl;
                }
            }
        }
        for (b, acc_b) in acc.iter().enumerate() {
            out[(v + b) * n_out + oc..(v + b) * n_out + oc + I32_LANES].copy_from_slice(acc_b);
        }
        oc += I32_LANES;
    }
    // Output remainder (n_out % 8): plain scalar accumulation.
    for b in 0..B {
        for o in oc..n_out {
            let mut acc = 0i32;
            for r in 0..rows {
                acc += a[(v + b) * rows + r] * w[r * n_out + o];
            }
            out[(v + b) * n_out + o] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Explicit ISA kernels (feature = "simd")
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd", any(target_arch = "x86", target_arch = "x86_64")))]
mod x86 {
    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// AVX2 i32 gemm chunk: 8-lane `__m256i` output tiles × 4 batch
    /// vectors (4 accumulator registers per weight pass).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_i32_chunk_avx2(
        a: &[i32],
        w: &[i32],
        rows: usize,
        n_out: usize,
        out: &mut [i32],
    ) {
        let n_vec = a.len() / rows;
        let mut v = 0;
        while v + 4 <= n_vec {
            vecs_avx2::<4>(a, w, rows, n_out, v, out);
            v += 4;
        }
        while v < n_vec {
            vecs_avx2::<1>(a, w, rows, n_out, v, out);
            v += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available; only called from `matmul_i32_chunk_avx2`,
    /// whose caller has already verified the ISA at runtime.
    #[target_feature(enable = "avx2")]
    unsafe fn vecs_avx2<const B: usize>(
        a: &[i32],
        w: &[i32],
        rows: usize,
        n_out: usize,
        v: usize,
        out: &mut [i32],
    ) {
        let mut oc = 0;
        while oc + 8 <= n_out {
            let mut acc = [_mm256_setzero_si256(); B];
            for r in 0..rows {
                let wv = _mm256_loadu_si256(w.as_ptr().add(r * n_out + oc) as *const __m256i);
                for (b, acc_b) in acc.iter_mut().enumerate() {
                    let s = _mm256_set1_epi32(a[(v + b) * rows + r]);
                    *acc_b = _mm256_add_epi32(*acc_b, _mm256_mullo_epi32(s, wv));
                }
            }
            for (b, acc_b) in acc.iter().enumerate() {
                _mm256_storeu_si256(
                    out.as_mut_ptr().add((v + b) * n_out + oc) as *mut __m256i,
                    *acc_b,
                );
            }
            oc += 8;
        }
        for b in 0..B {
            for o in oc..n_out {
                let mut acc = 0i32;
                for r in 0..rows {
                    acc = acc.wrapping_add(a[(v + b) * rows + r].wrapping_mul(w[r * n_out + o]));
                }
                out[(v + b) * n_out + o] = acc;
            }
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod arm {
    use std::arch::aarch64::*;

    /// NEON i32 gemm chunk: two 4-lane `int32x4_t` tiles (8 outputs)
    /// × 4 batch vectors per weight pass.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn matmul_i32_chunk_neon(
        a: &[i32],
        w: &[i32],
        rows: usize,
        n_out: usize,
        out: &mut [i32],
    ) {
        let n_vec = a.len() / rows;
        let mut v = 0;
        while v + 4 <= n_vec {
            vecs_neon::<4>(a, w, rows, n_out, v, out);
            v += 4;
        }
        while v < n_vec {
            vecs_neon::<1>(a, w, rows, n_out, v, out);
            v += 1;
        }
    }

    /// # Safety
    /// NEON must be available; only called from `matmul_i32_chunk_neon`,
    /// whose caller has already verified the ISA at runtime.
    #[target_feature(enable = "neon")]
    unsafe fn vecs_neon<const B: usize>(
        a: &[i32],
        w: &[i32],
        rows: usize,
        n_out: usize,
        v: usize,
        out: &mut [i32],
    ) {
        let mut oc = 0;
        while oc + 8 <= n_out {
            let mut lo = [vdupq_n_s32(0); B];
            let mut hi = [vdupq_n_s32(0); B];
            for r in 0..rows {
                let wp = w.as_ptr().add(r * n_out + oc);
                let wlo = vld1q_s32(wp);
                let whi = vld1q_s32(wp.add(4));
                for b in 0..B {
                    let s = vdupq_n_s32(a[(v + b) * rows + r]);
                    lo[b] = vmlaq_s32(lo[b], s, wlo);
                    hi[b] = vmlaq_s32(hi[b], s, whi);
                }
            }
            for b in 0..B {
                let op = out.as_mut_ptr().add((v + b) * n_out + oc);
                vst1q_s32(op, lo[b]);
                vst1q_s32(op.add(4), hi[b]);
            }
            oc += 8;
        }
        for b in 0..B {
            for o in oc..n_out {
                let mut acc = 0i32;
                for r in 0..rows {
                    acc = acc.wrapping_add(a[(v + b) * rows + r].wrapping_mul(w[r * n_out + o]));
                }
                out[(v + b) * n_out + o] = acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-plane engine
// ---------------------------------------------------------------------------

/// Packed weight bit-planes for one `[rows × n_out]` weight matrix:
/// per output, four `u64` mask arrays (one per weight bit of
/// `k = (w+15)/2`) plus a validity mask `Z` that excludes zero-weight
/// padding rows and the unused tail of the last word.
///
/// The pack is a pure function of `(w, rows, n_out, r_in)`, so a copy
/// built once at deploy time ([`super::packed::PackedWeights`]) and
/// handed back through [`matmul_i32_packed_into`] is indistinguishable
/// from an in-call pack — the weight-stationary reuse the macro gets
/// for free in silicon.
#[derive(Clone, Debug)]
pub struct BitPlanes {
    r_in: u32,
    words: usize,
    /// `[n_out × W_PLANES × words]`, plane-major per output.
    planes: Vec<u64>,
    /// `[n_out × words]` validity masks.
    zmask: Vec<u64>,
    /// `pop(Z[o])` per output.
    zpop: Vec<i32>,
}

impl BitPlanes {
    /// Pack a weight matrix, or `None` if `r_in` is out of range or any
    /// weight is not an antipodal level / zero.
    pub fn pack(w: &[i32], rows: usize, n_out: usize, r_in: u32) -> Option<Self> {
        if !(1..=BITPLANE_RIN_LIMIT).contains(&r_in) || !weights_bitplane_eligible(w) {
            return None;
        }
        let words = rows.div_ceil(64);
        let mut planes = vec![0u64; n_out * W_PLANES * words];
        let mut zmask = vec![0u64; n_out * words];
        for r in 0..rows {
            let (wd, bit) = (r / 64, 1u64 << (r % 64));
            for (o, &v) in w[r * n_out..(r + 1) * n_out].iter().enumerate() {
                if v == 0 {
                    continue;
                }
                zmask[o * words + wd] |= bit;
                let k = ((v + W_LEVEL_MAX) / 2) as u64;
                let base = (o * W_PLANES) * words + wd;
                for j in 0..W_PLANES {
                    if (k >> j) & 1 == 1 {
                        planes[base + j * words] |= bit;
                    }
                }
            }
        }
        let zpop = zmask
            .chunks(words.max(1))
            .map(|zs| zs.iter().map(|z| z.count_ones() as i32).sum())
            .collect();
        Some(Self { r_in, words, planes, zmask, zpop })
    }
}

/// Pack one vector of antipodal factors into `r_in` bit-plane masks.
/// Returns `false` (leaving `planes` partially filled) if any entry is
/// not a valid factor `2q − M` with `q ∈ [0, M]` — the caller then
/// falls back to the scalar kernel for that vector.
fn pack_input_planes(sx: &[i32], r_in: u32, words: usize, planes: &mut [u64]) -> bool {
    let m = (1i32 << r_in) - 1;
    for (r, &s) in sx.iter().enumerate() {
        let q2 = s + m; // = 2q for a valid antipodal factor
        if q2 < 0 || q2 > 2 * m || (q2 & 1) != 0 {
            return false;
        }
        let q = (q2 >> 1) as u64;
        let (wd, bit) = (r / 64, 1u64 << (r % 64));
        for (b, plane) in planes.chunks_exact_mut(words).enumerate() {
            if (q >> b) & 1 == 1 {
                plane[wd] |= bit;
            }
        }
    }
    true
}

fn bitplane_chunk(
    bp: &BitPlanes,
    a: &[i32],
    w: &[i32],
    rows: usize,
    n_out: usize,
    out: &mut [i32],
) {
    if rows == 0 {
        return;
    }
    let words = bp.words;
    let r_bits = bp.r_in as usize;
    let base = W_LEVEL_MAX * ((1i32 << bp.r_in) - 1); // 15 · M
    let mut a_planes = super::arena::take_u64(r_bits * words);
    a_planes.resize(r_bits * words, 0);
    for (sx, bo) in a.chunks_exact(rows).zip(out.chunks_exact_mut(n_out)) {
        a_planes.iter_mut().for_each(|p| *p = 0);
        if !pack_input_planes(sx, bp.r_in, words, &mut a_planes) {
            // Not antipodal factors for this r_in — scalar fallback for
            // this vector only (bo is still all zeros; the scalar chunk
            // accumulates into it).
            gemm::matmul_i32_chunk(sx, w, rows, n_out, bo);
            continue;
        }
        for (o, slot) in bo.iter_mut().enumerate() {
            let z = &bp.zmask[o * words..(o + 1) * words];
            let mut weighted = 0i32;
            for (b, ab) in a_planes.chunks_exact(words).enumerate() {
                let mut per_bit = 0i32;
                for j in 0..W_PLANES {
                    let cj = &bp.planes[(o * W_PLANES + j) * words..(o * W_PLANES + j + 1) * words];
                    let mut pc = 0u32;
                    for ((aw, cw), zw) in ab.iter().zip(cj.iter()).zip(z.iter()) {
                        pc += ((aw ^ cw) & zw).count_ones();
                    }
                    per_bit += (pc as i32) << j;
                }
                weighted += per_bit << b;
            }
            // dot = 15·M·pop(Z) − 2·Σ_b 2^b Σ_j 2^j pop((A_b ⊕ C_j) & Z)
            *slot = base * bp.zpop[o] - 2 * weighted;
        }
    }
    super::arena::put_u64(a_planes);
}

// ---------------------------------------------------------------------------
// Direct (streaming) conv3x3
// ---------------------------------------------------------------------------

/// Drop-in for [`gemm::conv3x3_batch`] that never materializes the
/// whole-batch `[(img·oh·ow) × rows]` im2col buffer: each worker
/// re-assembles one image's signed rows into a scratch buffer
/// ([`gemm::conv3x3_signed_rows_into`]) and runs the selected kernel on
/// it, so peak extra memory is `workers × oh·ow·rows` i32 instead of
/// `n_img × oh·ow·rows`. Weight-side preparation (bit-plane packing) is
/// still done once for the whole batch. Bit-identical to
/// `conv3x3_batch` by the kernel equivalence contract.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_direct(
    images_q: &[Vec<u8>],
    c: usize,
    h: usize,
    w: usize,
    stride: usize,
    r_in: u32,
    w_phys: &[i32],
    rows: usize,
    n_out: usize,
    workers: usize,
) -> (Vec<i32>, usize, usize) {
    if images_q.is_empty() {
        assert_eq!(w_phys.len(), rows * n_out);
        return (Vec::new(), 0, 0);
    }
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let mut out = vec![0i32; images_q.len() * oh * ow * n_out];
    let view = NestedImages(images_q);
    let dims = conv3x3_direct_core(
        &view,
        c,
        h,
        w,
        stride,
        r_in,
        w_phys,
        rows,
        n_out,
        workers,
        None,
        &mut out,
    );
    debug_assert_eq!(dims, (oh, ow));
    (out, oh, ow)
}

/// [`conv3x3_direct`] over a flat `[n_img × c·h·w]` image buffer,
/// writing into a caller-owned dot buffer and honouring a deploy-time
/// weight pack — the zero-allocation steady-state form used by the
/// chunk-pipelined engine. Same bit-identity contract as
/// `conv3x3_direct` (the flat layout only changes how an image slice is
/// addressed, not any arithmetic).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_direct_packed_into(
    images_q: &[u8],
    n_img: usize,
    c: usize,
    h: usize,
    w: usize,
    stride: usize,
    r_in: u32,
    w_phys: &[i32],
    rows: usize,
    n_out: usize,
    workers: usize,
    packed: Option<&BitPlanes>,
    out: &mut Vec<i32>,
) -> (usize, usize) {
    assert_eq!(images_q.len(), n_img * c * h * w);
    if n_img == 0 {
        assert_eq!(w_phys.len(), rows * n_out);
        out.clear();
        return (0, 0);
    }
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    out.clear();
    out.resize(n_img * oh * ow * n_out, 0);
    let view = FlatImages { data: images_q, img_len: c * h * w };
    let dims = conv3x3_direct_core(
        &view,
        c,
        h,
        w,
        stride,
        r_in,
        w_phys,
        rows,
        n_out,
        workers,
        packed,
        out,
    );
    debug_assert_eq!(dims, (oh, ow));
    (oh, ow)
}

/// Indexed read-only access to a batch of quantized images — lets the
/// direct-conv core run identically over the historical per-image
/// `Vec<Vec<u8>>` layout and the engine's flat arena buffer.
trait ImageView: Sync {
    fn n_img(&self) -> usize;
    fn img(&self, i: usize) -> &[u8];
}

struct NestedImages<'a>(&'a [Vec<u8>]);

impl ImageView for NestedImages<'_> {
    fn n_img(&self) -> usize {
        self.0.len()
    }
    fn img(&self, i: usize) -> &[u8] {
        &self.0[i]
    }
}

struct FlatImages<'a> {
    data: &'a [u8],
    img_len: usize,
}

impl ImageView for FlatImages<'_> {
    fn n_img(&self) -> usize {
        self.data.len() / self.img_len.max(1)
    }
    fn img(&self, i: usize) -> &[u8] {
        &self.data[i * self.img_len..(i + 1) * self.img_len]
    }
}

#[allow(clippy::too_many_arguments)]
fn conv3x3_direct_core<V: ImageView>(
    images: &V,
    c: usize,
    h: usize,
    w: usize,
    stride: usize,
    r_in: u32,
    w_phys: &[i32],
    rows: usize,
    n_out: usize,
    workers: usize,
    packed: Option<&BitPlanes>,
    out: &mut [i32],
) -> (usize, usize) {
    assert_eq!(w_phys.len(), rows * n_out);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let n_pix = oh * ow;
    let n_img = images.n_img();
    if n_out == 0 || n_pix == 0 || n_img == 0 {
        return (oh, ow);
    }
    let selected = select_gemm(Some(r_in), rows, n_out, n_img * n_pix, w_phys);
    let cached = packed.filter(|bp| selected == KernelPath::BitPlane && bp.r_in == r_in);
    let (path, prep) = if cached.is_some() {
        (KernelPath::BitPlane, None)
    } else {
        prepare_gemm(selected, w_phys, rows, n_out, n_img * n_pix, Some(r_in))
    };
    let bp = cached.or_else(|| prep.as_ref());
    let run_images = |first: usize, count: usize, out_chunk: &mut [i32]| {
        let mut sx = super::arena::take_i32(n_pix * rows);
        for i in 0..count {
            sx.clear();
            let img = images.img(first + i);
            let dims = gemm::conv3x3_signed_rows_into(img, c, h, w, stride, r_in, rows, &mut sx);
            debug_assert_eq!(dims, (oh, ow));
            run_gemm_chunk(
                path,
                bp,
                &sx,
                w_phys,
                rows,
                n_out,
                &mut out_chunk[i * n_pix * n_out..(i + 1) * n_pix * n_out],
            );
        }
        super::arena::put_i32(sx);
    };
    let workers = workers.clamp(1, n_img);
    if workers == 1 {
        run_images(0, n_img, out);
        return (oh, ow);
    }
    let chunk_imgs = n_img.div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(chunk_imgs * n_pix * n_out).enumerate() {
            let first = ci * chunk_imgs;
            let count = chunk_imgs.min(n_img - first);
            let run_images = &run_images;
            s.spawn(move || run_images(first, count, out_chunk));
        }
    });
    (oh, ow)
}

// ---------------------------------------------------------------------------
// f64 rowdot (order-preserving lanes)
// ---------------------------------------------------------------------------

/// Drop-in for [`gemm::rowdot_f64`] with a lane-blocked fast path:
/// weights are transposed into `[k × 4]` tiles and each of 4 lanes owns
/// one *output*, accumulating ascending-`k` — the identical
/// floating-point operation sequence as the scalar loop per output, so
/// results are bit-identical (float addition is never re-associated;
/// the lanes merely run four independent scalar recurrences side by
/// side, which is also why it beats the scalar kernel: the serial
/// add-latency chain stops being the bottleneck).
pub fn rowdot_f64(
    x: &[f64],
    w: &[f64],
    n_vec: usize,
    k_dim: usize,
    n_out: usize,
    workers: usize,
) -> Vec<f64> {
    assert_eq!(x.len(), n_vec * k_dim);
    assert_eq!(w.len(), n_out * k_dim);
    match select_rowdot(n_vec, k_dim, n_out) {
        KernelPath::Scalar => gemm::rowdot_f64(x, w, n_vec, k_dim, n_out, workers),
        _ => rowdot_lanes(x, w, n_vec, k_dim, n_out, workers),
    }
}

/// Run the f64 rowdot through one specific path (`Scalar` or
/// `Portable`); `None` for paths that have no f64 kernel.
pub fn rowdot_f64_with(
    path: KernelPath,
    x: &[f64],
    w: &[f64],
    n_vec: usize,
    k_dim: usize,
    n_out: usize,
    workers: usize,
) -> Option<Vec<f64>> {
    assert_eq!(x.len(), n_vec * k_dim);
    assert_eq!(w.len(), n_out * k_dim);
    match path {
        KernelPath::Scalar => Some(gemm::rowdot_f64(x, w, n_vec, k_dim, n_out, workers)),
        KernelPath::Portable => {
            if n_vec == 0 || n_out == 0 {
                return Some(vec![0f64; n_vec * n_out]);
            }
            Some(rowdot_lanes(x, w, n_vec, k_dim, n_out, workers))
        }
        _ => None,
    }
}

fn select_rowdot(n_vec: usize, k_dim: usize, n_out: usize) -> KernelPath {
    if n_vec > 0 && n_out >= F64_LANES && k_dim >= 4 {
        KernelPath::Portable
    } else {
        KernelPath::Scalar
    }
}

fn rowdot_lanes(
    x: &[f64],
    w: &[f64],
    n_vec: usize,
    k_dim: usize,
    n_out: usize,
    workers: usize,
) -> Vec<f64> {
    // Transpose whole output tiles once: wt[t][k][lane] = w[t·4+lane][k].
    let n_tiles = n_out / F64_LANES;
    let mut wt = vec![0f64; n_tiles * k_dim * F64_LANES];
    for t in 0..n_tiles {
        let tile = &mut wt[t * k_dim * F64_LANES..(t + 1) * k_dim * F64_LANES];
        for l in 0..F64_LANES {
            let wo = &w[(t * F64_LANES + l) * k_dim..(t * F64_LANES + l + 1) * k_dim];
            for (k, &wv) in wo.iter().enumerate() {
                tile[k * F64_LANES + l] = wv;
            }
        }
    }
    let mut out = vec![0f64; n_vec * n_out];
    let workers = workers.clamp(1, n_vec);
    let chunk_vecs = n_vec.div_ceil(workers);
    if workers == 1 {
        rowdot_lanes_chunk(x, w, &wt, k_dim, n_out, &mut out);
        return out;
    }
    let wt_ref = &wt;
    std::thread::scope(|s| {
        for (x_chunk, out_chunk) in x
            .chunks(chunk_vecs * k_dim)
            .zip(out.chunks_mut(chunk_vecs * n_out))
        {
            s.spawn(move || rowdot_lanes_chunk(x_chunk, w, wt_ref, k_dim, n_out, out_chunk));
        }
    });
    out
}

fn rowdot_lanes_chunk(
    x: &[f64],
    w: &[f64],
    wt: &[f64],
    k_dim: usize,
    n_out: usize,
    out: &mut [f64],
) {
    let n_vec = x.len() / k_dim;
    let n_tiles = n_out / F64_LANES;
    for v in 0..n_vec {
        let xv = &x[v * k_dim..(v + 1) * k_dim];
        let bo = &mut out[v * n_out..(v + 1) * n_out];
        for t in 0..n_tiles {
            let tile = &wt[t * k_dim * F64_LANES..(t + 1) * k_dim * F64_LANES];
            let mut acc = [0f64; F64_LANES];
            for (xk, wk) in xv.iter().zip(tile.chunks_exact(F64_LANES)) {
                for (lane, &wv) in acc.iter_mut().zip(wk.iter()) {
                    *lane += *xk * wv;
                }
            }
            bo[t * F64_LANES..(t + 1) * F64_LANES].copy_from_slice(&acc);
        }
        // Output remainder: the plain scalar ascending-k loop.
        for (o, slot) in bo.iter_mut().enumerate().skip(n_tiles * F64_LANES) {
            let wo = &w[o * k_dim..(o + 1) * k_dim];
            let mut dot = 0f64;
            for (xk, wv) in xv.iter().zip(wo.iter()) {
                dot += xk * wv;
            }
            *slot = dot;
        }
    }
}

// ---------------------------------------------------------------------------
// Exact-integer fast path helpers (trainer / graph forward)
// ---------------------------------------------------------------------------

/// Convert a quantized weight matrix stored one row per *output*
/// (`[n_out × k_dim]` f32, the training layout) into the kernel's
/// row-major `[k_dim × n_out]` i32 layout. Returns `None` if any weight
/// is non-integral or implausibly large — the caller then keeps the f64
/// rowdot path. Also returns `max |w|` for the overflow bound.
pub fn quantized_rowmajor_i32(w_q: &[f32], n_out: usize, k_dim: usize) -> Option<(Vec<i32>, i32)> {
    assert_eq!(w_q.len(), n_out * k_dim);
    let mut wi = vec![0i32; k_dim * n_out];
    let mut wmax = 0i32;
    for (o, row) in w_q.chunks_exact(k_dim.max(1)).enumerate() {
        for (k, &v) in row.iter().enumerate() {
            if v != v.trunc() || v.abs() > 1_048_576.0 {
                return None;
            }
            let vi = v as i32;
            wmax = wmax.max(vi.abs());
            wi[k * n_out + o] = vi;
        }
    }
    Some((wi, wmax))
}

/// Whether integer dots for this shape are exact in both i32 and f64:
/// `k_dim · (2^r_in − 1) · max|w| ≤ i32::MAX` bounds every partial sum,
/// and anything below 2³¹ is trivially exact in f64 — so computing the
/// dots through the i32 kernels and casting is bit-identical to the
/// f64 rowdot on the same integers.
pub fn quantized_dot_fits_i32(k_dim: usize, r_in: u32, w_abs_max: i32) -> bool {
    r_in <= 16 && (k_dim as i64) * ((1i64 << r_in) - 1) * (w_abs_max as i64) <= i32::MAX as i64
}

// ---------------------------------------------------------------------------
// Deterministic scoped-thread chunk map
// ---------------------------------------------------------------------------

/// Split `0..n` into fixed-size `chunk` ranges and map `f` over them on
/// scoped worker threads, returning the per-chunk results **in chunk
/// order**. The chunk grid depends only on `(n, chunk)` — never on
/// `workers` — so any reduction over the returned Vec is bit-identical
/// across worker counts. This is the helper the trainer's parallel
/// backward pass uses to keep float gradient accumulation
/// deterministic.
pub fn scoped_chunk_map<T, F>(n: usize, chunk: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    let ranges: Vec<std::ops::Range<usize>> =
        (0..n_chunks).map(|i| i * chunk..((i + 1) * chunk).min(n)).collect();
    let workers = workers.clamp(1, n_chunks);
    if workers == 1 {
        return ranges.into_iter().enumerate().map(|(i, r)| f(i, r)).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n_chunks).map(|_| None).collect();
    let stride = n_chunks.div_ceil(workers);
    let (ranges_ref, f_ref) = (&ranges, &f);
    std::thread::scope(|s| {
        for (wi, slot_chunk) in slots.chunks_mut(stride).enumerate() {
            s.spawn(move || {
                for (j, slot) in slot_chunk.iter_mut().enumerate() {
                    let idx = wi * stride + j;
                    *slot = Some(f_ref(idx, ranges_ref[idx].clone()));
                }
            });
        }
    });
    slots.into_iter().map(|o| o.expect("chunk result missing")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitplane_hand_example() {
        // r_in = 1 (M = 1), rows = 2, w = [3, −5], factors s = [+1, −1]
        // (q = [1, 0]): dot = 1·3 + (−1)·(−5) = 8.
        let w = vec![3i32, -5];
        let a = vec![1i32, -1];
        let got = matmul_i32_with(KernelPath::BitPlane, &a, &w, 1, 2, 1, 1, Some(1)).unwrap();
        assert_eq!(got, vec![8]);
    }

    #[test]
    fn bitplane_rejects_even_weights_and_missing_rin() {
        let w = vec![2i32, 3]; // 2 is not an odd antipodal level
        let a = vec![1i32, -1];
        assert!(matmul_i32_with(KernelPath::BitPlane, &a, &w, 1, 2, 1, 1, Some(1)).is_none());
        let w_ok = vec![3i32, -5];
        assert!(matmul_i32_with(KernelPath::BitPlane, &a, &w_ok, 1, 2, 1, 1, None).is_none());
    }

    #[test]
    fn selection_table_with_injected_caps() {
        let none = Caps::default();
        let avx = Caps { avx2: true, neon: false };
        let w_ok = vec![1i32; 64 * 16];
        let w_bad = vec![2i32; 64 * 16];
        // Bit-plane tier: low r_in + eligible weights + big enough call.
        assert_eq!(select_gemm_with(none, Some(1), 64, 16, 8, &w_ok), KernelPath::BitPlane);
        assert_eq!(select_gemm_with(avx, Some(2), 64, 16, 8, &w_ok), KernelPath::BitPlane);
        // Ineligible weights or high precision → SIMD tier.
        assert_eq!(select_gemm_with(none, Some(1), 64, 16, 8, &w_bad), KernelPath::Portable);
        assert_eq!(select_gemm_with(avx, Some(8), 64, 16, 8, &w_ok), KernelPath::Avx2);
        // Too-small calls stay scalar / skip bit-plane.
        assert_eq!(select_gemm_with(none, Some(1), 64, 4, 8, &w_ok[..64 * 4]), KernelPath::Scalar);
        assert_eq!(select_gemm_with(none, Some(1), 64, 16, 2, &w_ok), KernelPath::Portable);
        assert_eq!(select_gemm_with(none, None, 64, 16, 8, &w_ok), KernelPath::Portable);
    }

    #[test]
    fn scoped_chunk_map_is_worker_invariant() {
        let f = |i: usize, r: std::ops::Range<usize>| (i, r.start, r.end);
        let one = scoped_chunk_map(23, 8, 1, f);
        for workers in [2usize, 3, 7, 16] {
            assert_eq!(scoped_chunk_map(23, 8, workers, f), one);
        }
        assert_eq!(one, vec![(0, 0, 8), (1, 8, 16), (2, 16, 23)]);
        assert!(scoped_chunk_map(0, 8, 4, f).is_empty());
    }

    #[test]
    fn quantized_rowmajor_rejects_non_integral() {
        assert!(quantized_rowmajor_i32(&[1.0, -3.0, 0.5, 2.0], 2, 2).is_none());
        let (wi, wmax) = quantized_rowmajor_i32(&[1.0, -3.0, 15.0, 0.0], 2, 2).unwrap();
        // [n_out × k] row-per-output → row-major [k × n_out].
        assert_eq!(wi, vec![1, 15, -3, 0]);
        assert_eq!(wmax, 15);
        assert!(quantized_dot_fits_i32(1152, 8, 15));
        assert!(!quantized_dot_fits_i32(1 << 20, 16, 1 << 16));
    }
}
