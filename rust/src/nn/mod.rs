//! Rust-native NN stack: dataset loading, MLP training and CIM-mapped
//! post-training evaluation (the Fig. 3b study).

pub mod cim_eval;
pub mod dataset;
pub mod mlp;
