//! Rust-native NN stack: dataset loading, MLP training, the layer-graph
//! IR for CNNs and the CIM-mapped post-training evaluation (the Fig. 3b
//! study generalized to the paper's conv workloads).
//!
//! * [`mlp`] — float MLP training (SGD/Adam, no BLAS);
//! * [`layers`] — typed graph nodes (`Conv3x3`, `Dense`, `Pool2x2`,
//!   `Relu`, `Flatten`) with per-layer CIM mapping overrides;
//! * [`graph`] — the layer-graph IR: calibration/quantization to the
//!   macro contract, the batched graph executor (conv lowered through
//!   the §IV streaming im2col into whole-batch gemm kernels), and
//!   lowering to a physical [`NetworkModel`](crate::coordinator::manifest::NetworkModel)
//!   for the `Session` backends;
//! * [`cim_eval`] — the Fig. 3(b) sweep, now the Dense-only graph
//!   special case;
//! * [`train`] — CIM-aware training: STE gradients through the macro's
//!   quantizers with the post-silicon equivalent noise injected into
//!   every forward (the paper's distribution-aware training loop);
//! * [`dataset`] — IMGT dataset loading with CHW validation and the
//!   deterministic synthetic task generator the trainer smoke-tests on;
//! * [`autotune`] — the per-layer `(r_in, r_out)` precision search:
//!   modeled-energy minimization under an accuracy floor, with accuracy
//!   measured at each point's probed equivalent noise.

pub mod autotune;
pub mod cim_eval;
pub mod dataset;
pub mod graph;
pub mod layers;
pub mod mlp;
pub mod train;
