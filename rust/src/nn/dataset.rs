//! Dataset loading: the synthetic test sets exported by the compile path
//! (`artifacts/digits_test.imgt`, `textures_test.imgt`).

use crate::util::tensorfile::TensorFile;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// An image classification dataset in CHW float form.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Flattened images, `n × (c*h*w)`.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub shape: Vec<usize>, // per-image shape (e.g. [28,28] or [3,32,32])
}

impl Dataset {
    pub fn load_imgt(path: impl AsRef<Path>) -> Result<Dataset> {
        let tf = TensorFile::load(path.as_ref())
            .with_context(|| format!("loading dataset {:?}", path.as_ref()))?;
        let xt = tf.req("x")?;
        let yt = tf.req("y")?;
        let n = xt.dims[0];
        if yt.len() != n {
            bail!("x/y count mismatch: {} vs {}", n, yt.len());
        }
        let shape = xt.dims[1..].to_vec();
        let x = xt.to_f32();
        let y = match &yt.data {
            crate::util::tensorfile::TensorData::I32(v) => v.clone(),
            other => bail!("labels must be i32, got {other:?}"),
        };
        Ok(Dataset { x, y, n, shape })
    }

    pub fn image_len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let len = self.image_len();
        &self.x[i * len..(i + 1) * len]
    }

    /// Flattened image (for MLP input).
    pub fn flat(&self, i: usize) -> &[f32] {
        self.image(i)
    }

    /// Image padded to `c_target` channels (zero fill) in CHW order —
    /// mirrors python `model.pad_input_channels`.
    pub fn image_padded(&self, i: usize, c_target: usize) -> Vec<f32> {
        let img = self.image(i);
        let (c, hw) = match self.shape.len() {
            2 => (1usize, self.shape[0] * self.shape[1]),
            3 => (self.shape[0], self.shape[1] * self.shape[2]),
            _ => (1, img.len()),
        };
        let mut out = vec![0f32; c_target * hw];
        out[..c * hw].copy_from_slice(img);
        out
    }

    /// Spatial dims (h, w).
    pub fn hw(&self) -> (usize, usize) {
        match self.shape.len() {
            2 => (self.shape[0], self.shape[1]),
            3 => (self.shape[1], self.shape[2]),
            _ => (1, self.image_len()),
        }
    }

    /// Take the first `k` samples (cheap view-copy).
    pub fn take(&self, k: usize) -> Dataset {
        let k = k.min(self.n);
        Dataset {
            x: self.x[..k * self.image_len()].to_vec(),
            y: self.y[..k].to_vec(),
            n: k,
            shape: self.shape.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensorfile::{Tensor, TensorData, TensorFile};

    fn fake_dataset(n: usize) -> Dataset {
        let mut tf = TensorFile::new();
        tf.push(Tensor {
            name: "x".into(),
            dims: vec![n, 2, 3, 3],
            data: TensorData::F32((0..n * 18).map(|i| i as f32).collect()),
        });
        tf.push(Tensor {
            name: "y".into(),
            dims: vec![n],
            data: TensorData::I32((0..n as i32).collect()),
        });
        let dir = std::env::temp_dir().join("imagine_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("ds{n}.imgt"));
        tf.save(&path).unwrap();
        Dataset::load_imgt(&path).unwrap()
    }

    #[test]
    fn roundtrip_and_access() {
        let ds = fake_dataset(4);
        assert_eq!(ds.n, 4);
        assert_eq!(ds.image_len(), 18);
        assert_eq!(ds.image(1)[0], 18.0);
        assert_eq!(ds.y[2], 2);
        assert_eq!(ds.hw(), (3, 3));
    }

    #[test]
    fn channel_padding() {
        let ds = fake_dataset(2);
        let p = ds.image_padded(0, 4);
        assert_eq!(p.len(), 4 * 9);
        assert_eq!(&p[..18], ds.image(0));
        assert!(p[18..].iter().skip(18 - 18).all(|_| true));
        assert!(p[2 * 9..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn take_subsets() {
        let ds = fake_dataset(5);
        let t = ds.take(2);
        assert_eq!(t.n, 2);
        assert_eq!(t.image(1), ds.image(1));
    }
}
