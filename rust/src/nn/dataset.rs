//! Dataset loading: the synthetic test sets exported by the compile path
//! (`artifacts/digits_test.imgt`, `textures_test.imgt`).

use crate::util::tensorfile::TensorFile;
use anyhow::{bail, Context, Result};
use std::fmt;
use std::path::Path;

/// Typed shape-validation failures of a loaded dataset. These cross the
/// loader boundary inside an `anyhow` chain but stay matchable for
/// callers that want to distinguish a malformed file from a missing one.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DatasetError {
    /// The flattened tensor length disagrees with `n × Π(shape)`.
    ShapeMismatch {
        n: usize,
        shape: Vec<usize>,
        len: usize,
    },
    /// Per-image rank must be 2 (`[h, w]`) or 3 (`[c, h, w]`).
    BadRank { dims: Vec<usize> },
    /// A CHW view was requested of a non-image (flat) dataset.
    NotImage { shape: Vec<usize> },
}

impl fmt::Display for DatasetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DatasetError::ShapeMismatch { n, shape, len } => write!(
                f,
                "dataset length {len} != {n} images x per-image shape {shape:?}"
            ),
            DatasetError::BadRank { dims } => write!(
                f,
                "dataset tensor dims {dims:?}: per-image rank must be 2 ([h,w]) or 3 ([c,h,w])"
            ),
            DatasetError::NotImage { shape } => {
                write!(f, "per-image shape {shape:?} has no CHW view")
            }
        }
    }
}

impl std::error::Error for DatasetError {}

/// CHW consistency checks applied to every loaded dataset: per-image
/// rank must be 2/3 and the flattened length must equal `n × Π(shape)`
/// (defense in depth over the tensor container's own dims check).
fn validate_images(
    n: usize,
    shape: &[usize],
    dims: &[usize],
    len: usize,
) -> Result<(), DatasetError> {
    if !matches!(shape.len(), 2 | 3) {
        return Err(DatasetError::BadRank { dims: dims.to_vec() });
    }
    let per_image: usize = shape.iter().product();
    if len != n * per_image {
        return Err(DatasetError::ShapeMismatch { n, shape: shape.to_vec(), len });
    }
    Ok(())
}

/// An image classification dataset in CHW float form.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Flattened images, `n × (c*h*w)`.
    pub x: Vec<f32>,
    /// Class labels, one per image.
    pub y: Vec<i32>,
    /// Number of images.
    pub n: usize,
    /// Per-image shape (e.g. `[28, 28]` or `[3, 32, 32]`).
    pub shape: Vec<usize>,
}

impl Dataset {
    /// Load a dataset from an `.imgt` tensorfile (`x` float images,
    /// `y` i32 labels), validating the CHW shape.
    pub fn load_imgt(path: impl AsRef<Path>) -> Result<Dataset> {
        let tf = TensorFile::load(path.as_ref())
            .with_context(|| format!("loading dataset {:?}", path.as_ref()))?;
        let xt = tf.req("x")?;
        let yt = tf.req("y")?;
        let n = xt.dims[0];
        if yt.len() != n {
            bail!("x/y count mismatch: {} vs {}", n, yt.len());
        }
        let shape = xt.dims[1..].to_vec();
        let x = xt.to_f32();
        validate_images(n, &shape, &xt.dims, x.len())?;
        let y = match &yt.data {
            crate::util::tensorfile::TensorData::I32(v) => v.clone(),
            other => bail!("labels must be i32, got {other:?}"),
        };
        Ok(Dataset { x, y, n, shape })
    }

    /// CHW view of the per-image shape (`[h, w]` reads as one channel) —
    /// the accessor the conv path builds its graph input shape from.
    pub fn chw(&self) -> Result<(usize, usize, usize), DatasetError> {
        match self.shape.as_slice() {
            [h, w] => Ok((1, *h, *w)),
            [c, h, w] => Ok((*c, *h, *w)),
            other => Err(DatasetError::NotImage { shape: other.to_vec() }),
        }
    }

    /// Flattened length of one image (the product of `shape`).
    pub fn image_len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Image `i` as a flat CHW slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let len = self.image_len();
        &self.x[i * len..(i + 1) * len]
    }

    /// Flattened image (for MLP input).
    pub fn flat(&self, i: usize) -> &[f32] {
        self.image(i)
    }

    /// Image padded to `c_target` channels (zero fill) in CHW order —
    /// mirrors python `model.pad_input_channels`.
    pub fn image_padded(&self, i: usize, c_target: usize) -> Vec<f32> {
        let img = self.image(i);
        let (c, hw) = match self.shape.len() {
            2 => (1usize, self.shape[0] * self.shape[1]),
            3 => (self.shape[0], self.shape[1] * self.shape[2]),
            _ => (1, img.len()),
        };
        let mut out = vec![0f32; c_target * hw];
        out[..c * hw].copy_from_slice(img);
        out
    }

    /// Spatial dims (h, w).
    pub fn hw(&self) -> (usize, usize) {
        match self.shape.len() {
            2 => (self.shape[0], self.shape[1]),
            3 => (self.shape[1], self.shape[2]),
            _ => (1, self.image_len()),
        }
    }

    /// Deterministic synthetic classification task: `n_classes` fixed
    /// random templates (drawn from `task_seed` alone, so train and test
    /// splits built with different `draw_seed`s share one task), each
    /// sample a template plus `jitter`-σ Gaussian pixel noise, clamped
    /// to `[0, 1]`. Labels cycle `0..n_classes` (balanced). This is what
    /// `imagine train --data synthetic`, the training examples and the
    /// convergence smoke tests run on — no artifacts required.
    pub fn synthetic(
        n: usize,
        shape: Vec<usize>,
        n_classes: usize,
        task_seed: u64,
        draw_seed: u64,
        jitter: f64,
    ) -> Dataset {
        assert!(n_classes >= 2, "need at least two classes");
        let len: usize = shape.iter().product();
        let mut trng = crate::util::rng::Rng::new(task_seed ^ 0x7A5C_7A5C_7A5C_7A5C);
        let templates: Vec<f32> = (0..n_classes * len)
            .map(|_| trng.uniform_range(0.1, 0.9) as f32)
            .collect();
        let mut rng = crate::util::rng::Rng::new(draw_seed);
        let mut x = Vec::with_capacity(n * len);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % n_classes;
            for j in 0..len {
                let v = templates[class * len + j] as f64 + rng.normal(0.0, jitter);
                x.push(v.clamp(0.0, 1.0) as f32);
            }
            y.push(class as i32);
        }
        Dataset { x, y, n, shape }
    }

    /// Take the first `k` samples (cheap view-copy).
    pub fn take(&self, k: usize) -> Dataset {
        let k = k.min(self.n);
        Dataset {
            x: self.x[..k * self.image_len()].to_vec(),
            y: self.y[..k].to_vec(),
            n: k,
            shape: self.shape.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensorfile::{Tensor, TensorData, TensorFile};

    fn fake_dataset(n: usize) -> Dataset {
        let mut tf = TensorFile::new();
        tf.push(Tensor {
            name: "x".into(),
            dims: vec![n, 2, 3, 3],
            data: TensorData::F32((0..n * 18).map(|i| i as f32).collect()),
        });
        tf.push(Tensor {
            name: "y".into(),
            dims: vec![n],
            data: TensorData::I32((0..n as i32).collect()),
        });
        let dir = std::env::temp_dir().join("imagine_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("ds{n}.imgt"));
        tf.save(&path).unwrap();
        Dataset::load_imgt(&path).unwrap()
    }

    #[test]
    fn roundtrip_and_access() {
        let ds = fake_dataset(4);
        assert_eq!(ds.n, 4);
        assert_eq!(ds.image_len(), 18);
        assert_eq!(ds.image(1)[0], 18.0);
        assert_eq!(ds.y[2], 2);
        assert_eq!(ds.hw(), (3, 3));
    }

    #[test]
    fn channel_padding() {
        let ds = fake_dataset(2);
        let p = ds.image_padded(0, 4);
        assert_eq!(p.len(), 4 * 9);
        assert_eq!(&p[..18], ds.image(0));
        assert!(p[18..].iter().skip(18 - 18).all(|_| true));
        assert!(p[2 * 9..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn take_subsets() {
        let ds = fake_dataset(5);
        let t = ds.take(2);
        assert_eq!(t.n, 2);
        assert_eq!(t.image(1), ds.image(1));
    }

    #[test]
    fn chw_accessor_reads_both_image_ranks() {
        let ds = fake_dataset(2);
        assert_eq!(ds.chw().unwrap(), (2, 3, 3));
        let gray = Dataset { x: vec![0.0; 8], y: vec![0, 1], n: 2, shape: vec![2, 2] };
        assert_eq!(gray.chw().unwrap(), (1, 2, 2));
        let flat = Dataset { x: vec![0.0; 8], y: vec![0, 1], n: 2, shape: vec![4] };
        assert!(matches!(flat.chw(), Err(DatasetError::NotImage { .. })));
    }

    #[test]
    fn synthetic_tasks_are_deterministic_and_share_templates() {
        let a = Dataset::synthetic(24, vec![4, 4], 3, 5, 11, 0.2);
        let b = Dataset::synthetic(24, vec![4, 4], 3, 5, 11, 0.2);
        assert_eq!(a.x, b.x, "same seeds ⇒ bit-identical draws");
        assert_eq!(a.y, b.y);
        assert!(a.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Same task, different draw: different samples, same class
        // structure (the per-class means track the shared templates).
        let c = Dataset::synthetic(240, vec![4, 4], 3, 5, 12, 0.05);
        let d = Dataset::synthetic(240, vec![4, 4], 3, 5, 13, 0.05);
        assert_ne!(c.x, d.x);
        for class in 0..3 {
            let mean = |ds: &Dataset, cl: usize| -> f32 {
                let mut s = 0.0;
                let mut k = 0;
                for i in 0..ds.n {
                    if ds.y[i] as usize == cl {
                        s += ds.image(i)[0];
                        k += 1;
                    }
                }
                s / k as f32
            };
            assert!((mean(&c, class) - mean(&d, class)).abs() < 0.05);
        }
    }

    #[test]
    fn load_rejects_bad_rank_and_length_mismatch() {
        let dir = std::env::temp_dir().join("imagine_ds_test");
        std::fs::create_dir_all(&dir).unwrap();

        // Rank-1 per-image shape: rejected with the typed error.
        let mut tf = TensorFile::new();
        tf.push(Tensor {
            name: "x".into(),
            dims: vec![3, 9],
            data: TensorData::F32(vec![0.0; 27]),
        });
        tf.push(Tensor {
            name: "y".into(),
            dims: vec![3],
            data: TensorData::I32(vec![0, 1, 2]),
        });
        let path = dir.join("flat.imgt");
        tf.save(&path).unwrap();
        let err = Dataset::load_imgt(&path).unwrap_err();
        assert!(format!("{err:#}").contains("rank"), "{err:#}");

        // Inconsistent tensor length vs n × shape product (the tensor
        // container catches this on write, so exercise the loader's own
        // defense directly).
        let err = super::validate_images(2, &[3, 3], &[2, 3, 3], 10).unwrap_err();
        assert_eq!(
            err,
            DatasetError::ShapeMismatch { n: 2, shape: vec![3, 3], len: 10 }
        );
        assert!(format!("{err}").contains("10"), "{err}");
        assert!(super::validate_images(2, &[3, 3], &[2, 3, 3], 18).is_ok());
    }
}
