//! Per-layer precision autotuner — the paper's workload-adaptive
//! 1-to-8b claim turned into an automatic tool.
//!
//! IMAGINE's macro trades energy for precision across 0.15–8 POPS/W
//! (§V; Fig. 24): halving `r_in`/`r_out` roughly halves charge moved
//! per op, while distribution-aware reshaping keeps accuracy usable at
//! the low end. The IR already carries per-layer overrides
//! ([`AbnSpec`]) and the serving stack routes per-request precision —
//! this module *searches* that space: a Pareto sweep over per-layer
//! `(r_in, r_out)` assignments minimizing modeled system energy
//! ([`crate::energy::system`]) subject to an accuracy floor, with
//! accuracy measured under the *probed* equivalent noise of each
//! operating point ([`crate::engine::noise`]) at the configured
//! supply/corner — not just the ideal contract.
//!
//! The search exploits structure instead of brute-forcing the
//! `(8×8)^layers` grid:
//!
//! 1. **Uniform sweep** — evaluate a small uniform-precision grid
//!    ([`AutotuneConfig::uniform_points`]), keep the cheapest point
//!    that clears the floor.
//! 2. **Greedy per-layer refinement** — from the best uniform seed,
//!    repeatedly try single-ladder-step-down moves (one layer, one
//!    knob), ranked by *memoized* per-layer energy savings
//!    ([`crate::engine::ideal::network_layer_costs_at`] — one cost
//!    vector per operating point, reused across all candidates), and
//!    accept the best-saving move that still clears the floor.
//!
//! Candidate evaluation never re-lowers or rebuilds a backend: the
//! calibration pass runs once ([`GraphCalibration::collect`]) and each
//! candidate binds against it with per-node overrides
//! ([`MappedGraph::bind_with`]), exactly the O(layers) re-targeting the
//! manifest path uses. Probed noise σ per `(r_in, r_out)` point is
//! memoized too; points whose probe rails out (very low `r_out`) are
//! marked unusable and skipped.
//!
//! The winning profile is exported as a versioned
//! [`PrecisionProfile`] for the saved deployment manifest, so
//! [`ModelHub`](crate::api::ModelHub) serves it with zero flags.
//! [`operating_point_matrix`] produces the Fig. 3(b)-style
//! supply/corner × precision atlas behind `imagine autotune --matrix`
//! (rendered into `docs/OPERATING_POINTS.md`).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::params::{Corner, MacroParams, Supply};
use crate::coordinator::manifest::{NetworkModel, PrecisionProfile, ProfileEntry};
use crate::engine::ideal::network_layer_costs_at;
use crate::engine::noise::probe_equivalent_noise_with;
use crate::nn::cim_eval::EvalCfg;
use crate::nn::dataset::Dataset;
use crate::nn::graph::{Graph, GraphCalibration, MappedGraph};
use crate::nn::layers::AbnSpec;
use crate::util::json::{obj, Json};
use crate::util::stats::argmax_f32;

/// Configuration of the per-layer precision search.
#[derive(Clone, Debug)]
pub struct AutotuneConfig {
    /// Allowed accuracy drop below the full-precision reference: the
    /// feasibility floor is `reference_accuracy - floor_drop`.
    pub floor_drop: f64,
    /// Uniform `(r_in, r_out)` seed grid swept before refinement.
    pub uniform_points: Vec<(u32, u32)>,
    /// Refinement ladder for `r_in` (any order; refinement steps to the
    /// next lower rung). Its maximum defines the reference `r_in`.
    pub r_in_ladder: Vec<u32>,
    /// Refinement ladder for `r_out`; maximum defines the reference.
    pub r_out_ladder: Vec<u32>,
    /// Hard cap on accuracy evaluations (reference + sweep +
    /// refinement); the search stops when the budget is spent.
    pub max_evals: usize,
    /// Images per accuracy evaluation (capped by the eval set size).
    pub eval_n: usize,
    /// Worker threads for the batched candidate forwards.
    pub workers: usize,
    /// Probe the analog die pool's equivalent noise per operating point
    /// (`false` inherits the graph-level `noise_lsb` everywhere —
    /// faster, used by deterministic smoke tests).
    pub probe: bool,
    /// Dies in the mismatch probe population.
    pub probe_dies: usize,
    /// Repeated reads per die for the temporal-noise estimate.
    pub probe_repeats: usize,
}

impl Default for AutotuneConfig {
    fn default() -> AutotuneConfig {
        AutotuneConfig {
            floor_drop: 0.02,
            uniform_points: vec![(8, 8), (6, 6), (4, 4), (2, 2)],
            r_in_ladder: vec![8, 7, 6, 5, 4, 3, 2],
            r_out_ladder: vec![8, 7, 6, 5, 4, 3],
            max_evals: 96,
            eval_n: 128,
            workers: crate::engine::default_workers(),
            probe: true,
            probe_dies: 2,
            probe_repeats: 4,
        }
    }
}

impl AutotuneConfig {
    fn validate(&self) -> Result<()> {
        ensure!(self.floor_drop >= 0.0, "floor_drop must be >= 0");
        ensure!(!self.uniform_points.is_empty(), "empty uniform sweep grid");
        ensure!(!self.r_in_ladder.is_empty(), "empty r_in ladder");
        ensure!(!self.r_out_ladder.is_empty(), "empty r_out ladder");
        for &r in self.r_in_ladder.iter().chain(&self.r_out_ladder) {
            ensure!((1..=8).contains(&r), "ladder precision {r} outside 1..=8");
        }
        for &(ri, ro) in &self.uniform_points {
            ensure!(
                (1..=8).contains(&ri) && (1..=8).contains(&ro),
                "uniform point ({ri}, {ro}) outside 1..=8"
            );
        }
        ensure!(self.max_evals >= 1, "max_evals must be >= 1");
        ensure!(self.eval_n >= 1, "eval_n must be >= 1");
        ensure!(self.workers >= 1, "workers must be >= 1");
        ensure!(self.probe_dies >= 1, "probe_dies must be >= 1");
        ensure!(self.probe_repeats >= 2, "probe_repeats must be >= 2");
        Ok(())
    }

    /// The full-precision reference operating point: the maximum rung
    /// of each ladder.
    pub fn reference_point(&self) -> (u32, u32) {
        let ri = self.r_in_ladder.iter().copied().max().unwrap_or(8);
        let ro = self.r_out_ladder.iter().copied().max().unwrap_or(8);
        (ri, ro)
    }
}

/// One entry of the uniform-precision sweep.
#[derive(Clone, Debug)]
pub struct UniformPoint {
    /// Input precision [bits].
    pub r_in: u32,
    /// Output (ADC) precision [bits].
    pub r_out: u32,
    /// Probed equivalent noise σ [ADC LSB]; `None` when the probe
    /// railed out (point unusable).
    pub sigma_lsb: Option<f64>,
    /// Measured accuracy under that noise; `None` when unusable or the
    /// eval budget ran out first.
    pub accuracy: Option<f64>,
    /// Modeled system energy per image [J].
    pub energy_j: f64,
    /// Did this point clear the accuracy floor?
    pub feasible: bool,
}

/// One accepted refinement move.
#[derive(Clone, Debug)]
pub struct MoveRecord {
    /// CIM-layer index the move touched.
    pub layer: usize,
    /// Operating point before the move.
    pub from: (u32, u32),
    /// Operating point after the move.
    pub to: (u32, u32),
    /// Accuracy measured after the move.
    pub accuracy: f64,
    /// Memoized per-image energy saving of the move [J].
    pub saving_j: f64,
}

/// Result of a per-layer precision search.
#[derive(Clone, Debug)]
pub struct AutotuneReport {
    /// Manifest layer names, index-aligned with [`AutotuneReport::profile`].
    pub layer_names: Vec<String>,
    /// Full-precision reference operating point.
    pub reference_point: (u32, u32),
    /// Reference accuracy (the floor's anchor).
    pub reference_accuracy: f64,
    /// Reference modeled energy per image [J].
    pub reference_energy_j: f64,
    /// Accuracy floor every accepted candidate must clear.
    pub floor: f64,
    /// The uniform-precision sweep, in grid order.
    pub uniform: Vec<UniformPoint>,
    /// Best feasible uniform point (the refinement seed; falls back to
    /// the reference when no grid point is feasible).
    pub best_uniform: (u32, u32),
    /// Energy of the best uniform point [J/image].
    pub best_uniform_energy_j: f64,
    /// Accuracy of the best uniform point.
    pub best_uniform_accuracy: f64,
    /// The chosen per-layer `(r_in, r_out)` profile.
    pub profile: Vec<(u32, u32)>,
    /// Accuracy of the chosen profile.
    pub accuracy: f64,
    /// Modeled energy of the chosen profile [J/image].
    pub energy_j: f64,
    /// Accepted refinement moves, in order.
    pub moves: Vec<MoveRecord>,
    /// Accuracy evaluations spent (memoized hits not counted).
    pub evals: usize,
}

impl AutotuneReport {
    /// The chosen profile as a versioned manifest section.
    pub fn precision_profile(&self) -> PrecisionProfile {
        PrecisionProfile {
            version: PrecisionProfile::VERSION,
            layers: self
                .layer_names
                .iter()
                .zip(&self.profile)
                .map(|(name, &(r_in, r_out))| ProfileEntry { name: name.clone(), r_in, r_out })
                .collect(),
        }
    }

    /// Per-CIM-node [`AbnSpec`] overrides realizing the chosen profile
    /// (for [`Graph::lower_with`] / [`MappedGraph::bind_with`]).
    pub fn overrides(&self) -> Vec<AbnSpec> {
        overrides_for(&self.profile)
    }

    /// JSON form of the report (the `imagine autotune --json` payload).
    pub fn to_json(&self) -> Json {
        let uniform = self
            .uniform
            .iter()
            .map(|u| {
                obj(vec![
                    ("r_in", Json::Num(u.r_in as f64)),
                    ("r_out", Json::Num(u.r_out as f64)),
                    ("sigma_lsb", opt_num(u.sigma_lsb)),
                    ("accuracy", opt_num(u.accuracy)),
                    ("energy_j", Json::Num(u.energy_j)),
                    ("feasible", Json::Bool(u.feasible)),
                ])
            })
            .collect();
        let profile = self
            .layer_names
            .iter()
            .zip(&self.profile)
            .map(|(name, &(ri, ro))| {
                obj(vec![
                    ("layer", Json::Str(name.clone())),
                    ("r_in", Json::Num(ri as f64)),
                    ("r_out", Json::Num(ro as f64)),
                ])
            })
            .collect();
        let moves = self
            .moves
            .iter()
            .map(|m| {
                obj(vec![
                    ("layer", Json::Num(m.layer as f64)),
                    ("from", point_json(m.from)),
                    ("to", point_json(m.to)),
                    ("accuracy", Json::Num(m.accuracy)),
                    ("saving_j", Json::Num(m.saving_j)),
                ])
            })
            .collect();
        obj(vec![
            ("tool", Json::Str("imagine-autotune".into())),
            ("reference", point_json(self.reference_point)),
            ("reference_accuracy", Json::Num(self.reference_accuracy)),
            ("reference_energy_j", Json::Num(self.reference_energy_j)),
            ("floor", Json::Num(self.floor)),
            ("uniform", Json::Arr(uniform)),
            ("best_uniform", point_json(self.best_uniform)),
            ("best_uniform_energy_j", Json::Num(self.best_uniform_energy_j)),
            ("best_uniform_accuracy", Json::Num(self.best_uniform_accuracy)),
            ("profile", Json::Arr(profile)),
            ("accuracy", Json::Num(self.accuracy)),
            ("energy_j", Json::Num(self.energy_j)),
            ("moves", Json::Arr(moves)),
            ("evals", Json::Num(self.evals as f64)),
        ])
    }
}

/// Per-CIM-node overrides pinning each node to its `(r_in, r_out)`
/// point (noise inherited from the graph-level configuration).
pub fn overrides_for(points: &[(u32, u32)]) -> Vec<AbnSpec> {
    points
        .iter()
        .map(|&(ri, ro)| AbnSpec { r_in: Some(ri), r_out: Some(ro), ..AbnSpec::INHERIT })
        .collect()
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

fn point_json((ri, ro): (u32, u32)) -> Json {
    obj(vec![("r_in", Json::Num(ri as f64)), ("r_out", Json::Num(ro as f64))])
}

/// The next lower rung of a ladder, if any.
fn next_lower(ladder: &[u32], v: u32) -> Option<u32> {
    ladder.iter().copied().filter(|&x| x < v).max()
}

/// Shared candidate-evaluation state: one calibration, one lowered base
/// model for energy memoization, and per-point σ / per-point layer-cost
/// / per-candidate accuracy memos.
struct Tuner<'a> {
    graph: &'a Graph,
    cal: GraphCalibration,
    eval: &'a Dataset,
    eval_n: usize,
    p: &'a MacroParams,
    cfg: EvalCfg,
    at: &'a AutotuneConfig,
    base: NetworkModel,
    sigma: BTreeMap<(u32, u32), Option<f64>>,
    layer_energy: BTreeMap<(u32, u32), Vec<f64>>,
    acc_memo: BTreeMap<Vec<(u32, u32)>, f64>,
    evals: usize,
}

impl Tuner<'_> {
    /// Probed equivalent noise σ [LSB] of an operating point, memoized;
    /// `None` marks the point unusable (the probe railed out).
    fn sigma(&mut self, pt: (u32, u32)) -> Option<f64> {
        if !self.at.probe {
            return Some(self.cfg.noise_lsb);
        }
        if let Some(&s) = self.sigma.get(&pt) {
            return s;
        }
        let s = probe_equivalent_noise_with(
            self.p,
            pt.0,
            pt.1,
            self.cfg.seed,
            self.at.probe_dies,
            self.at.probe_repeats,
        )
        .ok()
        .map(|stats| stats.total_lsb());
        self.sigma.insert(pt, s);
        s
    }

    /// Per-layer modeled energy [J/image] with every layer at `pt`,
    /// memoized — the basis for O(1) candidate-move savings.
    fn layer_energies(&mut self, pt: (u32, u32)) -> Vec<f64> {
        if let Some(v) = self.layer_energy.get(&pt) {
            return v.clone();
        }
        let pts = vec![pt; self.base.layers.len()];
        let v: Vec<f64> = network_layer_costs_at(&self.base, self.p, &pts)
            .iter()
            .map(|c| c.e_total())
            .collect();
        self.layer_energy.insert(pt, v.clone());
        v
    }

    /// Total modeled energy [J/image] of a per-layer assignment.
    fn energy_of(&mut self, points: &[(u32, u32)]) -> f64 {
        points.iter().enumerate().map(|(li, &pt)| self.layer_energies(pt)[li]).sum()
    }

    /// Accuracy of a per-layer assignment under each point's probed
    /// noise; memoized per assignment. Errors when any point has no
    /// usable probe (callers screen with [`Tuner::sigma`] first).
    fn accuracy(&mut self, points: &[(u32, u32)]) -> Result<f64> {
        let key = points.to_vec();
        if let Some(&a) = self.acc_memo.get(&key) {
            return Ok(a);
        }
        let mut overrides = Vec::with_capacity(points.len());
        for &pt in points {
            let Some(sigma) = self.sigma(pt) else {
                bail!("operating point ({}, {}) has no usable noise probe", pt.0, pt.1);
            };
            overrides.push(AbnSpec {
                r_in: Some(pt.0),
                r_out: Some(pt.1),
                noise_lsb: Some(sigma),
                ..AbnSpec::INHERIT
            });
        }
        let acc = accuracy_with_overrides(
            self.graph,
            &self.cal,
            self.p,
            &self.cfg,
            &overrides,
            self.eval,
            self.eval_n,
            self.at.workers,
        )?;
        self.evals += 1;
        self.acc_memo.insert(key, acc);
        Ok(acc)
    }
}

/// Bind the graph with per-node overrides and measure top-1 accuracy on
/// the first `eval_n` images of `eval`.
#[allow(clippy::too_many_arguments)]
fn accuracy_with_overrides(
    graph: &Graph,
    cal: &GraphCalibration,
    p: &MacroParams,
    cfg: &EvalCfg,
    overrides: &[AbnSpec],
    eval: &Dataset,
    eval_n: usize,
    workers: usize,
) -> Result<f64> {
    let mapped = MappedGraph::bind_with(graph, cal, p, cfg, overrides)?;
    let out = mapped.forward_flat(&eval.x[..eval_n * eval.image_len()], eval_n, workers)?;
    let n_out = mapped.output_len();
    let mut correct = 0usize;
    for i in 0..eval_n {
        if argmax_f32(&out[i * n_out..(i + 1) * n_out]) == eval.y[i] as usize {
            correct += 1;
        }
    }
    Ok(correct as f64 / eval_n as f64)
}

/// Search a per-layer `(r_in, r_out)` profile minimizing modeled system
/// energy subject to `reference_accuracy - floor_drop`.
///
/// `calib` calibrates activation ranges (once); `eval` measures
/// candidate accuracy (first [`AutotuneConfig::eval_n`] images). The
/// graph-level `cfg` supplies every non-precision knob (γ bits,
/// adaptive swing, seed) and the fallback `noise_lsb` when probing is
/// off. Deterministic: same inputs and seed, same profile.
pub fn autotune(
    graph: &Graph,
    calib: &Dataset,
    eval: &Dataset,
    p: &MacroParams,
    cfg: &EvalCfg,
    at: &AutotuneConfig,
) -> Result<AutotuneReport> {
    at.validate()?;
    let n_cim = graph.n_cim();
    ensure!(n_cim > 0, "graph has no macro-mapped nodes to tune");
    let eval_n = eval.n.min(at.eval_n);
    ensure!(eval_n > 0, "empty evaluation set");

    let ref_pt = at.reference_point();
    let ref_cfg = EvalCfg { r_in: ref_pt.0, r_out: ref_pt.1, ..*cfg };
    let base = graph.lower(calib, p, &ref_cfg)?;
    ensure!(
        base.layers.len() == n_cim,
        "lowered model has {} layers for {n_cim} CIM nodes",
        base.layers.len()
    );
    let layer_names: Vec<String> = base.layers.iter().map(|l| l.name.clone()).collect();
    let cal = GraphCalibration::collect(graph, calib)?;

    let mut t = Tuner {
        graph,
        cal,
        eval,
        eval_n,
        p,
        cfg: *cfg,
        at,
        base,
        sigma: BTreeMap::new(),
        layer_energy: BTreeMap::new(),
        acc_memo: BTreeMap::new(),
        evals: 0,
    };

    // Reference measurement anchors the floor; its probe must succeed.
    ensure!(
        t.sigma(ref_pt).is_some(),
        "reference operating point ({}, {}): noise probe railed out",
        ref_pt.0,
        ref_pt.1
    );
    let ref_points = vec![ref_pt; n_cim];
    let ref_acc = t.accuracy(&ref_points)?;
    let ref_energy = t.energy_of(&ref_points);
    let floor = ref_acc - at.floor_drop;

    // Phase 1: uniform-precision sweep.
    let mut uniform = Vec::with_capacity(at.uniform_points.len());
    let mut best: Option<((u32, u32), f64, f64)> = None;
    for &pt in &at.uniform_points {
        let points = vec![pt; n_cim];
        let energy = t.energy_of(&points);
        let sigma = t.sigma(pt);
        let accuracy = match sigma {
            None => None,
            Some(_) if t.evals >= at.max_evals => None,
            Some(_) => Some(t.accuracy(&points)?),
        };
        let feasible = accuracy.is_some_and(|a| a >= floor);
        if let Some(a) = accuracy {
            if a >= floor && best.is_none_or(|(_, e, _)| energy < e) {
                best = Some((pt, energy, a));
            }
        }
        uniform.push(UniformPoint {
            r_in: pt.0,
            r_out: pt.1,
            sigma_lsb: sigma,
            accuracy,
            energy_j: energy,
            feasible,
        });
    }
    let (best_pt, best_energy, best_acc) = best.unwrap_or((ref_pt, ref_energy, ref_acc));

    // Phase 2: greedy per-layer refinement from the best uniform seed.
    let mut cur = vec![best_pt; n_cim];
    let mut cur_acc = best_acc;
    let mut moves = Vec::new();
    loop {
        if t.evals >= at.max_evals {
            break;
        }
        // Enumerate single-step-down candidates with their memoized
        // savings; deterministic order (saving desc, then layer, then
        // point) makes the whole search reproducible.
        let mut cands: Vec<(f64, usize, (u32, u32))> = Vec::new();
        for (li, &(ri, ro)) in cur.iter().enumerate() {
            let mut opts = Vec::new();
            if let Some(nri) = next_lower(&at.r_in_ladder, ri) {
                opts.push((nri, ro));
            }
            if let Some(nro) = next_lower(&at.r_out_ladder, ro) {
                opts.push((ri, nro));
            }
            for npt in opts {
                if t.sigma(npt).is_none() {
                    continue;
                }
                let saving = t.layer_energies((ri, ro))[li] - t.layer_energies(npt)[li];
                if saving <= 0.0 {
                    continue;
                }
                cands.push((saving, li, npt));
            }
        }
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        let mut accepted = false;
        for (saving, li, npt) in cands {
            if t.evals >= at.max_evals {
                break;
            }
            let mut next = cur.clone();
            next[li] = npt;
            let acc = t.accuracy(&next)?;
            if acc >= floor {
                moves.push(MoveRecord {
                    layer: li,
                    from: cur[li],
                    to: npt,
                    accuracy: acc,
                    saving_j: saving,
                });
                cur = next;
                cur_acc = acc;
                accepted = true;
                break;
            }
        }
        if !accepted {
            break;
        }
    }
    let cur_energy = t.energy_of(&cur);

    Ok(AutotuneReport {
        layer_names,
        reference_point: ref_pt,
        reference_accuracy: ref_acc,
        reference_energy_j: ref_energy,
        floor,
        uniform,
        best_uniform: best_pt,
        best_uniform_energy_j: best_energy,
        best_uniform_accuracy: best_acc,
        profile: cur,
        accuracy: cur_acc,
        energy_j: cur_energy,
        moves,
        evals: t.evals,
    })
}

/// One cell of the supply/corner × precision operating-point atlas.
#[derive(Clone, Debug)]
pub struct MatrixEntry {
    /// Supply label (`"nominal"` / `"low-power"`).
    pub supply: String,
    /// Low rail V_DDL [V].
    pub vddl: f64,
    /// High rail V_DDH [V].
    pub vddh: f64,
    /// Process corner name (TT/FF/SS/FS/SF).
    pub corner: String,
    /// Input precision [bits].
    pub r_in: u32,
    /// Output (ADC) precision [bits].
    pub r_out: u32,
    /// Probed equivalent noise σ [ADC LSB]; `None` when railed out.
    pub sigma_lsb: Option<f64>,
    /// Accuracy under that noise; `None` when the point is unusable.
    pub accuracy: Option<f64>,
    /// Modeled system energy per image [J].
    pub energy_j: f64,
    /// 8b-normalized system energy efficiency [TOPS/W].
    pub ee_tops_8b: f64,
}

/// Sweep `{nominal, low-power} × Corner::ALL ×`
/// [`AutotuneConfig::uniform_points`] on a graph: the Fig. 3(b)-style
/// accuracy/energy atlas behind `imagine autotune --matrix`.
pub fn operating_point_matrix(
    graph: &Graph,
    calib: &Dataset,
    eval: &Dataset,
    base_p: &MacroParams,
    cfg: &EvalCfg,
    at: &AutotuneConfig,
) -> Result<Vec<MatrixEntry>> {
    at.validate()?;
    ensure!(graph.n_cim() > 0, "graph has no macro-mapped nodes");
    let eval_n = eval.n.min(at.eval_n);
    ensure!(eval_n > 0, "empty evaluation set");
    let cal = GraphCalibration::collect(graph, calib)?;
    let ref_pt = at.reference_point();
    let supplies = [("nominal", Supply::NOMINAL), ("low-power", Supply::LOW_POWER)];
    let mut out = Vec::new();
    for (supply_name, supply) in supplies {
        for corner in Corner::ALL {
            let p = base_p.clone().with_supply(supply).with_corner(corner);
            let ref_cfg = EvalCfg { r_in: ref_pt.0, r_out: ref_pt.1, ..*cfg };
            let base = graph.lower(calib, &p, &ref_cfg)?;
            for &(ri, ro) in &at.uniform_points {
                let pts = vec![(ri, ro); base.layers.len()];
                let costs = network_layer_costs_at(&base, &p, &pts);
                let energy_j: f64 = costs.iter().map(|c| c.e_total()).sum();
                let ops_8b: f64 = costs.iter().map(|c| c.ops_8b).sum();
                let sigma = if at.probe {
                    probe_equivalent_noise_with(
                        &p,
                        ri,
                        ro,
                        cfg.seed,
                        at.probe_dies,
                        at.probe_repeats,
                    )
                    .ok()
                    .map(|s| s.total_lsb())
                } else {
                    Some(cfg.noise_lsb)
                };
                let accuracy = match sigma {
                    None => None,
                    Some(s) => {
                        let overrides: Vec<AbnSpec> = (0..graph.n_cim())
                            .map(|_| AbnSpec {
                                r_in: Some(ri),
                                r_out: Some(ro),
                                noise_lsb: Some(s),
                                ..AbnSpec::INHERIT
                            })
                            .collect();
                        Some(accuracy_with_overrides(
                            graph,
                            &cal,
                            &p,
                            cfg,
                            &overrides,
                            eval,
                            eval_n,
                            at.workers,
                        )?)
                    }
                };
                out.push(MatrixEntry {
                    supply: supply_name.to_string(),
                    vddl: supply.vddl,
                    vddh: supply.vddh,
                    corner: corner.name().to_string(),
                    r_in: ri,
                    r_out: ro,
                    sigma_lsb: sigma,
                    accuracy,
                    energy_j,
                    ee_tops_8b: ops_8b / energy_j / 1e12,
                });
            }
        }
    }
    Ok(out)
}

/// JSON form of the atlas (`imagine autotune --matrix` output; consumed
/// by `scripts/operating_points.py`).
pub fn matrix_to_json(entries: &[MatrixEntry]) -> Json {
    let rows = entries
        .iter()
        .map(|e| {
            obj(vec![
                ("supply", Json::Str(e.supply.clone())),
                ("vddl", Json::Num(e.vddl)),
                ("vddh", Json::Num(e.vddh)),
                ("corner", Json::Str(e.corner.clone())),
                ("r_in", Json::Num(e.r_in as f64)),
                ("r_out", Json::Num(e.r_out as f64)),
                ("sigma_lsb", opt_num(e.sigma_lsb)),
                ("accuracy", opt_num(e.accuracy)),
                ("energy_j", Json::Num(e.energy_j)),
                ("ee_tops_8b", Json::Num(e.ee_tops_8b)),
            ])
        })
        .collect();
    obj(vec![
        ("schema", Json::Str("imagine-operating-points/v1".into())),
        ("entries", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::{DenseNode, Node};
    use crate::nn::mlp::Dense;
    use crate::util::rng::Rng;

    fn small_graph(seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        Graph::new("tune-t", vec![36])
            .with(Node::Dense(DenseNode::new(Dense::new(36, 16, &mut rng))))
            .with(Node::Relu)
            .with(Node::Dense(DenseNode::new(Dense::new(16, 4, &mut rng))))
    }

    fn fast_config() -> AutotuneConfig {
        AutotuneConfig {
            floor_drop: 1.0,
            uniform_points: vec![(8, 8), (4, 4)],
            r_in_ladder: vec![8, 6, 4, 3, 2],
            r_out_ladder: vec![8, 6, 4, 3],
            max_evals: 24,
            eval_n: 24,
            workers: 1,
            probe: false,
            probe_dies: 1,
            probe_repeats: 2,
        }
    }

    #[test]
    fn next_lower_steps_down_the_ladder() {
        let ladder = [8, 6, 4, 3];
        assert_eq!(next_lower(&ladder, 8), Some(6));
        assert_eq!(next_lower(&ladder, 6), Some(4));
        assert_eq!(next_lower(&ladder, 4), Some(3));
        assert_eq!(next_lower(&ladder, 3), None);
        assert_eq!(next_lower(&ladder, 5), Some(4));
    }

    #[test]
    fn config_validation_rejects_bad_grids() {
        let ok = fast_config();
        assert!(ok.validate().is_ok());
        let mut bad = fast_config();
        bad.r_in_ladder.clear();
        assert!(bad.validate().is_err(), "empty ladder");
        let mut bad = fast_config();
        bad.uniform_points.push((0, 4));
        assert!(bad.validate().is_err(), "precision 0");
        let mut bad = fast_config();
        bad.r_out_ladder.push(9);
        assert!(bad.validate().is_err(), "precision 9");
        let mut bad = fast_config();
        bad.probe_repeats = 1;
        assert!(bad.validate().is_err(), "probe needs >= 2 repeats");
    }

    #[test]
    fn autotune_is_deterministic_and_never_beats_the_budget() {
        let graph = small_graph(11);
        let calib = Dataset::synthetic(48, vec![6, 6], 4, 5, 6, 0.2);
        let eval = Dataset::synthetic(32, vec![6, 6], 4, 5, 7, 0.2);
        let p = MacroParams::paper();
        let cfg = EvalCfg::new(8, 5, true);
        let at = fast_config();
        let a = autotune(&graph, &calib, &eval, &p, &cfg, &at).unwrap();
        let b = autotune(&graph, &calib, &eval, &p, &cfg, &at).unwrap();
        assert_eq!(a.profile, b.profile, "same seed, same profile");
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.moves.len(), b.moves.len());
        assert!(a.evals <= at.max_evals);
        assert_eq!(a.profile.len(), graph.n_cim());
        assert_eq!(a.layer_names, vec!["fc0".to_string(), "fc1".to_string()]);
        assert!(
            a.energy_j <= a.best_uniform_energy_j + 1e-18,
            "refinement never regresses the uniform seed"
        );
        // With a wide-open floor the greedy descent runs to the ladder
        // floor for every layer, which uniform (4, 4) cannot match.
        assert!(a.energy_j < a.best_uniform_energy_j);
        assert!(!a.moves.is_empty());
        let json = a.to_json().to_string_compact();
        assert!(json.contains("\"tool\":\"imagine-autotune\""));
    }

    #[test]
    fn probe_mode_memoizes_sigma_per_point() {
        let graph = small_graph(3);
        let calib = Dataset::synthetic(32, vec![6, 6], 4, 9, 10, 0.2);
        let eval = Dataset::synthetic(16, vec![6, 6], 4, 9, 11, 0.2);
        let p = MacroParams::paper();
        let cfg = EvalCfg::new(8, 5, true);
        let at = AutotuneConfig {
            uniform_points: vec![(8, 8)],
            r_in_ladder: vec![8],
            r_out_ladder: vec![8],
            max_evals: 4,
            eval_n: 16,
            workers: 1,
            probe: true,
            probe_dies: 1,
            probe_repeats: 2,
            ..fast_config()
        };
        let r = autotune(&graph, &calib, &eval, &p, &cfg, &at).unwrap();
        assert_eq!(r.profile, vec![(8, 8); 2], "single-rung ladders cannot move");
        let sigma = r.uniform[0].sigma_lsb.expect("probe succeeds at (8, 8)");
        assert!(sigma > 0.0 && sigma.is_finite());
        assert!(r.moves.is_empty());
    }

    #[test]
    fn matrix_covers_the_supply_corner_grid() {
        let graph = small_graph(7);
        let calib = Dataset::synthetic(32, vec![6, 6], 4, 1, 2, 0.2);
        let eval = Dataset::synthetic(8, vec![6, 6], 4, 1, 3, 0.2);
        let p = MacroParams::paper();
        let cfg = EvalCfg::new(8, 5, true);
        let at = AutotuneConfig {
            uniform_points: vec![(8, 8), (4, 4)],
            eval_n: 8,
            workers: 1,
            probe: false,
            ..fast_config()
        };
        let m = operating_point_matrix(&graph, &calib, &eval, &p, &cfg, &at).unwrap();
        assert_eq!(m.len(), 2 * Corner::ALL.len() * 2);
        for e in &m {
            assert!(e.energy_j > 0.0);
            assert!(e.accuracy.is_some(), "probe off: every point usable");
        }
        // Lower precision must cost less energy at fixed supply/corner.
        let mut tt: Vec<&MatrixEntry> =
            m.iter().filter(|e| e.supply == "nominal" && e.corner == "TT").collect();
        tt.sort_by_key(|e| e.r_in);
        assert!(tt[0].energy_j < tt[1].energy_j, "4b cheaper than 8b");
        let json = matrix_to_json(&m).to_string_compact();
        assert!(json.contains("imagine-operating-points/v1"));
    }
}
