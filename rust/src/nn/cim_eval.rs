//! Post-training CIM-mapped evaluation of an MLP — the Fig. 3(b) study.
//!
//! Takes a float-trained [`Mlp`] and evaluates it through the macro's
//! functional contract: 4b antipodal weights, r_in-bit unsigned
//! activations, an `r_out`-bit ADC, an ABN gain quantized to
//! `gamma_bits` (γ ∈ {1, 2, …, 2^gamma_bits}), optional channel-adaptive
//! swing (the α_eff(C_in) array split of §II) and the macro's equivalent
//! output noise. Sweeping (gamma_bits × r_out × adaptive) regenerates the
//! Fig. 3(b) trend: test error falls as γ precision grows, and the
//! adaptive swing shifts the curve left by about one bit.
//!
//! The digital reconstruction inverts the macro contract exactly:
//! `dot = Σ (2X−M)·W` is recovered from the code, then the offset-binary
//! identity `Σ X·W = (dot + M·ΣW)/2` restores the real pre-activation
//! (the `M·ΣW` constant is what the silicon's ABN offset/bias absorbs).
//!
//! Execution goes through the engine layer's batched kernel
//! ([`crate::engine::gemm::rowdot_f64`]): the whole test set advances one
//! *layer* at a time, so each layer's weight matrix is streamed once per
//! sweep point instead of once per image. Noiseless results are
//! bit-identical to the historical per-image loop (same per-element float
//! expressions, same ascending-k accumulation); with `noise_lsb > 0` the
//! RNG draw order is layer-major instead of image-major, so individual
//! noisy codes differ draw-by-draw while the statistics are unchanged.

use crate::config::params::MacroParams;
use crate::engine::gemm;
use crate::nn::dataset::Dataset;
use crate::nn::mlp::Mlp;
use crate::util::rng::Rng;

/// Weight precision used by the mapping (the paper's 4b LeNet setting).
const R_W: u32 = 4;

/// Evaluation configuration for one Fig. 3b grid point.
#[derive(Clone, Copy, Debug)]
pub struct EvalCfg {
    /// ADC output precision (4..=8 in the figure).
    pub r_out: u32,
    /// Input activation precision.
    pub r_in: u32,
    /// Bits available to represent the ABN gain (0 ⇒ γ ≡ 1).
    pub gamma_bits: u32,
    /// Channel-adaptive DPL swing (serial-split α) vs fixed full-array α.
    pub adaptive_swing: bool,
    /// Equivalent output noise in LSB (0 disables).
    pub noise_lsb: f64,
    pub seed: u64,
}

impl EvalCfg {
    pub fn new(r_out: u32, gamma_bits: u32, adaptive_swing: bool) -> Self {
        Self {
            r_out,
            r_in: 8,
            gamma_bits,
            adaptive_swing,
            noise_lsb: 0.5,
            seed: 7,
        }
    }
}

/// Per-layer quantized mapping state.
struct QLayer {
    /// Antipodal integer weights [out × in], odd levels in [−15, 15].
    w_q: Vec<f32>,
    /// Per-output ΣW (offset-binary correction).
    sum_w: Vec<f32>,
    w_scale: f32,
    a_scale: f32,
    alpha: f64,
    gamma: f64,
}

fn build_qlayers(mlp: &Mlp, data: &Dataset, p: &MacroParams, cfg: &EvalCfg) -> Vec<QLayer> {
    let m = ((1u32 << cfg.r_in) - 1) as f32;
    let mx = ((1u32 << R_W) - 1) as f32;

    // Pass 1: activation ranges from the float network.
    let calib_n = data.n.min(96);
    let mut act_hi = vec![1e-6f32; mlp.layers.len()];
    for i in 0..calib_n {
        let (acts, _) = mlp.forward_all(data.flat(i));
        for (li, a) in acts.iter().enumerate() {
            for &v in a.iter() {
                act_hi[li] = act_hi[li].max(v);
            }
        }
    }

    // Quantize weights and derive per-layer state (γ from dv statistics).
    let mut qlayers = Vec::new();
    for (li, layer) in mlp.layers.iter().enumerate() {
        let w_abs_max = layer.w.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-9);
        let w_scale = w_abs_max / mx;
        let w_q: Vec<f32> = layer
            .w
            .iter()
            .map(|&v| {
                let b = ((v / w_scale + mx) / 2.0).round().clamp(0.0, mx);
                2.0 * b - mx
            })
            .collect();
        let sum_w: Vec<f32> = (0..layer.n_out)
            .map(|o| w_q[o * layer.n_in..(o + 1) * layer.n_in].iter().sum())
            .collect();

        let rows = layer.n_in.div_ceil(p.rows_per_unit) * p.rows_per_unit;
        let alpha = if cfg.adaptive_swing {
            p.alpha_eff(rows)
        } else {
            p.alpha_eff(p.n_rows)
        };
        let a_scale = act_hi[li] / m;

        // dv σ estimate over the calibration subset.
        let dv_unit = alpha * p.supply.vddl
            / (1u64 << (cfg.r_in + R_W)) as f64;
        let mut sq = 0f64;
        let mut cnt = 0usize;
        for i in 0..calib_n.min(32) {
            let (acts, _) = mlp.forward_all(data.flat(i));
            let a = &acts[li];
            for o in 0..layer.n_out.min(32) {
                let row = &w_q[o * layer.n_in..(o + 1) * layer.n_in];
                let mut dot = 0f64;
                for (j, &av) in a.iter().enumerate() {
                    let xq = (av / a_scale).round().clamp(0.0, m);
                    dot += (2.0 * xq - m) as f64 * row[j] as f64;
                }
                let dv = dv_unit * dot;
                sq += dv * dv;
                cnt += 1;
            }
        }
        let dv_sigma = (sq / cnt.max(1) as f64).sqrt().max(1e-9);

        // γ: fill the ADC range with ~3.5σ, quantized to {1..2^bits}.
        let ideal = p.alpha_adc() * p.supply.vddh / (3.5 * dv_sigma);
        let max_gamma = (1u64 << cfg.gamma_bits) as f64;
        let mut gamma = 1.0;
        while gamma * 2.0 <= ideal.min(max_gamma) {
            gamma *= 2.0;
        }
        let _ = li;
        qlayers.push(QLayer { w_q, sum_w, w_scale, a_scale, alpha, gamma });
    }
    qlayers
}

/// Evaluate the MLP through the CIM contract; returns test accuracy.
/// The dataset advances layer-by-layer through batched dot products.
pub fn eval_cim(mlp: &Mlp, data: &Dataset, p: &MacroParams, cfg: &EvalCfg) -> f64 {
    eval_cim_workers(mlp, data, p, cfg, crate::engine::default_workers())
}

/// [`eval_cim`] with an explicit worker-thread count for the batched
/// matmuls (`1` reproduces a fully serial evaluation).
pub fn eval_cim_workers(
    mlp: &Mlp,
    data: &Dataset,
    p: &MacroParams,
    cfg: &EvalCfg,
    workers: usize,
) -> f64 {
    let qlayers = build_qlayers(mlp, data, p, cfg);
    let mut rng = Rng::new(cfg.seed);
    let m = ((1u32 << cfg.r_in) - 1) as f32;
    let half = (1u64 << (cfg.r_out - 1)) as f64;
    let top = (1u64 << cfg.r_out) as f64 - 1.0;
    let n = data.n;

    // The whole test set as one activation matrix [n × width].
    let mut cur: Vec<f32> = data.x[..n * data.image_len()].to_vec();
    for (li, (layer, ql)) in mlp.layers.iter().zip(&qlayers).enumerate() {
        let lsb = p.adc_lsb(cfg.r_out, ql.gamma);
        let dv_unit = ql.alpha * p.supply.vddl / (1u64 << (cfg.r_in + R_W)) as f64;
        // Quantize and recenter every activation to the antipodal grid.
        let sx: Vec<f64> = cur
            .iter()
            .map(|&v| {
                let xq = (v / ql.a_scale).round().clamp(0.0, m);
                (2.0 * xq - m) as f64
            })
            .collect();
        let w64: Vec<f64> = ql.w_q.iter().map(|&w| w as f64).collect();
        let dots = gemm::rowdot_f64(&sx, &w64, n, layer.n_in, layer.n_out, workers);

        let mut out = vec![0f32; n * layer.n_out];
        for i in 0..n {
            for o in 0..layer.n_out {
                // Macro + ADC (Eq. 7), with equivalent noise.
                let dv = dv_unit * dots[i * layer.n_out + o];
                let mut code = half + dv / lsb;
                if cfg.noise_lsb > 0.0 {
                    code += rng.normal(0.0, cfg.noise_lsb * (1.0 + ql.gamma / 16.0));
                }
                let code = code.floor().clamp(0.0, top);
                // Digital reconstruction: invert Eq. 7, undo offset-binary.
                let dot_rec = (code - half) * lsb / dv_unit;
                let xw = (dot_rec as f32 + m * ql.sum_w[o]) / 2.0;
                let mut v = xw * ql.a_scale * ql.w_scale + layer.b[o];
                if li + 1 < mlp.layers.len() {
                    v = v.max(0.0);
                }
                out[i * layer.n_out + o] = v;
            }
        }
        cur = out;
    }

    let n_out = mlp.layers.last().map(|l| l.n_out).unwrap_or(1);
    let mut correct = 0usize;
    for i in 0..n {
        let logits = &cur[i * n_out..(i + 1) * n_out];
        let pred = crate::util::stats::argmax_f32(logits);
        if pred == data.y[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Mlp;
    use crate::util::rng::Rng;

    fn toy(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let dim = 36; // one DP unit
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let c = rng.below(2) as i32;
            let mu = if c == 1 { 0.7 } else { 0.25 };
            for _ in 0..dim {
                x.push(rng.normal(mu, 0.12).max(0.0) as f32);
            }
            y.push(c);
        }
        Dataset { x, y, n, shape: vec![dim] }
    }

    fn trained() -> (Mlp, Dataset) {
        let train = toy(400, 1);
        let test = toy(200, 2);
        let mut mlp = Mlp::new(&[36, 24, 2], 5);
        mlp.train(&train, 10, 32, 1e-2, 3);
        (mlp, test)
    }

    #[test]
    fn cim_eval_tracks_float_accuracy_at_high_precision() {
        let (mlp, test) = trained();
        let float_acc = mlp.accuracy(&test);
        assert!(float_acc > 0.9);
        let p = MacroParams::paper();
        let cfg = EvalCfg {
            noise_lsb: 0.0,
            ..EvalCfg::new(8, 5, true)
        };
        let acc = eval_cim(&mlp, &test, &p, &cfg);
        assert!(acc > float_acc - 0.08, "float={float_acc} cim={acc}");
    }

    #[test]
    fn gamma_recovery_beats_fixed_unity_gain() {
        // The Fig. 3b mechanism: γ≡1 + fixed full-array swing buries the
        // DP distribution in a few ADC codes at low ADC precision.
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        let bad = EvalCfg {
            noise_lsb: 0.0,
            ..EvalCfg::new(4, 0, false)
        };
        let good = EvalCfg {
            noise_lsb: 0.0,
            ..EvalCfg::new(4, 5, true)
        };
        let acc_bad = eval_cim(&mlp, &test, &p, &bad);
        let acc_good = eval_cim(&mlp, &test, &p, &good);
        assert!(
            acc_good > acc_bad + 0.1,
            "bad={acc_bad} good={acc_good} (recovery expected)"
        );
    }

    #[test]
    fn worker_count_does_not_change_noiseless_results() {
        // The batched evaluation must be invariant to how the batch is
        // split across threads (same per-element float expressions, same
        // ascending-k accumulation order).
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(6, 3, true) };
        let a1 = eval_cim_workers(&mlp, &test, &p, &cfg, 1);
        let a4 = eval_cim_workers(&mlp, &test, &p, &cfg, 4);
        assert_eq!(a1, a4);
    }

    #[test]
    fn adaptive_swing_saves_gamma_bits() {
        // With few γ bits, enabling the channel-adaptive swing should not
        // hurt and typically helps small-C_in layers (the §II claim).
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        for gb in [1u32, 2] {
            let fixed = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(5, gb, false) };
            let adapt = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(5, gb, true) };
            let a_f = eval_cim(&mlp, &test, &p, &fixed);
            let a_a = eval_cim(&mlp, &test, &p, &adapt);
            assert!(a_a + 0.02 >= a_f, "gb={gb}: fixed={a_f} adaptive={a_a}");
        }
    }
}
