//! Post-training CIM-mapped evaluation of an MLP — the Fig. 3(b) study.
//!
//! Takes a float-trained [`Mlp`] and evaluates it through the macro's
//! functional contract: 4b antipodal weights, r_in-bit unsigned
//! activations, an `r_out`-bit ADC, an ABN gain quantized to
//! `gamma_bits` (γ ∈ {1, 2, …, 2^gamma_bits}), optional channel-adaptive
//! swing (the α_eff(C_in) array split of §II) and the macro's equivalent
//! output noise. Sweeping (gamma_bits × r_out × adaptive) regenerates the
//! Fig. 3(b) trend: test error falls as γ precision grows, and the
//! adaptive swing shifts the curve left by about one bit.
//!
//! Since the layer-graph IR landed, an MLP is just the Dense-only
//! special case of a [`Graph`](crate::nn::graph::Graph):
//! [`eval_cim`] builds the trivial graph (`Dense → ReLU → … → Dense`)
//! and runs it through the one quantize/reconstruct/noise code path in
//! [`crate::nn::graph`] — the same contract expressions that execute the
//! conv layers, evaluated whole-batch through
//! [`gemm::rowdot_f64`](crate::engine::gemm::rowdot_f64). The graph
//! path preserves the historical dense-only implementation's exact
//! float expressions, calibration subset sizes and noise draw order, so
//! noiseless results are bit-identical by construction (the independent
//! behavioral guard is the naive-reference property test in
//! `tests/graph_executor.rs`, not the delegation tests).

use crate::config::params::MacroParams;
use crate::nn::dataset::Dataset;
use crate::nn::graph::{eval_graph_workers, Graph};
use crate::nn::mlp::Mlp;

/// Weight precision used by the mapping (the paper's 4b LeNet setting).
pub const R_W: u32 = crate::nn::graph::R_W;

/// Evaluation configuration for one Fig. 3b grid point — also the
/// graph-level default every [`AbnSpec`](crate::nn::layers::AbnSpec)
/// resolves against.
#[derive(Clone, Copy, Debug)]
pub struct EvalCfg {
    /// ADC output precision (4..=8 in the figure).
    pub r_out: u32,
    /// Input activation precision.
    pub r_in: u32,
    /// Bits available to represent the ABN gain (0 ⇒ γ ≡ 1).
    pub gamma_bits: u32,
    /// Channel-adaptive DPL swing (serial-split α) vs fixed full-array α.
    pub adaptive_swing: bool,
    /// Equivalent output noise in LSB (0 disables).
    pub noise_lsb: f64,
    /// Noise RNG seed (re-seeded per evaluation pass).
    pub seed: u64,
}

impl EvalCfg {
    /// A configuration at the given ADC precision/γ-bits/swing mode,
    /// with the defaults the Fig. 3(b) sweep uses for everything else
    /// (8b inputs, σ = 0.5 LSB, seed 7).
    pub fn new(r_out: u32, gamma_bits: u32, adaptive_swing: bool) -> Self {
        Self {
            r_out,
            r_in: 8,
            gamma_bits,
            adaptive_swing,
            noise_lsb: 0.5,
            seed: 7,
        }
    }
}

/// Evaluate the MLP through the CIM contract; returns test accuracy.
/// The dataset advances layer-by-layer through batched dot products.
pub fn eval_cim(mlp: &Mlp, data: &Dataset, p: &MacroParams, cfg: &EvalCfg) -> f64 {
    eval_cim_workers(mlp, data, p, cfg, crate::engine::default_workers())
}

/// [`eval_cim`] with an explicit worker-thread count for the batched
/// matmuls (`1` reproduces a fully serial evaluation).
pub fn eval_cim_workers(
    mlp: &Mlp,
    data: &Dataset,
    p: &MacroParams,
    cfg: &EvalCfg,
    workers: usize,
) -> f64 {
    let graph = Graph::from_mlp("mlp", mlp);
    eval_graph_workers(&graph, data, p, cfg, workers)
        .expect("Dense-only graph evaluation cannot fail on a well-formed MLP/dataset pair")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Mlp;
    use crate::util::rng::Rng;

    fn toy(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let dim = 36; // one DP unit
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let c = rng.below(2) as i32;
            let mu = if c == 1 { 0.7 } else { 0.25 };
            for _ in 0..dim {
                x.push(rng.normal(mu, 0.12).max(0.0) as f32);
            }
            y.push(c);
        }
        Dataset { x, y, n, shape: vec![dim] }
    }

    fn trained() -> (Mlp, Dataset) {
        let train = toy(400, 1);
        let test = toy(200, 2);
        let mut mlp = Mlp::new(&[36, 24, 2], 5);
        mlp.train(&train, 10, 32, 1e-2, 3);
        (mlp, test)
    }

    #[test]
    fn cim_eval_tracks_float_accuracy_at_high_precision() {
        let (mlp, test) = trained();
        let float_acc = mlp.accuracy(&test);
        assert!(float_acc > 0.9);
        let p = MacroParams::paper();
        let cfg = EvalCfg {
            noise_lsb: 0.0,
            ..EvalCfg::new(8, 5, true)
        };
        let acc = eval_cim(&mlp, &test, &p, &cfg);
        assert!(acc > float_acc - 0.08, "float={float_acc} cim={acc}");
    }

    #[test]
    fn gamma_recovery_beats_fixed_unity_gain() {
        // The Fig. 3b mechanism: γ≡1 + fixed full-array swing buries the
        // DP distribution in a few ADC codes at low ADC precision.
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        let bad = EvalCfg {
            noise_lsb: 0.0,
            ..EvalCfg::new(4, 0, false)
        };
        let good = EvalCfg {
            noise_lsb: 0.0,
            ..EvalCfg::new(4, 5, true)
        };
        let acc_bad = eval_cim(&mlp, &test, &p, &bad);
        let acc_good = eval_cim(&mlp, &test, &p, &good);
        assert!(
            acc_good > acc_bad + 0.1,
            "bad={acc_bad} good={acc_good} (recovery expected)"
        );
    }

    #[test]
    fn worker_count_does_not_change_noiseless_results() {
        // The batched evaluation must be invariant to how the batch is
        // split across threads (same per-element float expressions, same
        // ascending-k accumulation order).
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(6, 3, true) };
        let a1 = eval_cim_workers(&mlp, &test, &p, &cfg, 1);
        let a4 = eval_cim_workers(&mlp, &test, &p, &cfg, 4);
        assert_eq!(a1, a4);
    }

    #[test]
    fn adaptive_swing_saves_gamma_bits() {
        // With few γ bits, enabling the channel-adaptive swing should not
        // hurt and typically helps small-C_in layers (the §II claim).
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        for gb in [1u32, 2] {
            let fixed = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(5, gb, false) };
            let adapt = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(5, gb, true) };
            let a_f = eval_cim(&mlp, &test, &p, &fixed);
            let a_a = eval_cim(&mlp, &test, &p, &adapt);
            assert!(a_a + 0.02 >= a_f, "gb={gb}: fixed={a_f} adaptive={a_a}");
        }
    }

    #[test]
    fn graph_delegation_is_exact() {
        // eval_cim is the Dense-only graph: evaluating the hand-built
        // graph directly must give the identical accuracy (one quantize/
        // reconstruct/noise code path, not two).
        let (mlp, test) = trained();
        let p = MacroParams::paper();
        for cfg in [
            EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(8, 5, true) },
            EvalCfg::new(5, 2, false), // with noise: same seed, same draws
        ] {
            let via_mlp = eval_cim(&mlp, &test, &p, &cfg);
            let graph = crate::nn::graph::Graph::from_mlp("mlp", &mlp);
            let via_graph =
                crate::nn::graph::eval_graph(&graph, &test, &p, &cfg).unwrap();
            assert_eq!(via_mlp, via_graph);
        }
    }
}
