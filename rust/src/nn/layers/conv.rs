//! The 3×3 convolution node: float weights in natural patch order.
//!
//! Weights are stored `[c_out × (9·c_in)]` with the patch feature index
//! `tap * c_in + ch` — the same (tap-major, channel-minor) layout
//! [`crate::dataflow::im2col::patch_at`] produces and the layout the
//! macro's physical row order ([`crate::dataflow::im2col::row_order`])
//! permutes from. [`Conv3x3::forward_image`] is the naive nested-loop
//! float reference; the quantized macro execution in
//! [`crate::nn::graph`] must reproduce it exactly (up to the macro
//! contract's quantization), which the property tests assert.

use super::AbnSpec;
use crate::util::rng::Rng;

/// A 3×3 convolution (zero padding 1, stride 1) with per-channel bias.
#[derive(Clone, Debug)]
pub struct Conv3x3 {
    /// Input channels.
    pub c_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Float weights `[c_out × (9·c_in)]`, natural patch order
    /// (`tap * c_in + ch`).
    pub w: Vec<f32>,
    /// Per-output-channel bias.
    pub b: Vec<f32>,
    /// Per-layer CIM mapping overrides.
    pub abn: AbnSpec,
}

impl Conv3x3 {
    /// He-initialized random kernel (the fan-in is the 9·c_in patch).
    pub fn new(c_in: usize, c_out: usize, rng: &mut Rng) -> Self {
        let fan_in = 9 * c_in;
        let scale = (2.0 / fan_in as f64).sqrt();
        let w = (0..c_out * fan_in)
            .map(|_| (rng.gaussian() * scale) as f32)
            .collect();
        Conv3x3 { c_in, c_out, w, b: vec![0.0; c_out], abn: AbnSpec::INHERIT }
    }

    /// Build from explicit weights/bias (tests, trained imports).
    pub fn from_weights(c_in: usize, c_out: usize, w: Vec<f32>, b: Vec<f32>) -> Self {
        assert_eq!(w.len(), c_out * 9 * c_in);
        assert_eq!(b.len(), c_out);
        Conv3x3 { c_in, c_out, w, b, abn: AbnSpec::INHERIT }
    }

    /// The weight row for output channel `oc` (natural patch order).
    pub fn w_row(&self, oc: usize) -> &[f32] {
        &self.w[oc * 9 * self.c_in..(oc + 1) * 9 * self.c_in]
    }

    /// Naive float convolution of one CHW image (zero padding 1,
    /// stride 1); `out` is `[c_out × h × w]` CHW.
    pub fn forward_image(&self, x: &[f32], h: usize, w: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.c_in * h * w);
        debug_assert_eq!(out.len(), self.c_out * h * w);
        for oc in 0..self.c_out {
            let wrow = self.w_row(oc);
            for oy in 0..h {
                for ox in 0..w {
                    let mut acc = self.b[oc];
                    for tap in 0..9 {
                        let iy = (oy + tap / 3) as isize - 1;
                        let ix = (ox + tap % 3) as isize - 1;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue; // zero padding
                        }
                        let base = iy as usize * w + ix as usize;
                        for ch in 0..self.c_in {
                            acc += wrow[tap * self.c_in + ch] * x[ch * h * w + base];
                        }
                    }
                    out[oc * h * w + oy * w + ox] = acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_reproduces_the_input() {
        // Center tap (tap 4) of channel 0 set to 1: output = input channel.
        let (c_in, h, w) = (2usize, 4usize, 5usize);
        let mut weights = vec![0f32; 9 * c_in];
        weights[4 * c_in] = 1.0;
        let conv = Conv3x3::from_weights(c_in, 1, weights, vec![0.0]);
        let x: Vec<f32> = (0..c_in * h * w).map(|i| i as f32).collect();
        let mut out = vec![0f32; h * w];
        conv.forward_image(&x, h, w, &mut out);
        assert_eq!(out, x[..h * w].to_vec());
    }

    #[test]
    fn border_taps_read_zero_padding() {
        // All-ones 1-channel kernel on an all-ones image: interior sums 9,
        // edges 6, corners 4.
        let conv = Conv3x3::from_weights(1, 1, vec![1.0; 9], vec![0.0]);
        let (h, w) = (3usize, 3usize);
        let mut out = vec![0f32; h * w];
        conv.forward_image(&[1.0; 9], h, w, &mut out);
        assert_eq!(out, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn bias_offsets_every_pixel() {
        let conv = Conv3x3::from_weights(1, 2, vec![0.0; 18], vec![0.5, -1.0]);
        let mut out = vec![0f32; 2 * 4];
        conv.forward_image(&[0.0; 4], 2, 2, &mut out);
        assert!(out[..4].iter().all(|&v| v == 0.5));
        assert!(out[4..].iter().all(|&v| v == -1.0));
    }
}
