//! Typed nodes of the nn-side layer-graph IR (see [`crate::nn::graph`]).
//!
//! A [`Node`] is one step of a feed-forward CNN expressed the way the
//! paper's workloads are built: 3×3 convolutions and dense matmuls that
//! run *on the macro*, interleaved with the digital glue (ReLU, 2×2
//! pooling, flatten) that runs in the accelerator's post-ADC datapath.
//! Each macro-mapped node carries an [`AbnSpec`] — the per-layer CIM
//! mapping knobs (r_in/r_out precision, ABN gain bits, channel-adaptive
//! swing) that override the graph-level [`EvalCfg`] when set.
//!
//! Float forwards here are the *calibration* path: the quantized macro
//! execution lives in [`crate::nn::graph`], and the float reference for
//! conv layers in [`conv::Conv3x3::forward_image`] doubles as the naive
//! nested-loop oracle the property tests compare against.

pub mod conv;

pub use conv::Conv3x3;

use crate::coordinator::executor::apply_pool;
use crate::coordinator::manifest::Pool;
use crate::nn::cim_eval::EvalCfg;
use crate::nn::mlp::Dense;
use anyhow::{bail, Result};

/// Per-node overrides of the graph-level CIM mapping configuration —
/// the knobs the silicon exposes per layer (§II/§III.D): input/output
/// precision, ABN gain bits and the channel-adaptive DPL swing. `None`
/// inherits the graph-level [`EvalCfg`].
#[derive(Clone, Copy, Debug, Default)]
pub struct AbnSpec {
    /// Input (activation) precision in bits, 1..=8.
    pub r_in: Option<u32>,
    /// Output (ADC) precision in bits, 1..=8.
    pub r_out: Option<u32>,
    /// ABN gain quantization bits.
    pub gamma_bits: Option<u32>,
    /// Channel-adaptive DPL swing on/off.
    pub adaptive_swing: Option<bool>,
    /// Equivalent output noise σ in ADC LSB injected at this node —
    /// the autotuner sets this to the probed σ of the node's own
    /// `(r_in, r_out)` operating point.
    pub noise_lsb: Option<f64>,
}

impl AbnSpec {
    /// Inherit every knob from the graph-level configuration.
    pub const INHERIT: AbnSpec = AbnSpec {
        r_in: None,
        r_out: None,
        gamma_bits: None,
        adaptive_swing: None,
        noise_lsb: None,
    };

    /// Resolve against the graph-level configuration.
    pub fn resolve(&self, cfg: &EvalCfg) -> EvalCfg {
        EvalCfg {
            r_in: self.r_in.unwrap_or(cfg.r_in),
            r_out: self.r_out.unwrap_or(cfg.r_out),
            gamma_bits: self.gamma_bits.unwrap_or(cfg.gamma_bits),
            adaptive_swing: self.adaptive_swing.unwrap_or(cfg.adaptive_swing),
            noise_lsb: self.noise_lsb.unwrap_or(cfg.noise_lsb),
            ..*cfg
        }
    }
}

/// 2×2 pooling flavor (stride 2, floor crop on odd dims — the same
/// semantics as the manifest executor's [`Pool::Max2`]/[`Pool::Avg2`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    /// Max-pooling.
    Max,
    /// Average-pooling.
    Avg,
}

impl PoolKind {
    /// The manifest-side pool this node lowers to.
    pub fn to_manifest(self) -> Pool {
        match self {
            PoolKind::Max => Pool::Max2,
            PoolKind::Avg => Pool::Avg2,
        }
    }
}

/// A dense (fully-connected) graph node: the float layer plus its CIM
/// mapping overrides.
#[derive(Clone, Debug)]
pub struct DenseNode {
    /// The float dense layer (weights + bias).
    pub dense: Dense,
    /// Per-layer CIM mapping overrides.
    pub abn: AbnSpec,
}

impl DenseNode {
    /// Wrap a float dense layer with inherit-everything CIM overrides.
    pub fn new(dense: Dense) -> Self {
        DenseNode { dense, abn: AbnSpec::INHERIT }
    }
}

/// One node of the layer graph.
#[derive(Clone, Debug)]
pub enum Node {
    /// 3×3 convolution, zero padding 1, stride 1 — lowered onto the
    /// macro through the §IV streaming im2col row order.
    Conv3x3(Conv3x3),
    /// Dense matmul — the MLP special case.
    Dense(DenseNode),
    /// 2×2 stride-2 pooling (digital, post-ADC).
    Pool2x2(PoolKind),
    /// ReLU (digital, post-ADC).
    Relu,
    /// CHW → flat feature vector (layout no-op; shape change only).
    Flatten,
}

impl Node {
    /// Short kind tag for names/logs.
    pub fn kind(&self) -> &'static str {
        match self {
            Node::Conv3x3(_) => "conv3",
            Node::Dense(_) => "dense",
            Node::Pool2x2(_) => "pool2",
            Node::Relu => "relu",
            Node::Flatten => "flatten",
        }
    }

    /// Does this node run on the macro (vs the digital datapath)?
    pub fn is_cim(&self) -> bool {
        matches!(self, Node::Conv3x3(_) | Node::Dense(_))
    }

    /// Shape inference: output shape for `in_shape`, or an error when
    /// the node cannot consume it.
    pub fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        match self {
            Node::Conv3x3(c) => {
                let [ci, h, w] = chw(in_shape)?;
                if ci != c.c_in {
                    bail!("conv3x3 expects {} input channels, got shape {in_shape:?}", c.c_in);
                }
                Ok(vec![c.c_out, h, w])
            }
            Node::Pool2x2(_) => {
                let [c, h, w] = chw(in_shape)?;
                if h < 2 || w < 2 {
                    bail!("pool2x2 needs spatial dims >= 2, got {in_shape:?}");
                }
                Ok(vec![c, h / 2, w / 2])
            }
            Node::Relu => Ok(in_shape.to_vec()),
            Node::Flatten => Ok(vec![in_shape.iter().product()]),
            Node::Dense(d) => {
                if in_shape.len() != 1 || in_shape[0] != d.dense.n_in {
                    bail!(
                        "dense expects a flat [{}] input, got shape {in_shape:?} \
                         (insert a Flatten node?)",
                        d.dense.n_in
                    );
                }
                Ok(vec![d.dense.n_out])
            }
        }
    }

    /// Float forward of one activation (the calibration / reference
    /// path; the quantized macro path lives in [`crate::nn::graph`]).
    pub fn forward_float(&self, x: &[f32], in_shape: &[usize]) -> Result<Vec<f32>> {
        let out_shape = self.out_shape(in_shape)?;
        Ok(match self {
            Node::Conv3x3(c) => {
                let [_, h, w] = chw(in_shape)?;
                let mut out = vec![0f32; out_shape.iter().product()];
                c.forward_image(x, h, w, &mut out);
                out
            }
            Node::Dense(d) => {
                let mut y = vec![0f32; d.dense.n_out];
                d.dense.forward(x, &mut y);
                y
            }
            Node::Pool2x2(kind) => {
                let [c, h, w] = chw(in_shape)?;
                apply_pool(x, c, h, w, kind.to_manifest()).0
            }
            Node::Relu => x.iter().map(|&v| v.max(0.0)).collect(),
            Node::Flatten => x.to_vec(),
        })
    }
}

/// Destructure a CHW shape.
pub(crate) fn chw(shape: &[usize]) -> Result<[usize; 3]> {
    match shape {
        [c, h, w] => Ok([*c, *h, *w]),
        other => bail!("expected a CHW shape, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn shape_inference_through_a_cnn_stack() {
        let mut rng = Rng::new(1);
        let conv = Node::Conv3x3(Conv3x3::new(3, 8, &mut rng));
        let shape = conv.out_shape(&[3, 16, 16]).unwrap();
        assert_eq!(shape, vec![8, 16, 16]);
        let shape = Node::Pool2x2(PoolKind::Max).out_shape(&shape).unwrap();
        assert_eq!(shape, vec![8, 8, 8]);
        let shape = Node::Flatten.out_shape(&shape).unwrap();
        assert_eq!(shape, vec![512]);
        let dense = Node::Dense(DenseNode::new(Dense::new(512, 10, &mut rng)));
        assert_eq!(dense.out_shape(&shape).unwrap(), vec![10]);
    }

    #[test]
    fn shape_errors_are_typed_out() {
        let mut rng = Rng::new(2);
        let conv = Node::Conv3x3(Conv3x3::new(4, 8, &mut rng));
        assert!(conv.out_shape(&[3, 8, 8]).is_err(), "channel mismatch");
        assert!(conv.out_shape(&[16]).is_err(), "flat input into conv");
        let dense = Node::Dense(DenseNode::new(Dense::new(16, 4, &mut rng)));
        assert!(dense.out_shape(&[4, 2, 2]).is_err(), "unflattened input");
        assert!(Node::Pool2x2(PoolKind::Avg).out_shape(&[1, 1, 1]).is_err());
    }

    #[test]
    fn abn_spec_resolution_overrides_only_set_fields() {
        let base = EvalCfg::new(8, 5, true);
        let spec = AbnSpec { r_out: Some(4), adaptive_swing: Some(false), ..AbnSpec::INHERIT };
        let resolved = spec.resolve(&base);
        assert_eq!(resolved.r_out, 4);
        assert_eq!(resolved.r_in, base.r_in);
        assert_eq!(resolved.gamma_bits, base.gamma_bits);
        assert!(!resolved.adaptive_swing);
        assert_eq!(resolved.noise_lsb, base.noise_lsb);
    }

    #[test]
    fn pool_and_relu_float_forward() {
        let x = vec![1.0, -2.0, 3.0, 4.0];
        let r = Node::Relu.forward_float(&x, &[1, 2, 2]).unwrap();
        assert_eq!(r, vec![1.0, 0.0, 3.0, 4.0]);
        let p = Node::Pool2x2(PoolKind::Max).forward_float(&x, &[1, 2, 2]).unwrap();
        assert_eq!(p, vec![4.0]);
    }
}
