//! Quantization-aware forward/backward of one macro-mapped node.
//!
//! The forward half is the *inference* contract, verbatim: the same
//! [`macro_contract_masked`] expression the graph executor evaluates —
//! r_in-grid activation quantization, 4b-antipodal weights, the Eq. 7
//! ADC code (γ gain, floor, rails), the configured equivalent output
//! noise, offset-binary reconstruction. What training adds is the
//! *straight-through estimator* backward: each quantizer acts as the
//! identity inside its representable range and blocks gradients where it
//! clipped —
//!
//! * activations: gradients pass where `x / a_scale ∈ [−½, M+½]` (the
//!   rounding basin of a representable code), stop where the input grid
//!   clamped;
//! * the ADC: gradients pass where the code stayed inside `[0, top]`,
//!   stop where the conversion railed;
//! * weights: the antipodal grid spans the per-tensor max, so every
//!   master weight is representable and gradients always pass; the
//!   backward matmuls use the *dequantized* values (`w_q · w_scale`,
//!   `x_q · a_scale`) the macro actually multiplied.
//!
//! The bias is applied after the ADC (the ABN offset path), so its
//! gradient is never masked by the rails.
//!
//! Conv nodes replicate the macro's im2col border convention: out-of-map
//! taps read the mid-rail constant (signed factor +1), not zero — the
//! network trains against the exact arithmetic it will be lowered onto.
//!
//! Both halves are threaded. The forward dots go through the engine's
//! precision/ISA-adaptive [`kernels`] dispatch (the quantized weights
//! and signed factors are exact small integers, so the i32 kernels are
//! bit-identical to the f64 rowdot). The backward pass splits the batch
//! into **fixed-size** image chunks ([`BACKWARD_IMG_CHUNK`]) via
//! [`kernels::scoped_chunk_map`] and reduces the per-chunk gradient
//! partials in chunk order — the chunk grid depends only on the batch
//! size, never on the worker count, so training results are
//! bit-identical across worker counts.

use crate::config::params::MacroParams;
use crate::engine::packed::NodeKernel;
use crate::engine::{arena, kernels};
use crate::nn::graph::{macro_contract_masked, permute_conv_rows, quantize_weights, CimKind, QNode};
use crate::nn::layers::Node;
use crate::util::rng::Rng;

/// Fixed image-chunk size of the parallel backward pass. Each chunk's
/// gradient partial is accumulated image-sequentially and the partials
/// are reduced in chunk order, so the float result depends only on the
/// batch size — not on how many workers happened to run the chunks.
pub(crate) const BACKWARD_IMG_CHUNK: usize = 8;

/// Everything the backward pass needs from one quantized forward.
pub(crate) struct CimCache {
    /// Dequantized inputs the macro actually saw (`x_q · a_scale`),
    /// `[n × in_len]` (conv: natural CHW).
    pub x_tilde: Vec<f32>,
    /// STE pass-through per input element (inside the r_in grid).
    pub in_mask: Vec<bool>,
    /// STE pass-through per output element (ADC stayed off the rails).
    pub out_mask: Vec<bool>,
}

/// Gradients of one node w.r.t. its master parameters and input.
pub(crate) struct NodeGrads {
    /// Natural-order weight gradient (dense `[n_out × n_in]`, conv
    /// `[c_out × 9·c_in]`).
    pub gw: Vec<f32>,
    pub gb: Vec<f32>,
    /// Gradient w.r.t. the node input, `[n × in_len]`.
    pub dx: Vec<f32>,
}

/// One macro-mapped node under training: the mapping state (recalibrated
/// per epoch) plus the natural-order quantized weights (refreshed after
/// every optimizer step).
pub(crate) struct TrainNode {
    /// Mapping state in the executor's layout (conv weights in macro row
    /// order) — `w_q`/`sum_w`/`w_scale`/`bias` are refreshed per step,
    /// `a_scale`/`alpha`/`gamma`/`cfg` per recalibration.
    pub q: QNode,
    /// Natural-order quantized weight levels (the layout the backward
    /// pass and the master weights use). For dense nodes this aliases
    /// `q.w_q`'s layout; for conv it is the un-permuted kernel.
    pub w_q_nat: Vec<f32>,
}

impl TrainNode {
    pub fn new(q: QNode, node: &Node) -> TrainNode {
        let mut t = TrainNode { q, w_q_nat: Vec::new() };
        t.refresh_weights(node);
        t
    }

    /// Adopt a freshly recalibrated mapping (new `a_scale`/`γ`/`α`) and
    /// re-derive the weight-dependent fields from the master weights.
    pub fn recalibrate(&mut self, q: QNode, node: &Node) {
        self.q = q;
        self.refresh_weights(node);
    }

    /// Re-quantize the master weights after an optimizer step — the
    /// forward half of the weight STE.
    pub fn refresh_weights(&mut self, node: &Node) {
        match node {
            Node::Dense(d) => {
                let (w_q, w_scale) = quantize_weights(&d.dense.w, d.dense.n_out, d.dense.n_in);
                self.q.sum_w = (0..d.dense.n_out)
                    .map(|o| w_q[o * d.dense.n_in..(o + 1) * d.dense.n_in].iter().sum())
                    .collect();
                self.w_q_nat = w_q.clone();
                self.q.w_q = w_q;
                self.q.w_scale = w_scale;
                self.q.bias = d.dense.b.clone();
            }
            Node::Conv3x3(c) => {
                let (w_nat, w_scale) = quantize_weights(&c.w, c.c_out, 9 * c.c_in);
                let (w_rows, rows) = permute_conv_rows(&w_nat, c.c_in, c.c_out);
                debug_assert_eq!(rows, self.q.rows);
                self.q.sum_w = (0..c.c_out)
                    .map(|oc| w_rows[oc * rows..(oc + 1) * rows].iter().sum())
                    .collect();
                self.w_q_nat = w_nat;
                self.q.w_q = w_rows;
                self.q.w_scale = w_scale;
                self.q.bias = c.b.clone();
            }
            other => unreachable!("TrainNode over a digital node {}", other.kind()),
        }
        self.rebuild_kernel();
    }

    /// Re-resolve the cached kernel form ([`NodeKernel`]) after the
    /// quantized weights changed — the train-side equivalent of the
    /// engine's deploy-time packing.
    fn rebuild_kernel(&mut self) {
        let (n_out, k) = match self.q.kind {
            CimKind::Dense { n_in, n_out } => (n_out, n_in),
            CimKind::Conv { c_out, .. } => (c_out, self.q.rows),
        };
        self.q.kernel = NodeKernel::build(&self.q.w_q, n_out, k, self.q.cfg.r_in);
    }

    /// Quantize a batch of activations onto the node's r_in grid.
    /// Returns `(x_q, x_tilde, in_mask)`; `x_q` comes from the scratch
    /// arena and the caller returns it with `arena::put_f32` once the
    /// kernel pass consumed it.
    fn quantize_input(&self, x: &[f32], m: f32) -> (Vec<f32>, Vec<f32>, Vec<bool>) {
        let a = self.q.a_scale;
        let mut x_q = arena::take_f32(x.len());
        let mut x_tilde = Vec::with_capacity(x.len());
        let mut in_mask = Vec::with_capacity(x.len());
        for &v in x {
            let t = v / a;
            in_mask.push((-0.5..=m + 0.5).contains(&t));
            let q = t.round().clamp(0.0, m);
            x_q.push(q);
            x_tilde.push(q * a);
        }
        (x_q, x_tilde, in_mask)
    }

    /// Quantized dense forward over a flat batch `[n × n_in]` — the
    /// executor's batched dense path plus the STE masks.
    pub fn forward_dense(
        &self,
        p: &MacroParams,
        x: &[f32],
        n: usize,
        workers: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, CimCache) {
        let (n_in, n_out) = match self.q.kind {
            CimKind::Dense { n_in, n_out } => (n_in, n_out),
            _ => unreachable!(),
        };
        let (m, half, top, lsb, dv_unit) = self.q.contract_consts(p);
        let (x_q, x_tilde, in_mask) = self.quantize_input(x, m);
        let mut dots = arena::take_f64(n * n_out);
        match &self.q.kernel {
            NodeKernel::I32 { wi, planes, .. } => {
                let mut sx_i = arena::take_i32(x_q.len());
                sx_i.extend(x_q.iter().map(|&q| (2.0 * q - m) as i32));
                let mut di = arena::take_i32(n * n_out);
                kernels::matmul_i32_packed_into(
                    &sx_i,
                    wi,
                    n,
                    n_in,
                    n_out,
                    workers,
                    Some(self.q.cfg.r_in),
                    planes.as_ref(),
                    &mut di,
                );
                dots.extend(di.iter().map(|&d| d as f64));
                arena::put_i32(di);
                arena::put_i32(sx_i);
            }
            NodeKernel::F64 { w64 } => {
                let mut sx = arena::take_f64(x_q.len());
                sx.extend(x_q.iter().map(|&q| (2.0 * q - m) as f64));
                dots.extend(kernels::rowdot_f64(&sx, w64, n, n_in, n_out, workers));
                arena::put_f64(sx);
            }
        }
        arena::put_f32(x_q);

        // lint:allow(hot-path-alloc) per-batch output + STE mask, returned in CimCache
        let mut out = vec![0f32; n * n_out];
        // lint:allow(hot-path-alloc) per-batch output + STE mask, returned in CimCache
        let mut out_mask = vec![false; n * n_out];
        for i in 0..n {
            for o in 0..n_out {
                let (y, ok) = macro_contract_masked(
                    &self.q,
                    dots[i * n_out + o],
                    o,
                    dv_unit,
                    lsb,
                    half,
                    top,
                    m,
                    rng,
                );
                out[i * n_out + o] = y;
                out_mask[i * n_out + o] = ok;
            }
        }
        arena::put_f64(dots);
        (out, CimCache { x_tilde, in_mask, out_mask })
    }

    /// Dense STE backward: `delta` is `∂L/∂y`, `[n × n_out]`. Splits the
    /// batch into fixed [`BACKWARD_IMG_CHUNK`]-image chunks across
    /// `workers` threads; results are bit-identical for every worker
    /// count (the chunk grid and reduction order never change).
    pub fn backward_dense(
        &self,
        cache: &CimCache,
        delta: &[f32],
        n: usize,
        workers: usize,
    ) -> NodeGrads {
        let (n_in, n_out) = match self.q.kind {
            CimKind::Dense { n_in, n_out } => (n_in, n_out),
            _ => unreachable!(),
        };
        let parts = kernels::scoped_chunk_map(n, BACKWARD_IMG_CHUNK, workers, |_, range| {
            self.backward_dense_range(cache, delta, n_in, n_out, range)
        });
        merge_grads(parts, n_out * n_in, n_out)
    }

    fn backward_dense_range(
        &self,
        cache: &CimCache,
        delta: &[f32],
        n_in: usize,
        n_out: usize,
        range: std::ops::Range<usize>,
    ) -> NodeGrads {
        let ws = self.q.w_scale;
        let mut gw = vec![0f32; n_out * n_in];
        let mut gb = vec![0f32; n_out];
        let mut dx = vec![0f32; range.len() * n_in];
        for i in range.clone() {
            let x_t = &cache.x_tilde[i * n_in..(i + 1) * n_in];
            let li = i - range.start;
            let dxi = &mut dx[li * n_in..(li + 1) * n_in];
            for o in 0..n_out {
                let d_raw = delta[i * n_out + o];
                if d_raw == 0.0 {
                    continue;
                }
                gb[o] += d_raw; // bias is post-ADC: never rail-masked
                if !cache.out_mask[i * n_out + o] {
                    continue;
                }
                let grow = &mut gw[o * n_in..(o + 1) * n_in];
                let wrow = &self.w_q_nat[o * n_in..(o + 1) * n_in];
                for j in 0..n_in {
                    grow[j] += d_raw * x_t[j];
                    dxi[j] += d_raw * wrow[j] * ws;
                }
            }
            for (v, &ok) in dxi.iter_mut().zip(&cache.in_mask[i * n_in..(i + 1) * n_in]) {
                if !ok {
                    *v = 0.0;
                }
            }
        }
        NodeGrads { gw, gb, dx }
    }

    /// Quantized conv forward over a flat CHW batch `[n × c·h·w]` — the
    /// executor's im2col batch path (mid-rail borders, macro row order)
    /// plus the STE masks.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_conv(
        &self,
        p: &MacroParams,
        x: &[f32],
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        workers: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, CimCache) {
        let c_out = self.q.n_out();
        let (m, half, top, lsb, dv_unit) = self.q.contract_consts(p);
        let (x_q, x_tilde, in_mask) = self.quantize_input(x, m);

        let in_len = c * h * w;
        let n_pix = h * w;
        let rows = self.q.rows;
        let r_in = self.q.cfg.r_in;
        let mut dots = arena::take_f64(n * n_pix * c_out);
        match &self.q.kernel {
            NodeKernel::I32 { wi, planes, .. } => {
                let mut images_q = arena::take_u8(x_q.len());
                images_q.extend(x_q.iter().map(|&q| q as u8));
                let mut di = arena::take_i32(n * n_pix * c_out);
                let (oh, ow) = kernels::conv3x3_direct_packed_into(
                    &images_q,
                    n,
                    c,
                    h,
                    w,
                    1,
                    r_in,
                    wi,
                    rows,
                    c_out,
                    workers,
                    planes.as_ref(),
                    &mut di,
                );
                debug_assert_eq!((oh, ow), (h, w));
                dots.extend(di.iter().map(|&d| d as f64));
                arena::put_i32(di);
                arena::put_u8(images_q);
            }
            NodeKernel::F64 { w64 } => {
                let images_q: Vec<Vec<u8>> = x_q
                    .chunks(in_len)
                    // lint:allow(hot-path-alloc) f64 fallback arm: per-batch buffers on the rare non-i32 path
                    .map(|img| img.iter().map(|&q| q as u8).collect())
                    // lint:allow(hot-path-alloc) f64 fallback arm (see above)
                    .collect();
                let (sx_i, oh, ow) =
                    kernels::conv3x3_signed_rows(&images_q, c, h, w, 1, r_in, rows);
                debug_assert_eq!((oh, ow), (h, w));
                // lint:allow(hot-path-alloc) f64 fallback arm (see above)
                let sx: Vec<f64> = sx_i.iter().map(|&v| v as f64).collect();
                dots.extend(kernels::rowdot_f64(&sx, w64, n * n_pix, rows, c_out, workers));
            }
        }
        arena::put_f32(x_q);

        // lint:allow(hot-path-alloc) per-batch output + STE mask, returned in CimCache
        let mut out = vec![0f32; n * c_out * n_pix];
        // lint:allow(hot-path-alloc) per-batch output + STE mask, returned in CimCache
        let mut out_mask = vec![false; n * c_out * n_pix];
        for img in 0..n {
            let fmap = &mut out[img * c_out * n_pix..(img + 1) * c_out * n_pix];
            let fmask = &mut out_mask[img * c_out * n_pix..(img + 1) * c_out * n_pix];
            for pix in 0..n_pix {
                let d = &dots[(img * n_pix + pix) * c_out..(img * n_pix + pix + 1) * c_out];
                for (oc, &dot) in d.iter().enumerate() {
                    let (y, ok) = macro_contract_masked(
                        &self.q, dot, oc, dv_unit, lsb, half, top, m, rng,
                    );
                    fmap[oc * n_pix + pix] = y;
                    fmask[oc * n_pix + pix] = ok;
                }
            }
        }
        arena::put_f64(dots);
        (out, CimCache { x_tilde, in_mask, out_mask })
    }

    /// Conv STE backward. Border taps read the mid-rail constant in the
    /// forward, so they contribute a constant-input term to the weight
    /// gradient and no input gradient. Parallelized over fixed
    /// [`BACKWARD_IMG_CHUNK`]-image chunks like
    /// [`backward_dense`](Self::backward_dense) — bit-identical across
    /// worker counts.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_conv(
        &self,
        cache: &CimCache,
        delta: &[f32],
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        workers: usize,
    ) -> NodeGrads {
        let c_out = self.q.n_out();
        let parts = kernels::scoped_chunk_map(n, BACKWARD_IMG_CHUNK, workers, |_, range| {
            self.backward_conv_range(cache, delta, c, h, w, range)
        });
        merge_grads(parts, c_out * 9 * c, c_out)
    }

    #[allow(clippy::too_many_arguments)]
    fn backward_conv_range(
        &self,
        cache: &CimCache,
        delta: &[f32],
        c: usize,
        h: usize,
        w: usize,
        range: std::ops::Range<usize>,
    ) -> NodeGrads {
        let c_out = self.q.n_out();
        let ws = self.q.w_scale;
        // Mid-rail border: signed factor +1 ⇒ x̃ = a_scale · 2^(r_in−1).
        let pad_x = self.q.a_scale * ((1u32 << self.q.cfg.r_in) / 2) as f32;
        let n_pix = h * w;
        let in_len = c * n_pix;
        let mut gw = vec![0f32; c_out * 9 * c];
        let mut gb = vec![0f32; c_out];
        let mut dx = vec![0f32; range.len() * in_len];
        for img in range.clone() {
            let x_t = &cache.x_tilde[img * in_len..(img + 1) * in_len];
            let li = img - range.start;
            let dxi = &mut dx[li * in_len..(li + 1) * in_len];
            let dimg = &delta[img * c_out * n_pix..(img + 1) * c_out * n_pix];
            let mimg = &cache.out_mask[img * c_out * n_pix..(img + 1) * c_out * n_pix];
            for oc in 0..c_out {
                let grow = &mut gw[oc * 9 * c..(oc + 1) * 9 * c];
                let wrow = &self.w_q_nat[oc * 9 * c..(oc + 1) * 9 * c];
                for oy in 0..h {
                    for ox in 0..w {
                        let pix = oy * w + ox;
                        let d_raw = dimg[oc * n_pix + pix];
                        if d_raw == 0.0 {
                            continue;
                        }
                        gb[oc] += d_raw;
                        if !mimg[oc * n_pix + pix] {
                            continue;
                        }
                        for tap in 0..9 {
                            let iy = (oy + tap / 3) as isize - 1;
                            let ix = (ox + tap % 3) as isize - 1;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                for ch in 0..c {
                                    grow[tap * c + ch] += d_raw * pad_x;
                                }
                                continue;
                            }
                            let base = iy as usize * w + ix as usize;
                            for ch in 0..c {
                                grow[tap * c + ch] += d_raw * x_t[ch * n_pix + base];
                                dxi[ch * n_pix + base] += d_raw * wrow[tap * c + ch] * ws;
                            }
                        }
                    }
                }
            }
            for (v, &ok) in dxi.iter_mut().zip(&cache.in_mask[img * in_len..(img + 1) * in_len])
            {
                if !ok {
                    *v = 0.0;
                }
            }
        }
        NodeGrads { gw, gb, dx }
    }
}

/// Reduce per-chunk gradient partials **in chunk order**. Combined with
/// the fixed chunk grid of [`kernels::scoped_chunk_map`], this makes
/// the parallel backward deterministic and worker-count invariant.
fn merge_grads(parts: Vec<NodeGrads>, w_len: usize, b_len: usize) -> NodeGrads {
    let mut gw = vec![0f32; w_len];
    let mut gb = vec![0f32; b_len];
    let mut dx = Vec::new();
    for part in parts {
        for (acc, v) in gw.iter_mut().zip(&part.gw) {
            *acc += v;
        }
        for (acc, v) in gb.iter_mut().zip(&part.gb) {
            *acc += v;
        }
        dx.extend_from_slice(&part.dx);
    }
    NodeGrads { gw, gb, dx }
}
