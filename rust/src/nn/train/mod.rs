//! CIM-aware training over the layer-graph IR — the paper's missing
//! pillar: "including the post-silicon equivalent noise within a
//! CIM-aware CNN training framework".
//!
//! [`train_graph`] runs minibatch SGD with momentum — or Adam, see
//! [`OptimizerKind`] — and softmax
//! cross-entropy over a [`Graph`], where every macro-mapped node's
//! forward is the *inference* contract itself (the same
//! quantize/reconstruct/noise expression the executor evaluates — see
//! the `qat` submodule) and the backward is its straight-through
//! estimator. Each
//! forward injects the macro's equivalent output noise, so the network
//! learns weights whose decision margins survive the analog conversion —
//! distribution-aware robustness, not just quantization awareness.
//!
//! Three noise sources are selectable through [`NoiseInjection`]:
//! nothing (pure QAT), a fixed σ in ADC LSB, or [`NoiseInjection::Probe`]
//! — σ measured from the circuit-behavioral analog backend at the
//! configured supply/corner via
//! [`engine::noise::probe_equivalent_noise`], the software image of
//! characterizing a fabricated die and feeding the measurement back into
//! training.
//!
//! The mapping (activation ranges, ABN gains, adaptive swings) is
//! recalibrated from the evolving float weights every
//! [`TrainConfig::recalibrate_every`] epochs — the training-time
//! counterpart of the paper's distribution-aware data reshaping — and a
//! trained graph lowers through the existing [`Graph::lower`] path
//! straight into the serving stack.

pub(crate) mod qat;

use crate::config::params::MacroParams;
use crate::engine;
use crate::nn::cim_eval::EvalCfg;
use crate::nn::dataset::Dataset;
use crate::nn::graph::{Graph, MappedGraph};
use crate::nn::layers::{chw, Node, PoolKind};
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use qat::TrainNode;

/// Where the equivalent output noise injected during training comes
/// from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoiseInjection {
    /// No injection: plain quantization-aware training.
    Off,
    /// Fixed equivalent output noise, in ADC LSB (the γ-dependent
    /// scaling of the macro contract applies on top, exactly as at
    /// inference).
    Lsb(f64),
    /// Measure σ from the circuit-behavioral analog backend at the
    /// configured supply/corner ([`engine::noise::probe_equivalent_noise`])
    /// and train against it — the paper's post-silicon loop.
    Probe,
}

/// Per-epoch learning-rate schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LrSchedule {
    /// Constant learning rate (the pre-schedule behavior).
    #[default]
    Const,
    /// Cosine annealing from the base `lr` down to 2% of it over the
    /// configured epochs. Pure function of (epoch, epochs), so two runs
    /// with the same seed stay bit-identical.
    Cosine,
}

impl LrSchedule {
    /// CLI spelling → schedule (`cosine` | `const`).
    pub fn parse(s: &str) -> Option<LrSchedule> {
        match s {
            "const" => Some(LrSchedule::Const),
            "cosine" => Some(LrSchedule::Cosine),
            _ => None,
        }
    }

    /// Protocol/CLI spelling of this schedule.
    pub fn name(self) -> &'static str {
        match self {
            LrSchedule::Const => "const",
            LrSchedule::Cosine => "cosine",
        }
    }

    /// Effective learning rate for 0-based `epoch` of `epochs`. Cosine
    /// starts at `base` (epoch 0) and anneals to `0.02 * base` at the
    /// last epoch; a 1-epoch run just uses `base`.
    pub fn lr_at(self, base: f32, epoch: usize, epochs: usize) -> f32 {
        match self {
            LrSchedule::Const => base,
            LrSchedule::Cosine => {
                if epochs <= 1 {
                    return base;
                }
                let floor = 0.02 * base;
                let t = epoch as f32 / (epochs - 1) as f32;
                floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

/// Which optimizer moves the master float weights each minibatch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Minibatch SGD with momentum (the historical default).
    #[default]
    Sgd,
    /// Adam: bias-corrected first/second moment estimates with
    /// per-tensor state (β₁ = 0.9, β₂ = 0.999, ε = 1e-8).
    Adam,
}

impl OptimizerKind {
    /// CLI spelling → optimizer (`sgd` | `adam`).
    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s {
            "sgd" => Some(OptimizerKind::Sgd),
            "adam" => Some(OptimizerKind::Adam),
            _ => None,
        }
    }

    /// Protocol/CLI spelling of this optimizer.
    pub fn name(self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }
}

/// Hyper-parameters and CIM operating point of one training run.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Passes over the training set.
    pub epochs: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Base learning rate.
    pub lr: f32,
    /// How `lr` evolves across epochs.
    pub lr_schedule: LrSchedule,
    /// Which update rule consumes the STE gradients.
    pub optimizer: OptimizerKind,
    /// SGD momentum coefficient (ignored by Adam).
    pub momentum: f32,
    /// Seeds minibatch shuffling and the noise draws; two runs with the
    /// same config and seed are bit-identical.
    pub seed: u64,
    /// Where the injected equivalent-noise σ comes from.
    pub noise: NoiseInjection,
    /// Input activation precision the network trains (and deploys) at.
    pub r_in: u32,
    /// ADC output precision.
    pub r_out: u32,
    /// Bits available to represent the ABN gain (0 ⇒ γ ≡ 1).
    pub gamma_bits: u32,
    /// Channel-adaptive DPL swing vs fixed full-array swing.
    pub adaptive_swing: bool,
    /// Calibration subset size for the per-epoch remapping.
    pub calib_n: usize,
    /// Remap (activation ranges, γ, α) every this many epochs (0 ⇒ only
    /// once, before the first epoch).
    pub recalibrate_every: usize,
    /// Worker threads for the batched matmuls *and* the chunked backward
    /// pass (does not affect results — the forward kernels are
    /// bit-identical across splits, and the backward reduces fixed-size
    /// image-chunk partials in chunk order regardless of worker count).
    pub workers: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 6,
            batch: 32,
            lr: 0.04,
            lr_schedule: LrSchedule::Const,
            optimizer: OptimizerKind::Sgd,
            momentum: 0.9,
            seed: 7,
            noise: NoiseInjection::Lsb(0.5),
            r_in: 8,
            r_out: 6,
            gamma_bits: 5,
            adaptive_swing: true,
            calib_n: 96,
            recalibrate_every: 1,
            workers: 0, // 0 ⇒ engine::default_workers()
        }
    }
}

impl TrainConfig {
    /// The graph-level evaluation config this run trains against, with
    /// the resolved injection σ.
    pub fn eval_cfg(&self, noise_lsb: f64) -> EvalCfg {
        EvalCfg {
            r_out: self.r_out,
            r_in: self.r_in,
            gamma_bits: self.gamma_bits,
            adaptive_swing: self.adaptive_swing,
            noise_lsb,
            seed: self.seed,
        }
    }

    /// Resolve [`TrainConfig::noise`] to a σ in ADC LSB (probing the
    /// analog backend when asked to).
    pub fn resolve_noise_lsb(&self, p: &MacroParams) -> Result<f64> {
        match self.noise {
            NoiseInjection::Off => Ok(0.0),
            NoiseInjection::Lsb(v) => {
                ensure!(v.is_finite() && v >= 0.0, "noise σ must be finite and >= 0, got {v}");
                Ok(v)
            }
            NoiseInjection::Probe => {
                let stats =
                    engine::noise::probe_equivalent_noise(p, self.r_in, self.r_out, self.seed)?;
                Ok(stats.total_lsb())
            }
        }
    }

    fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            engine::default_workers()
        } else {
            self.workers
        }
    }
}

/// What one training run did.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Mean minibatch loss per epoch (measured with the configured noise
    /// injected, so it fluctuates with σ > 0).
    pub epoch_losses: Vec<f64>,
    /// Optimizer steps taken.
    pub steps: u64,
    /// Images consumed across all epochs.
    pub images: u64,
    /// Wall-clock training time.
    pub wall_seconds: f64,
    /// The σ actually injected (resolved from [`NoiseInjection`]).
    pub noise_lsb: f64,
}

impl TrainReport {
    /// Mean minibatch loss of the last epoch (NaN before any epoch).
    pub fn final_loss(&self) -> f64 {
        self.epoch_losses.last().copied().unwrap_or(f64::NAN)
    }

    /// Optimizer steps per wall-clock second.
    pub fn steps_per_s(&self) -> f64 {
        self.steps as f64 / self.wall_seconds.max(1e-12)
    }

    /// Images consumed per wall-clock second.
    pub fn images_per_s(&self) -> f64 {
        self.images as f64 / self.wall_seconds.max(1e-12)
    }
}

const ADAM_BETA1: f32 = 0.9;
const ADAM_BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Per-parameter-tensor optimizer state ([`OptimizerKind`] resolved to
/// its buffers).
enum OptState {
    /// SGD momentum velocities.
    Sgd { vw: Vec<f32>, vb: Vec<f32> },
    /// Adam first/second moments plus the bias-correction step count.
    Adam {
        mw: Vec<f32>,
        vw: Vec<f32>,
        mb: Vec<f32>,
        vb: Vec<f32>,
        t: u64,
    },
}

impl OptState {
    fn new(kind: OptimizerKind, w_len: usize, b_len: usize) -> OptState {
        match kind {
            OptimizerKind::Sgd => OptState::Sgd { vw: vec![0.0; w_len], vb: vec![0.0; b_len] },
            OptimizerKind::Adam => OptState::Adam {
                mw: vec![0.0; w_len],
                vw: vec![0.0; w_len],
                mb: vec![0.0; b_len],
                vb: vec![0.0; b_len],
                t: 0,
            },
        }
    }

    fn step(&mut self, w: &mut [f32], b: &mut [f32], g: &qat::NodeGrads, lr: f32, mu: f32) {
        match self {
            OptState::Sgd { vw, vb } => {
                for (i, wv) in w.iter_mut().enumerate() {
                    vw[i] = mu * vw[i] - lr * g.gw[i];
                    *wv += vw[i];
                }
                for (i, bv) in b.iter_mut().enumerate() {
                    vb[i] = mu * vb[i] - lr * g.gb[i];
                    *bv += vb[i];
                }
            }
            OptState::Adam { mw, vw, mb, vb, t } => {
                *t += 1;
                let tt = (*t).min(i32::MAX as u64) as i32;
                let bc1 = 1.0 - ADAM_BETA1.powi(tt);
                let bc2 = 1.0 - ADAM_BETA2.powi(tt);
                adam_tensor(w, &g.gw, mw, vw, lr, bc1, bc2);
                adam_tensor(b, &g.gb, mb, vb, lr, bc1, bc2);
            }
        }
    }
}

/// One bias-corrected Adam update over a parameter tensor. Element
/// order is ascending, so updates are bit-identical run to run.
fn adam_tensor(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
) {
    for (i, pv) in p.iter_mut().enumerate() {
        m[i] = ADAM_BETA1 * m[i] + (1.0 - ADAM_BETA1) * g[i];
        v[i] = ADAM_BETA2 * v[i] + (1.0 - ADAM_BETA2) * g[i] * g[i];
        let m_hat = m[i] / bc1;
        let v_hat = v[i] / bc2;
        *pv -= lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
    }
}

/// Train `graph` in place on `data`. Deterministic: the same graph,
/// data, params and config produce bit-identical weights and losses.
pub fn train_graph(
    graph: &mut Graph,
    data: &Dataset,
    p: &MacroParams,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    ensure!(cfg.epochs > 0, "epochs must be >= 1");
    ensure!(cfg.batch > 0, "batch must be >= 1");
    ensure!(cfg.lr > 0.0 && cfg.lr.is_finite(), "lr must be a positive float");
    ensure!((0.0..1.0).contains(&cfg.momentum), "momentum must be in [0, 1)");
    ensure!(
        (1..=8).contains(&cfg.r_in) && (1..=8).contains(&cfg.r_out),
        "precision r_in={} r_out={} outside the macro's 1..=8 range",
        cfg.r_in,
        cfg.r_out
    );
    ensure!(data.n > 0, "empty training set");
    ensure!(
        data.image_len() == graph.input_len(),
        "training image length {} != graph input {}",
        data.image_len(),
        graph.input_len()
    );
    let out_shape = graph.output_shape()?;
    ensure!(
        out_shape.len() == 1 && out_shape[0] >= 2,
        "training needs a flat class-logit output, got shape {out_shape:?}"
    );
    let n_classes = out_shape[0];
    for (i, &y) in data.y.iter().enumerate() {
        ensure!(
            (0..n_classes as i32).contains(&y),
            "label {y} of image {i} outside 0..{n_classes}"
        );
    }

    let noise_lsb = cfg.resolve_noise_lsb(p).context("resolving noise injection")?;
    let ecfg = cfg.eval_cfg(noise_lsb);
    let workers = cfg.resolved_workers();
    let shapes = graph.shapes()?;
    let calib = data.take(cfg.calib_n.max(1));

    // Initial mapping: per-node activation ranges, γ, α from the float
    // graph — the same procedure inference mapping uses.
    let mut states = build_states(graph, &calib, p, &ecfg)?;
    let cim_nodes: Vec<usize> = graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.is_cim())
        .map(|(i, _)| i)
        .collect();
    let mut opt: Vec<OptState> = cim_nodes
        .iter()
        .map(|&ni| match &graph.nodes[ni] {
            Node::Dense(d) => OptState::new(cfg.optimizer, d.dense.w.len(), d.dense.b.len()),
            Node::Conv3x3(c) => OptState::new(cfg.optimizer, c.w.len(), c.b.len()),
            _ => unreachable!(),
        })
        .collect();

    let mut shuffle_rng = Rng::new(cfg.seed ^ 0x5EED_5EED_5EED_5EED);
    let mut noise_rng = Rng::new(cfg.seed ^ 0x0153_0153_0153_0153);
    let mut order: Vec<usize> = (0..data.n).collect();
    let mut epoch_losses = Vec::with_capacity(cfg.epochs);
    let mut steps = 0u64;
    let mut images = 0u64;
    // lint:allow(determinism) wall-clock images/s reporting only; never feeds computed results
    let t0 = std::time::Instant::now();

    for epoch in 0..cfg.epochs {
        let epoch_lr = cfg.lr_schedule.lr_at(cfg.lr, epoch, cfg.epochs);
        if epoch > 0 && cfg.recalibrate_every > 0 && epoch % cfg.recalibrate_every == 0 {
            let mapped = MappedGraph::build(graph, &calib, p, &ecfg)?;
            for (state, (q, &ni)) in
                states.iter_mut().zip(mapped.cim.into_iter().zip(&cim_nodes))
            {
                state.recalibrate(q, &graph.nodes[ni]);
            }
        }
        shuffle_rng.shuffle(&mut order);
        let mut ep_loss = 0.0f64;
        let mut n_batches = 0usize;
        for chunk in order.chunks(cfg.batch) {
            let n = chunk.len();
            let mut x = Vec::with_capacity(n * data.image_len());
            for &i in chunk {
                x.extend_from_slice(data.image(i));
            }

            // ---- forward, caching what each backward needs ----
            // Only Relu/Pool2x2 backwards read their forward input (CIM
            // nodes carry their own CimCache); don't clone activations
            // for the rest.
            let mut inputs: Vec<Option<Vec<f32>>> = Vec::with_capacity(graph.nodes.len());
            let mut caches: Vec<Option<qat::CimCache>> = Vec::with_capacity(graph.nodes.len());
            let mut ci = 0usize;
            let mut cur = x;
            for (ni, node) in graph.nodes.iter().enumerate() {
                inputs.push(match node {
                    Node::Relu | Node::Pool2x2(_) => Some(cur.clone()),
                    _ => None,
                });
                let in_shape = &shapes[ni];
                cur = match node {
                    Node::Dense(_) => {
                        let (y, cache) =
                            states[ci].forward_dense(p, &cur, n, workers, &mut noise_rng);
                        caches.push(Some(cache));
                        ci += 1;
                        y
                    }
                    Node::Conv3x3(_) => {
                        let [c, h, w] = chw(in_shape)?;
                        let (y, cache) = states[ci]
                            .forward_conv(p, &cur, n, c, h, w, workers, &mut noise_rng);
                        caches.push(Some(cache));
                        ci += 1;
                        y
                    }
                    Node::Relu => {
                        caches.push(None);
                        cur.iter().map(|&v| v.max(0.0)).collect()
                    }
                    Node::Pool2x2(kind) => {
                        caches.push(None);
                        let [c, h, w] = chw(in_shape)?;
                        let in_len = c * h * w;
                        let mut next = Vec::new();
                        for img in cur.chunks(in_len) {
                            next.extend(
                                crate::coordinator::executor::apply_pool(
                                    img,
                                    c,
                                    h,
                                    w,
                                    kind.to_manifest(),
                                )
                                .0,
                            );
                        }
                        next
                    }
                    Node::Flatten => {
                        caches.push(None);
                        cur
                    }
                };
            }

            // ---- softmax cross-entropy ----
            let logits = cur;
            let mut delta = vec![0f32; n * n_classes];
            let mut loss = 0.0f64;
            let inv = 1.0 / n as f32;
            for i in 0..n {
                let lrow = &logits[i * n_classes..(i + 1) * n_classes];
                let yi = data.y[chunk[i]] as usize;
                let mx = lrow.iter().cloned().fold(f32::MIN, f32::max);
                let exps: Vec<f32> = lrow.iter().map(|&v| (v - mx).exp()).collect();
                let sum: f32 = exps.iter().sum();
                loss -= f64::from((exps[yi] / sum).max(1e-12).ln());
                let drow = &mut delta[i * n_classes..(i + 1) * n_classes];
                for (d, &e) in drow.iter_mut().zip(&exps) {
                    *d = e / sum * inv;
                }
                drow[yi] -= inv;
            }
            ep_loss += loss / n as f64;

            // ---- backward + SGD, walking the graph in reverse ----
            let mut ci = states.len();
            for ni in (0..graph.nodes.len()).rev() {
                if graph.nodes[ni].is_cim() {
                    ci -= 1;
                    let grads = {
                        let cache = caches[ni].as_ref().unwrap();
                        match &graph.nodes[ni] {
                            Node::Dense(_) => {
                                states[ci].backward_dense(cache, &delta, n, workers)
                            }
                            Node::Conv3x3(_) => {
                                let [c, h, w] = chw(&shapes[ni])?;
                                states[ci].backward_conv(cache, &delta, n, c, h, w, workers)
                            }
                            _ => unreachable!(),
                        }
                    };
                    // Parameter update on the master float weights.
                    apply_update(
                        &mut graph.nodes[ni],
                        &mut opt[ci],
                        &grads,
                        epoch_lr,
                        cfg.momentum,
                    );
                    delta = grads.dx;
                    continue;
                }
                delta = match &graph.nodes[ni] {
                    Node::Relu => {
                        let mut d = delta;
                        let x_in = inputs[ni].as_ref().unwrap();
                        for (dv, &xv) in d.iter_mut().zip(x_in) {
                            if xv <= 0.0 {
                                *dv = 0.0;
                            }
                        }
                        d
                    }
                    Node::Pool2x2(kind) => {
                        let [c, h, w] = chw(&shapes[ni])?;
                        pool_backward(&delta, inputs[ni].as_ref().unwrap(), n, c, h, w, *kind)
                    }
                    Node::Flatten => delta,
                    _ => unreachable!(),
                };
            }

            // The optimizer moved the master weights: re-quantize for
            // the next minibatch (the STE's forward half).
            for (state, &ni) in states.iter_mut().zip(&cim_nodes) {
                state.refresh_weights(&graph.nodes[ni]);
            }
            steps += 1;
            images += n as u64;
            n_batches += 1;
        }
        epoch_losses.push(ep_loss / n_batches as f64);
    }

    Ok(TrainReport {
        epoch_losses,
        steps,
        images,
        wall_seconds: t0.elapsed().as_secs_f64(),
        noise_lsb,
    })
}

/// Build per-CIM-node training state from a fresh mapping of `graph`.
fn build_states(
    graph: &Graph,
    calib: &Dataset,
    p: &MacroParams,
    ecfg: &EvalCfg,
) -> Result<Vec<TrainNode>> {
    let mapped = MappedGraph::build(graph, calib, p, ecfg)?;
    Ok(mapped
        .cim
        .into_iter()
        .zip(graph.nodes.iter().filter(|n| n.is_cim()))
        .map(|(q, node)| TrainNode::new(q, node))
        .collect())
}

fn apply_update(node: &mut Node, opt: &mut OptState, grads: &qat::NodeGrads, lr: f32, mu: f32) {
    match node {
        Node::Dense(d) => opt.step(&mut d.dense.w, &mut d.dense.b, grads, lr, mu),
        Node::Conv3x3(c) => opt.step(&mut c.w, &mut c.b, grads, lr, mu),
        _ => unreachable!(),
    }
}

/// Backward of the executor's 2×2 stride-2 pool (floor crop on odd
/// dims): max routes to the first element attaining the window max, avg
/// spreads evenly; cropped cells get no gradient.
fn pool_backward(
    delta: &[f32],
    input: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kind: PoolKind,
) -> Vec<f32> {
    let (ph, pw) = (h / 2, w / 2);
    let in_len = c * h * w;
    let out_len = c * ph * pw;
    let mut dx = vec![0f32; n * in_len];
    for img in 0..n {
        let xin = &input[img * in_len..(img + 1) * in_len];
        let din = &delta[img * out_len..(img + 1) * out_len];
        let dxi = &mut dx[img * in_len..(img + 1) * in_len];
        for ch in 0..c {
            for py in 0..ph {
                for px in 0..pw {
                    let d = din[ch * ph * pw + py * pw + px];
                    if d == 0.0 {
                        continue;
                    }
                    let idx = [
                        ch * h * w + (2 * py) * w + 2 * px,
                        ch * h * w + (2 * py) * w + 2 * px + 1,
                        ch * h * w + (2 * py + 1) * w + 2 * px,
                        ch * h * w + (2 * py + 1) * w + 2 * px + 1,
                    ];
                    match kind {
                        PoolKind::Max => {
                            let mut best = idx[0];
                            for &i in &idx[1..] {
                                if xin[i] > xin[best] {
                                    best = i;
                                }
                            }
                            dxi[best] += d;
                        }
                        PoolKind::Avg => {
                            for &i in &idx {
                                dxi[i] += d / 4.0;
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::{Conv3x3, DenseNode};
    use crate::nn::mlp::Dense;

    fn toy_task(n: usize, draw_seed: u64) -> Dataset {
        Dataset::synthetic(n, vec![6, 6], 4, 5, draw_seed, 0.2)
    }

    fn mlp_graph(seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        Graph::new("train_mlp", vec![36])
            .with(Node::Dense(DenseNode::new(Dense::new(36, 16, &mut rng))))
            .with(Node::Relu)
            .with(Node::Dense(DenseNode::new(Dense::new(16, 4, &mut rng))))
    }

    #[test]
    fn qat_training_reduces_loss_and_learns() {
        let train = toy_task(240, 11);
        let mut g = mlp_graph(3);
        let cfg = TrainConfig {
            epochs: 5,
            noise: NoiseInjection::Off,
            workers: 1,
            ..TrainConfig::default()
        };
        let p = MacroParams::paper();
        let report = train_graph(&mut g, &train, &p, &cfg).unwrap();
        assert_eq!(report.epoch_losses.len(), 5);
        assert!(
            report.final_loss() < report.epoch_losses[0] * 0.6,
            "losses {:?}",
            report.epoch_losses
        );
        // The trained graph classifies held-out draws well under the
        // noiseless CIM mapping it was trained against.
        let test = toy_task(120, 12);
        let acc = crate::nn::graph::eval_graph_workers(
            &g,
            &test,
            &p,
            &cfg.eval_cfg(0.0),
            1,
        )
        .unwrap();
        assert!(acc > 0.8, "acc {acc}");
    }

    #[test]
    fn conv_graph_trains_end_to_end() {
        let mut rng = Rng::new(5);
        let mut g = Graph::new("train_cnn", vec![1, 6, 6])
            .with(Node::Conv3x3(Conv3x3::new(1, 4, &mut rng)))
            .with(Node::Relu)
            .with(Node::Pool2x2(PoolKind::Max))
            .with(Node::Flatten)
            .with(Node::Dense(DenseNode::new(Dense::new(4 * 3 * 3, 4, &mut rng))));
        let train = Dataset::synthetic(120, vec![1, 6, 6], 4, 9, 1, 0.18);
        let cfg = TrainConfig {
            epochs: 3,
            noise: NoiseInjection::Lsb(0.25),
            workers: 1,
            ..TrainConfig::default()
        };
        let p = MacroParams::paper();
        let report = train_graph(&mut g, &train, &p, &cfg).unwrap();
        assert!(
            report.final_loss() < report.epoch_losses[0],
            "losses {:?}",
            report.epoch_losses
        );
        assert_eq!(report.noise_lsb, 0.25);
    }

    #[test]
    fn training_is_bit_identical_across_worker_counts() {
        // batch 20 → backward chunks of 8+8+4: the fixed chunk grid and
        // chunk-order reduction make every float result — losses and
        // final weights — identical no matter how many workers ran.
        let p = MacroParams::paper();
        let run = |workers: usize| {
            let train = toy_task(60, 21);
            let mut g = mlp_graph(7);
            let cfg = TrainConfig {
                epochs: 2,
                batch: 20,
                workers,
                noise: NoiseInjection::Lsb(0.3),
                ..TrainConfig::default()
            };
            let report = train_graph(&mut g, &train, &p, &cfg).unwrap();
            let weights: Vec<Vec<f32>> = g
                .nodes
                .iter()
                .filter_map(|n| match n {
                    Node::Dense(d) => Some(d.dense.w.clone()),
                    _ => None,
                })
                .collect();
            (report.epoch_losses, weights)
        };
        let (losses_1, w_1) = run(1);
        for workers in [2usize, 3, 8] {
            let (losses_n, w_n) = run(workers);
            assert_eq!(losses_1, losses_n, "losses diverged at workers={workers}");
            assert_eq!(w_1, w_n, "weights diverged at workers={workers}");
        }
    }

    #[test]
    fn conv_training_is_bit_identical_across_worker_counts() {
        let p = MacroParams::paper();
        let run = |workers: usize| {
            let mut rng = Rng::new(5);
            let mut g = Graph::new("train_cnn_workers", vec![1, 6, 6])
                .with(Node::Conv3x3(Conv3x3::new(1, 4, &mut rng)))
                .with(Node::Relu)
                .with(Node::Flatten)
                .with(Node::Dense(DenseNode::new(Dense::new(4 * 6 * 6, 4, &mut rng))));
            let train = Dataset::synthetic(24, vec![1, 6, 6], 4, 9, 1, 0.18);
            let cfg = TrainConfig {
                epochs: 1,
                batch: 12,
                workers,
                noise: NoiseInjection::Off,
                ..TrainConfig::default()
            };
            let report = train_graph(&mut g, &train, &p, &cfg).unwrap();
            let conv_w: Vec<f32> = g
                .nodes
                .iter()
                .find_map(|n| match n {
                    Node::Conv3x3(c) => Some(c.w.clone()),
                    _ => None,
                })
                .unwrap();
            (report.epoch_losses, conv_w)
        };
        let (losses_1, w_1) = run(1);
        let (losses_4, w_4) = run(4);
        assert_eq!(losses_1, losses_4);
        assert_eq!(w_1, w_4);
    }

    #[test]
    fn adam_training_reduces_loss_and_learns() {
        let train = toy_task(240, 11);
        let mut g = mlp_graph(3);
        let cfg = TrainConfig {
            epochs: 5,
            lr: 0.01,
            optimizer: OptimizerKind::Adam,
            noise: NoiseInjection::Off,
            workers: 1,
            ..TrainConfig::default()
        };
        let p = MacroParams::paper();
        let report = train_graph(&mut g, &train, &p, &cfg).unwrap();
        assert!(
            report.final_loss() < report.epoch_losses[0] * 0.6,
            "losses {:?}",
            report.epoch_losses
        );
        let test = toy_task(120, 12);
        let acc = crate::nn::graph::eval_graph_workers(
            &g,
            &test,
            &p,
            &cfg.eval_cfg(0.0),
            1,
        )
        .unwrap();
        assert!(acc > 0.8, "acc {acc}");
    }

    #[test]
    fn adam_is_deterministic_and_distinct_from_sgd() {
        // Same seed + config ⇒ bit-identical losses and weights; the
        // optimizer choice itself must change the trajectory.
        let p = MacroParams::paper();
        let run = |optimizer: OptimizerKind| {
            let train = toy_task(80, 31);
            let mut g = mlp_graph(9);
            let cfg = TrainConfig {
                epochs: 2,
                optimizer,
                workers: 1,
                noise: NoiseInjection::Lsb(0.3),
                ..TrainConfig::default()
            };
            let report = train_graph(&mut g, &train, &p, &cfg).unwrap();
            let weights: Vec<Vec<f32>> = g
                .nodes
                .iter()
                .filter_map(|n| match n {
                    Node::Dense(d) => Some(d.dense.w.clone()),
                    _ => None,
                })
                .collect();
            (report.epoch_losses, weights)
        };
        let (losses_a, w_a) = run(OptimizerKind::Adam);
        let (losses_b, w_b) = run(OptimizerKind::Adam);
        assert_eq!(losses_a, losses_b, "same-seed Adam runs diverged");
        assert_eq!(w_a, w_b, "same-seed Adam weights diverged");
        let (_, w_sgd) = run(OptimizerKind::Sgd);
        assert_ne!(w_a, w_sgd, "optimizer choice must change the update");
    }

    #[test]
    fn optimizer_kind_parses_and_names() {
        assert_eq!(OptimizerKind::parse("sgd"), Some(OptimizerKind::Sgd));
        assert_eq!(OptimizerKind::parse("adam"), Some(OptimizerKind::Adam));
        assert_eq!(OptimizerKind::parse("lamb"), None);
        assert_eq!(OptimizerKind::Adam.name(), "adam");
        assert_eq!(OptimizerKind::default(), OptimizerKind::Sgd);
    }

    #[test]
    fn lr_schedule_parses_and_anneals() {
        assert_eq!(LrSchedule::parse("cosine"), Some(LrSchedule::Cosine));
        assert_eq!(LrSchedule::parse("const"), Some(LrSchedule::Const));
        assert_eq!(LrSchedule::parse("step"), None);
        assert_eq!(LrSchedule::Cosine.name(), "cosine");

        // Const is the identity on lr.
        for e in 0..5 {
            assert_eq!(LrSchedule::Const.lr_at(0.04, e, 5), 0.04);
        }
        // Cosine: starts at base, strictly decreases, ends at 2% of base.
        let epochs = 10;
        let lrs: Vec<f32> = (0..epochs)
            .map(|e| LrSchedule::Cosine.lr_at(0.04, e, epochs))
            .collect();
        assert_eq!(lrs[0], 0.04);
        assert!(lrs.windows(2).all(|w| w[1] < w[0]), "{lrs:?}");
        assert!((lrs[epochs - 1] - 0.0008).abs() < 1e-6, "{lrs:?}");
        // Degenerate 1-epoch run: just the base lr, no division by zero.
        assert_eq!(LrSchedule::Cosine.lr_at(0.04, 0, 1), 0.04);
        // Pure function: repeated evaluation is bit-identical.
        assert_eq!(
            LrSchedule::Cosine.lr_at(0.04, 3, 7).to_bits(),
            LrSchedule::Cosine.lr_at(0.04, 3, 7).to_bits()
        );
    }

    #[test]
    fn cosine_schedule_actually_changes_the_updates() {
        // Same seed/config except the schedule: after >1 epoch the
        // trained weights must differ (the schedule is wired into the
        // optimizer, not just parsed).
        let p = MacroParams::paper();
        let run = |schedule: LrSchedule| {
            let train = toy_task(80, 31);
            let mut g = mlp_graph(9);
            let cfg = TrainConfig {
                epochs: 3,
                workers: 1,
                noise: NoiseInjection::Off,
                lr_schedule: schedule,
                ..TrainConfig::default()
            };
            train_graph(&mut g, &train, &p, &cfg).unwrap();
            g.nodes
                .iter()
                .filter_map(|n| match n {
                    Node::Dense(d) => Some(d.dense.w.clone()),
                    _ => None,
                })
                .collect::<Vec<_>>()
        };
        assert_ne!(run(LrSchedule::Const), run(LrSchedule::Cosine));
    }

    #[test]
    fn training_rejects_malformed_inputs() {
        let p = MacroParams::paper();
        let mut g = mlp_graph(1);
        let bad_len = Dataset { x: vec![0.0; 10], y: vec![0], n: 1, shape: vec![10] };
        assert!(train_graph(&mut g, &bad_len, &p, &TrainConfig::default()).is_err());
        let bad_label = Dataset { x: vec![0.0; 36], y: vec![9], n: 1, shape: vec![36] };
        assert!(train_graph(&mut g, &bad_label, &p, &TrainConfig::default()).is_err());
        let data = toy_task(8, 1);
        let bad_lr = TrainConfig { lr: 0.0, ..TrainConfig::default() };
        assert!(train_graph(&mut g, &data, &p, &bad_lr).is_err());
        let bad_r = TrainConfig { r_out: 9, ..TrainConfig::default() };
        assert!(train_graph(&mut g, &data, &p, &bad_r).is_err());
    }

    #[test]
    fn pool_backward_routes_to_argmax_and_spreads_avg() {
        // One channel, 2x2 → one output.
        let input = vec![0.1, 0.9, 0.3, 0.2];
        let delta = vec![1.0];
        let dmax = pool_backward(&delta, &input, 1, 1, 2, 2, PoolKind::Max);
        assert_eq!(dmax, vec![0.0, 1.0, 0.0, 0.0]);
        let davg = pool_backward(&delta, &input, 1, 1, 2, 2, PoolKind::Avg);
        assert_eq!(davg, vec![0.25; 4]);
        // Odd dims: the cropped column gets no gradient.
        let input3 = vec![0.0, 0.0, 5.0, 0.1, 0.0, 5.0, 1.0, 1.0, 5.0];
        let d3 = pool_backward(&[1.0], &input3, 1, 1, 3, 3, PoolKind::Max);
        assert_eq!(d3[2], 0.0);
        assert_eq!(d3[5], 0.0);
        assert_eq!(d3.iter().sum::<f32>(), 1.0);
    }
}
