//! The CNN layer-graph IR and its batched CIM executor.
//!
//! A [`Graph`] is a typed sequence of [`Node`]s — `Conv3x3`, `Dense`,
//! `Pool2x2`, `Relu`, `Flatten` — with per-layer CIM mapping overrides
//! ([`AbnSpec`](crate::nn::layers::AbnSpec)). It is the nn-side
//! generalization of the Fig. 3(b) MLP
//! study to the paper's actual workload class: CNNs lowered onto the
//! 1152×256 macro through the §IV streaming im2col.
//!
//! Three things happen here:
//!
//! 1. **Mapping** ([`MappedGraph::build`]): calibrate activation ranges
//!    on a data subset, quantize weights to 4b antipodal levels, permute
//!    conv kernels into the macro's physical row order
//!    ([`im2col::row_order`], padding rows carry zero weight), derive the
//!    channel-adaptive DPL swing α(C_in) and the ABN gain γ from the DP
//!    voltage statistics — the same procedure `cim_eval` has always
//!    applied to dense layers, now the crate's single quantize path.
//! 2. **Batched execution** ([`MappedGraph::forward_batch`]): the whole
//!    batch advances one node at a time; `Conv3x3` streams every im2col
//!    patch of every image through the precision/ISA-adaptive
//!    [`kernels`] dispatch (the quantized weights and signed factors are
//!    exact small integers, so the i32 kernels — SIMD or bit-plane — are
//!    bit-identical to [`gemm::rowdot_f64`](crate::engine::gemm::rowdot_f64)
//!    on the same data), then
//!    applies the macro contract per output (Eq. 7 code, equivalent
//!    output noise, offset-binary reconstruction
//!    `Σ X·W = (dot + M·ΣW)/2`, ABN gain/offset).
//!    Dense nodes are the single-pixel special case — bit-identical to
//!    the historical `cim_eval` path.
//! 3. **Lowering** ([`Graph::lower`]): emit a physical
//!    [`NetworkModel`] (integer antipodal weights in macro row order, 5b
//!    ABN offset codes absorbing the offset-binary constant and bias,
//!    post-ADC gain) so the same graph runs through the
//!    [`Session`](crate::api::Session) facade on the ideal/engine/analog
//!    backends.

use crate::config::params::MacroParams;
use crate::coordinator::manifest::{Kind, Layer, NetworkModel, Pool, PrecisionProfile, ProfileEntry};
use crate::dataflow::im2col;
use crate::engine::packed::NodeKernel;
use crate::engine::{arena, kernels};
use crate::nn::cim_eval::EvalCfg;
use crate::nn::dataset::Dataset;
use crate::nn::layers::{chw, AbnSpec, Conv3x3, DenseNode, Node, PoolKind};
use crate::nn::mlp::Mlp;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};

/// Weight precision of the CIM mapping (the paper's 4b setting).
pub const R_W: u32 = 4;

/// A feed-forward layer graph.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Graph name (becomes the lowered model's manifest name).
    pub name: String,
    /// Natural input shape (`[features]` or `[c, h, w]`).
    pub input_shape: Vec<usize>,
    /// Nodes in execution order.
    pub nodes: Vec<Node>,
}

impl Graph {
    /// An empty graph over the given input shape; append nodes with
    /// [`Graph::with`].
    pub fn new(name: impl Into<String>, input_shape: Vec<usize>) -> Graph {
        Graph { name: name.into(), input_shape, nodes: Vec::new() }
    }

    /// Builder-style node append.
    pub fn with(mut self, node: Node) -> Graph {
        self.nodes.push(node);
        self
    }

    /// An MLP as a trivial graph: Dense nodes with ReLU between them —
    /// the special case `cim_eval` evaluates.
    pub fn from_mlp(name: impl Into<String>, mlp: &Mlp) -> Graph {
        let n_in = mlp.layers.first().map(|l| l.n_in).unwrap_or(0);
        let mut graph = Graph::new(name, vec![n_in]);
        let n_layers = mlp.layers.len();
        for (li, layer) in mlp.layers.iter().enumerate() {
            graph.nodes.push(Node::Dense(DenseNode::new(layer.clone())));
            if li + 1 < n_layers {
                graph.nodes.push(Node::Relu);
            }
        }
        graph
    }

    /// Number of macro-mapped (conv/dense) nodes.
    pub fn n_cim(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_cim()).count()
    }

    /// Shape entering every node plus the final output shape; fails on
    /// inconsistent wiring.
    pub fn shapes(&self) -> Result<Vec<Vec<usize>>> {
        let mut shapes = vec![self.input_shape.clone()];
        for (i, node) in self.nodes.iter().enumerate() {
            let next = node
                .out_shape(shapes.last().unwrap())
                .with_context(|| format!("node {i} ({})", node.kind()))?;
            shapes.push(next);
        }
        Ok(shapes)
    }

    /// Final output shape.
    pub fn output_shape(&self) -> Result<Vec<usize>> {
        Ok(self.shapes()?.pop().unwrap())
    }

    /// Flattened input length (the product of `input_shape`).
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Float forward through the first `n_nodes` nodes (the calibration
    /// / feature-extraction path).
    pub fn forward_float_prefix(&self, x: &[f32], n_nodes: usize) -> Result<Vec<f32>> {
        ensure!(
            x.len() == self.input_len(),
            "input length {} != graph input {}",
            x.len(),
            self.input_len()
        );
        let mut act = x.to_vec();
        let mut shape = self.input_shape.clone();
        for node in self.nodes.iter().take(n_nodes) {
            act = node.forward_float(&act, &shape)?;
            shape = node.out_shape(&shape)?;
        }
        Ok(act)
    }

    /// Full float forward (no quantization) — the reference the CIM
    /// mapping is calibrated against.
    pub fn forward_float(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.forward_float_prefix(x, self.nodes.len())
    }

    /// Lower to a physical [`NetworkModel`] runnable by every
    /// [`Session`](crate::api::Session) backend: calibrates/maps on
    /// `calib`, then emits integer antipodal weights in macro row order
    /// (padding rows store +1 against the +1 mid-rail input factor —
    /// zero is not a storable level), a per-channel 5b ABN offset β
    /// absorbing the offset-binary `M·ΣW` constant, the padding-row
    /// constant and the float bias, and the post-ADC gain that restores
    /// real-valued activations. ReLU and
    /// Pool2x2 nodes directly following a macro node fuse into its
    /// manifest layer (the accelerator's post-ADC datapath); standalone
    /// digital nodes in other positions cannot be expressed and fail.
    pub fn lower(&self, calib: &Dataset, p: &MacroParams, cfg: &EvalCfg) -> Result<NetworkModel> {
        self.lower_with(calib, p, cfg, &[])
    }

    /// [`Graph::lower`] with per-CIM-node [`AbnSpec`] overrides (see
    /// [`MappedGraph::bind_with`]) — how an autotuned per-layer
    /// precision profile is baked into the emitted manifest layers.
    pub fn lower_with(
        &self,
        calib: &Dataset,
        p: &MacroParams,
        cfg: &EvalCfg,
        overrides: &[AbnSpec],
    ) -> Result<NetworkModel> {
        let cal = GraphCalibration::collect(self, calib)?;
        let mapped = MappedGraph::bind_with(self, &cal, p, cfg, overrides)?;
        let mut layers = Vec::new();
        let mut qi = 0usize;
        let mut i = 0usize;
        while i < self.nodes.len() {
            match &self.nodes[i] {
                Node::Flatten => {}
                Node::Conv3x3(_) | Node::Dense(_) => {
                    let kind = match &self.nodes[i] {
                        Node::Conv3x3(_) => Kind::Conv3,
                        _ => Kind::Dense,
                    };
                    let mut relu = false;
                    let mut pool = Pool::None;
                    if matches!(self.nodes.get(i + 1), Some(Node::Relu)) {
                        relu = true;
                        i += 1;
                    }
                    if kind == Kind::Conv3 {
                        if let Some(Node::Pool2x2(k)) = self.nodes.get(i + 1) {
                            pool = k.to_manifest();
                            i += 1;
                        }
                    }
                    let name = format!(
                        "{}{}",
                        if kind == Kind::Conv3 { "conv" } else { "fc" },
                        qi
                    );
                    layers.push(lower_cim_node(&mapped.cim[qi], kind, relu, pool, name, p)?);
                    qi += 1;
                }
                Node::Relu => bail!(
                    "node {i}: standalone ReLU (not directly after a conv/dense node) \
                     cannot be lowered to the manifest executor"
                ),
                Node::Pool2x2(_) => bail!(
                    "node {i}: Pool2x2 must directly follow a Conv3x3 (+ReLU) to lower \
                     onto the conv layer's post-ADC pool stage"
                ),
            }
            i += 1;
        }
        // A graph whose nodes resolve to different (r_in, r_out) points
        // is a mixed-precision model: record the per-layer profile so
        // the saved manifest serves it with zero flags. Uniform models
        // stay profile-free (the legacy manifest shape).
        let uniform = layers
            .windows(2)
            .all(|w| (w[0].cfg.r_in, w[0].cfg.r_out) == (w[1].cfg.r_in, w[1].cfg.r_out));
        let profile = if uniform {
            None
        } else {
            Some(PrecisionProfile {
                version: PrecisionProfile::VERSION,
                layers: layers
                    .iter()
                    .map(|l| ProfileEntry {
                        name: l.name.clone(),
                        r_in: l.cfg.r_in,
                        r_out: l.cfg.r_out,
                    })
                    .collect(),
            })
        };
        Ok(NetworkModel {
            name: self.name.clone(),
            input_shape: self.input_shape.clone(),
            layers,
            metrics: Json::Null,
            profile,
        })
    }
}

/// What a macro-mapped node executes as: dense single-pixel or conv
/// over the im2col patch grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CimKind {
    /// Fully-connected: one gemm row per image.
    Dense {
        /// Input features.
        n_in: usize,
        /// Output features.
        n_out: usize,
    },
    /// 3×3 conv: one gemm row per output pixel (im2col patch).
    Conv {
        /// Input channels.
        c_in: usize,
        /// Output channels.
        c_out: usize,
    },
}

/// Quantized per-node mapping state — the generalization of the QLayer
/// `cim_eval` builds for dense layers.
#[derive(Clone, Debug)]
pub struct QNode {
    /// What this node executes as (dense or conv).
    pub kind: CimKind,
    /// gemm reduction length: dense = `n_in` (no physical padding
    /// needed), conv = DP units × 36 macro rows (padding rows carry
    /// zero weight).
    pub rows: usize,
    /// Row count the adaptive swing sees (padded to DP-unit multiples).
    pub alpha_rows: usize,
    /// Quantized antipodal weights `[n_out × rows]` (macro row order for
    /// conv; odd levels in [−15, 15], exactly representable in f32).
    pub w_q: Vec<f32>,
    /// Per-output ΣW (offset-binary reconstruction constant).
    pub sum_w: Vec<f32>,
    /// Per-output float bias (rides the post-ADC ABN offset path).
    pub bias: Vec<f32>,
    /// Weight dequantization scale (float weight ≈ `w_q · w_scale`).
    pub w_scale: f32,
    /// Activation quantization scale from the calibrated range.
    pub a_scale: f32,
    /// Effective DPL swing α for this node's connected rows.
    pub alpha: f64,
    /// ABN gain chosen from the DP voltage statistics.
    pub gamma: f64,
    /// Resolved per-node CIM configuration.
    pub cfg: EvalCfg,
    /// Kernel-resolved form of `w_q`, built once at mapping time (and
    /// rebuilt by the trainer's weight refresh) instead of re-derived on
    /// every forward — see [`NodeKernel`].
    pub kernel: NodeKernel,
}

impl QNode {
    /// Output features (dense) or output channels (conv).
    pub fn n_out(&self) -> usize {
        match self.kind {
            CimKind::Dense { n_out, .. } => n_out,
            CimKind::Conv { c_out, .. } => c_out,
        }
    }

    /// The contract constants every per-output evaluation needs:
    /// `(m, half, top, lsb, dv_unit)` — shared by the inference forwards
    /// here and the trainer's quantization-aware forward.
    pub(crate) fn contract_consts(&self, p: &MacroParams) -> (f32, f64, f64, f64, f64) {
        let m = ((1u32 << self.cfg.r_in) - 1) as f32;
        let half = (1u64 << (self.cfg.r_out - 1)) as f64;
        let top = (1u64 << self.cfg.r_out) as f64 - 1.0;
        let lsb = p.adc_lsb(self.cfg.r_out, self.gamma);
        let dv_unit = self.alpha * p.supply.vddl / (1u64 << (self.cfg.r_in + R_W)) as f64;
        (m, half, top, lsb, dv_unit)
    }
}

/// One executable step of a mapped graph.
#[derive(Clone, Debug)]
enum ExecOp {
    Cim(usize),
    Relu,
    Pool(PoolKind),
    Flatten,
}

/// A graph bound to the macro contract: quantized weights, per-node
/// mapping state and the shape schedule — ready for batched execution.
#[derive(Clone, Debug)]
pub struct MappedGraph {
    /// Graph name, carried through from [`Graph::name`].
    pub name: String,
    /// Natural input shape (`[features]` or `[c, h, w]`).
    pub input_shape: Vec<usize>,
    /// Macro-mapped nodes in execution order.
    pub cim: Vec<QNode>,
    ops: Vec<ExecOp>,
    /// `shapes[i]` enters op `i`; `shapes.last()` is the output shape.
    shapes: Vec<Vec<usize>>,
    /// Graph-level configuration (seed and noise for execution).
    pub cfg: EvalCfg,
    /// Macro parameters the mapping was calibrated against (supply and
    /// ADC constants are needed again at execution time).
    pub params: MacroParams,
}

/// The cfg-independent half of [`MappedGraph::build`]: float-forward
/// calibration statistics (activation ranges entering each macro node
/// plus a stash of early activations for the DP-voltage statistics),
/// collected once per `(graph, calib)` pair and reusable across every
/// precision binding — what lets the autotuner evaluate hundreds of
/// per-layer `(r_in, r_out)` candidates without re-running the float
/// forwards.
#[derive(Clone, Debug)]
pub struct GraphCalibration {
    shapes: Vec<Vec<usize>>,
    act_hi: Vec<f32>,
    stash: Vec<Vec<Vec<f32>>>,
}

impl GraphCalibration {
    /// Run the calibration float forwards on (a subset of) `calib`.
    pub fn collect(graph: &Graph, calib: &Dataset) -> Result<GraphCalibration> {
        let shapes = graph.shapes()?;
        ensure!(calib.n > 0, "empty calibration set");
        ensure!(
            calib.image_len() == graph.input_len(),
            "calibration image length {} != graph input {}",
            calib.image_len(),
            graph.input_len()
        );

        // Activation ranges entering each macro node, plus the first few
        // activations stashed for the DP-voltage statistics.
        let calib_n = calib.n.min(96);
        let n_keep = calib_n.min(32);
        let n_cim = graph.n_cim();
        let mut act_hi = vec![1e-6f32; n_cim];
        let mut stash: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_cim];
        for i in 0..calib_n {
            let mut act = calib.image(i).to_vec();
            let mut ci = 0usize;
            for (ni, node) in graph.nodes.iter().enumerate() {
                if node.is_cim() {
                    for &v in &act {
                        act_hi[ci] = act_hi[ci].max(v);
                    }
                    if i < n_keep {
                        stash[ci].push(act.clone());
                    }
                    ci += 1;
                }
                act = node.forward_float(&act, &shapes[ni])?;
            }
        }
        Ok(GraphCalibration { shapes, act_hi, stash })
    }

    /// Number of macro-mapped nodes this calibration covers.
    pub fn n_cim(&self) -> usize {
        self.act_hi.len()
    }
}

impl MappedGraph {
    /// Calibrate and quantize `graph` on (a subset of) `calib` —
    /// [`GraphCalibration::collect`] followed by [`MappedGraph::bind`].
    pub fn build(
        graph: &Graph,
        calib: &Dataset,
        p: &MacroParams,
        cfg: &EvalCfg,
    ) -> Result<MappedGraph> {
        let cal = GraphCalibration::collect(graph, calib)?;
        Self::bind(graph, &cal, p, cfg)
    }

    /// Quantize `graph` against pre-collected calibration statistics.
    pub fn bind(
        graph: &Graph,
        cal: &GraphCalibration,
        p: &MacroParams,
        cfg: &EvalCfg,
    ) -> Result<MappedGraph> {
        Self::bind_with(graph, cal, p, cfg, &[])
    }

    /// [`MappedGraph::bind`] with per-CIM-node [`AbnSpec`] overrides
    /// applied on top of each node's own spec (overrides win, then the
    /// node's spec, then the graph-level `cfg`). `overrides` is indexed
    /// by CIM-node position and must be empty or cover every CIM node —
    /// the autotuner's candidate-binding entry point.
    pub fn bind_with(
        graph: &Graph,
        cal: &GraphCalibration,
        p: &MacroParams,
        cfg: &EvalCfg,
        overrides: &[AbnSpec],
    ) -> Result<MappedGraph> {
        let n_cim = graph.n_cim();
        ensure!(
            cal.n_cim() == n_cim,
            "calibration covers {} CIM nodes, graph has {n_cim}",
            cal.n_cim()
        );
        ensure!(
            overrides.is_empty() || overrides.len() == n_cim,
            "{} overrides for {n_cim} CIM nodes",
            overrides.len()
        );
        let node_cfg = |abn: &AbnSpec, ci: usize| -> EvalCfg {
            let base = abn.resolve(cfg);
            match overrides.get(ci) {
                Some(over) => over.resolve(&base),
                None => base,
            }
        };
        let mut cim = Vec::with_capacity(n_cim);
        let mut ops = Vec::with_capacity(graph.nodes.len());
        let mut ci = 0usize;
        for (ni, node) in graph.nodes.iter().enumerate() {
            match node {
                Node::Dense(d) => {
                    let ncfg = node_cfg(&d.abn, ci);
                    cim.push(map_dense(d, &ncfg, cal.act_hi[ci], &cal.stash[ci], p));
                    ops.push(ExecOp::Cim(ci));
                    ci += 1;
                }
                Node::Conv3x3(c) => {
                    let ncfg = node_cfg(&c.abn, ci);
                    let [_, h, w] = chw(&cal.shapes[ni])?;
                    cim.push(map_conv(c, &ncfg, cal.act_hi[ci], &cal.stash[ci], h, w, p));
                    ops.push(ExecOp::Cim(ci));
                    ci += 1;
                }
                Node::Relu => ops.push(ExecOp::Relu),
                Node::Pool2x2(k) => ops.push(ExecOp::Pool(*k)),
                Node::Flatten => ops.push(ExecOp::Flatten),
            }
        }
        Ok(MappedGraph {
            name: graph.name.clone(),
            input_shape: graph.input_shape.clone(),
            cim,
            ops,
            shapes: cal.shapes.clone(),
            cfg: *cfg,
            params: p.clone(),
        })
    }

    /// Flattened input length (the product of `input_shape`).
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Flattened output length (logits per image).
    pub fn output_len(&self) -> usize {
        self.shapes.last().unwrap().iter().product()
    }

    /// Run a whole batch (flat `[n × input_len]`) through the quantized
    /// graph; returns flat `[n × output_len]` outputs.
    ///
    /// Each call re-seeds the equivalent-noise RNG from `cfg.seed` (one
    /// call = one reproducible evaluation). When evaluating a set in
    /// chunks, use [`MappedGraph::forward_flat_rng`] with one RNG
    /// threaded across the calls so noise draws stay independent
    /// between chunks.
    pub fn forward_flat(&self, x: &[f32], n: usize, workers: usize) -> Result<Vec<f32>> {
        self.forward_flat_rng(x, n, workers, &mut Rng::new(self.cfg.seed))
    }

    /// [`MappedGraph::forward_flat`] with a caller-owned noise RNG.
    pub fn forward_flat_rng(
        &self,
        x: &[f32],
        n: usize,
        workers: usize,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        ensure!(
            x.len() == n * self.input_len(),
            "batch length {} != {n} × {}",
            x.len(),
            self.input_len()
        );
        let mut cur = x.to_vec();
        for (oi, op) in self.ops.iter().enumerate() {
            let in_shape = &self.shapes[oi];
            let out_shape = &self.shapes[oi + 1];
            cur = match op {
                ExecOp::Relu => {
                    cur.iter_mut().for_each(|v| *v = v.max(0.0));
                    cur
                }
                ExecOp::Flatten => cur,
                ExecOp::Pool(kind) => {
                    let [c, h, w] = chw(in_shape)?;
                    let in_len = c * h * w;
                    let out_len: usize = out_shape.iter().product();
                    let mut next = Vec::with_capacity(n * out_len);
                    for img in cur.chunks(in_len) {
                        next.extend(crate::coordinator::executor::apply_pool(
                            img,
                            c,
                            h,
                            w,
                            kind.to_manifest(),
                        ).0);
                    }
                    next
                }
                ExecOp::Cim(qi) => {
                    let q = &self.cim[*qi];
                    match q.kind {
                        CimKind::Dense { .. } => {
                            forward_dense(q, &self.params, &cur, n, workers, rng)
                        }
                        CimKind::Conv { .. } => {
                            let [c, h, w] = chw(in_shape)?;
                            forward_conv(q, &self.params, &cur, n, c, h, w, workers, rng)
                        }
                    }
                }
            };
        }
        Ok(cur)
    }

    /// [`MappedGraph::forward_flat`] over per-image vectors.
    pub fn forward_batch(&self, images: &[Vec<f32>], workers: usize) -> Result<Vec<Vec<f32>>> {
        let len = self.input_len();
        let mut flat = Vec::with_capacity(images.len() * len);
        for (i, im) in images.iter().enumerate() {
            ensure!(im.len() == len, "image {i}: expected {len} values, got {}", im.len());
            flat.extend_from_slice(im);
        }
        let out = self.forward_flat(&flat, images.len(), workers)?;
        let out_len = self.output_len();
        Ok(out.chunks(out_len).map(|c| c.to_vec()).collect())
    }
}

/// Quantize a float weight matrix `[n_out × k]` to antipodal `R_W`-bit
/// levels; returns (w_q, w_scale). Shared with the CIM-aware trainer
/// (`nn::train`), which re-quantizes after every weight update — the
/// straight-through estimator's forward half.
pub(crate) fn quantize_weights(w: &[f32], n_out: usize, k: usize) -> (Vec<f32>, f32) {
    let mx = ((1u32 << R_W) - 1) as f32;
    let w_abs_max = w.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-9);
    let w_scale = w_abs_max / mx;
    let w_q: Vec<f32> = w
        .iter()
        .map(|&v| {
            let b = ((v / w_scale + mx) / 2.0).round().clamp(0.0, mx);
            2.0 * b - mx
        })
        .collect();
    debug_assert_eq!(w_q.len(), n_out * k);
    (w_q, w_scale)
}

/// Quantize an ideal ABN gain to the hardware's power-of-two levels in
/// {1 .. 2^gamma_bits}.
fn quantize_gamma(ideal: f64, gamma_bits: u32) -> f64 {
    let max_gamma = (1u64 << gamma_bits) as f64;
    let mut gamma = 1.0;
    while gamma * 2.0 <= ideal.min(max_gamma) {
        gamma *= 2.0;
    }
    gamma
}

/// ABN gain from the DP voltage σ: fill the ADC range with ~3.5σ,
/// quantized to powers of two in {1 .. 2^gamma_bits}.
fn gamma_from_sigma(dv_sigma: f64, cfg: &EvalCfg, p: &MacroParams) -> f64 {
    quantize_gamma(p.alpha_adc() * p.supply.vddh / (3.5 * dv_sigma), cfg.gamma_bits)
}

/// Permute natural-order conv weights `[c_out × 9·c_in]` into the
/// macro's physical row order; padding rows (units not filled by real
/// channels) carry zero weight so the mid-rail padding input contributes
/// nothing. Returns `(w_rows, rows)`. Shared by the mapping and the
/// trainer's per-step weight refresh.
pub(crate) fn permute_conv_rows(w_nat: &[f32], c_in: usize, c_out: usize) -> (Vec<f32>, usize) {
    let order = im2col::row_order(c_in);
    let rows = order.len();
    let mut w_q = vec![0f32; c_out * rows];
    for oc in 0..c_out {
        let nat = &w_nat[oc * 9 * c_in..(oc + 1) * 9 * c_in];
        for (r, o) in order.iter().enumerate() {
            if let Some(f) = o {
                w_q[oc * rows + r] = nat[*f];
            }
        }
    }
    (w_q, rows)
}

fn map_dense(
    d: &DenseNode,
    cfg: &EvalCfg,
    act_hi: f32,
    stash: &[Vec<f32>],
    p: &MacroParams,
) -> QNode {
    let layer = &d.dense;
    let m = ((1u32 << cfg.r_in) - 1) as f32;
    let (w_q, w_scale) = quantize_weights(&layer.w, layer.n_out, layer.n_in);
    let sum_w: Vec<f32> = (0..layer.n_out)
        .map(|o| w_q[o * layer.n_in..(o + 1) * layer.n_in].iter().sum())
        .collect();

    let alpha_rows = layer.n_in.div_ceil(p.rows_per_unit) * p.rows_per_unit;
    let alpha = if cfg.adaptive_swing {
        p.alpha_eff(alpha_rows)
    } else {
        p.alpha_eff(p.n_rows)
    };
    let a_scale = act_hi / m;

    // DP voltage σ over the stashed calibration activations — the same
    // loop (image/channel caps, natural ascending-k accumulation) the
    // historical cim_eval used, so MLP mappings stay bit-identical.
    let dv_unit = alpha * p.supply.vddl / (1u64 << (cfg.r_in + R_W)) as f64;
    let mut sq = 0f64;
    let mut cnt = 0usize;
    for a in stash.iter().take(32) {
        for o in 0..layer.n_out.min(32) {
            let row = &w_q[o * layer.n_in..(o + 1) * layer.n_in];
            let mut dot = 0f64;
            for (j, &av) in a.iter().enumerate() {
                let xq = (av / a_scale).round().clamp(0.0, m);
                dot += (2.0 * xq - m) as f64 * row[j] as f64;
            }
            let dv = dv_unit * dot;
            sq += dv * dv;
            cnt += 1;
        }
    }
    let dv_sigma = (sq / cnt.max(1) as f64).sqrt().max(1e-9);

    let kernel = NodeKernel::build(&w_q, layer.n_out, layer.n_in, cfg.r_in);
    QNode {
        kind: CimKind::Dense { n_in: layer.n_in, n_out: layer.n_out },
        rows: layer.n_in,
        alpha_rows,
        w_q,
        sum_w,
        bias: layer.b.clone(),
        w_scale,
        a_scale,
        alpha,
        gamma: gamma_from_sigma(dv_sigma, cfg, p),
        cfg: *cfg,
        kernel,
    }
}

#[allow(clippy::too_many_arguments)]
fn map_conv(
    c: &Conv3x3,
    cfg: &EvalCfg,
    act_hi: f32,
    stash: &[Vec<f32>],
    h: usize,
    w: usize,
    p: &MacroParams,
) -> QNode {
    let m = ((1u32 << cfg.r_in) - 1) as f32;
    let (w_nat, w_scale) = quantize_weights(&c.w, c.c_out, 9 * c.c_in);

    // Permute each output's kernel into the macro's physical row order.
    let (w_q, rows) = permute_conv_rows(&w_nat, c.c_in, c.c_out);
    let sum_w: Vec<f32> = (0..c.c_out)
        .map(|oc| w_q[oc * rows..(oc + 1) * rows].iter().sum())
        .collect();

    let alpha = if cfg.adaptive_swing {
        p.alpha_eff(rows)
    } else {
        p.alpha_eff(p.n_rows)
    };
    let a_scale = act_hi / m;

    // DP voltage σ over a deterministic subset: a few calibration
    // images, output pixels spread over the whole flattened index range
    // (stride (n_pix−1)/15 is generally coprime with the row width, so
    // the samples sweep columns instead of collapsing onto one border
    // column when the width divides a power-of-two stride), the first
    // output channels.
    let dv_unit = alpha * p.supply.vddl / (1u64 << (cfg.r_in + R_W)) as f64;
    let pad_val = ((1u32 << cfg.r_in) / 2) as u8;
    let n_pix = h * w;
    let n_samples = n_pix.min(16);
    let mut sq = 0f64;
    let mut cnt = 0usize;
    for a in stash.iter().take(8) {
        let xq: Vec<u8> = a
            .iter()
            .map(|&v| (v / a_scale).round().clamp(0.0, m) as u8)
            .collect();
        let (row_vecs, _, _) = im2col::im2col_image(&xq, c.c_in, h, w, 1, pad_val);
        for s in 0..n_samples {
            let pix = if n_samples > 1 { s * (n_pix - 1) / (n_samples - 1) } else { 0 };
            let rv = &row_vecs[pix];
            for oc in 0..c.c_out.min(32) {
                let wrow = &w_q[oc * rows..(oc + 1) * rows];
                let mut dot = 0f64;
                for (r, &q) in rv.iter().enumerate() {
                    dot += (2.0 * q as f32 - m) as f64 * wrow[r] as f64;
                }
                let dv = dv_unit * dot;
                sq += dv * dv;
                cnt += 1;
            }
        }
    }
    let dv_sigma = (sq / cnt.max(1) as f64).sqrt().max(1e-9);

    let kernel = NodeKernel::build(&w_q, c.c_out, rows, cfg.r_in);
    QNode {
        kind: CimKind::Conv { c_in: c.c_in, c_out: c.c_out },
        rows,
        alpha_rows: rows,
        w_q,
        sum_w,
        bias: c.b.clone(),
        w_scale,
        a_scale,
        alpha,
        gamma: gamma_from_sigma(dv_sigma, cfg, p),
        cfg: *cfg,
        kernel,
    }
}

/// Macro + ADC + digital reconstruction for one signed dot product —
/// the crate's single quantize/reconstruct/noise expression (Eq. 7
/// forward, equivalent output noise, offset-binary inversion, ABN
/// gain/offset and bias). The boolean reports whether the ADC code
/// stayed inside its `[0, top]` rails — the trainer's straight-through
/// pass-through mask (gradients stop where the conversion clipped).
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn macro_contract_masked(
    q: &QNode,
    dot: f64,
    o: usize,
    dv_unit: f64,
    lsb: f64,
    half: f64,
    top: f64,
    m: f32,
    rng: &mut Rng,
) -> (f32, bool) {
    let dv = dv_unit * dot;
    let mut code = half + dv / lsb;
    if q.cfg.noise_lsb > 0.0 {
        code += rng.normal(0.0, q.cfg.noise_lsb * (1.0 + q.gamma / 16.0));
    }
    let code = code.floor();
    let in_range = (0.0..=top).contains(&code);
    let code = code.clamp(0.0, top);
    let dot_rec = (code - half) * lsb / dv_unit;
    let xw = (dot_rec as f32 + m * q.sum_w[o]) / 2.0;
    (xw * q.a_scale * q.w_scale + q.bias[o], in_range)
}

/// [`macro_contract_masked`] without the rail mask (the inference path).
#[allow(clippy::too_many_arguments)]
#[inline]
fn macro_contract(
    q: &QNode,
    dot: f64,
    o: usize,
    dv_unit: f64,
    lsb: f64,
    half: f64,
    top: f64,
    m: f32,
    rng: &mut Rng,
) -> f32 {
    macro_contract_masked(q, dot, o, dv_unit, lsb, half, top, m, rng).0
}

/// Batched dense node: quantize + recenter the whole batch, one
/// dispatched kernel pass, then the macro contract per output.
///
/// The quantized weights are exact small integers and the signed
/// factors are exact small integers, so (when the overflow bound
/// holds) the dots are computed through the i32 kernel dispatch —
/// picking up SIMD and, at `r_in ≤ 2`, the bit-plane engine — and cast
/// back to f64, bit-identical to the f64 rowdot on the same data. The
/// kernel form (and any bit-plane pack) comes pre-resolved from the
/// node's [`NodeKernel`] cache; scratch buffers come from the
/// thread-local [`arena`].
fn forward_dense(
    q: &QNode,
    p: &MacroParams,
    cur: &[f32],
    n: usize,
    workers: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    let (n_in, n_out) = match q.kind {
        CimKind::Dense { n_in, n_out } => (n_in, n_out),
        _ => unreachable!(),
    };
    let (m, half, top, lsb, dv_unit) = q.contract_consts(p);

    // lint:allow(hot-path-alloc) one output buffer per batch, returned to the caller
    let mut out = vec![0f32; n * n_out];
    match &q.kernel {
        NodeKernel::I32 { wi, planes, .. } => {
            let mut sx_i = arena::take_i32(cur.len());
            sx_i.extend(cur.iter().map(|&v| {
                let xq = (v / q.a_scale).round().clamp(0.0, m);
                (2.0 * xq - m) as i32
            }));
            let mut dots = arena::take_i32(n * n_out);
            kernels::matmul_i32_packed_into(
                &sx_i,
                wi,
                n,
                n_in,
                n_out,
                workers,
                Some(q.cfg.r_in),
                planes.as_ref(),
                &mut dots,
            );
            for i in 0..n {
                for o in 0..n_out {
                    let dot = dots[i * n_out + o] as f64;
                    out[i * n_out + o] = macro_contract(q, dot, o, dv_unit, lsb, half, top, m, rng);
                }
            }
            arena::put_i32(dots);
            arena::put_i32(sx_i);
        }
        NodeKernel::F64 { w64 } => {
            let mut sx = arena::take_f64(cur.len());
            sx.extend(cur.iter().map(|&v| {
                let xq = (v / q.a_scale).round().clamp(0.0, m);
                (2.0 * xq - m) as f64
            }));
            let dots = kernels::rowdot_f64(&sx, w64, n, n_in, n_out, workers);
            arena::put_f64(sx);
            for i in 0..n {
                for o in 0..n_out {
                    out[i * n_out + o] =
                        macro_contract(q, dots[i * n_out + o], o, dv_unit, lsb, half, top, m, rng);
                }
            }
        }
    }
    out
}

/// Batched conv node: every im2col patch of every image becomes one row
/// of a signed-factor matrix; a single whole-batch gemm produces all the
/// dot products, then the macro contract maps them to output pixels.
#[allow(clippy::too_many_arguments)]
fn forward_conv(
    q: &QNode,
    p: &MacroParams,
    cur: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    workers: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    if n == 0 {
        // lint:allow(hot-path-alloc) empty Vec::new never touches the heap
        return Vec::new();
    }
    let c_out = q.n_out();
    let (m, half, top, lsb, dv_unit) = q.contract_consts(p);

    // One shared im2col row assembly with the engine backend (the signed
    // factors are exact small integers, so the i32 → f64 cast is lossless
    // and both paths stay in lock-step on the row-order convention).
    let in_len = c * h * w;
    let n_pix = h * w;
    // lint:allow(hot-path-alloc) one output buffer per batch, returned to the caller
    let mut out = vec![0f32; n * c_out * n_pix];
    match &q.kernel {
        NodeKernel::I32 { wi, planes, .. } => {
            // Stream the flat batch through the direct conv kernel:
            // per-worker im2col scratch, SIMD or bit-plane dots per the
            // dispatch, reusing the node's deploy-time pack.
            let mut images_q = arena::take_u8(cur.len());
            for &v in cur {
                images_q.push((v / q.a_scale).round().clamp(0.0, m) as u8);
            }
            let mut dots = arena::take_i32(n * n_pix * c_out);
            let (oh, ow) = kernels::conv3x3_direct_packed_into(
                &images_q,
                n,
                c,
                h,
                w,
                1,
                q.cfg.r_in,
                wi,
                q.rows,
                c_out,
                workers,
                planes.as_ref(),
                &mut dots,
            );
            debug_assert_eq!((oh, ow), (h, w));
            for img in 0..n {
                let fmap = &mut out[img * c_out * n_pix..(img + 1) * c_out * n_pix];
                for pix in 0..n_pix {
                    let base = (img * n_pix + pix) * c_out;
                    let d = &dots[base..base + c_out];
                    for (oc, &dot) in d.iter().enumerate() {
                        fmap[oc * n_pix + pix] =
                            macro_contract(q, dot as f64, oc, dv_unit, lsb, half, top, m, rng);
                    }
                }
            }
            arena::put_i32(dots);
            arena::put_u8(images_q);
        }
        NodeKernel::F64 { w64 } => {
            // lint:allow(hot-path-alloc) f64 fallback arm: engaged only when the
            // dot cannot be proven to fit i32; allocates per batch by design.
            let images_q: Vec<Vec<u8>> = cur
                .chunks(in_len)
                .map(|img| {
                    img.iter()
                        .map(|&v| (v / q.a_scale).round().clamp(0.0, m) as u8)
                        // lint:allow(hot-path-alloc) f64 fallback arm (see above)
                        .collect()
                })
                // lint:allow(hot-path-alloc) f64 fallback arm (see above)
                .collect();
            let (sx_i, oh, ow) =
                kernels::conv3x3_signed_rows(&images_q, c, h, w, 1, q.cfg.r_in, q.rows);
            debug_assert_eq!((oh, ow), (h, w));
            // lint:allow(hot-path-alloc) f64 fallback arm (see above)
            let sx: Vec<f64> = sx_i.iter().map(|&v| v as f64).collect();
            let dots = kernels::rowdot_f64(&sx, w64, n * n_pix, q.rows, c_out, workers);
            for img in 0..n {
                let fmap = &mut out[img * c_out * n_pix..(img + 1) * c_out * n_pix];
                for pix in 0..n_pix {
                    let base = (img * n_pix + pix) * c_out;
                    let d = &dots[base..base + c_out];
                    for (oc, &dot) in d.iter().enumerate() {
                        fmap[oc * n_pix + pix] =
                            macro_contract(q, dot, oc, dv_unit, lsb, half, top, m, rng);
                    }
                }
            }
        }
    }
    out
}

/// Evaluate a graph on a dataset through the CIM mapping; returns test
/// accuracy (the graph-level generalization of `cim_eval::eval_cim`).
pub fn eval_graph(graph: &Graph, data: &Dataset, p: &MacroParams, cfg: &EvalCfg) -> Result<f64> {
    eval_graph_workers(graph, data, p, cfg, crate::engine::default_workers())
}

/// [`eval_graph`] with an explicit worker count for the batched matmuls.
pub fn eval_graph_workers(
    graph: &Graph,
    data: &Dataset,
    p: &MacroParams,
    cfg: &EvalCfg,
    workers: usize,
) -> Result<f64> {
    let mapped = MappedGraph::build(graph, data, p, cfg)?;
    let n = data.n;
    let out = mapped.forward_flat(&data.x[..n * data.image_len()], n, workers)?;
    let n_out = mapped.output_len();
    let mut correct = 0usize;
    for i in 0..n {
        let logits = &out[i * n_out..(i + 1) * n_out];
        if crate::util::stats::argmax_f32(logits) == data.y[i] as usize {
            correct += 1;
        }
    }
    Ok(correct as f64 / n as f64)
}

/// Emit one physical manifest layer from a mapped node. The post-ADC
/// gain is chosen so `(code − half)·out_gain` reproduces the real-valued
/// `a_scale·w_scale·ΣX·W` pre-activation, and the per-channel 5b ABN
/// offset absorbs the offset-binary `M·ΣW` constant plus the float bias
/// (quantized to the silicon's ±16 codes of 1.875 mV — the lossy part of
/// the lowering, exactly as on the die).
fn lower_cim_node(
    q: &QNode,
    kind: Kind,
    relu: bool,
    pool: Pool,
    name: String,
    p: &MacroParams,
) -> Result<Layer> {
    let (in_features, out_features) = match q.kind {
        CimKind::Dense { n_in, n_out } => (n_in, n_out),
        CimKind::Conv { c_in, c_out } => (c_in, c_out),
    };
    // Physical rows: conv nodes are already in padded macro row order;
    // dense nodes pad up to whole DP units. Padding rows carry a +1
    // weight against the +1 mid-rail input factor (0 is not an
    // antipodal level — the analog bitcells cannot store it); their
    // constant `n_pad` contribution to every dot product is absorbed by
    // the ABN offset below, exactly the python compile path's
    // convention.
    let rows_phys = match q.kind {
        CimKind::Conv { .. } => q.rows,
        CimKind::Dense { .. } => q.rows.div_ceil(p.rows_per_unit) * p.rows_per_unit,
    };
    ensure!(
        rows_phys <= p.n_rows,
        "{name}: {rows_phys} rows exceed the {}-row macro (split the layer)",
        p.n_rows
    );
    let real_rows = match q.kind {
        CimKind::Dense { n_in, .. } => n_in,
        CimKind::Conv { c_in, .. } => 9 * c_in,
    };
    let n_pad = (rows_phys - real_rows) as f64;
    let mut w_phys = vec![1i32; rows_phys * out_features];
    for o in 0..out_features {
        for r in 0..q.rows {
            let wv = q.w_q[o * q.rows + r];
            // The nn-side mapping marks conv padding rows with a 0.0
            // weight (quantized real weights are always odd).
            if wv != 0.0 {
                w_phys[r * out_features + o] = wv as i32;
            }
        }
    }

    // The manifest executor's IdealContract convention: always the
    // per-layer (adaptive) swing, and 1b lanes carry no sub-LSB scaling.
    let rin_eff = if q.cfg.r_in > 1 { q.cfg.r_in } else { 0 };
    let rw_eff = if R_W > 1 { R_W } else { 0 };
    let dv_scale =
        p.alpha_eff(rows_phys) * p.supply.vddl / (1u64 << (rin_eff + rw_eff)) as f64;
    // The mapping calibrated γ against its own dv convention (q.alpha,
    // 2^(r_in+R_W)); re-fit it to the physical contract's dv scale so
    // the ADC fill is preserved — keep γ·dv invariant, re-quantized to
    // the hardware's power-of-two gains. With the adaptive swing and
    // r_in > 1 the two conventions coincide and γ passes through
    // unchanged.
    let dv_unit_map = q.alpha * p.supply.vddl / (1u64 << (q.cfg.r_in + R_W)) as f64;
    let gamma = quantize_gamma(q.gamma * dv_unit_map / dv_scale, q.cfg.gamma_bits);
    let lsb = p.adc_lsb(q.cfg.r_out, gamma);
    let s = (q.a_scale * q.w_scale) as f64;
    let out_gain = (s * lsb / (2.0 * dv_scale)) as f32;

    // β absorbs the offset-binary constant M·ΣW, the float bias, and
    // the −n_pad correction for the padding rows' constant +1·(+1)
    // contribution to the physical dot product. One ABN code moves the
    // DPL by abn_offset_range/16 — the same step the ADC model applies.
    let beta_step = p.abn_offset_range / 16.0;
    let m = ((1u64 << q.cfg.r_in) - 1) as f64;
    let beta: Vec<i32> = (0..out_features)
        .map(|o| {
            let code = dv_scale
                * (m * q.sum_w[o] as f64 - n_pad + 2.0 * q.bias[o] as f64 / s)
                / beta_step;
            code.round().clamp(-16.0, 15.0) as i32
        })
        .collect();

    Ok(Layer {
        name,
        kind,
        in_features,
        out_features,
        relu,
        stride: 1,
        pool,
        rows: rows_phys,
        cfg: crate::analog::macro_model::OpConfig {
            r_in: q.cfg.r_in,
            r_w: R_W,
            r_out: q.cfg.r_out,
            gamma,
            connected_units: rows_phys / p.rows_per_unit,
            t_dp: 5e-9,
        },
        w_phys,
        beta,
        a_scale: q.a_scale,
        out_gain,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_conv_graph(seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let conv1 = Conv3x3::new(3, 4, &mut rng);
        let conv2 = Conv3x3::new(4, 4, &mut rng);
        let head = crate::nn::mlp::Dense::new(4 * 3 * 3, 2, &mut rng);
        Graph::new("toy_cnn", vec![3, 6, 6])
            .with(Node::Conv3x3(conv1))
            .with(Node::Relu)
            .with(Node::Conv3x3(conv2))
            .with(Node::Relu)
            .with(Node::Pool2x2(PoolKind::Max))
            .with(Node::Flatten)
            .with(Node::Dense(DenseNode::new(head)))
    }

    fn toy_data(n: usize, len: usize, seed: u64, shape: Vec<usize>) -> Dataset {
        let mut rng = Rng::new(seed);
        let x = (0..n * len).map(|_| rng.uniform() as f32).collect();
        let y = (0..n).map(|i| (i % 2) as i32).collect();
        Dataset { x, y, n, shape }
    }

    #[test]
    fn graph_shapes_and_float_forward() {
        let g = toy_conv_graph(3);
        let shapes = g.shapes().unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![2]);
        assert_eq!(shapes[5], vec![4, 3, 3]); // after pool
        let y = g.forward_float(&vec![0.5; g.input_len()]).unwrap();
        assert_eq!(y.len(), 2);
    }

    #[test]
    fn mapped_graph_runs_and_is_worker_invariant() {
        let g = toy_conv_graph(5);
        let data = toy_data(12, g.input_len(), 7, vec![3, 6, 6]);
        let p = MacroParams::paper();
        let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(8, 5, true) };
        let mapped = MappedGraph::build(&g, &data, &p, &cfg).unwrap();
        let images: Vec<Vec<f32>> = (0..data.n).map(|i| data.image(i).to_vec()).collect();
        let a = mapped.forward_batch(&images, 1).unwrap();
        let b = mapped.forward_batch(&images, 4).unwrap();
        assert_eq!(a, b, "worker split must not change noiseless results");
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|v| v.len() == 2 && v.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn noiseless_cim_tracks_float_at_high_precision() {
        // With 8b precision and 5 γ bits the quantized graph output must
        // correlate with the float forward (loose: same argmax usually).
        let g = toy_conv_graph(11);
        let data = toy_data(24, g.input_len(), 13, vec![3, 6, 6]);
        let p = MacroParams::paper();
        let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(8, 5, true) };
        let mapped = MappedGraph::build(&g, &data, &p, &cfg).unwrap();
        let mut agree = 0usize;
        for i in 0..data.n {
            let x = data.image(i);
            let f = g.forward_float(x).unwrap();
            let qv = mapped.forward_batch(&[x.to_vec()], 1).unwrap();
            if crate::util::stats::argmax_f32(&f) == crate::util::stats::argmax_f32(&qv[0]) {
                agree += 1;
            }
        }
        assert!(agree >= data.n * 7 / 10, "agreement {agree}/{}", data.n);
    }

    #[test]
    fn lowering_produces_a_valid_manifest_model() {
        let g = toy_conv_graph(17);
        let data = toy_data(16, g.input_len(), 19, vec![3, 6, 6]);
        let p = MacroParams::paper();
        let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(8, 5, true) };
        let model = g.lower(&data, &p, &cfg).unwrap();
        assert_eq!(model.layers.len(), 3);
        assert_eq!(model.layers[0].kind, Kind::Conv3);
        assert!(model.layers[0].relu);
        assert_eq!(model.layers[1].pool, Pool::Max2);
        assert_eq!(model.layers[2].kind, Kind::Dense);
        assert!(!model.layers[2].relu);
        for l in &model.layers {
            assert_eq!(l.rows % p.rows_per_unit, 0, "{}", l.name);
            assert_eq!(l.w_phys.len(), l.rows * l.out_features);
            assert!(l.beta.iter().all(|&b| (-16..=15).contains(&b)));
            let mx = (1 << l.cfg.r_w) - 1;
            // Every physical weight is a representable antipodal level
            // (odd, in range) — the analog bitcells reject anything else.
            assert!(l.w_phys.iter().all(|&w| w.abs() <= mx && (w + mx) % 2 == 0));
            assert!(l.out_gain.is_finite() && l.out_gain > 0.0);
        }
        // Conv padding rows (c_in=3 < 4-channel unit) carry the +1
        // weight whose constant contribution β absorbs.
        let conv = &model.layers[0];
        let order = im2col::row_order(3);
        for (r, o) in order.iter().enumerate() {
            if o.is_none() {
                for oc in 0..conv.out_features {
                    assert_eq!(conv.w_phys[r * conv.out_features + oc], 1, "row {r}");
                }
            }
        }
        // The toy head (36 features) fills exactly one DP unit.
        assert_eq!(model.layers[2].rows, 36);
    }

    #[test]
    fn lowering_refits_gamma_to_the_physical_swing() {
        // With the fixed full-array swing the mapping's dv convention is
        // ~10x smaller than the physical per-layer contract (the
        // executor always uses alpha_eff(rows)); the lowered γ must
        // compensate so γ·dv stays invariant up to the power-of-two
        // requantization — otherwise the lowered ADC rails.
        let mut rng = Rng::new(31);
        let dense = crate::nn::mlp::Dense::new(40, 6, &mut rng);
        let g = Graph::new("fixed_swing", vec![40]).with(Node::Dense(DenseNode::new(dense)));
        let data = toy_data(16, 40, 3, vec![40]);
        let p = MacroParams::paper();
        let cfg = EvalCfg { noise_lsb: 0.0, ..EvalCfg::new(8, 5, false) };
        let mapped = MappedGraph::build(&g, &data, &p, &cfg).unwrap();
        let model = g.lower(&data, &p, &cfg).unwrap();
        let q = &mapped.cim[0];
        let layer = &model.layers[0];
        let dv_map = q.alpha * p.supply.vddl / (1u64 << (8 + R_W)) as f64;
        let dv_phys =
            p.alpha_eff(layer.rows) * p.supply.vddl / (1u64 << (8 + R_W)) as f64;
        let product_map = q.gamma * dv_map;
        let product_phys = layer.cfg.gamma * dv_phys;
        assert!(layer.cfg.gamma < q.gamma, "phys {} map {}", layer.cfg.gamma, q.gamma);
        assert!(product_phys <= product_map * (1.0 + 1e-12), "{product_phys} > {product_map}");
        assert!(
            layer.cfg.gamma == 1.0 || product_phys * 2.0 > product_map,
            "gamma under-fitted: {product_phys} vs {product_map}"
        );
    }

    #[test]
    fn standalone_digital_nodes_refuse_to_lower() {
        let mut rng = Rng::new(23);
        let g = Graph::new("bad", vec![4, 4, 4])
            .with(Node::Pool2x2(PoolKind::Max))
            .with(Node::Conv3x3(Conv3x3::new(4, 4, &mut rng)));
        let data = toy_data(4, 64, 1, vec![4, 4, 4]);
        let err = g.lower(&data, &MacroParams::paper(), &EvalCfg::new(8, 5, true));
        assert!(err.is_err());
    }
}
