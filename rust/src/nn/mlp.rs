//! Rust-native MLP training — the substrate for the Fig. 3(b) study
//! (784-512-128-10 MLP, test error vs ABN gain precision × ADC bits).
//!
//! Plain f32 SGD/Adam with hand-rolled dense layers; no BLAS in the
//! vendored dependency set, so matmuls are cache-blocked loops. Training
//! the Fig. 3b topology on a few thousand synthetic digits takes seconds
//! in release mode, which is all the sweep needs.

use crate::nn::dataset::Dataset;
use crate::util::rng::Rng;

/// One dense layer: row-major weights `[out × in]` + bias.
#[derive(Clone, Debug)]
pub struct Dense {
    /// Row-major float weights `[n_out × n_in]`.
    pub w: Vec<f32>,
    /// Per-output bias.
    pub b: Vec<f32>,
    /// Input features.
    pub n_in: usize,
    /// Output features.
    pub n_out: usize,
}

impl Dense {
    /// He-initialized random layer (zero bias).
    pub fn new(n_in: usize, n_out: usize, rng: &mut Rng) -> Self {
        let scale = (2.0 / n_in as f64).sqrt();
        let w = (0..n_in * n_out)
            .map(|_| (rng.gaussian() * scale) as f32)
            .collect();
        Self { w, b: vec![0.0; n_out], n_in, n_out }
    }

    /// y = W x + b.
    pub fn forward(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for (o, yo) in y.iter_mut().enumerate() {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = self.b[o];
            // 4-way unroll; the compiler vectorizes the rest.
            let mut i = 0;
            while i + 4 <= self.n_in {
                acc += row[i] * x[i]
                    + row[i + 1] * x[i + 1]
                    + row[i + 2] * x[i + 2]
                    + row[i + 3] * x[i + 3];
                i += 4;
            }
            while i < self.n_in {
                acc += row[i] * x[i];
                i += 1;
            }
            *yo = acc;
        }
    }
}

/// The MLP: dense layers with ReLU between them.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Dense layers in execution order (ReLU between them).
    pub layers: Vec<Dense>,
}

/// Adam state per parameter tensor.
struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n] }
    }

    fn step(&mut self, p: &mut [f32], g: &[f32], lr: f32, t: i32) {
        let b1 = 0.9f32;
        let b2 = 0.999f32;
        let eps = 1e-8f32;
        let c1 = 1.0 / (1.0 - b1.powi(t));
        let c2 = 1.0 / (1.0 - b2.powi(t));
        for i in 0..p.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g[i] * g[i];
            p[i] -= lr * (self.m[i] * c1) / ((self.v[i] * c2).sqrt() + eps);
        }
    }
}

impl Mlp {
    /// Build with the given layer widths, e.g. `[784, 512, 128, 10]`.
    pub fn new(widths: &[usize], seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let layers = widths
            .windows(2)
            .map(|w| Dense::new(w[0], w[1], &mut rng))
            .collect();
        Self { layers }
    }

    /// Forward pass returning all post-ReLU activations (input included)
    /// and the final logits.
    pub fn forward_all(&self, x: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts = vec![x.to_vec()];
        let mut cur = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let mut y = vec![0f32; layer.n_out];
            layer.forward(&cur, &mut y);
            if li + 1 < self.layers.len() {
                for v in y.iter_mut() {
                    *v = v.max(0.0);
                }
                acts.push(y.clone());
            }
            cur = y;
        }
        (acts, cur)
    }

    /// Forward `x` through every layer; returns the final logits.
    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        self.forward_all(x).1
    }

    /// Train with Adam + softmax cross-entropy. Returns final train loss.
    pub fn train(
        &mut self,
        data: &Dataset,
        epochs: usize,
        batch: usize,
        lr: f32,
        seed: u64,
    ) -> f32 {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let mut adam_w: Vec<Adam> = self.layers.iter().map(|l| Adam::new(l.w.len())).collect();
        let mut adam_b: Vec<Adam> = self.layers.iter().map(|l| Adam::new(l.b.len())).collect();
        let mut order: Vec<usize> = (0..data.n).collect();
        let mut t = 0i32;
        let mut last_loss = 0.0f32;

        for _ep in 0..epochs {
            rng.shuffle(&mut order);
            let mut ep_loss = 0.0f32;
            let mut nb = 0;
            for chunk in order.chunks(batch) {
                t += 1;
                // Accumulate gradients over the batch.
                let mut gw: Vec<Vec<f32>> =
                    self.layers.iter().map(|l| vec![0.0; l.w.len()]).collect();
                let mut gb: Vec<Vec<f32>> =
                    self.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();
                let mut loss = 0.0f32;
                for &i in chunk {
                    let x = data.flat(i);
                    let yi = data.y[i] as usize;
                    let (acts, logits) = self.forward_all(x);
                    // softmax CE
                    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
                    let exps: Vec<f32> = logits.iter().map(|&v| (v - mx).exp()).collect();
                    let sum: f32 = exps.iter().sum();
                    loss -= (exps[yi] / sum).ln();
                    // backward
                    let mut delta: Vec<f32> =
                        exps.iter().map(|&e| e / sum).collect();
                    delta[yi] -= 1.0;
                    for li in (0..self.layers.len()).rev() {
                        let layer = &self.layers[li];
                        let a_in = &acts[li];
                        for o in 0..layer.n_out {
                            let d = delta[o];
                            if d != 0.0 {
                                gb[li][o] += d;
                                let grow = &mut gw[li][o * layer.n_in..(o + 1) * layer.n_in];
                                for (gi, &ai) in grow.iter_mut().zip(a_in.iter()) {
                                    *gi += d * ai;
                                }
                            }
                        }
                        if li > 0 {
                            let mut next = vec![0f32; layer.n_in];
                            for o in 0..layer.n_out {
                                let d = delta[o];
                                if d != 0.0 {
                                    let row = &layer.w[o * layer.n_in..(o + 1) * layer.n_in];
                                    for (ni, &wv) in next.iter_mut().zip(row.iter()) {
                                        *ni += d * wv;
                                    }
                                }
                            }
                            // ReLU mask of the upstream activation.
                            for (nv, &av) in next.iter_mut().zip(acts[li].iter()) {
                                if av <= 0.0 {
                                    *nv = 0.0;
                                }
                            }
                            delta = next;
                        }
                    }
                }
                let inv = 1.0 / chunk.len() as f32;
                for li in 0..self.layers.len() {
                    for g in gw[li].iter_mut() {
                        *g *= inv;
                    }
                    for g in gb[li].iter_mut() {
                        *g *= inv;
                    }
                    adam_w[li].step(&mut self.layers[li].w, &gw[li], lr, t);
                    adam_b[li].step(&mut self.layers[li].b, &gb[li], lr, t);
                }
                ep_loss += loss * inv;
                nb += 1;
            }
            last_loss = ep_loss / nb as f32;
        }
        last_loss
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..data.n {
            let logits = self.logits(data.flat(i));
            let pred = crate::util::stats::argmax_f32(&logits);
            if pred == data.y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / data.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dataset::Dataset;

    /// A tiny separable 2-class problem: class = sign of the mean.
    fn toy(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let dim = 16;
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.bool(0.5) as i32;
            let mu = if c == 1 { 0.6 } else { 0.2 };
            for _ in 0..dim {
                x.push(rng.normal(mu, 0.15) as f32);
            }
            y.push(c);
        }
        Dataset { x, y, n, shape: vec![dim] }
    }

    #[test]
    fn dense_forward_matches_manual() {
        let mut rng = Rng::new(0);
        let mut d = Dense::new(3, 2, &mut rng);
        d.w = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        d.b = vec![0.5, -0.5];
        let mut y = vec![0.0; 2];
        d.forward(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![6.5, -0.5]);
    }

    #[test]
    fn mlp_learns_toy_problem() {
        let train = toy(400, 1);
        let test = toy(200, 2);
        let mut mlp = Mlp::new(&[16, 32, 2], 7);
        let before = mlp.accuracy(&test);
        mlp.train(&train, 8, 32, 1e-2, 3);
        let after = mlp.accuracy(&test);
        assert!(after > 0.95, "before={before} after={after}");
    }

    #[test]
    fn forward_all_shapes() {
        let mlp = Mlp::new(&[8, 6, 4, 3], 1);
        let (acts, logits) = mlp.forward_all(&[0.5; 8]);
        assert_eq!(acts.len(), 3); // input + two hidden
        assert_eq!(acts[1].len(), 6);
        assert_eq!(logits.len(), 3);
        assert!(acts[1].iter().all(|&v| v >= 0.0)); // post-ReLU
    }
}
