//! Area and density model (§V, Fig. 16c; Table I rows "Density" and
//! "Peak AE").

use crate::analog::macro_model::OpConfig;
use crate::config::params::MacroParams;
use crate::energy::timing;

/// Macro area breakdown [mm²] (Fig. 16c: DP array 74%, ADCs <5%, the
/// rest MBIW + periphery).
#[derive(Clone, Copy, Debug)]
pub struct MacroArea {
    pub dp_array: f64,
    pub adc: f64,
    pub mbiw_periphery: f64,
}

impl MacroArea {
    pub fn of(p: &MacroParams) -> Self {
        let total = p.macro_area_mm2;
        MacroArea {
            dp_array: 0.74 * total,
            adc: 0.045 * total,
            mbiw_periphery: total - 0.74 * total - 0.045 * total,
        }
    }

    pub fn total(&self) -> f64 {
        self.dp_array + self.adc + self.mbiw_periphery
    }
}

/// Consistency check: bitcell area × cell count against the DP-array
/// share (layout efficiency ≈ 0.9 for the custom MoM-over-cell stack).
pub fn dp_array_from_bitcells(p: &MacroParams) -> f64 {
    p.n_rows as f64 * p.n_cols as f64 * p.bitcell_area_um2 * 1e-6 / 0.9
}

/// Area efficiency [ops/s/mm²], 8b-normalized (Table I "Peak AE").
pub fn area_efficiency_8b(p: &MacroParams, cfg: &OpConfig) -> f64 {
    timing::peak_throughput_8b(p, cfg) / p.macro_area_mm2
}

/// Area efficiency at raw precision [ops/s/mm²] — the 1b end of the
/// paper's 2.6–154 TOPS/mm² span.
pub fn area_efficiency_raw(p: &MacroParams, cfg: &OpConfig) -> f64 {
    timing::peak_throughput_raw(p, cfg) / p.macro_area_mm2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums_to_total() {
        let p = MacroParams::paper();
        let a = MacroArea::of(&p);
        assert!((a.total() - p.macro_area_mm2).abs() < 1e-12);
        assert!(a.dp_array > 10.0 * a.adc); // ADCs < 5%, array 74%
    }

    #[test]
    fn bitcell_accounting_consistent() {
        let p = MacroParams::paper();
        let from_cells = dp_array_from_bitcells(&p);
        let a = MacroArea::of(&p);
        let ratio = from_cells / a.dp_array;
        assert!((0.7..1.3).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn density_matches_table1() {
        let p = MacroParams::paper();
        assert!((p.density_kb_mm2() - 187.0).abs() < 15.0);
    }

    #[test]
    fn area_efficiency_span_matches_table1() {
        // Table I: 2.6 TOPS/mm² at 8b (norm) up to ~154 TOPS/mm² at 1b raw.
        let p = MacroParams::paper();
        let ae8 = area_efficiency_8b(&p, &OpConfig::new(8, 1, 8)) / 1e12;
        assert!((1.0..6.0).contains(&ae8), "8b AE={ae8} TOPS/mm²");
        let ae1 = area_efficiency_raw(&p, &OpConfig::new(1, 1, 1)) / 1e12;
        assert!((50.0..300.0).contains(&ae1), "1b AE={ae1} TOPS/mm²");
        assert!(ae1 / ae8 > 20.0);
    }
}
