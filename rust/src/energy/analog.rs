//! Analog macro energy model (§V.A; Figs. 6c, 18c, 22).
//!
//! Component-wise CV² accounting over one full-array macro operation.
//! Constants are anchored to the paper's measured headline numbers
//! (1.2 POPS/W raw at 8b-in/1b-w/8b-out, 0.3/0.6 V, C_in = 128) and the
//! stated qualitative behaviours: ADC+ladder dominate at small C_in
//! (Fig. 22b), split-DPL saves up to ~72% of DP energy at 64 channels
//! with a 40 fF load (Fig. 6c), γ=1 is the most efficient gain (Fig. 18c).

use crate::analog::macro_model::OpConfig;
use crate::config::params::{DplTopology, MacroParams};
use crate::energy::timing;

/// Mean switching activity of input lines (random data).
const A_IN: f64 = 0.5;
/// Mean |ΔV| on the DPL relative to full swing (narrow DP distributions).
const A_DPL: f64 = 0.25;
/// Sense-amp decision energy at V_DDH = 0.8 V [J].
const E_SA0: f64 = 15.0e-15;
/// Macro-internal control/timing energy per op at nominal [J].
const E_CTRL0: f64 = 30.0e-12;
/// S-IN line load per column seen by the ladder taps [F] (γ > 1 only —
/// at unity gain the MSB taps tie to the rails).
const C_SIN: f64 = 10.0e-15;
/// Global calibration factor anchoring the 8b raw EE to the measured
/// 1.2 POPS/W: covers clock distribution, references and biasing that
/// the per-block CV² accounting does not see.
const K_CAL: f64 = 2.9;

/// DP-phase energy for one macro op [J] with `active_cols` columns
/// enabled: input drivers charging the bitcell caps of *connected* rows
/// across the active columns plus the DPL precharge, per input bitplane.
pub fn e_dp_cols(p: &MacroParams, cfg: &OpConfig, active_cols: usize) -> f64 {
    let rows = cfg.active_rows(p) as f64;
    let cols = active_cols as f64;
    let vddl2 = p.supply.vddl * p.supply.vddl;
    // Input drivers see the coupling caps of the active columns.
    let e_drivers = rows * cols * p.c_c * vddl2 * A_IN;
    // Per-column DPL precharge of the *connected* segment + load.
    let c_dpl = match p.topology {
        DplTopology::Baseline => {
            p.n_rows as f64 * (p.c_c + p.c_p_per_row) + p.c_load
        }
        DplTopology::ParallelSplit => {
            rows * (p.c_c + p.c_p_per_row) + p.c_p_global + p.c_load
        }
        DplTopology::SerialSplit => rows * (p.c_c + p.c_p_per_row) + p.c_load,
    };
    let e_pre = cols * c_dpl * vddl2 * A_DPL;
    (e_drivers + e_pre) * cfg.r_in as f64
}

/// Full-array DP energy (peak characterization mode).
pub fn e_dp(p: &MacroParams, cfg: &OpConfig) -> f64 {
    e_dp_cols(p, cfg, p.n_cols)
}

/// DP energy with an explicit load override (Fig. 6c sweeps C_L).
pub fn e_dp_with_load(p: &MacroParams, cfg: &OpConfig, c_load: f64) -> f64 {
    let mut p2 = p.clone();
    p2.c_load = c_load;
    e_dp(&p2, cfg)
}

/// MBIW accumulation energy [J]: charge sharing on C_acc per input bit
/// plus the inter-column weight shares.
pub fn e_mbiw_cols(p: &MacroParams, cfg: &OpConfig, active_cols: usize) -> f64 {
    let vddl2 = p.supply.vddl * p.supply.vddl;
    let shares = if cfg.r_in > 1 { cfg.r_in as f64 } else { 0.0 }
        + if cfg.r_w > 1 { cfg.r_w as f64 } else { 0.0 };
    active_cols as f64 * p.c_acc() * vddl2 * A_DPL * shares
}

pub fn e_mbiw(p: &MacroParams, cfg: &OpConfig) -> f64 {
    e_mbiw_cols(p, cfg, p.n_cols)
}

/// Shared resistive ladder energy per op [J]: 1 mA DC during settling +
/// per-step reloads; γ = 1 ties the MSB taps to the rails, relieving the
/// ladder (§V.A / Fig. 18c).
pub fn e_ladder(p: &MacroParams, cfg: &OpConfig) -> f64 {
    let ladder_duty = if cfg.gamma <= 1.0 { 0.35 } else { 1.0 };
    let t_active = p.t_ladder + cfg.r_out as f64 * p.t_sar;
    let e_dc = 1.0e-3 * p.supply.vddh * t_active * ladder_duty;
    // Tap loading: at γ > 1 every S-IN line reloads from a resistive tap
    // each SAR step; at γ = 1 the MSB taps are rail-tied.
    let e_taps = if cfg.gamma > 1.0 {
        p.n_cols as f64 * cfg.r_out as f64 * C_SIN * p.supply.vddh * p.supply.vddh
    } else {
        0.0
    };
    e_dc + e_taps
}

/// DSCI ADC energy [J]: SAR array switching + SA decisions + ladder.
/// Only `active_cols` column ADCs convert (column-enable gating).
pub fn e_adc_cols(p: &MacroParams, cfg: &OpConfig, active_cols: usize) -> f64 {
    let vddh = p.supply.vddh;
    let es = p.supply.energy_scale();
    // SAR switching: injected charge scales with the γ-compressed step
    // (Q = C·V_step) but is drawn from the V_DDH rail (E = Q·V_DDH).
    let v_step = vddh / cfg.gamma.max(1.0);
    let e_sar = active_cols as f64
        * (p.c_sar + p.c_p_sar)
        * v_step
        * vddh
        * 0.33
        * cfg.r_out as f64;
    let e_sa = active_cols as f64 * cfg.r_out as f64 * E_SA0 * es;
    // Ladder scales its tap-loading with active columns; DC is shared.
    let col_frac = active_cols as f64 / p.n_cols as f64;
    let ladder_duty = if cfg.gamma <= 1.0 { 0.35 } else { 1.0 };
    let t_active = p.t_ladder + cfg.r_out as f64 * p.t_sar;
    let e_lad_dc = 1.0e-3 * vddh * t_active * ladder_duty;
    let e_taps = if cfg.gamma > 1.0 {
        active_cols as f64 * cfg.r_out as f64 * C_SIN * vddh * vddh
    } else {
        0.0
    };
    let _ = col_frac;
    e_sar + e_sa + e_lad_dc + e_taps
}

pub fn e_adc(p: &MacroParams, cfg: &OpConfig) -> f64 {
    e_adc_cols(p, cfg, p.n_cols)
}

/// Macro control / timing-generator energy [J]: part flat (clocking,
/// timing generator), part per-column (output registers, local CG).
pub fn e_ctrl_cols(p: &MacroParams, cfg: &OpConfig, active_cols: usize) -> f64 {
    let col_frac = active_cols as f64 / p.n_cols as f64;
    E_CTRL0
        * p.supply.energy_scale()
        * (cfg.r_in + cfg.r_out) as f64
        / 16.0
        * (0.3 + 0.7 * col_frac)
}

pub fn e_ctrl(p: &MacroParams, cfg: &OpConfig) -> f64 {
    e_ctrl_cols(p, cfg, p.n_cols)
}

/// Total macro energy for one operation with `active_cols` columns [J].
pub fn e_macro_op_cols(p: &MacroParams, cfg: &OpConfig, active_cols: usize) -> f64 {
    K_CAL
        * (e_dp_cols(p, cfg, active_cols)
            + e_mbiw_cols(p, cfg, active_cols)
            + e_adc_cols(p, cfg, active_cols)
            + e_ctrl_cols(p, cfg, active_cols))
}

/// Total macro energy, full array (peak characterization mode) [J].
pub fn e_macro_op(p: &MacroParams, cfg: &OpConfig) -> f64 {
    e_macro_op_cols(p, cfg, p.n_cols)
}

/// Component breakdown (Fig. 22b): (V_DDL-side, V_DDH-side, ladder) [J].
pub fn breakdown(p: &MacroParams, cfg: &OpConfig) -> (f64, f64, f64) {
    let vddl_side = K_CAL * (e_dp(p, cfg) + e_mbiw(p, cfg));
    let ladder = K_CAL * e_ladder(p, cfg);
    let vddh_side = K_CAL * (e_adc(p, cfg) - e_ladder(p, cfg) + e_ctrl(p, cfg));
    (vddl_side, vddh_side, ladder)
}

/// Macro energy efficiency, raw ops at configured precision [ops/J].
pub fn ee_raw(p: &MacroParams, cfg: &OpConfig) -> f64 {
    timing::raw_ops(p, cfg) / e_macro_op(p, cfg)
}

/// Macro energy efficiency, 8b-normalized [ops/J] (Table I).
pub fn ee_8b(p: &MacroParams, cfg: &OpConfig) -> f64 {
    timing::ops_8b_norm(p, cfg) / e_macro_op(p, cfg)
}

/// DP energy savings of the serial-split DPL versus baseline (Fig. 6c),
/// for a given number of connected units and load.
pub fn dp_savings(p: &MacroParams, units: usize, c_load: f64) -> f64 {
    let cfg = OpConfig::new(8, 1, 8).with_units(units);
    let split = p
        .clone()
        .with_topology(DplTopology::SerialSplit);
    let base = p.clone().with_topology(DplTopology::Baseline);
    1.0 - e_dp_with_load(&split, &cfg, c_load) / e_dp_with_load(&base, &cfg, c_load)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::Supply;

    #[test]
    fn anchor_8b_raw_ee_near_1_2_pops_per_watt() {
        // §V.A: r_in=r_out=8b, binary weights, 128 channels, unity gain,
        // 0.3/0.6 V ⇒ ~1.2 POPS/W raw (0.15 POPS/W 8b-normalized).
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let cfg = OpConfig::new(8, 1, 8).with_units(32);
        let ee = ee_raw(&p, &cfg) / 1e15;
        assert!((0.8..1.6).contains(&ee), "raw EE={ee} POPS/W");
        let ee8 = ee_8b(&p, &cfg) / 1e12;
        assert!((100.0..200.0).contains(&ee8), "8b-norm EE={ee8} TOPS/W");
    }

    #[test]
    fn quasi_linear_precision_scaling() {
        // Conclusion: 0.15→8 POPS/W from 8b to 1b ⇒ ~50× with r_in·r_w
        // normalization removed. Raw EE for 1b ops should land in the
        // several-POPS/W range.
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let cfg1 = OpConfig::new(1, 1, 1).with_units(32);
        let ee1 = ee_raw(&p, &cfg1) / 1e15;
        assert!((3.0..14.0).contains(&ee1), "1b raw EE={ee1} POPS/W");
        let cfg8 = OpConfig::new(8, 1, 8).with_units(32);
        let ratio = ee1 / (ee_raw(&p, &cfg8) / 1e15);
        assert!((3.0..10.0).contains(&ratio), "1b/8b ratio={ratio}");
    }

    #[test]
    fn adc_dominates_at_small_cin() {
        // Fig. 22b: at C_in=4 (1 unit) the ADC+ladder dwarf the DP side;
        // at C_in=128 the supplies contribute comparably.
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let small = OpConfig::new(8, 1, 8).with_units(1);
        let big = OpConfig::new(8, 1, 8).with_units(32);
        let (dp_s, adc_s, lad_s) = breakdown(&p, &small);
        let (dp_b, adc_b, lad_b) = breakdown(&p, &big);
        assert!(adc_s + lad_s > 2.0 * dp_s, "small: adc={adc_s} lad={lad_s} dp={dp_s}");
        let ratio_big = (adc_b + lad_b) / dp_b;
        assert!((0.3..3.0).contains(&ratio_big), "big ratio={ratio_big}");
    }

    #[test]
    fn energy_per_op_decreases_with_cin_amortization() {
        // Fig. 22b x-axis trend: energy / (8b-norm op) drops with C_in.
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let mut last = f64::INFINITY;
        for units in [1usize, 4, 16, 32] {
            let cfg = OpConfig::new(8, 1, 8).with_units(units);
            let e_per_op = e_macro_op(&p, &cfg) / timing::ops_8b_norm(&p, &cfg);
            assert!(e_per_op < last, "units={units}");
            last = e_per_op;
        }
    }

    #[test]
    fn unity_gain_most_efficient() {
        // Fig. 18c: γ=1 keeps the best EE (rail-tied MSB taps).
        let p = MacroParams::paper();
        let e1 = e_adc(&p, &OpConfig::new(8, 1, 8).with_gamma(1.0));
        let e8 = e_adc(&p, &OpConfig::new(8, 1, 8).with_gamma(8.0));
        assert!(e1 < e8, "e1={e1} e8={e8}");
    }

    #[test]
    fn split_dpl_savings_match_fig6c() {
        // Fig. 6c: up to ~72% DP energy saving at 64 channels (16 units)
        // with the 40 fF load; savings shrink as the load grows.
        let p = MacroParams::paper();
        // Our CV² substitution peaks lower than the paper's post-layout
        // 72% at this utilization (see EXPERIMENTS.md); the shape holds:
        // monotone in disconnected units, diminishing with load, zero at
        // full utilization.
        let s40 = dp_savings(&p, 16, 40e-15);
        assert!((0.2..0.85).contains(&s40), "s40={s40}");
        let s40_small = dp_savings(&p, 4, 40e-15);
        assert!(s40_small > 0.55, "s40_small={s40_small}");
        let s160 = dp_savings(&p, 4, 160e-15);
        assert!(s160 < s40_small, "s160={s160} s40_small={s40_small}");
        // Full utilization ⇒ no saving.
        let s_full = dp_savings(&p, 32, 40e-15);
        assert!(s_full.abs() < 0.05, "s_full={s_full}");
    }

    #[test]
    fn low_voltage_saves_energy() {
        let cfg = OpConfig::new(8, 1, 8);
        let e_nom = e_macro_op(&MacroParams::paper(), &cfg);
        let e_low = e_macro_op(
            &MacroParams::paper().with_supply(Supply::LOW_POWER),
            &cfg,
        );
        assert!(e_low < 0.8 * e_nom);
    }
}
