//! System-level (CIM-CNN accelerator) energy model (§V.B; Figs. 22b, 23,
//! Table I): digital transfers, im2col/register activity and leakage on
//! top of the macro energy.

use crate::analog::macro_model::OpConfig;
use crate::config::params::MacroParams;
use crate::dataflow::pipeline::LayerShape;
use crate::energy::{analog, timing};

/// Energy of one 128b LMEM beat at V_DDH = 0.8 V [J] (SRAM access + bus).
const E_BEAT0: f64 = 9.0e-12;
/// Shift-register / im2col datapath energy per macro op at nominal [J].
const E_IM2COL0: f64 = 6.0e-12;
/// Accelerator leakage power at nominal supply [W] (integrates over the
/// MHz-range transfer cycles — the §V.B leakage sensitivity).
const P_LEAK0: f64 = 95.0e-6;

/// Per-beat transfer energy at the configured supply.
pub fn e_beat(p: &MacroParams) -> f64 {
    E_BEAT0 * p.supply.energy_scale()
}

/// Leakage power at the configured supply/corner.
pub fn p_leak(p: &MacroParams) -> f64 {
    P_LEAK0 * (p.supply.vddh / 0.8) * p.corner.leakage().sqrt()
}

/// Energy and timing summary of running one layer on the accelerator.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerCost {
    /// Total macro (analog) energy [J].
    pub e_macro: f64,
    /// Total transfer + digital datapath energy [J].
    pub e_digital: f64,
    /// Leakage energy integrated over the layer runtime [J].
    pub e_leak: f64,
    /// Total cycles (pipelined) and wall time [s].
    pub cycles: u64,
    pub seconds: f64,
    /// 8b-normalized operations executed.
    pub ops_8b: f64,
}

impl LayerCost {
    pub fn e_total(&self) -> f64 {
        self.e_macro + self.e_digital + self.e_leak
    }

    /// System energy efficiency for this layer [ops/J], 8b-normalized.
    pub fn ee_8b(&self) -> f64 {
        self.ops_8b / self.e_total()
    }

    /// Effective throughput [ops/s], 8b-normalized.
    pub fn throughput_8b(&self) -> f64 {
        self.ops_8b / self.seconds
    }

    /// This cost replicated over `n` identical executions (the batched
    /// engine books `n` images at once instead of accumulating per image).
    pub fn scaled(&self, n: u64) -> LayerCost {
        LayerCost {
            e_macro: self.e_macro * n as f64,
            e_digital: self.e_digital * n as f64,
            e_leak: self.e_leak * n as f64,
            cycles: self.cycles * n,
            seconds: self.seconds * n as f64,
            ops_8b: self.ops_8b * n as f64,
        }
    }

    pub fn accumulate(&mut self, other: &LayerCost) {
        self.e_macro += other.e_macro;
        self.e_digital += other.e_digital;
        self.e_leak += other.e_leak;
        self.cycles += other.cycles;
        self.seconds += other.seconds;
        self.ops_8b += other.ops_8b;
    }
}

/// Cost one layer: `shape` describes the transfer geometry, `cfg` the
/// macro configuration; `col_passes` counts how many times the output
/// columns must be re-tiled through the macro (out_features / 64 blocks),
/// and `pipelined` selects Eq. 8 vs Eq. 9/10 behaviour.
pub fn layer_cost(
    p: &MacroParams,
    shape: &LayerShape,
    cfg: &OpConfig,
    col_passes: usize,
    pipelined: bool,
) -> LayerCost {
    let f_clk = timing::f_system(p, cfg, shape.n_cim);
    let cycles_one = if pipelined {
        shape.total_cycles_pipelined()
    } else {
        shape.total_cycles_serial()
    };
    let cycles = cycles_one * col_passes as u64;
    let seconds = cycles as f64 / f_clk;

    let macro_ops = shape.macro_ops() * col_passes as u64;
    // Column-enable gating: only the columns this layer's outputs occupy
    // switch (c_out outputs × r_w columns each, per pass).
    let active_cols = (shape.c_out.div_ceil(col_passes) * cfg.r_w as usize).min(p.n_cols);
    let e_macro = analog::e_macro_op_cols(p, cfg, active_cols) * macro_ops as f64;

    let beats_per_pixel = shape.input_beats() + shape.output_beats();
    let beats = beats_per_pixel as u64 * macro_ops;
    let e_digital = beats as f64 * e_beat(p)
        + macro_ops as f64 * E_IM2COL0 * p.supply.energy_scale();

    let e_leak = p_leak(p) * seconds;

    // 8b-normalized ops: only the utilized rows/columns count at the
    // system level (unlike the macro's peak numbers).
    let used_rows = cfg.active_rows(p) as f64;
    let used_cols = (shape.c_out.min(p.n_cols / cfg.r_w as usize)) as f64;
    let ops_8b = 2.0 * used_rows * used_cols * macro_ops as f64
        * (cfg.r_in as f64 / 8.0)
        * (cfg.r_w as f64 / 8.0);

    LayerCost { e_macro, e_digital, e_leak, cycles, seconds, ops_8b }
}

/// The §V.B dedicated power test: loop the convolution of a 32×32 image
/// with `c_in = c_out` channels at a given precision (Fig. 23's workload).
pub fn conv_loop_cost(p: &MacroParams, c_in: usize, r: u32, pipelined: bool) -> LayerCost {
    let units = p.units_for_cin(c_in);
    let cfg = OpConfig::new(r, 1, r).with_units(units);
    let shape = LayerShape::conv(c_in, c_in.max(16), r, r, 32, 32);
    let col_passes = (c_in.max(16)).div_ceil(p.n_cols);
    layer_cost(p, &shape, &cfg, col_passes.max(1), pipelined)
}

/// Peak-system workload: full array utilization (128 input channels, all
/// 256 output columns) — the Table I system-EE configuration.
pub fn peak_system_cost(p: &MacroParams, r: u32) -> LayerCost {
    let cfg = OpConfig::new(r, 1, r).with_units(32);
    let shape = LayerShape::conv(128, 256, r, r, 32, 32);
    layer_cost(p, &shape, &cfg, 1, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::Supply;

    #[test]
    fn system_ee_anchor_40_tops_per_watt() {
        // §V / Table I: ~40 TOPS/W peak system EE at 0.3/0.6 V in the
        // high-channel 8b configuration.
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let cost = conv_loop_cost(&p, 128, 8, true);
        let ee = cost.ee_8b() / 1e12;
        assert!((25.0..70.0).contains(&ee), "system EE={ee} TOPS/W");
        // Nominal supply trades a bit of efficiency for speed (40→35).
        let pn = MacroParams::paper();
        let een = conv_loop_cost(&pn, 128, 8, true).ee_8b() / 1e12;
        assert!(een < ee, "nominal EE={een} low-power EE={ee}");
    }

    #[test]
    fn transfers_dominate_small_layers() {
        // §V.B: layers using <128b per transfer are dominated by data
        // movement, not the macro.
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let small = conv_loop_cost(&p, 4, 2, true);
        assert!(
            small.e_digital + small.e_leak > small.e_macro,
            "digital={} leak={} macro={}",
            small.e_digital,
            small.e_leak,
            small.e_macro
        );
        // ... while the full-utilization high-precision config is macro-
        // dominated (paper: 70–75%; our substitution lands lower but
        // clearly macro-first once leakage is excluded).
        let big = peak_system_cost(&p, 8);
        let frac = big.e_macro / big.e_total();
        assert!((0.42..0.95).contains(&frac), "macro frac={frac}");
        let frac_switching = big.e_macro / (big.e_macro + big.e_digital);
        assert!(frac_switching > 0.6, "switching frac={frac_switching}");
    }

    #[test]
    fn energy_per_op_decreases_with_cin() {
        // Fig. 23: energy/op drops with C_in (ADC + transfer amortization).
        let p = MacroParams::paper().with_supply(Supply::LOW_POWER);
        let mut last = f64::INFINITY;
        for c_in in [4usize, 16, 64, 128] {
            let c = conv_loop_cost(&p, c_in, 8, true);
            let e_per_op = c.e_total() / c.ops_8b;
            assert!(e_per_op < last, "c_in={c_in}: {e_per_op} !< {last}");
            last = e_per_op;
        }
    }

    #[test]
    fn pipelining_improves_throughput_not_energy_much() {
        let p = MacroParams::paper();
        let ser = conv_loop_cost(&p, 32, 8, false);
        let pip = conv_loop_cost(&p, 32, 8, true);
        assert!(pip.seconds < ser.seconds);
        // Leakage shrinks with runtime; switching energy is identical.
        assert!(pip.e_total() <= ser.e_total());
        assert!((pip.e_macro - ser.e_macro).abs() < 1e-18);
    }

    #[test]
    fn layer_cost_accumulates() {
        let p = MacroParams::paper();
        let a = conv_loop_cost(&p, 16, 4, true);
        let mut sum = LayerCost::default();
        sum.accumulate(&a);
        sum.accumulate(&a);
        assert!((sum.e_total() - 2.0 * a.e_total()).abs() < 1e-15);
        assert_eq!(sum.cycles, 2 * a.cycles);
        // scaled(n) is accumulate applied n times.
        let s = a.scaled(2);
        assert_eq!(s.cycles, sum.cycles);
        assert!((s.e_total() - sum.e_total()).abs() < 1e-15);
        assert!((s.ops_8b - sum.ops_8b).abs() < 1e-6);
    }
}
