//! Macro and system timing model (§III/§IV; Figs. 22–23).
//!
//! One macro operation walks the four-phase flow: r_in bit-serial DP +
//! accumulate cycles, the inter-column weight share, the ABN offset
//! phase, the ladder settling and r_out SAR decision/update cycles. The
//! system clock is set so a macro operation fits in N_cim cycles; digital
//! transfer beats run at the same clock (§V.B measures both together).

use crate::analog::macro_model::OpConfig;
use crate::config::params::{MacroParams, Supply};

/// Fixed per-phase overheads [s] at nominal supply.
const T_OFFSET: f64 = 2.0e-9; // ABN offset + calibration injection
const T_CTRL: f64 = 1.5e-9; // timing-generator margins per op

/// Duration of one full macro operation [s].
pub fn t_macro_op(p: &MacroParams, cfg: &OpConfig) -> f64 {
    let ds = p.supply.delay_scale();
    let t_input = cfg.r_in as f64 * (cfg.t_dp + if cfg.r_in > 1 { p.t_acc } else { 0.0 });
    let t_weight = if cfg.r_w > 1 {
        cfg.r_w as f64 * p.t_acc
    } else {
        0.0
    };
    let t_adc = p.t_ladder + cfg.r_out as f64 * p.t_sar;
    // Analog phases stretch with supply-dependent switch drive too.
    (t_input + t_weight + T_OFFSET + t_adc + T_CTRL) * ds / p.corner.drive()
}

/// Maximum macro operating frequency [Hz] for a configuration — the
/// quantity Fig. 23 sweeps (higher precision ⇒ more serial phases ⇒
/// lower frequency).
pub fn f_max_macro(p: &MacroParams, cfg: &OpConfig) -> f64 {
    1.0 / t_macro_op(p, cfg)
}

/// Digital datapath maximum clock [Hz] (limits transfers; generous at
/// nominal, ~3× slower at 0.3/0.6 V).
pub fn f_max_digital(supply: &Supply) -> f64 {
    250.0e6 / supply.delay_scale()
}

/// System clock: macro op must fit in `n_cim` cycles, transfers in one.
pub fn f_system(p: &MacroParams, cfg: &OpConfig, n_cim: usize) -> f64 {
    let f_macro_limited = (n_cim as f64) / t_macro_op(p, cfg);
    f_macro_limited.min(f_max_digital(&p.supply))
}

/// γ-dependent frequency tweak (§V.A, Fig. 18c): compressed V_sar levels
/// settle slightly faster between γ=2 and 16; γ=1 ties the MSB taps to
/// the rails (fastest reference but full swing); γ=32 strains the ladder.
pub fn gamma_speed_factor(gamma: f64) -> f64 {
    if gamma <= 1.0 {
        1.0
    } else if gamma <= 16.0 {
        1.0 + 0.06 * (gamma.log2() / 4.0)
    } else {
        0.98
    }
}

/// Raw MAC operations of one full-array macro op (2 ops per MAC).
pub fn raw_ops(p: &MacroParams, cfg: &OpConfig) -> f64 {
    let rows = cfg.active_rows(p);
    let cols = p.n_cols / cfg.r_w as usize; // r_w columns form one output
    2.0 * rows as f64 * cols as f64
}

/// 8b-normalized ops (Table I note 1: inputs AND weights to 8b).
pub fn ops_8b_norm(p: &MacroParams, cfg: &OpConfig) -> f64 {
    raw_ops(p, cfg) * (cfg.r_in as f64 / 8.0) * (cfg.r_w as f64 / 8.0)
}

/// Macro peak throughput [ops/s], raw at configured precision.
pub fn peak_throughput_raw(p: &MacroParams, cfg: &OpConfig) -> f64 {
    raw_ops(p, cfg) * f_max_macro(p, cfg) * gamma_speed_factor(cfg.gamma)
}

/// Macro peak throughput, 8b-normalized [ops/s].
pub fn peak_throughput_8b(p: &MacroParams, cfg: &OpConfig) -> f64 {
    ops_8b_norm(p, cfg) * f_max_macro(p, cfg) * gamma_speed_factor(cfg.gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::Supply;

    fn cfg8() -> OpConfig {
        OpConfig::new(8, 1, 8)
    }

    #[test]
    fn op_time_scales_with_precision() {
        let p = MacroParams::paper();
        let t1 = t_macro_op(&p, &OpConfig::new(1, 1, 1));
        let t8 = t_macro_op(&p, &cfg8());
        assert!(t8 > 2.0 * t1, "t1={t1} t8={t8}");
        // 8b op lands in the tens-of-ns regime (≈12–16 MHz at nominal).
        assert!(t8 > 50e-9 && t8 < 120e-9, "t8={t8}");
    }

    #[test]
    fn low_voltage_slows_down() {
        let p_nom = MacroParams::paper();
        let p_low = MacroParams::paper().with_supply(Supply::LOW_POWER);
        assert!(t_macro_op(&p_low, &cfg8()) > 1.5 * t_macro_op(&p_nom, &cfg8()));
    }

    #[test]
    fn throughput_in_paper_range() {
        // Table I: peak throughput 0.1–0.5 TOPS (8b-normalized) across
        // supplies; binary weights ⇒ /8 normalization.
        let cfg = cfg8();
        for supply in [Supply::NOMINAL, Supply::LOW_POWER] {
            let p = MacroParams::paper().with_supply(supply);
            let tput = peak_throughput_8b(&p, &cfg) / 1e12;
            assert!((0.05..1.5).contains(&tput), "tput={tput} TOPS");
        }
    }

    #[test]
    fn raw_ops_count_full_array() {
        let p = MacroParams::paper();
        assert_eq!(raw_ops(&p, &cfg8()), 2.0 * 1152.0 * 256.0);
        let cfg4 = OpConfig::new(8, 4, 8);
        assert_eq!(raw_ops(&p, &cfg4), 2.0 * 1152.0 * 64.0);
    }

    #[test]
    fn system_clock_respects_both_limits() {
        let p = MacroParams::paper();
        let f1 = f_system(&p, &cfg8(), 1);
        assert!(f1 <= f_max_digital(&p.supply));
        assert!((f1 - f_max_macro(&p, &cfg8())).abs() / f1 < 1e-9);
        // Multi-cycle macro allows a faster clock.
        let f4 = f_system(&p, &cfg8(), 4);
        assert!(f4 > 2.0 * f1);
    }

    #[test]
    fn gamma_speed_bump_midrange() {
        assert!(gamma_speed_factor(8.0) > gamma_speed_factor(1.0));
        assert!(gamma_speed_factor(32.0) < gamma_speed_factor(16.0));
    }
}
