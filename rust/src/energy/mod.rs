//! Energy / timing / area models of the macro and the accelerator
//! (§V; Figs. 6c, 18c, 22, 23; Table I).

pub mod analog;
pub mod area;
pub mod system;
pub mod timing;
