//! Physical and architectural parameters of the IMAGINE macro.
//!
//! Every constant here is traceable to a number stated in the paper
//! (section references in comments). The [`MacroParams`] struct is the
//! single source of truth shared by the analog simulator, the energy
//! model and the dataflow model; experiments mutate copies of it to
//! sweep supplies, timings and corners.

/// Process corner of the simulated die. The measured CERBERUS sample sits
/// in the slow corner (§V.A: "measured slow chip corner"), which is why
/// several measurement artefacts (zero-DP INL peak, clustered-weight
/// distortion) appear; the simulator reproduces them under `Ss`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Corner {
    /// Typical-typical.
    Tt,
    /// Fast nMOS / fast pMOS.
    Ff,
    /// Slow nMOS / slow pMOS (the measured sample).
    Ss,
    /// Fast n / slow p.
    Fs,
    /// Slow n / fast p.
    Sf,
}

impl Corner {
    pub const ALL: [Corner; 5] = [Corner::Tt, Corner::Ff, Corner::Ss, Corner::Fs, Corner::Sf];

    /// Transistor drive-strength multiplier (affects settling time
    /// constants of transmission gates and ladder buffers).
    pub fn drive(self) -> f64 {
        match self {
            Corner::Tt => 1.00,
            Corner::Ff => 1.22,
            Corner::Ss => 0.80,
            Corner::Fs => 1.05,
            Corner::Sf => 0.93,
        }
    }

    /// Subthreshold leakage multiplier (affects V_acc droop, Fig. 10a).
    pub fn leakage(self) -> f64 {
        match self {
            Corner::Tt => 1.0,
            Corner::Ff => 4.0,
            Corner::Ss => 0.25,
            Corner::Fs => 2.0,
            Corner::Sf => 0.5,
        }
    }

    /// Threshold-voltage shift [V] (affects charge injection, Fig. 10b).
    pub fn vt_shift(self) -> f64 {
        match self {
            Corner::Tt => 0.0,
            Corner::Ff => -0.040,
            Corner::Ss => 0.040,
            Corner::Fs => -0.015,
            Corner::Sf => 0.015,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Corner::Tt => "TT",
            Corner::Ff => "FF",
            Corner::Ss => "SS",
            Corner::Fs => "FS",
            Corner::Sf => "SF",
        }
    }
}

/// Supply configuration. The paper operates the analog core between a low
/// rail (V_DDL, DPL precharge / input drivers) and a high rail (V_DDH,
/// ADC references and digital periphery); nominal 0.4/0.8 V with a
/// low-power point at 0.3/0.6 V (§III.A, §V).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Supply {
    pub vddl: f64,
    pub vddh: f64,
}

impl Supply {
    pub const NOMINAL: Supply = Supply { vddl: 0.4, vddh: 0.8 };
    pub const LOW_POWER: Supply = Supply { vddl: 0.3, vddh: 0.6 };

    pub fn new(vddl: f64, vddh: f64) -> Self {
        Supply { vddl, vddh }
    }

    /// Logic-delay scale factor relative to nominal (alpha-power law fit;
    /// ~2.8× slower at 0.6 V than 0.8 V in this 22nm FD-SOI flavour).
    pub fn delay_scale(&self) -> f64 {
        let x = self.vddh / 0.8;
        x.powf(-2.4)
    }

    /// Dynamic-energy scale ∝ V².
    pub fn energy_scale(&self) -> f64 {
        (self.vddh / 0.8).powi(2)
    }
}

/// Boltzmann constant × 300 K [J].
pub const KT: f64 = 1.380649e-23 * 300.0;

/// All physical/architectural parameters of the CIM-SRAM macro.
#[derive(Clone, Debug)]
pub struct MacroParams {
    // ---- array geometry (§III.A) ----
    /// Total DP rows (1152 = 32 units × 36 rows).
    pub n_rows: usize,
    /// Rows per DP unit (3×3 kernel × C_in=4 minimum → 36).
    pub rows_per_unit: usize,
    /// Total columns (256 = 64 blocks × 4 columns).
    pub n_cols: usize,
    /// Columns per MBIW block (max 4b weights).
    pub cols_per_block: usize,

    // ---- capacitances [F] ----
    /// Bitcell coupling MoM capacitance C_c = 0.7 fF (§III.B).
    pub c_c: f64,
    /// Per-row parasitic routing capacitance on the DPL [F/row].
    pub c_p_per_row: f64,
    /// Total non-DP load on the DPL: MBIW + ADC ≈ 40 fF (§III.D).
    pub c_load: f64,
    /// Share of `c_load` on the ADC side (C_adc; the rest is C_mb).
    pub c_adc_frac: f64,
    /// Extra global-DPL parasitics for the *parallel*-split topology [F].
    pub c_p_global: f64,
    /// SAR array capacitance C_sar = 33 C_c (§III.D, Eq. 7).
    pub c_sar: f64,
    /// SAR-side parasitics C_p,sar [F].
    pub c_p_sar: f64,

    // ---- timing [s] ----
    /// Single-bit DP duration (5 ns nominal, ±1 ns configurable; §III.B).
    pub t_dp: f64,
    /// Elmore base constant of the serial-split DPL chain [s]: unit `u`
    /// settles with τ_u = tau_tg·(u+1)²·m(V)/drive (RC-diffusion along the
    /// daisy-chained transmission gates).
    pub tau_tg: f64,
    /// MBIW accumulate/share phase duration [s].
    pub t_acc: f64,
    /// Single SAR decision+update cycle [s].
    pub t_sar: f64,
    /// Ladder settling before conversion (5 ns, 1 mA; §III.D).
    pub t_ladder: f64,
    /// Leakage integration window for a full 8b accumulation (Fig. 10a).
    pub t_leak: f64,

    // ---- noise / mismatch ----
    /// kT/C noise at the bitcell, 2.4 mV rms (§III.B).
    pub v_noise_cell: f64,
    /// StrongArm SA offset sigma pre-layout [V] (3σ = 60 mV ⇒ σ = 20 mV).
    pub sa_sigma_prelayout: f64,
    /// Post-layout degradation of SA sigma (+75%, §III.E).
    pub sa_postlayout_factor: f64,
    /// SA temporal (decision) noise sigma [V].
    pub sa_noise: f64,
    /// Relative mismatch sigma of ladder taps (distorts S-IN levels).
    pub ladder_mismatch: f64,
    /// Relative MoM capacitor mismatch sigma (device-to-device).
    pub cap_mismatch: f64,

    // ---- ADC / ABN (§III.D–E) ----
    /// ABN offset DAC bits (5b, ±30 mV on the DPL).
    pub abn_offset_bits: u32,
    /// ABN offset full range [V] (one side).
    pub abn_offset_range: f64,
    /// Calibration DAC bits (7b array + sign side; 256 signed levels).
    pub cal_bits: u32,
    /// Calibration resolution 0.47 mV (§III.E). The 4×C_c MSB device gives
    /// a ±60 mV range covering the 3σ pre-layout SA offset.
    pub cal_step: f64,
    /// Minimum ladder voltage step = V_DDH / 32 (§III.D).
    pub ladder_min_step_div: f64,
    /// Maximum MSB-array gain (16; beyond that LSB info is lost, §III.D).
    pub max_msb_gain: f64,

    // ---- leakage / charge injection ----
    /// Relative sizing imbalance of C_acc vs its DPL load (<1%, §III.C) —
    /// the source of α_mb's deviation from exactly ½.
    pub alpha_mb_imbalance: f64,
    /// Leakage current scale on the accumulation node [A] at nominal.
    pub i_leak0: f64,
    /// Charge injected per transmission-gate toggle, as charge ΔQ = k·C_c·V
    /// (dimensionless k; fitted so peak error ≈ 1 LSB @8b, Fig. 10b).
    pub inj_k: f64,

    // ---- environment ----
    pub supply: Supply,
    pub corner: Corner,
    /// DPL topology (baseline / parallel-split / serial-split).
    pub topology: DplTopology,

    // ---- area [mm²], density (§V, Fig. 16) ----
    /// Bitcell area 0.44 µm².
    pub bitcell_area_um2: f64,
    /// Macro area share of total accelerator (53% of 0.373 mm²).
    pub macro_area_mm2: f64,
    pub accel_area_mm2: f64,
}

/// DPL splitting strategy (§III.B, Fig. 6a).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DplTopology {
    /// Single DPL spanning all 1152 rows; α = C_c / (N_rows·C_c + C_p + C_L).
    Baseline,
    /// Local DPL per unit + global DPL through switches; extra C_p,glob.
    ParallelSplit,
    /// Units daisy-chained with series switches (the fabricated choice).
    SerialSplit,
}

impl Default for MacroParams {
    fn default() -> Self {
        Self::paper()
    }
}

impl MacroParams {
    /// Parameters of the fabricated macro, as stated in the paper.
    pub fn paper() -> Self {
        let c_c = 0.7e-15;
        MacroParams {
            n_rows: 1152,
            rows_per_unit: 36,
            n_cols: 256,
            cols_per_block: 4,

            c_c,
            // Fitted so baseline C_p ≈ 0.15×(N_dp·C_c) — metal routing over
            // 1152 rows; contributes to the swing compression of Fig. 8a.
            c_p_per_row: 0.105e-15,
            c_load: 40e-15,
            c_adc_frac: 0.58, // ADC dominates C_L (§III.B)
            c_p_global: 35e-15,
            c_sar: 33.0 * c_c,
            c_p_sar: 6.0 * c_c,

            t_dp: 5e-9,
            tau_tg: 1.3e-12,
            t_acc: 2e-9,
            t_sar: 2.5e-9,
            t_ladder: 5e-9,
            t_leak: 8.0 * (5e-9 + 2e-9),

            v_noise_cell: 2.4e-3,
            sa_sigma_prelayout: 0.020,
            sa_postlayout_factor: 1.75,
            sa_noise: 0.45e-3,
            ladder_mismatch: 0.004,
            cap_mismatch: 0.002,

            abn_offset_bits: 5,
            abn_offset_range: 0.030,
            cal_bits: 7,
            cal_step: 0.47e-3,
            ladder_min_step_div: 32.0,
            max_msb_gain: 16.0,

            alpha_mb_imbalance: 0.008,
            i_leak0: 2.2e-12,
            inj_k: 0.0035,

            supply: Supply::NOMINAL,
            corner: Corner::Tt,
            topology: DplTopology::SerialSplit,

            bitcell_area_um2: 0.44,
            macro_area_mm2: 0.373 * 0.53,
            accel_area_mm2: 0.373,
        }
    }

    /// The measured chip: slow corner, nominal supplies.
    pub fn measured_chip() -> Self {
        MacroParams { corner: Corner::Ss, ..Self::paper() }
    }

    pub fn with_supply(mut self, s: Supply) -> Self {
        self.supply = s;
        self
    }

    pub fn with_corner(mut self, c: Corner) -> Self {
        self.corner = c;
        self
    }

    pub fn with_topology(mut self, t: DplTopology) -> Self {
        self.topology = t;
        self
    }

    /// Number of DP units (32).
    pub fn n_units(&self) -> usize {
        self.n_rows / self.rows_per_unit
    }

    /// Number of MBIW column blocks (64).
    pub fn n_blocks(&self) -> usize {
        self.n_cols / self.cols_per_block
    }

    /// Rows activated for a given number of connected units.
    pub fn rows_for_units(&self, units: usize) -> usize {
        units.min(self.n_units()) * self.rows_per_unit
    }

    /// Units needed for `c_in` input channels with a 3×3 kernel
    /// (one unit = 9 taps × 4 channels).
    pub fn units_for_cin(&self, c_in: usize) -> usize {
        (c_in).div_ceil(4).min(self.n_units()).max(1)
    }

    /// MBIW-side share of the DPL load, C_mb [F].
    pub fn c_mb(&self) -> f64 {
        self.c_load * (1.0 - self.c_adc_frac)
    }

    /// ADC-side share of the DPL load, C_adc [F].
    pub fn c_adc(&self) -> f64 {
        self.c_load * self.c_adc_frac
    }

    /// Accumulation capacitance, sized to C_mb + C_adc (§III.C).
    pub fn c_acc(&self) -> f64 {
        self.c_load
    }

    /// Multi-bit attenuation factor α_mb ≈ 1/2 (Eq. 5). The below-1%
    /// imbalance comes from capacitor sizing granularity.
    pub fn alpha_mb(&self) -> f64 {
        let c_acc = self.c_acc() * (1.0 + self.alpha_mb_imbalance);
        (self.c_mb() + self.c_adc()) / (c_acc + self.c_mb() + self.c_adc())
    }

    /// SAR attenuation α_adc = C_sar / (C_sar + C_p,sar) (Eq. 7).
    pub fn alpha_adc(&self) -> f64 {
        self.c_sar / (self.c_sar + self.c_p_sar)
    }

    /// Effective charge-injection attenuation α_eff (Eq. 4) for a given
    /// number of *connected* DP rows (serial/parallel split) — or all
    /// rows for the baseline topology.
    pub fn alpha_eff(&self, connected_rows: usize) -> f64 {
        let (n_dp, c_p_extra) = match self.topology {
            DplTopology::Baseline => (self.n_rows, 0.0),
            DplTopology::ParallelSplit => (connected_rows, self.c_p_global),
            DplTopology::SerialSplit => (connected_rows, 0.0),
        };
        let c_p = self.c_p_per_row * n_dp as f64 + c_p_extra;
        self.c_c / (n_dp as f64 * self.c_c + c_p + self.c_load)
    }

    /// kT/C thermal noise sigma [V] for capacitance `c` [F].
    pub fn ktc_sigma(c: f64) -> f64 {
        (KT / c).sqrt()
    }

    /// Post-layout SA offset sigma [V].
    pub fn sa_sigma(&self) -> f64 {
        self.sa_sigma_prelayout * self.sa_postlayout_factor
    }

    /// 8b ADC LSB referred to the DPL at unity gain [V] (Eq. 7):
    /// LSB(γ) = α_adc · V_DDH / (γ · 2^(r_out − 1)).
    pub fn adc_lsb(&self, r_out: u32, gamma: f64) -> f64 {
        self.alpha_adc() * self.supply.vddh / (gamma * (1u64 << (r_out - 1)) as f64)
    }

    /// SRAM capacity in kB (1152×256 bits of weights).
    pub fn capacity_kb(&self) -> f64 {
        (self.n_rows * self.n_cols) as f64 / 8.0 / 1024.0
    }

    /// Macro density [kB/mm²] — paper: 187 kB/mm².
    pub fn density_kb_mm2(&self) -> f64 {
        self.capacity_kb() / self.macro_area_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_paper() {
        let p = MacroParams::paper();
        assert_eq!(p.n_units(), 32);
        assert_eq!(p.n_blocks(), 64);
        assert_eq!(p.rows_for_units(32), 1152);
        assert_eq!(p.units_for_cin(4), 1);
        assert_eq!(p.units_for_cin(128), 32);
        assert_eq!(p.units_for_cin(5), 2);
    }

    #[test]
    fn density_near_187_kb_per_mm2() {
        let p = MacroParams::paper();
        let d = p.density_kb_mm2();
        assert!((d - 187.0).abs() < 15.0, "density={d}");
    }

    #[test]
    fn alpha_mb_close_to_half() {
        let p = MacroParams::paper();
        let a = p.alpha_mb();
        assert!((a - 0.5).abs() < 0.01, "alpha_mb={a}");
    }

    #[test]
    fn alpha_eff_improves_with_fewer_connected_rows() {
        let p = MacroParams::paper(); // serial split
        let a_full = p.alpha_eff(1152);
        let a_small = p.alpha_eff(36);
        assert!(a_small > a_full * 5.0, "split should strongly boost alpha");
        // Baseline cannot benefit.
        let pb = p.clone().with_topology(DplTopology::Baseline);
        assert!((pb.alpha_eff(36) - pb.alpha_eff(1152)).abs() < 1e-20);
    }

    #[test]
    fn parallel_split_pays_global_parasitics() {
        let p = MacroParams::paper();
        let ser = p.clone().with_topology(DplTopology::SerialSplit);
        let par = p.clone().with_topology(DplTopology::ParallelSplit);
        assert!(ser.alpha_eff(36) > par.alpha_eff(36));
    }

    #[test]
    fn ktc_noise_magnitude() {
        // kT/C of 0.7 fF at 300K ≈ 2.4 mV — the paper's §III.B number.
        let sigma = MacroParams::ktc_sigma(0.7e-15);
        assert!((sigma - 2.4e-3).abs() < 0.3e-3, "sigma={sigma}");
    }

    #[test]
    fn adc_lsb_scales_with_gamma_and_bits() {
        let p = MacroParams::paper();
        let l1 = p.adc_lsb(8, 1.0);
        assert!((p.adc_lsb(8, 2.0) - l1 / 2.0).abs() < 1e-12);
        assert!((p.adc_lsb(7, 1.0) - l1 * 2.0).abs() < 1e-12);
    }

    #[test]
    fn corner_multipliers_ordered() {
        assert!(Corner::Ff.drive() > Corner::Tt.drive());
        assert!(Corner::Ss.drive() < Corner::Tt.drive());
        assert!(Corner::Ff.leakage() > Corner::Ss.leakage());
    }

    #[test]
    fn supply_scales() {
        assert!(Supply::LOW_POWER.delay_scale() > 1.5);
        assert!((Supply::NOMINAL.delay_scale() - 1.0).abs() < 1e-9);
        assert!(Supply::LOW_POWER.energy_scale() < 0.6);
    }
}
