//! Configuration: physical macro parameters, supplies, corners, and the
//! accelerator/runtime configuration surface.

pub mod params;

pub use params::{Corner, DplTopology, MacroParams, Supply};
